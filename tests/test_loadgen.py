"""Load generation: seeded determinism, window iteration, boundaries."""
import numpy as np
import pytest

from repro.core.network import FixedCVNetwork
from repro.serving.loadgen import (
    BurstyArrivals,
    LoadTrace,
    PoissonArrivals,
    iter_windows,
    make_trace,
)


def _trace_from_arrivals(arrival_ms):
    arrival_ms = np.asarray(arrival_ms, dtype=np.float64)
    nw = np.full_like(arrival_ms, 10.0)
    return LoadTrace(arrival_ms=arrival_ms, t_nw_ms=nw, t_nw_est_ms=nw)


# ---------------------------------------------------------------------------
# Seeded determinism.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "process",
    [PoissonArrivals(150.0), BurstyArrivals(150.0, burst_factor=6.0)],
    ids=["poisson", "bursty"],
)
def test_arrivals_deterministic_under_seed(process):
    a = process.sample_arrivals_ms(np.random.default_rng(42), 2_000)
    b = process.sample_arrivals_ms(np.random.default_rng(42), 2_000)
    np.testing.assert_array_equal(a, b)
    c = process.sample_arrivals_ms(np.random.default_rng(43), 2_000)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)  # non-decreasing timestamps


def test_make_trace_deterministic_under_seed():
    args = (300, PoissonArrivals(80.0), FixedCVNetwork(100.0, 0.4))
    t1 = make_trace(*args, seed=9)
    t2 = make_trace(*args, seed=9)
    np.testing.assert_array_equal(t1.arrival_ms, t2.arrival_ms)
    np.testing.assert_array_equal(t1.t_nw_ms, t2.t_nw_ms)
    np.testing.assert_array_equal(t1.t_nw_est_ms, t2.t_nw_est_ms)
    t3 = make_trace(*args, seed=10)
    assert not np.array_equal(t1.arrival_ms, t3.arrival_ms)


# ---------------------------------------------------------------------------
# Window iteration.
# ---------------------------------------------------------------------------
def test_iter_windows_skips_empty_windows():
    # Arrivals leave windows [50,100) .. [950,1000) empty; only occupied
    # windows are yielded, each non-empty, covering every request once.
    trace = _trace_from_arrivals([10.0, 20.0, 1_000.0, 1_010.0])
    windows = list(iter_windows(trace, 50.0))
    assert len(windows) == 2
    np.testing.assert_array_equal(windows[0], [0, 1])
    np.testing.assert_array_equal(windows[1], [2, 3])
    for w in windows:
        assert len(w) > 0


def test_iter_windows_boundary_arrival_opens_next_window():
    # An arrival exactly at k*window belongs to window k (half-open
    # [k*w, (k+1)*w) buckets).
    trace = _trace_from_arrivals([0.0, 49.999, 50.0, 99.999, 100.0])
    windows = list(iter_windows(trace, 50.0))
    assert len(windows) == 3
    np.testing.assert_array_equal(windows[0], [0, 1])
    np.testing.assert_array_equal(windows[1], [2, 3])
    np.testing.assert_array_equal(windows[2], [4])


def test_iter_windows_empty_trace_yields_nothing():
    trace = _trace_from_arrivals([])
    assert list(iter_windows(trace, 50.0)) == []
    assert trace.duration_ms == 0.0
    assert trace.offered_rps == float("inf")


def test_iter_windows_single_window_holds_all():
    trace = _trace_from_arrivals([1.0, 2.0, 3.0])
    (only,) = iter_windows(trace, 1e6)
    np.testing.assert_array_equal(only, [0, 1, 2])


@pytest.mark.parametrize("bad", [0.0, -5.0])
def test_iter_windows_rejects_nonpositive_window(bad):
    trace = _trace_from_arrivals([1.0])
    with pytest.raises(ValueError):
        list(iter_windows(trace, bad))


def test_windows_partition_in_arrival_order():
    trace = make_trace(
        400, BurstyArrivals(120.0), FixedCVNetwork(80.0, 0.5), seed=3
    )
    seen = np.concatenate(list(iter_windows(trace, 25.0)))
    np.testing.assert_array_equal(seen, np.arange(400))
