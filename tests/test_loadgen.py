"""Load generation: seeded determinism, window iteration, boundaries, and
bursty/overload traces driven through the ServingLoop seam (drain_trace)."""
import numpy as np
import pytest

from repro.core.network import FixedCVNetwork
from repro.serving.loadgen import (
    BurstyArrivals,
    DiurnalArrivals,
    LoadTrace,
    MixedTenantArrivals,
    OverloadArrivals,
    PoissonArrivals,
    RampArrivals,
    SpikeArrivals,
    iter_windows,
    make_trace,
)


def _trace_from_arrivals(arrival_ms):
    arrival_ms = np.asarray(arrival_ms, dtype=np.float64)
    nw = np.full_like(arrival_ms, 10.0)
    return LoadTrace(arrival_ms=arrival_ms, t_nw_ms=nw, t_nw_est_ms=nw)


# ---------------------------------------------------------------------------
# Seeded determinism.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "process",
    [
        PoissonArrivals(150.0),
        BurstyArrivals(150.0, burst_factor=6.0),
        OverloadArrivals(150.0, overload_factor=3.0),
        RampArrivals(50.0, 400.0),
    ],
    ids=["poisson", "bursty", "overload", "ramp"],
)
def test_arrivals_deterministic_under_seed(process):
    a = process.sample_arrivals_ms(np.random.default_rng(42), 2_000)
    b = process.sample_arrivals_ms(np.random.default_rng(42), 2_000)
    np.testing.assert_array_equal(a, b)
    c = process.sample_arrivals_ms(np.random.default_rng(43), 2_000)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)  # non-decreasing timestamps


def test_make_trace_deterministic_under_seed():
    args = (300, PoissonArrivals(80.0), FixedCVNetwork(100.0, 0.4))
    t1 = make_trace(*args, seed=9)
    t2 = make_trace(*args, seed=9)
    np.testing.assert_array_equal(t1.arrival_ms, t2.arrival_ms)
    np.testing.assert_array_equal(t1.t_nw_ms, t2.t_nw_ms)
    np.testing.assert_array_equal(t1.t_nw_est_ms, t2.t_nw_est_ms)
    t3 = make_trace(*args, seed=10)
    assert not np.array_equal(t1.arrival_ms, t3.arrival_ms)


# ---------------------------------------------------------------------------
# Window iteration.
# ---------------------------------------------------------------------------
def test_iter_windows_skips_empty_windows():
    # Arrivals leave windows [50,100) .. [950,1000) empty; only occupied
    # windows are yielded, each non-empty, covering every request once.
    trace = _trace_from_arrivals([10.0, 20.0, 1_000.0, 1_010.0])
    windows = list(iter_windows(trace, 50.0))
    assert len(windows) == 2
    np.testing.assert_array_equal(windows[0], [0, 1])
    np.testing.assert_array_equal(windows[1], [2, 3])
    for w in windows:
        assert len(w) > 0


def test_iter_windows_boundary_arrival_opens_next_window():
    # An arrival exactly at k*window belongs to window k (half-open
    # [k*w, (k+1)*w) buckets).
    trace = _trace_from_arrivals([0.0, 49.999, 50.0, 99.999, 100.0])
    windows = list(iter_windows(trace, 50.0))
    assert len(windows) == 3
    np.testing.assert_array_equal(windows[0], [0, 1])
    np.testing.assert_array_equal(windows[1], [2, 3])
    np.testing.assert_array_equal(windows[2], [4])


def test_iter_windows_empty_trace_yields_nothing():
    trace = _trace_from_arrivals([])
    assert list(iter_windows(trace, 50.0)) == []
    assert trace.duration_ms == 0.0
    assert trace.offered_rps == float("inf")


def test_iter_windows_single_window_holds_all():
    trace = _trace_from_arrivals([1.0, 2.0, 3.0])
    (only,) = iter_windows(trace, 1e6)
    np.testing.assert_array_equal(only, [0, 1, 2])


@pytest.mark.parametrize("bad", [0.0, -5.0])
def test_iter_windows_rejects_nonpositive_window(bad):
    trace = _trace_from_arrivals([1.0])
    with pytest.raises(ValueError):
        list(iter_windows(trace, bad))


def test_windows_partition_in_arrival_order():
    trace = make_trace(
        400, BurstyArrivals(120.0), FixedCVNetwork(80.0, 0.5), seed=3
    )
    seen = np.concatenate(list(iter_windows(trace, 25.0)))
    np.testing.assert_array_equal(seen, np.arange(400))


# ---------------------------------------------------------------------------
# Overload / ramp arrival shapes.
# ---------------------------------------------------------------------------
def test_overload_phase_compresses_gaps():
    n = 6_000
    a = OverloadArrivals(
        100.0, overload_factor=4.0, overload_start=0.25, overload_stop=0.75
    ).sample_arrivals_ms(np.random.default_rng(0), n)
    gaps = np.diff(np.concatenate([[0.0], a]))
    base = np.concatenate([gaps[: n // 4], gaps[3 * n // 4:]])
    overload = gaps[n // 4: 3 * n // 4]
    # The overload phase's mean gap is ~4x tighter than the base phases'.
    assert np.mean(overload) < np.mean(base) / 2.5
    assert np.mean(base) == pytest.approx(10.0, rel=0.15)  # 100 rps
    assert np.mean(overload) == pytest.approx(2.5, rel=0.15)  # 400 rps


def test_ramp_rate_increases_across_the_stream():
    n = 6_000
    a = RampArrivals(50.0, 400.0).sample_arrivals_ms(
        np.random.default_rng(1), n
    )
    gaps = np.diff(np.concatenate([[0.0], a]))
    first, last = gaps[: n // 3], gaps[-n // 3:]
    assert np.mean(first) > 2.5 * np.mean(last)  # 50 rps -> ~400 rps


@pytest.mark.parametrize(
    "bad",
    [
        dict(overload_start=0.8, overload_stop=0.2),
        dict(overload_start=-0.1),
        dict(overload_stop=1.5),
        dict(overload_factor=0.0),
    ],
)
def test_overload_arrivals_validation(bad):
    with pytest.raises(ValueError):
        OverloadArrivals(100.0, **bad)


def test_ramp_arrivals_validation():
    with pytest.raises(ValueError):
        RampArrivals(0.0, 100.0)
    with pytest.raises(ValueError):
        RampArrivals(100.0, -5.0)


# ---------------------------------------------------------------------------
# MixedTenantArrivals: tagged two-lane mix.
# ---------------------------------------------------------------------------
def test_mixed_tenant_arrivals_tagged_and_sorted():
    mix = MixedTenantArrivals(interactive_rps=50.0, batch_rps=200.0)
    arrival, tenant = mix.sample_tagged(np.random.default_rng(5), 1_000)
    assert arrival.shape == tenant.shape == (1_000,)
    assert np.all(np.diff(arrival) >= 0)  # merged stream is arrival-sorted
    counts = {t: int(np.sum(tenant == t)) for t in ("interactive", "batch")}
    assert counts["interactive"] + counts["batch"] == 1_000
    # Lane counts are proportional to the rates (50:200 -> 1:4).
    assert counts["interactive"] == pytest.approx(200, abs=2)
    # Each lane realizes roughly its own offered rate over the horizon.
    for name, rps in (("interactive", 50.0), ("batch", 200.0)):
        lane = arrival[tenant == name]
        assert np.mean(np.diff(lane)) == pytest.approx(1e3 / rps, rel=0.15)
    # Determinism + the untagged protocol view.
    a2, t2 = mix.sample_tagged(np.random.default_rng(5), 1_000)
    np.testing.assert_array_equal(arrival, a2)
    np.testing.assert_array_equal(tenant, t2)
    np.testing.assert_array_equal(
        mix.sample_arrivals_ms(np.random.default_rng(5), 1_000), arrival
    )


def test_mixed_tenant_arrivals_edges_and_validation():
    mix = MixedTenantArrivals()
    a, t = mix.sample_tagged(np.random.default_rng(0), 0)
    assert len(a) == 0 and len(t) == 0
    # n >= 2 always yields both lanes, however skewed the rates.
    _, t = MixedTenantArrivals(
        interactive_rps=0.001, batch_rps=1_000.0
    ).sample_tagged(np.random.default_rng(0), 2)
    assert set(t) == {"interactive", "batch"}
    with pytest.raises(ValueError):
        MixedTenantArrivals(interactive_rps=0.0)
    with pytest.raises(ValueError):
        MixedTenantArrivals(batch_rps=-1.0)


def test_make_trace_carries_tenant_tags():
    trace = make_trace(
        200, MixedTenantArrivals(40.0, 160.0), FixedCVNetwork(20.0, 0.3),
        seed=6,
    )
    assert trace.tenant is not None and len(trace.tenant) == 200
    assert set(trace.tenant) == {"interactive", "batch"}
    # Untagged processes keep the None default (the compat pin).
    plain = make_trace(
        50, PoissonArrivals(100.0), FixedCVNetwork(20.0, 0.3), seed=6
    )
    assert plain.tenant is None


# ---------------------------------------------------------------------------
# The loop seam: saturated bursty/overload traces through drain_trace keep
# every tick's batch within max_chunk (previously only arrival sampling
# was covered, not the serving path).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "arrivals",
    [
        BurstyArrivals(400.0, burst_factor=6.0),
        OverloadArrivals(200.0, overload_factor=3.0),
    ],
    ids=["bursty", "overload"],
)
def test_saturated_trace_batches_capped_at_max_chunk(arrivals):
    from repro.serving.admission import AdmissionConfig
    from repro.serving.loop import ServingLoop

    from loop_stubs import StubHedgeBackend, StubRemoteBackend, stub_scheduler

    n, window_ms, max_chunk = 300, 50.0, 8
    trace = make_trace(n, arrivals, FixedCVNetwork(20.0, 0.3), seed=7)
    loop = ServingLoop(
        stub_scheduler(t_sla_ms=10_000.0, profile_ewma=0.0),
        StubRemoteBackend(0.0),
        StubHedgeBackend(0.0),
        dispatch="sync",
        admission=AdmissionConfig(max_chunk=max_chunk),
    )
    stats = []
    done, metrics = loop.drain_trace(
        trace, window_ms,
        tokens_for=lambda i: np.zeros(4, np.int32), n_steps=2,
        on_tick=lambda t, res: stats.append(res.stats),
    )
    # Saturation really happened (windows bigger than the cap) ...
    assert any(s.n_requests == max_chunk for s in stats)
    # ... yet no tick's batch ever exceeded the cap, and the leftovers
    # persisted across ticks until everything was served exactly once.
    assert all(s.n_requests <= max_chunk for s in stats)
    assert sorted(c.rid for c in done) == list(range(n))
    assert metrics.n_requests == n and metrics.n_rejected == 0


# ---------------------------------------------------------------------------
# Units (PR 9): every rate parameter is requests per *second*, every
# timestamp a millisecond — so doubling the rate halves the mean gap and
# doubles the arrivals landing inside any fixed horizon, in expectation.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "make",
    [
        lambda r: PoissonArrivals(r),
        lambda r: OverloadArrivals(r, overload_factor=3.0),
        lambda r: RampArrivals(r, 2.0 * r),
        lambda r: DiurnalArrivals(trough_rps=r, peak_rps=2.0 * r),
        lambda r: SpikeArrivals(rate_rps=r),
    ],
    ids=["poisson", "overload", "ramp", "diurnal", "spike"],
)
def test_double_rate_doubles_arrivals_in_expectation(make):
    n, rate = 4_000, 100.0
    slow = make(rate).sample_arrivals_ms(np.random.default_rng(0), n)
    fast = make(2.0 * rate).sample_arrivals_ms(np.random.default_rng(0), n)
    # Mean inter-arrival gap: 1e3 / rate_rps milliseconds, so 2x the rate
    # halves it (15% tolerance: these are seeded exponential draws).
    ratio = np.mean(np.diff(slow)) / np.mean(np.diff(fast))
    assert ratio == pytest.approx(2.0, rel=0.15)
    # Equivalently: a fixed horizon holds ~2x the arrivals.
    horizon = np.percentile(slow, 50)
    n_slow = int(np.sum(slow <= horizon))
    n_fast = int(np.sum(fast <= horizon))
    assert n_fast == pytest.approx(2 * n_slow, rel=0.2)


def test_poisson_rate_is_requests_per_second():
    # 200 req/s for ~2000 requests => mean gap 5ms, total span ~10s.
    t = PoissonArrivals(200.0).sample_arrivals_ms(
        np.random.default_rng(1), 2_000
    )
    assert np.mean(np.diff(t)) == pytest.approx(5.0, rel=0.1)
    assert t[-1] == pytest.approx(10_000.0, rel=0.15)


# ---------------------------------------------------------------------------
# The PR 9 drift shapes.
# ---------------------------------------------------------------------------
def test_diurnal_arrivals_swing_trough_peak_trough():
    arr = DiurnalArrivals(trough_rps=20.0, peak_rps=400.0)
    t = arr.sample_arrivals_ms(np.random.default_rng(3), 3_000)
    assert np.all(np.diff(t) >= 0)
    gaps = np.diff(t)
    third = len(gaps) // 3
    edges = np.mean(np.concatenate([gaps[:third], gaps[-third:]]))
    middle = np.mean(gaps[third:-third])
    # The middle of the run is the peak: much denser than the edges.
    assert middle < edges / 3.0
    # Determinism + validation.
    np.testing.assert_array_equal(
        t, arr.sample_arrivals_ms(np.random.default_rng(3), 3_000)
    )
    with pytest.raises(ValueError):
        DiurnalArrivals(trough_rps=0.0, peak_rps=100.0)
    with pytest.raises(ValueError):
        DiurnalArrivals(trough_rps=100.0, peak_rps=0.0)


def test_spike_arrivals_service_factor_window():
    arr = SpikeArrivals(
        rate_rps=100.0, spike_factor=30.0, spike_start=0.4, spike_stop=0.6
    )
    horizon = 10_000.0
    assert arr.service_factor(0.0, horizon) == 1.0
    assert arr.service_factor(3_999.0, horizon) == 1.0
    assert arr.service_factor(4_000.0, horizon) == 30.0  # [start, stop)
    assert arr.service_factor(5_999.0, horizon) == 30.0
    assert arr.service_factor(6_000.0, horizon) == 1.0
    assert arr.service_factor(horizon, horizon) == 1.0
    # Arrivals themselves are plain Poisson: the spike is a *service*
    # disturbance, not an arrival burst.
    t = arr.sample_arrivals_ms(np.random.default_rng(5), 2_000)
    assert np.mean(np.diff(t)) == pytest.approx(10.0, rel=0.1)
    with pytest.raises(ValueError):
        SpikeArrivals(spike_start=0.7, spike_stop=0.3)
    with pytest.raises(ValueError):
        SpikeArrivals(spike_factor=0.0)
