"""Unit + property tests for request duplication (§V-B)."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.duplication import (
    DEFAULT_ON_DEVICE,
    HedgePolicy,
    resolve_duplication,
)


def test_remote_within_sla_uses_remote():
    out = resolve_duplication(
        remote_latency_ms=np.array([200.0]),
        remote_accuracy=np.array([82.6]),
        ondevice_latency_ms=np.array([30.0]),
        ondevice_accuracy=41.4,
        t_sla_ms=250.0,
    )
    assert out.used_remote[0]
    assert out.accuracy[0] == 82.6
    assert out.latency_ms[0] == 200.0
    assert not out.violation[0]


def test_remote_misses_uses_ondevice_at_deadline():
    out = resolve_duplication(
        remote_latency_ms=np.array([400.0]),
        remote_accuracy=np.array([82.6]),
        ondevice_latency_ms=np.array([30.0]),
        ondevice_accuracy=41.4,
        t_sla_ms=250.0,
    )
    assert not out.used_remote[0]
    assert out.accuracy[0] == 41.4
    assert out.latency_ms[0] == 250.0  # bounded at the SLA
    assert not out.violation[0]


def test_violation_only_when_ondevice_slower_than_sla():
    out = resolve_duplication(
        remote_latency_ms=np.array([400.0]),
        remote_accuracy=np.array([82.6]),
        ondevice_latency_ms=np.array([60.0]),
        ondevice_accuracy=41.4,
        t_sla_ms=50.0,
    )
    assert out.violation[0]
    assert out.latency_ms[0] == 60.0


@given(
    st.lists(st.floats(1.0, 2000.0), min_size=1, max_size=64),
    st.floats(10.0, 500.0),
    st.floats(1.0, 200.0),
)
@settings(max_examples=200, deadline=None)
def test_duplication_bounds_latency(remote, sla, ondev):
    r = np.asarray(remote)
    out = resolve_duplication(
        remote_latency_ms=r,
        remote_accuracy=np.full_like(r, 80.0),
        ondevice_latency_ms=np.full_like(r, ondev),
        ondevice_accuracy=41.4,
        t_sla_ms=sla,
    )
    # Latency is bounded by max(SLA, on-device latency) for every request.
    assert np.all(out.latency_ms <= max(sla, ondev) + 1e-9)
    # With a fast duplicate there are no violations, ever.
    if ondev <= sla:
        assert not out.violation.any()
    # Accuracy is one of the two sources.
    assert np.all(np.isin(out.accuracy, [80.0, 41.4]))


def test_outcome_carries_per_tier_latencies():
    remote = np.array([200.0, 400.0])
    ondev = np.array([30.0, 35.0])
    out = resolve_duplication(
        remote_latency_ms=remote,
        remote_accuracy=np.array([82.6, 82.6]),
        ondevice_latency_ms=ondev,
        ondevice_accuracy=41.4,
        t_sla_ms=250.0,
    )
    np.testing.assert_array_equal(out.remote_ms, remote)
    np.testing.assert_array_equal(out.ondevice_ms, ondev)


def test_hedge_policy_always():
    p = HedgePolicy(always=True)
    assert p.should_hedge(np.array([1000.0]), np.array([5.0]), np.array([1.0]))[0]


def test_hedge_policy_headroom_skips_safe_requests():
    p = HedgePolicy(always=False, deadline_headroom_ms=50.0)
    # Budget 500, base model 5 +- 1ms -> slack 492 >= 50 -> skip the hedge.
    assert not p.should_hedge(np.array([500.0]), np.array([5.0]), np.array([1.0]))[0]
    # Budget 20 -> slack 12 < 50 -> hedge.
    assert p.should_hedge(np.array([20.0]), np.array([5.0]), np.array([1.0]))[0]


def test_default_on_device_profile():
    assert DEFAULT_ON_DEVICE.accuracy == 41.4
    assert DEFAULT_ON_DEVICE.mu_ms < 50.0
