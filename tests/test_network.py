"""Network models/estimators: calibration quantiles + property tests."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.network import (
    EWMAEstimator,
    ExactEstimator,
    FixedCVNetwork,
    LognormalNetwork,
    NoisyEstimator,
    TraceNetwork,
    residential_trace,
    university_trace,
)


def test_fixed_cv_moments():
    rng = np.random.default_rng(0)
    s = FixedCVNetwork(100.0, 0.5).sample(rng, 200_000)
    assert abs(s.mean() - 100.0) < 1.5
    assert abs(s.std() - 50.0) < 2.0


def test_fixed_cv_zero_is_constant():
    rng = np.random.default_rng(0)
    s = FixedCVNetwork(100.0, 0.0).sample(rng, 100)
    np.testing.assert_allclose(s, 100.0)


def test_lognormal_moments():
    rng = np.random.default_rng(0)
    s = LognormalNetwork(100.0, 0.74).sample(rng, 400_000)
    assert abs(s.mean() - 100.0) < 2.0
    assert abs(s.std() / s.mean() - 0.74) < 0.05


@pytest.mark.parametrize(
    "trace,q137,q247",
    [
        (university_trace(), 0.0367, 0.0026),
        (residential_trace(), 0.2303, 0.0316),
    ],
    ids=["university", "residential"],
)
def test_trace_calibration(trace, q137, q247):
    """Traces hit the Table IV reliance quantiles (see network.py docstring)."""
    t = np.asarray(trace.trace_ms)
    assert abs(np.mean(t > 137.4) - q137) < 0.02
    assert abs(np.mean(t > 246.8) - q247) < 0.012


def test_trace_bootstrap_sampling():
    rng = np.random.default_rng(0)
    t = TraceNetwork((10.0, 20.0, 30.0))
    s = t.sample(rng, 1000)
    assert set(np.unique(s)) <= {10.0, 20.0, 30.0}


def test_exact_estimator_identity():
    rng = np.random.default_rng(0)
    x = np.array([1.0, 2.0, 3.0])
    np.testing.assert_array_equal(ExactEstimator().estimate(rng, x), x)


def test_noisy_estimator_unbiased_median():
    rng = np.random.default_rng(0)
    x = np.full(100_000, 100.0)
    est = NoisyEstimator(0.2).estimate(rng, x)
    assert abs(np.median(est) - 100.0) < 1.5


def test_ewma_estimator_lags():
    rng = np.random.default_rng(0)
    actual = np.concatenate([np.full(50, 100.0), np.full(50, 200.0)])
    est = EWMAEstimator(0.5).estimate(rng, actual)
    assert est[0] == 100.0
    assert est[51] < 200.0  # lags the jump
    assert est[-1] > 190.0  # converges


@given(
    st.floats(10.0, 500.0), st.floats(0.0, 1.5), st.integers(0, 2**31 - 1)
)
@settings(max_examples=50, deadline=None)
def test_networks_always_positive(mean, cv, seed):
    rng = np.random.default_rng(seed)
    for net in (FixedCVNetwork(mean, cv), LognormalNetwork(mean, max(cv, 0.01))):
        s = net.sample(rng, 256)
        assert (s > 0).all()
        assert np.isfinite(s).all()


# ---------------------------------------------------------------------------
# SwitchedNetwork (PR 9): the mid-stream handover drift shape.
# ---------------------------------------------------------------------------
def test_switched_network_splits_at_the_switch_fraction():
    from repro.core.network import SwitchedNetwork

    rng = np.random.default_rng(0)
    net = SwitchedNetwork(
        FixedCVNetwork(10.0, 0.0), FixedCVNetwork(200.0, 0.0), 0.25
    )
    s = net.sample(rng, 400)
    assert s.shape == (400,)
    np.testing.assert_allclose(s[:100], 10.0)  # first quarter: before
    np.testing.assert_allclose(s[100:], 200.0)  # the rest: after
    # Degenerate fractions collapse to a single model.
    all_before = SwitchedNetwork(
        FixedCVNetwork(10.0, 0.0), FixedCVNetwork(200.0, 0.0), 1.0
    ).sample(rng, 50)
    np.testing.assert_allclose(all_before, 10.0)
    all_after = SwitchedNetwork(
        FixedCVNetwork(10.0, 0.0), FixedCVNetwork(200.0, 0.0), 0.0
    ).sample(rng, 50)
    np.testing.assert_allclose(all_after, 200.0)
    with pytest.raises(ValueError):
        SwitchedNetwork(
            FixedCVNetwork(10.0, 0.0), FixedCVNetwork(200.0, 0.0), 1.5
        )


def test_switched_network_university_to_lte_is_a_real_drift():
    from repro.core.network import SwitchedNetwork, lte_trace

    rng = np.random.default_rng(1)
    s = SwitchedNetwork(university_trace(), lte_trace(), 0.5).sample(
        rng, 2_000
    )
    assert (s > 0).all() and np.isfinite(s).all()
    # The LTE half is clearly slower in the median and carries the heavy
    # multi-second tail — the paper's university-vs-LTE gap inside one
    # trace (university's body is capped at 245ms; LTE's 2% tail is not).
    assert np.median(s[1_000:]) > 1.3 * np.median(s[:1_000])
    assert s[:1_000].max() < 1_000.0 < s[1_000:].max()
