"""Batched online scheduler: scalar-path equivalence + invariants.

The batched ``run_trace`` pre-draws all randomness, so with
``profile_ewma=0`` the outcome must be *identical* for any chunk size; with
EWMA enabled, ``chunk_size=1`` is the scalar reference and larger chunks
(which freeze profiles within a chunk) must agree within statistical
tolerance.  Property-based invariants (hedging never hurts attainment,
stage-1 accuracy is monotone in budget, sigma stays positive) are guarded
by the optional-hypothesis shim.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.mdinference_zoo import paper_zoo
from repro.core.duplication import HedgePolicy
from repro.core.registry import ModelProfile, ModelRegistry
from repro.serving.profiles import ONDEVICE_TIER
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

ZOO = paper_zoo()


def _trace(n=400, seed=0, mean=100.0, spread=80.0):
    rng = np.random.default_rng(seed)
    return np.abs(rng.normal(mean, spread, n)) + 1.0


def _run(chunk, *, t_nw, t_sla=250.0, ewma=0.0, hedge=None, seed=3,
         algorithm="mdinference", registry=None):
    cfg = SchedulerConfig(
        t_sla_ms=t_sla,
        profile_ewma=ewma,
        seed=seed,
        chunk_size=chunk,
        algorithm=algorithm,
        hedge=hedge if hedge is not None else HedgePolicy(),
    )
    sched = MDInferenceScheduler(registry or ZOO, ONDEVICE_TIER, cfg)
    metrics = sched.run_trace(t_nw)
    choices = [r["model"] for r in sched.log]
    return sched, metrics, choices


# ---------------------------------------------------------------------------
# Batched == scalar equivalence (the tentpole's correctness contract).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [2, 64, 1000])
@pytest.mark.parametrize(
    "hedge",
    [HedgePolicy(), HedgePolicy(always=False, deadline_headroom_ms=-1e12)],
    ids=["duplication_on", "duplication_off"],
)
def test_batched_matches_scalar_ewma_off(chunk, hedge):
    t_nw = _trace()
    _, m1, c1 = _run(1, t_nw=t_nw, hedge=hedge)
    _, mc, cc = _run(chunk, t_nw=t_nw, hedge=hedge)
    assert c1 == cc  # identical per-request model choices
    assert m1.model_usage == mc.model_usage
    np.testing.assert_allclose(m1.aggregate_accuracy, mc.aggregate_accuracy)
    np.testing.assert_allclose(m1.mean_latency_ms, mc.mean_latency_ms)
    np.testing.assert_allclose(m1.sla_attainment, mc.sla_attainment)
    np.testing.assert_allclose(m1.p99_latency_ms, mc.p99_latency_ms)


def test_batched_matches_scalar_fallback_heavy():
    # SLA of 30ms: nearly every request has a sub-mu budget -> fallback path.
    t_nw = _trace(mean=60.0, spread=30.0)
    _, m1, c1 = _run(1, t_nw=t_nw, t_sla=30.0)
    _, mc, cc = _run(128, t_nw=t_nw, t_sla=30.0)
    assert c1 == cc
    np.testing.assert_allclose(m1.mean_latency_ms, mc.mean_latency_ms)
    assert m1.sla_attainment == mc.sla_attainment


def test_batched_matches_scalar_with_ewma_within_tolerance():
    # EWMA on: chunks freeze profiles mid-chunk, so choices may drift but
    # the aggregate behavior must stay statistically equivalent.
    t_nw = _trace(n=2000, seed=5)
    _, m1, _ = _run(1, t_nw=t_nw, ewma=0.05)
    _, mc, _ = _run(256, t_nw=t_nw, ewma=0.05)
    assert abs(m1.aggregate_accuracy - mc.aggregate_accuracy) < 1.0
    assert abs(m1.sla_attainment - mc.sla_attainment) < 0.02
    assert abs(m1.mean_latency_ms - mc.mean_latency_ms) < 10.0


def test_ewma_chunk1_profiles_match_scalar_observe():
    # observe_batch replays observations in order: folding a chunk must be
    # bit-identical to scalar observe calls.
    a = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig(profile_ewma=0.2))
    b = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig(profile_ewma=0.2))
    rng = np.random.default_rng(7)
    idx = rng.integers(0, len(ZOO), 200)
    obs = rng.uniform(1.0, 400.0, 200)
    for i, x in zip(idx, obs):
        a.observe(int(i), float(x))
    b.observe_batch(idx, obs)
    np.testing.assert_array_equal(a.mu, b.mu)
    np.testing.assert_array_equal(a.sigma, b.sigma)


@pytest.mark.parametrize(
    "algorithm", ["static_greedy", "budget_greedy", "static_latency"]
)
def test_baseline_policies_dispatch_batched(algorithm):
    t_nw = _trace(n=200)
    _, m1, c1 = _run(1, t_nw=t_nw, algorithm=algorithm)
    _, mc, cc = _run(64, t_nw=t_nw, algorithm=algorithm)
    assert c1 == cc
    if algorithm == "static_latency":
        assert set(c1) == {ZOO[ZOO.fastest_index].name}


def test_policy_registries_stay_in_sync():
    """ALGORITHMS and POLICY_PROBABILITIES implement each policy twice;
    this pins them to each other so a tweak to one can't silently diverge.
    Deterministic policies must agree exactly (argmax of the probability
    row == the sampled index); stochastic ones must sample inside the
    probability row's support."""
    import jax
    import jax.numpy as jnp

    from repro.core.baselines import ALGORITHMS, POLICY_PROBABILITIES

    assert set(ALGORITHMS) == set(POLICY_PROBABILITIES)
    acc = jnp.asarray(ZOO.accuracy)
    mu = jnp.asarray(ZOO.mu)
    sigma = jnp.asarray(ZOO.sigma)
    t_sla = jnp.float32(250.0)
    budgets = jnp.asarray(np.linspace(-20.0, 260.0, 57), jnp.float32)
    deterministic = {
        "static_greedy", "budget_greedy", "oracle",
        "static_accuracy", "static_latency", "related_accurate",
    }
    for name in ALGORITHMS:
        idx, fb = ALGORITHMS[name](jax.random.key(0), acc, mu, sigma, t_sla, budgets)
        probs, _, fb_p = POLICY_PROBABILITIES[name](acc, mu, sigma, t_sla, budgets)
        probs = np.asarray(probs)
        np.testing.assert_array_equal(np.asarray(fb), np.asarray(fb_p))
        if name in deterministic:
            np.testing.assert_array_equal(np.asarray(idx), probs.argmax(axis=-1))
        else:
            assert np.all(probs[np.arange(len(budgets)), np.asarray(idx)] > 0)


def test_decide_batch_uses_live_profiles():
    reg = ModelRegistry(
        [
            ModelProfile("fast", 50.0, 10.0, 0.5),
            ModelProfile("big", 90.0, 100.0, 1.0),
        ]
    )
    sched = MDInferenceScheduler(
        reg, ONDEVICE_TIER, SchedulerConfig(t_sla_ms=250.0, profile_ewma=0.3)
    )
    d = sched.decide_batch(np.full(8, 100.0))
    assert np.all(d.model_index == 1)
    sched.observe_batch(np.full(30, 1), np.full(30, 400.0))
    d = sched.decide_batch(np.full(8, 100.0))
    assert np.all(d.model_index == 0)  # degraded 'big' abandoned


# ---------------------------------------------------------------------------
# Property-based invariants (skipped when hypothesis is unavailable).
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1), st.floats(60.0, 400.0))
@settings(max_examples=20, deadline=None)
def test_hedging_never_increases_miss_rate(seed, t_sla):
    """On the same draws, duplication can only improve SLA attainment."""
    t_nw = _trace(n=300, seed=seed)
    _, hedged, _ = _run(64, t_nw=t_nw, t_sla=t_sla, seed=seed)
    _, unhedged, _ = _run(
        64, t_nw=t_nw, t_sla=t_sla, seed=seed,
        hedge=HedgePolicy(always=False, deadline_headroom_ms=-1e12),
    )
    assert hedged.sla_attainment >= unhedged.sla_attainment - 1e-12


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_base_accuracy_monotone_in_budget(seed):
    """Shrinking t_budget never raises the stage-1 base model's accuracy."""
    rng = np.random.default_rng(seed)
    sched = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig())
    t_nw = np.sort(rng.uniform(0.0, 260.0, 64))  # budgets shrink with index
    d = sched.decide_batch(t_nw)
    base_acc = sched.accuracy[d.base_index]
    feasible = ~d.fallback
    # Among non-fallback rows, accuracy is non-increasing as budget shrinks.
    acc_seq = base_acc[feasible]
    assert np.all(np.diff(acc_seq) <= 1e-12)


@given(
    st.lists(st.floats(0.0, 1e6), min_size=1, max_size=64),
    st.floats(0.01, 0.99),
)
@settings(max_examples=50, deadline=None)
def test_observe_keeps_sigma_positive(observations, alpha):
    """sigma stays positive and finite for any finite observation stream."""
    sched = MDInferenceScheduler(
        ZOO, ONDEVICE_TIER, SchedulerConfig(profile_ewma=alpha)
    )
    for x in observations:
        sched.observe(0, x)
    assert sched.sigma[0] > 0.0
    assert np.isfinite(sched.sigma[0])
    assert np.isfinite(sched.mu[0])


# ---------------------------------------------------------------------------
# Placement-eligibility masks (the cluster's selection constraint).
# ---------------------------------------------------------------------------
def test_eligible_all_true_is_identical_to_unmasked():
    t_nw = _trace(n=200)
    a = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig(seed=3))
    b = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig(seed=3))
    da = a.decide_batch(t_nw)
    db = b.decide_batch(t_nw, eligible=np.ones(len(ZOO), bool))
    np.testing.assert_array_equal(da.model_index, db.model_index)
    np.testing.assert_array_equal(da.base_index, db.base_index)
    np.testing.assert_array_equal(da.hedged, db.hedged)
    np.testing.assert_array_equal(da.fallback, db.fallback)


def test_eligible_mask_excludes_unhosted_models():
    t_nw = _trace(n=400)
    sched = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig(seed=3))
    eligible = np.ones(len(ZOO), bool)
    eligible[::2] = False  # half the zoo has no hosting replica
    d = sched.decide_batch(t_nw, eligible=eligible)
    assert np.all(eligible[d.model_index])
    assert np.all(eligible[d.base_index])


def test_eligible_dead_rows_fall_back_to_fastest_eligible():
    # Only the slowest model is eligible; a sub-mu budget leaves zero
    # selection mass -> the row must fall back to the fastest *eligible*
    # model (which is that same model), flagged as fallback.
    sched = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig())
    eligible = np.zeros(len(ZOO), bool)
    slowest = int(np.argmax(sched.mu))
    eligible[slowest] = True
    d = sched.decide_batch(np.full(8, 249.0), eligible=eligible)
    assert np.all(d.model_index == slowest)
    assert np.all(d.fallback)


def test_eligible_mask_validation():
    sched = MDInferenceScheduler(ZOO, ONDEVICE_TIER, SchedulerConfig())
    with pytest.raises(ValueError, match="shape"):
        sched.decide_batch(np.full(4, 100.0), eligible=np.ones(3, bool))
    with pytest.raises(ValueError, match="excludes every model"):
        sched.decide_batch(
            np.full(4, 100.0), eligible=np.zeros(len(ZOO), bool)
        )


# ---------------------------------------------------------------------------
# Sub-chunk profile refresh (the frozen-intra-chunk EWMA ROADMAP item).
# ---------------------------------------------------------------------------
def test_subchunk_refresh_is_identity_with_ewma_off():
    """With profile_ewma=0 the refresh folds nothing, and the pre-drawn
    randomness makes the outcome independent of the refresh stride."""
    t_nw = _trace(n=500)
    cfg = dict(t_sla_ms=250.0, profile_ewma=0.0, seed=9, chunk_size=256)
    m_frozen = MDInferenceScheduler(
        ZOO, ONDEVICE_TIER, SchedulerConfig(**cfg)
    ).run_trace(t_nw)
    m_refresh = MDInferenceScheduler(
        ZOO, ONDEVICE_TIER, SchedulerConfig(subchunk_refresh=16, **cfg)
    ).run_trace(t_nw)
    assert m_frozen.model_usage == m_refresh.model_usage
    np.testing.assert_allclose(
        m_frozen.aggregate_accuracy, m_refresh.aggregate_accuracy
    )
    np.testing.assert_allclose(
        m_frozen.mean_latency_ms, m_refresh.mean_latency_ms
    )


def test_subchunk_refresh_adapts_to_drift_within_a_chunk():
    """Drift regression: a model whose real latency jumped 30x mid-stream.

    A frozen 512-request chunk keeps selecting it from the stale snapshot
    for the whole chunk; sub-chunk refresh folds the observations between
    sub-chunks and abandons the degraded model mid-chunk — strictly fewer
    picks, and a live mu that has moved toward the truth by chunk end.
    """
    reg = ModelRegistry(
        [
            ModelProfile("fast", 50.0, 10.0, 0.5),
            ModelProfile("big", 90.0, 100.0, 1.0),
        ]
    )
    t_nw = np.full(512, 100.0)  # budget 150ms: 'big' wins while healthy
    drifted_mu = 3000.0

    def drifted_sampler(model_index, rng):
        return drifted_mu if model_index == 1 else 10.0

    def picks(subchunk):
        sched = MDInferenceScheduler(
            reg,
            ONDEVICE_TIER,
            SchedulerConfig(
                t_sla_ms=250.0, profile_ewma=0.3, seed=2, chunk_size=512,
                subchunk_refresh=subchunk,
            ),
        )
        m = sched.run_trace(t_nw, exec_sampler=drifted_sampler)
        n_big = sum(1 for r in sched.log if r["model"] == "big")
        return n_big, float(sched.mu[1]), m

    n_frozen, mu_frozen, _ = picks(None)
    n_refresh, mu_refresh, _ = picks(32)
    assert n_frozen == 512  # the stale snapshot never learns mid-chunk
    assert n_refresh < n_frozen  # refresh abandons the degraded model
    assert n_refresh <= 64  # within ~two sub-chunks
    # Both folded what they observed; the refreshed path's selection saw it.
    assert abs(mu_refresh - drifted_mu) < drifted_mu  # moved toward truth
