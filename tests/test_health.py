"""Circuit-breaker lifecycle unit tests (repro.serving.health).

The breaker is pure loop-clock state — no processes, no threads — so the
full closed -> open -> half_open -> closed lifecycle is tested
deterministically here; the cluster/loop integration rides in
tests/test_cluster.py.
"""
import math

import pytest

from repro.serving.health import BreakerConfig, CircuitBreaker, ReplicaHealth


def make(threshold=3, cooldown=100.0, backoff=2.0, max_cooldown=400.0):
    return CircuitBreaker(
        BreakerConfig(
            failure_threshold=threshold,
            cooldown_ms=cooldown,
            backoff=backoff,
            max_cooldown_ms=max_cooldown,
        )
    )


# -- config validation ---------------------------------------------------------


@pytest.mark.parametrize(
    "kw",
    [
        {"failure_threshold": 0},
        {"cooldown_ms": 0.0},
        {"cooldown_ms": -5.0},
        {"backoff": 0.5},
    ],
)
def test_config_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        BreakerConfig(**kw)


# -- closed state --------------------------------------------------------------


def test_starts_closed_and_routable():
    b = make()
    assert b.state == "closed"
    assert b.healthy
    assert b.reason is None
    assert b.routable(0.0)


def test_subthreshold_failures_stay_closed():
    b = make(threshold=3)
    b.on_failure(0.0, "err")
    b.on_failure(1.0, "err")
    assert b.state == "closed"
    assert b.consecutive_failures == 2
    assert b.routable(2.0)


def test_success_resets_the_failure_streak():
    b = make(threshold=3)
    b.on_failure(0.0, "err")
    b.on_failure(1.0, "err")
    b.on_success(2.0)
    assert b.consecutive_failures == 0
    # Two more failures still don't reach the threshold of 3.
    b.on_failure(3.0, "err")
    b.on_failure(4.0, "err")
    assert b.state == "closed"


# -- tripping open -------------------------------------------------------------


def test_consecutive_failures_trip_at_threshold_with_reason():
    b = make(threshold=3, cooldown=100.0)
    for t in range(3):
        b.on_failure(float(t), "oom in decode")
    assert b.state == "open"
    assert b.reason == "oom in decode"
    assert b.open_until_ms == 2.0 + 100.0
    assert not b.routable(50.0)


def test_fatal_failure_trips_immediately():
    b = make(threshold=3)
    b.on_failure(10.0, "worker process died", fatal=True)
    assert b.state == "open"
    assert b.reason == "worker process died"
    assert not b.routable(10.0)


# -- cooldown -> half-open probe ----------------------------------------------


def test_open_blocks_until_cooldown_then_half_opens():
    b = make(cooldown=100.0)
    b.trip(0.0, "down")
    assert not b.routable(99.9)
    assert b.state == "open"
    assert b.routable(100.0)
    assert b.state == "half_open"


def test_half_open_admits_exactly_one_probe():
    b = make(cooldown=100.0)
    b.trip(0.0, "down")
    assert b.routable(150.0)  # transitions to half_open
    # Pure eligibility checks never claim the probe slot...
    assert b.routable(150.0)
    assert b.routable(151.0)
    # ...only an actual dispatch does.
    b.on_dispatch(151.0)
    assert not b.routable(152.0)


def test_probe_success_closes_and_resets_backoff():
    b = make(cooldown=100.0, backoff=2.0)
    b.trip(0.0, "down")
    assert b.routable(100.0)
    b.on_dispatch(100.0)
    b.on_success(120.0)
    assert b.state == "closed"
    assert b.reason is None
    assert b.trips == 0
    # The next trip starts from the base cooldown again.
    b.trip(200.0, "down again")
    assert b.open_until_ms == 200.0 + 100.0


def test_probe_failure_reopens_with_backed_off_cooldown():
    b = make(threshold=3, cooldown=100.0, backoff=2.0)
    b.trip(0.0, "down")
    assert b.routable(100.0)
    b.on_dispatch(100.0)
    # A single probe failure re-opens (no threshold accumulation).
    b.on_failure(110.0, "still down")
    assert b.state == "open"
    assert b.open_until_ms == 110.0 + 200.0  # cooldown * backoff**1


def test_cooldown_backoff_is_capped():
    b = make(cooldown=100.0, backoff=2.0, max_cooldown=250.0)
    spans = []
    for t in [0.0, 1000.0, 2000.0, 3000.0]:
        b.trip(t, "flap")
        spans.append(b.open_until_ms - t)
    assert spans == [100.0, 200.0, 250.0, 250.0]


# -- permanent trips (kill) ----------------------------------------------------


def test_permanent_trip_never_half_opens():
    b = make(cooldown=1.0)
    b.trip(0.0, "killed", permanent=True)
    assert b.permanently_open
    assert b.open_until_ms == math.inf
    assert not b.routable(1e12)
    # Further failures don't disturb the permanent state.
    b.on_failure(5.0, "late completion", fatal=True)
    assert b.permanently_open


def test_reset_recovers_a_permanently_open_breaker():
    b = make()
    b.trip(0.0, "killed", permanent=True)
    b.reset()
    assert b.state == "closed"
    assert b.reason is None
    assert b.trips == 0
    assert b.routable(0.0)


# -- drain flag (ReplicaHealth) ------------------------------------------------


def test_draining_is_unroutable_regardless_of_breaker_state():
    h = ReplicaHealth()
    assert h.routable(0.0)
    h.draining = True
    assert not h.routable(0.0)
    assert h.breaker.state == "closed"  # drain is not a failure
    h.draining = False
    assert h.routable(0.0)


def test_draining_masks_even_a_half_open_probe():
    h = ReplicaHealth(CircuitBreaker(BreakerConfig(cooldown_ms=10.0)))
    h.breaker.trip(0.0, "down")
    h.draining = True
    assert not h.routable(50.0)
