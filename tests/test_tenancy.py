"""Multi-tenant QoS: weighted-fair lanes, strict priority, streaming.

The PR-8 contract, bottom-up:

* ``TenantConfig`` / ``parse_tenant_spec`` validate their QoS fields.
* ``TenantLanes.select`` is deficit round-robin by weight within a class —
  long-run shares converge to the weights — with strict
  interactive-over-batch priority between classes, and a single lane
  degenerates to the plain FIFO prefix.
* The admission queue routes by tag, bounds per-tenant capacity, and
  charges shed/reject accounting to the right lane; ``tenants=None`` keeps
  the single-class FIFO path byte-identical (``_lanes`` never exists).
* ``RequestMetrics`` grows per-tenant rows and per-class p99 *only* when
  tenancy is in play — untenanted metrics stay exactly as before.
* ``InferenceFuture.stream()`` yields ``StreamChunk`` tokens; on a backend
  with no token channel it degrades to one burst of the completion's
  tokens (the continuous tier's true incremental stream is covered in
  ``tests/test_continuous.py``).

Driven through the sleep-tier stubs (``tests/loop_stubs.py``): no compiles,
deterministic.
"""
import numpy as np
import pytest

from repro.serving.admission import AdmissionConfig, AdmissionQueue
from repro.serving.lifecycle import (
    InferenceFuture,
    QueuedRequest,
    RequestState,
)
from repro.serving.loop import ServingLoop
from repro.serving.tenancy import (
    DEFAULT_TENANT,
    TenantConfig,
    TenantLanes,
    parse_tenant_spec,
)

from loop_stubs import StubHedgeBackend, StubRemoteBackend, stub_scheduler


def _request(rid, arrival_ms=0.0, tenant=None, priority=None, sla=None):
    return QueuedRequest(
        rid=rid,
        tokens=np.zeros(4, np.int32),
        n_steps=2,
        t_nw_est_ms=10.0,
        t_nw_actual_ms=10.0,
        arrival_ms=float(arrival_ms),
        sla_ms=sla,
        tenant=tenant,
        priority=priority,
    )


def _future(rid, tenant=None, **kw):
    return InferenceFuture(_request(rid, tenant=tenant, **kw))


def _lanes_with(tenants, fill):
    """TenantLanes pre-filled via resolve/append: {tenant: n_requests}."""
    lanes = TenantLanes(tenants)
    rid = 0
    for tenant, n in fill.items():
        for _ in range(n):
            f = _future(rid, tenant=tenant)
            lanes.append(lanes.resolve(f), f)
            rid += 1
    return lanes


def _loop(admission, *, t_sla_ms=1_000.0, **kw):
    kw.setdefault("profile_ewma", 0.0)
    return ServingLoop(
        stub_scheduler(t_sla_ms=t_sla_ms, **kw),
        StubRemoteBackend(0.0),
        StubHedgeBackend(0.0),
        dispatch="sync",
        admission=admission,
    )


# ---------------------------------------------------------------------------
# Config validation + spec parsing.
# ---------------------------------------------------------------------------
def test_tenant_config_validation():
    with pytest.raises(ValueError):
        TenantConfig("")
    with pytest.raises(ValueError):
        TenantConfig("a", weight=0.0)
    with pytest.raises(ValueError):
        TenantConfig("a", priority="realtime")
    with pytest.raises(ValueError):
        TenantConfig("a", max_pending=0)
    with pytest.raises(ValueError):
        TenantConfig("a", burst_credit=-1.0)
    with pytest.raises(ValueError):
        TenantLanes([TenantConfig("a"), TenantConfig("a")])  # dup names
    with pytest.raises(TypeError):
        AdmissionConfig(tenants=("not-a-config",))
    # A per-tenant bound satisfies a bounded policy's capacity requirement.
    cfg = AdmissionConfig(
        policy="shed", tenants=(TenantConfig("a", max_pending=4),)
    )
    assert cfg.max_pending is None
    with pytest.raises(ValueError):
        AdmissionConfig(policy="shed", tenants=(TenantConfig("a"),))


def test_parse_tenant_spec():
    tenants = parse_tenant_spec("ui:4:interactive,crawl:1:batch:32")
    assert tenants == (
        TenantConfig("ui", weight=4.0, priority="interactive"),
        TenantConfig("crawl", weight=1.0, priority="batch", max_pending=32),
    )
    assert parse_tenant_spec("solo") == (TenantConfig("solo"),)
    with pytest.raises(ValueError):
        parse_tenant_spec("a:1:interactive:8:extra")
    with pytest.raises(ValueError):
        parse_tenant_spec(":2")
    with pytest.raises(ValueError):
        parse_tenant_spec("a:0")  # weight must be > 0


# ---------------------------------------------------------------------------
# The DRR drain: weighted shares, strict priority, FIFO degeneration.
# ---------------------------------------------------------------------------
def test_drr_weighted_share_within_a_class():
    lanes = _lanes_with(
        [TenantConfig("a", weight=2.0), TenantConfig("b", weight=1.0)],
        {"a": 20, "b": 20},
    )
    out = lanes.select(6)
    names = [lanes.name_of(f) for f in out]
    assert names.count("a") == 4 and names.count("b") == 2  # 2:1 share
    # While both lanes stay backlogged, repeated budgets keep the
    # weighted share (deficits carry across selects).
    names += [lanes.name_of(f) for f in lanes.select(9)]
    assert names.count("a") == 10 and names.count("b") == 5
    assert len(lanes.select(None)) == 25  # the rest drains completely
    assert lanes.n_queued() == 0


def test_strict_interactive_over_batch_priority():
    lanes = _lanes_with(
        [
            TenantConfig("ui", weight=1.0),
            TenantConfig("crawl", weight=100.0, priority="batch"),
        ],
        {"crawl": 6, "ui": 3},
    )
    out = lanes.select(5)
    names = [lanes.name_of(f) for f in out]
    # Every interactive request precedes any batch one, regardless of the
    # batch lane's enormous weight — batch only soaks leftover budget.
    assert names == ["ui", "ui", "ui", "crawl", "crawl"]


def test_single_lane_select_is_fifo_prefix():
    lanes = _lanes_with([TenantConfig("only", weight=3.0)], {"only": 6})
    fs = lanes.all_queued()
    assert lanes.select(4) == fs[:4]
    assert lanes.select(None) == fs[4:]


def test_select_peek_does_not_advance_deficits_or_queues():
    lanes = _lanes_with(
        [TenantConfig("a", weight=2.0), TenantConfig("b", weight=1.0)],
        {"a": 4, "b": 4},
    )
    peek = lanes.select(3, commit=False)
    assert len(peek) == 3 and lanes.n_queued() == 8  # nothing dequeued
    assert all(lane.deficit == 0.0 for lane in lanes._lanes.values())
    assert lanes.select(3) == peek  # the commit pick matches the peek


def test_burst_credit_caps_banked_deficit_on_lane_empty():
    lanes = _lanes_with(
        [
            TenantConfig("burst", weight=5.0, burst_credit=2.0),
            TenantConfig("flat", weight=5.0),
        ],
        {"burst": 1, "flat": 1},
    )
    lanes.select(None)
    # Each lane earned 5 quanta, spent 1, then emptied: the banked
    # leftover collapses to the burst allowance (2) or to zero.
    assert lanes._lanes["burst"].deficit == 2.0
    assert lanes._lanes["flat"].deficit == 0.0


# ---------------------------------------------------------------------------
# Admission integration: routing, per-tenant bounds, accounting.
# ---------------------------------------------------------------------------
def test_untagged_and_unknown_tags_ride_the_default_lane():
    q = AdmissionQueue(
        AdmissionConfig(tenants=(TenantConfig("known"),))
    )
    assert q.offer(_mk := InferenceFuture(_request(0))) == "admitted"
    assert q.offer(InferenceFuture(_request(1, tenant="mystery"))) == "admitted"
    assert q.offer(InferenceFuture(_request(2, tenant="known"))) == "admitted"
    assert q.tenant_pending(DEFAULT_TENANT) == 2
    assert q.tenant_pending("known") == 1
    assert _mk.priority == "interactive"  # the default lane's class
    assert q.tenant_submitted == {DEFAULT_TENANT: 2, "known": 1}


def test_per_tenant_max_pending_sheds_and_charges_the_lane():
    q = AdmissionQueue(
        AdmissionConfig(
            policy="shed",
            tenants=(
                TenantConfig("cap2", max_pending=2),
                TenantConfig("open"),
            ),
        )
    )
    fs = [InferenceFuture(_request(i, tenant="cap2")) for i in range(5)]
    outcomes = [q.offer(f) for f in fs]
    assert outcomes == ["admitted", "admitted", "rejected", "rejected", "rejected"]
    # The full lane never blocks another tenant.
    assert q.offer(InferenceFuture(_request(9, tenant="open"))) == "admitted"
    assert q.n_rejected == 3
    assert q.tenant_rejected == {"cap2": 3}
    assert q.tenant_submitted == {"cap2": 5, "open": 1}
    assert all(f.state is RequestState.REJECTED for f in fs[2:])


def test_lane_priority_stamps_the_future_and_request_override_wins():
    q = AdmissionQueue(
        AdmissionConfig(
            tenants=(TenantConfig("crawl", priority="batch"),)
        )
    )
    lane_class = InferenceFuture(_request(0, tenant="crawl"))
    override = InferenceFuture(
        _request(1, tenant="crawl", priority="interactive")
    )
    q.offer(lane_class)
    q.offer(override)
    assert lane_class.priority == "batch"
    assert override.priority == "interactive"


def test_requeue_reenters_at_the_lane_front():
    q = AdmissionQueue(
        AdmissionConfig(
            max_chunk=2,
            tenants=(TenantConfig("a"), TenantConfig("b", priority="batch")),
        )
    )
    for i, tenant in enumerate(["a", "a", "b", "b"]):
        q.offer(InferenceFuture(_request(i, tenant=tenant)))
    batch = q.take(10.0, default_sla_ms=1e9)
    assert [f.rid for f in batch.chunk] == [0, 1]  # interactive lane first
    q.requeue(batch.chunk)
    assert q.n_requeued == 2
    # The lost rows head their own lane again — still ahead of the batch
    # class, in their original order.
    nxt = q.take(20.0, default_sla_ms=1e9)
    assert [f.rid for f in nxt.chunk] == [0, 1]


def test_fifo_mode_counts_tagged_rejects_only():
    # Without lanes, tenant accounting exists only for tagged requests —
    # an untagged run's counters (and metrics) stay empty.
    q = AdmissionQueue(
        AdmissionConfig(max_pending=1, policy="shed")
    )
    q.offer(InferenceFuture(_request(0)))
    assert q.offer(InferenceFuture(_request(1))) == "rejected"
    assert q.offer(InferenceFuture(_request(2, tenant="t"))) == "rejected"
    assert q.n_rejected == 2
    assert q.tenant_rejected == {"t": 1}  # the untagged reject uncounted


def test_tenants_none_never_builds_lanes():
    assert AdmissionQueue(AdmissionConfig())._lanes is None
    assert (
        AdmissionQueue(AdmissionConfig(max_pending=4, policy="shed"))._lanes
        is None
    )


# ---------------------------------------------------------------------------
# Loop-level: single-lane tenancy ≡ FIFO (regression pin) and the flood.
# ---------------------------------------------------------------------------
def _serve_rows(tenants, *, n=12):
    loop = _loop(
        AdmissionConfig(
            max_pending=6, max_chunk=4, policy="shed", tenants=tenants
        ),
        t_sla_ms=1_000.0,
        seed=3,
    )
    fs = [loop.submit(_request(i, arrival_ms=7.0 * i)) for i in range(n)]
    rows, t = [], 0.0
    while loop.backlog:
        t += 50.0
        res = loop.tick(now_ms=t)
        if res is not None:
            rows.extend(
                (c.rid, c.model_index, c.hedged, c.used_remote,
                 c.queue_wait_ms, c.race_resolution, c.tenant, c.priority)
                for c in res.completions
            )
    assert all(f.done() for f in fs)
    return rows


def test_single_default_lane_matches_fifo_rows():
    # An untagged stream through a tenancy queue whose only lane is the
    # default degenerates to the exact FIFO schedule (same rows, same
    # order, same accounting) — the lanes machinery adds no behavior
    # until real tenants diverge.
    fifo = _serve_rows(None)
    lanes = _serve_rows((TenantConfig(DEFAULT_TENANT),))
    assert fifo == lanes


def test_flood_isolation_metrics_and_ordering():
    tenants = (
        TenantConfig("ui", weight=4.0),
        TenantConfig("crawl", weight=1.0, priority="batch", max_pending=8),
    )
    loop = _loop(
        AdmissionConfig(policy="shed", max_chunk=8, tenants=tenants),
        t_sla_ms=10_000.0,
    )
    # A batch flood (40 requests) already queued when the interactive
    # tenant's 8 arrive.
    flood = [
        loop.submit(_request(i, arrival_ms=0.0, tenant="crawl"))
        for i in range(40)
    ]
    ui = [
        loop.submit(_request(100 + i, arrival_ms=1.0, tenant="ui"))
        for i in range(8)
    ]
    order, metrics_last = [], None
    t = 0.0
    while loop.backlog:
        t += 50.0
        res = loop.tick(now_ms=t)
        if res is not None:
            order.extend(c.rid for c in res.completions)
            metrics_last = res.metrics
    # Per-lane capacity absorbed the flood: 32 of 40 crawl requests shed
    # at offer, charged to their lane.
    assert loop.admission.tenant_rejected == {"crawl": 32}
    assert sum(f.rejected() for f in flood) == 32
    assert all(f.done() for f in flood + ui)
    # Strict priority: every ui request was served before any crawl one.
    ui_pos = [order.index(f.rid) for f in ui]
    crawl_pos = [
        order.index(f.rid) for f in flood if not f.rejected()
    ]
    assert max(ui_pos) < min(crawl_pos)
    # Tick metrics grew the tenancy view: per-lane rows + per-class p99.
    assert set(metrics_last.tenant_rows) <= {"ui", "crawl"}
    assert "crawl" in metrics_last.tenant_rows
    assert metrics_last.tenant_rows["crawl"].priority == "batch"
    assert set(metrics_last.priority_p99) <= {"interactive", "batch"}


def test_drain_trace_tenant_rows_and_priority_p99():
    from repro.core.network import FixedCVNetwork
    from repro.serving.loadgen import MixedTenantArrivals, make_trace

    n = 60
    trace = make_trace(
        n, MixedTenantArrivals(interactive_rps=50.0, batch_rps=200.0),
        FixedCVNetwork(10.0, 0.0), seed=8,
    )
    tenants = (
        TenantConfig("interactive", weight=4.0),
        TenantConfig("batch", weight=1.0, priority="batch", max_pending=16),
    )
    loop = _loop(
        AdmissionConfig(policy="shed", max_chunk=8, tenants=tenants),
        t_sla_ms=10_000.0,
    )
    done, metrics = loop.drain_trace(
        trace, 50.0, tokens_for=lambda i: np.zeros(4, np.int32), n_steps=2
    )
    assert len(done) + metrics.n_rejected == n
    assert set(metrics.tenant_rows) == {"interactive", "batch"}
    rows = metrics.tenant_rows
    assert rows["interactive"].priority == "interactive"
    assert rows["batch"].priority == "batch"
    share = sum(r.share for r in rows.values())
    assert share == pytest.approx(1.0)
    assert set(metrics.priority_p99) == {"interactive", "batch"}
    for c in done:
        assert c.tenant in ("interactive", "batch")
        assert c.priority == ("batch" if c.tenant == "batch" else "interactive")


def test_untenanted_metrics_stay_unchanged():
    loop = _loop(AdmissionConfig(max_pending=8, max_chunk=8, policy="shed"))
    for i in range(4):
        loop.submit(_request(i))
    res = loop.tick(now_ms=50.0)
    assert res.metrics.tenant_rows == {}
    assert res.metrics.priority_p99 == {}


# ---------------------------------------------------------------------------
# Streaming: the no-token-channel fallback (stubs have no decode stream).
# ---------------------------------------------------------------------------
def test_stream_fallback_bursts_completion_tokens():
    loop = _loop(AdmissionConfig())
    f = loop.submit(_request(0))
    chunks = list(f.stream())  # drives the loop, then bursts
    c = f.result(timeout=0)
    assert f.done() and c is not None
    assert [ch.index for ch in chunks] == list(range(len(chunks)))
    assert [ch.token for ch in chunks] == list(
        np.asarray(c.tokens).ravel()
    )
    assert len({ch.wall_ms for ch in chunks}) == 1  # one burst stamp
    assert f.chunks == chunks


def test_stream_on_resolved_future_replays_chunks():
    loop = _loop(AdmissionConfig())
    f = loop.submit(_request(0))
    f.result()  # resolve first
    first = list(f.stream())
    again = list(f.stream())  # replay is stable, no double-push
    assert first == again and len(first) == 2
