"""Training substrate: optimizer, schedules, microbatching, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.distributed import compression
from repro.training import (
    DataConfig,
    OptimizerConfig,
    TrainConfig,
    init_train_state,
    lr_at,
    make_pipeline,
    make_train_step,
)

CFG = reduced("llama3-8b")
OPT = OptimizerConfig(learning_rate=1e-3, warmup_steps=5, total_steps=100)


def _batch(step=0, bs=4, seq=64):
    pipe = make_pipeline(DataConfig(batch_size=bs, seq_len=seq), CFG)
    return {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}


def test_loss_decreases():
    state = init_train_state(CFG, jax.random.key(0))
    step_fn = make_train_step(CFG, OPT)
    losses = []
    for s in range(10):
        state, m = step_fn(state, _batch(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    assert np.isfinite(losses).all()


def test_lr_schedule():
    assert float(lr_at(OPT, 0)) == 0.0
    assert float(lr_at(OPT, 5)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr_at(OPT, 100)) == pytest.approx(1e-4, rel=1e-2)  # min ratio
    # monotone decay after warmup
    mid = float(lr_at(OPT, 50))
    assert 1e-4 < mid < 1e-3


def test_grad_clipping_bounds_update():
    opt = OptimizerConfig(learning_rate=1e-3, clip_norm=1e-6, warmup_steps=0,
                          total_steps=10, weight_decay=0.0)
    state = init_train_state(CFG, jax.random.key(0))
    step_fn = make_train_step(CFG, opt)
    before = jax.tree.leaves(state["params"])[0].copy()
    state, m = step_fn(state, _batch())
    after = jax.tree.leaves(state["params"])[0]
    # With a tiny clip norm the parameter change is tiny.
    assert float(jnp.abs(after - before).max()) < 1e-3


def test_microbatch_equivalence():
    b = _batch()
    s1, _ = make_train_step(CFG, OPT)(init_train_state(CFG, jax.random.key(0)), b)
    s2, _ = make_train_step(CFG, OPT, TrainConfig(microbatches=2))(
        init_train_state(CFG, jax.random.key(0)), b
    )
    for a, c in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32), atol=2e-5
        )


def test_compression_roundtrip_error_bound():
    g = jax.random.normal(jax.random.key(0), (256, 64)) * 3.0
    q, s = compression.quantize_int8(g)
    back = compression.dequantize_int8(q, s)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With EF, repeated compression of a constant gradient converges: the
    accumulated applied updates approach the true sum."""
    g = {"w": jax.random.normal(jax.random.key(1), (128,)) * 0.01}
    e = compression.init_error_feedback(g)
    applied = jnp.zeros_like(g["w"])
    for t in range(50):
        ghat, e = compression.quantize_dequantize(g, e)
        applied = applied + ghat["w"]
    true = g["w"] * 50
    rel = float(jnp.abs(applied - true).max() / (jnp.abs(true).max() + 1e-9))
    assert rel < 0.05


def test_compressed_psum_single_axis():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("d",))
    x = jax.random.normal(jax.random.key(2), (64,))

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    f = shard_map(
        lambda v: compression.compressed_psum(v, "d"),
        mesh=mesh, in_specs=P(), out_specs=P(),
    )
    out = f(x)
    np.testing.assert_allclose(out, x, atol=float(jnp.abs(x).max()) / 100)


def test_grad_compression_training_still_converges():
    state = init_train_state(CFG, jax.random.key(0), TrainConfig(grad_compression=True))
    step_fn = make_train_step(CFG, OPT, TrainConfig(grad_compression=True))
    losses = []
    for s in range(10):
        state, m = step_fn(state, _batch(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------
def test_data_deterministic_random_access():
    pipe = make_pipeline(DataConfig(batch_size=4, seq_len=32, seed=7), CFG)
    a = pipe.batch_at(123)
    b = pipe.batch_at(123)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch_at(124)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_tokens():
    pipe = make_pipeline(DataConfig(batch_size=2, seq_len=16), CFG)
    b = pipe.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_vocab_bounds():
    pipe = make_pipeline(DataConfig(batch_size=8, seq_len=64), CFG)
    b = pipe.batch_at(5)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab_size


def test_data_file_backed(tmp_path):
    import numpy as np

    toks = np.arange(10_000, dtype=np.int32) % 100
    path = tmp_path / "tokens.bin"
    toks.tofile(path)
    pipe = make_pipeline(
        DataConfig(batch_size=2, seq_len=32, path=str(path)), CFG
    )
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_frontends():
    acfg = reduced("hubert-xlarge")
    pipe = make_pipeline(DataConfig(batch_size=2, seq_len=16), acfg)
    b = pipe.batch_at(0)
    assert b["frames"].shape == (2, 16, acfg.frontend_dim)
    vcfg = reduced("paligemma-3b")
    pipe = make_pipeline(DataConfig(batch_size=2, seq_len=16), vcfg)
    b = pipe.batch_at(0)
    assert b["patches"].shape == (2, vcfg.num_prefix_tokens, vcfg.frontend_dim)
