"""AdmissionController law unit tests: hysteresis, AIMD clamps, margin
escalation/decay, and the unbounded/no-op edges.

The end-to-end behavior (does the law actually track drift?) lives in
``tests/test_drift_gauntlet.py``; this file pins the law's mechanics by
driving :meth:`observe`/:meth:`apply` directly with synthetic ticks.
"""
import dataclasses

import numpy as np
import pytest

from repro.serving.admission import AdmissionConfig, AdmissionQueue
from repro.serving.controller import AdmissionController, ControllerConfig

from loop_stubs import stub_scheduler


@dataclasses.dataclass
class _FakeCompletion:
    queue_wait_ms: float


@dataclasses.dataclass
class _FakeStats:
    n_shed: int = 0


@dataclasses.dataclass
class _FakeTick:
    completions: list
    stats: _FakeStats


def _tick(waits=(), n_shed=0):
    return _FakeTick(
        [_FakeCompletion(w) for w in waits], _FakeStats(n_shed)
    )


def _queue(max_pending=16, headroom=0.0, policy="shed"):
    return AdmissionQueue(
        AdmissionConfig(
            max_pending=max_pending,
            max_chunk=8,
            policy=policy,
            shed_headroom_ms=headroom,
        )
    )


def _controller(**kw):
    return AdmissionController(ControllerConfig(**kw))


SCHED = stub_scheduler(t_sla_ms=1_000.0)  # read-only signal source


def _observe(c, tick, *, backlog=0, now_ms=0.0):
    c.observe(tick, scheduler=SCHED, now_ms=now_ms, backlog=backlog)


# ---------------------------------------------------------------------------
# Config validation.
# ---------------------------------------------------------------------------
def test_controller_config_validation():
    for bad in (
        dict(target_wait_frac=0.0),
        dict(target_wait_frac=1.5),
        dict(low_water=0.9, high_water=0.5),
        dict(low_water=-0.1),
        dict(wait_alpha=0.0),
        dict(hysteresis=0),
        dict(increase_step=0),
        dict(decrease_factor=1.0),
        dict(decrease_factor=0.0),
        dict(min_pending=0),
        dict(min_pending=10, max_pending=5),
        dict(headroom_decay=1.0),
        dict(headroom_step_frac=-0.1),
    ):
        with pytest.raises(ValueError):
            ControllerConfig(**bad)
    ControllerConfig()  # defaults are valid


# ---------------------------------------------------------------------------
# Hysteresis: the law acts only on *consecutive* evidence.
# ---------------------------------------------------------------------------
def test_single_overload_tick_does_not_retune():
    c = _controller(hysteresis=2)
    q = _queue()
    _observe(c, _tick(n_shed=3))  # one overload tick...
    assert not c.apply(q)  # ...is not a streak
    assert q.cfg.max_pending == 16


def test_neutral_tick_resets_the_streak():
    c = _controller(hysteresis=2)
    q = _queue()
    _observe(c, _tick(n_shed=3))
    # Neutral: no shed, wait between the watermarks, so neither streak
    # advances (wait EWMA is now ~150 with target 200, low water 100).
    _observe(c, _tick(waits=[150.0]))
    _observe(c, _tick(n_shed=3))
    assert not c.apply(q)  # the lone spikes never added up
    assert q.cfg.max_pending == 16


def test_overload_streak_halves_capacity_and_tightens_margin():
    c = _controller(hysteresis=2)
    q = _queue(max_pending=16, headroom=0.0)
    for _ in range(2):
        _observe(c, _tick(n_shed=3))
    assert c.apply(q)
    assert q.cfg.max_pending == 8  # multiplicative decrease
    assert q.cfg.shed_headroom_ms > 0.0  # margin tightened
    assert c.n_retunes == 1 and len(c.log) == 1


def test_underload_streak_adds_capacity_and_decays_margin():
    c = _controller(hysteresis=2, increase_step=4, headroom_decay=0.5)
    q = _queue(max_pending=16, headroom=100.0)
    for _ in range(2):
        _observe(c, _tick(waits=[1.0]))  # calm: tiny waits, no shed
    assert c.apply(q)
    assert q.cfg.max_pending == 20  # additive increase
    assert q.cfg.shed_headroom_ms == 50.0  # multiplicative decay


def test_backlog_blocks_the_underload_verdict():
    c = _controller(hysteresis=2)
    q = _queue()
    for _ in range(2):
        _observe(c, _tick(waits=[1.0]), backlog=5)  # calm but backlogged
    assert not c.apply(q)  # a backlogged queue is not underloaded
    assert q.cfg.max_pending == 16


# ---------------------------------------------------------------------------
# Clamps and escalation.
# ---------------------------------------------------------------------------
def test_capacity_clamps_to_min_and_max_pending():
    c = _controller(hysteresis=1, min_pending=4, max_pending=24)
    q = _queue(max_pending=5)
    _observe(c, _tick(n_shed=1))
    assert c.apply(q)
    assert q.cfg.max_pending == 4  # floor, not 2
    for _ in range(20):
        _observe(c, _tick(waits=[1.0]))
        c.apply(q)
    assert q.cfg.max_pending == 24  # ceiling holds under sustained calm
    assert q.cfg.shed_headroom_ms < 1e-3  # margin decayed away
    for _ in range(20):  # ...and snaps to exactly zero, eventually
        _observe(c, _tick(waits=[1.0]))
        c.apply(q)
    assert q.cfg.shed_headroom_ms == 0.0


def test_margin_clamps_to_the_sla_fraction():
    c = _controller(hysteresis=1, max_headroom_frac=0.8)
    q = _queue()
    for _ in range(10):
        _observe(c, _tick(waits=[5_000.0], n_shed=2))
        c.apply(q)
    assert q.cfg.shed_headroom_ms == pytest.approx(0.8 * 1_000.0)


def test_persistent_overload_escalates_the_margin_to_its_clamp():
    # First tighten takes a proportional bite; overload that survives a
    # tighten jumps straight to the clamp (bounded escalation).
    c = _controller(hysteresis=1, headroom_step_frac=0.5)
    q = _queue(max_pending=64)
    _observe(c, _tick(n_shed=1))
    assert c.apply(q)
    first = q.cfg.shed_headroom_ms
    assert 0.0 < first < 0.8 * 1_000.0
    _observe(c, _tick(n_shed=1))
    assert c.apply(q)
    assert q.cfg.shed_headroom_ms == pytest.approx(0.8 * 1_000.0)


def test_retunes_are_logged_with_the_tick_clock():
    c = _controller(hysteresis=1)
    q = _queue()
    _observe(c, _tick(n_shed=1), now_ms=1_234.0)
    assert c.apply(q)
    ((t, mp, headroom),) = c.log
    assert t == 1_234.0 and mp == q.cfg.max_pending
    assert headroom == q.cfg.shed_headroom_ms


# ---------------------------------------------------------------------------
# No-op edges.
# ---------------------------------------------------------------------------
def test_apply_is_a_noop_on_unbounded_queues():
    c = _controller(hysteresis=1)
    q = AdmissionQueue(AdmissionConfig())  # unbounded compat default
    _observe(c, _tick(n_shed=0, waits=[10_000.0]))
    assert not c.apply(q)
    assert q.cfg.max_pending is None and c.n_retunes == 0


def test_apply_without_evidence_never_touches_the_queue():
    c = _controller()
    q = _queue()
    before = q.cfg
    assert not c.apply(q)
    assert q.cfg is before  # not even an identity-preserving swap


def test_service_estimate_tracks_the_live_signals():
    c = _controller()
    _observe(c, _tick(waits=[10.0]))
    # With no backend attached the estimate falls back to the
    # scheduler's fastest remote mu (stub-a: 30ms).
    assert c.service_est_ms == pytest.approx(float(np.min(SCHED.mu)))

    class _Backend:
        ewma_wall_ms = 250.0

    c2 = _controller()
    c2.observe(
        _tick(waits=[10.0]), scheduler=SCHED, backend=_Backend(), now_ms=0.0
    )
    assert c2.service_est_ms == 250.0  # the slow box lifts the estimate
