"""Optional-``hypothesis`` shim for the property-based tests.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When
it is installed, this module re-exports the real ``given`` / ``settings``
decorators and the ``st`` strategies namespace.  When it is missing, the
decorators turn each property test into a ``pytest.importorskip``-guarded
skip — so tier-1 collection succeeds and every non-property test in the
importing module still runs.

Usage in a test module::

    from hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import functools

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    HealthCheck = None

    class _StrategyStub:
        """Accepts any strategy-building call chain and returns itself."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def skipper(*args, **kwargs):
                pytest.importorskip("hypothesis")

            # functools.wraps sets __wrapped__, which pytest's signature
            # inspection follows — it would then treat the original
            # hypothesis-supplied arguments as missing fixtures.
            del skipper.__wrapped__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
