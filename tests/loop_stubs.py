"""Sleep-based stub execution tiers for deterministic ServingLoop tests.

``time.sleep`` releases the GIL, so two stub batches dispatched async
genuinely overlap — overlap/poll semantics become deterministic instead of
depending on XLA thread scheduling, and the tests skip all compile cost.
"""
import time

import numpy as np

from repro.core.registry import ModelProfile, ModelRegistry
from repro.serving.backend import ExecutionBackend, Variant
from repro.serving.cluster import ClusterBackend
from repro.serving.health import BreakerConfig
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig
from repro.serving.transport import ProcessTransportBackend

STUB_NAMES = ["stub-a", "stub-b"]


class StubRemoteBackend(ExecutionBackend):
    """Remote tier stub: generation is a fixed-duration sleep."""

    def __init__(self, delay_s: float = 0.05):
        super().__init__()
        self.delay_s = delay_s
        self.batch_rows = []  # rows of each executed (timed) batch
        self.batch_names = []  # variant of each executed batch

    def register(self, v):
        self.variants[v.name] = v

    def generate(self, name, tokens, n_steps):
        t0 = time.perf_counter()
        time.sleep(self.delay_s)
        self.batch_rows.append(int(np.shape(tokens)[0]))
        self.batch_names.append(name)
        out = np.zeros((np.shape(tokens)[0], n_steps), dtype=np.int32)
        return out, (time.perf_counter() - t0) * 1e3

    def run_batch(self, name, batch, n_steps):
        # No XLA: skip the warm-up so every stub execution is one sleep.
        return self.generate(name, batch, n_steps)


class StubHedgeBackend(StubRemoteBackend):
    """On-device tier stub with the OnDeviceBackend hedge surface."""

    hedge_name = "stub-hedge"

    def hedge(self, batch, n_steps):
        return self.run_batch(self.hedge_name, batch, n_steps)

    def submit_hedge(self, batch, n_steps, *, sync=False):
        return self.submit_batch(self.hedge_name, batch, n_steps, sync=sync)


def stub_cluster(
    n_replicas: int,
    delay_s: float = 0.0,
    *,
    router: str = "round_robin",
    slices=None,
    seed: int = 0,
) -> ClusterBackend:
    """A ClusterBackend of sleep-stub replicas hosting the stub zoo.

    Registration goes through the cluster (exercising slice placement);
    each replica's ``batch_rows`` log identifies the batches it ran.
    """
    cluster = ClusterBackend(
        [StubRemoteBackend(delay_s) for _ in range(n_replicas)],
        router=router, slices=slices, seed=seed,
    )
    for name, quality in zip(STUB_NAMES, (40.0, 80.0)):
        if slices is None or any(name in s for s in slices):
            cluster.register(Variant(name, None, None, quality))
    return cluster


def stub_fault_cluster(
    n_replicas: int,
    delay_s: float = 0.0,
    *,
    router: str = "round_robin",
    seed: int = 0,
    breaker: BreakerConfig = None,
) -> ClusterBackend:
    """Like :func:`stub_cluster`, but every replica rides an inline
    :class:`ProcessTransportBackend` (kill / inject_failures fault surface)
    and the pool carries circuit breakers — the harness for membership and
    fault-tolerance tests, deterministic under ``dispatch="sync"``.
    """
    cluster = ClusterBackend(
        [
            ProcessTransportBackend(
                lambda: StubRemoteBackend(delay_s), mode="inline"
            )
            for _ in range(n_replicas)
        ],
        router=router, seed=seed,
        breaker=breaker if breaker is not None else BreakerConfig(),
    )
    for name, quality in zip(STUB_NAMES, (40.0, 80.0)):
        cluster.register(Variant(name, None, None, quality))
    return cluster


def stub_registry() -> ModelRegistry:
    return ModelRegistry(
        [
            ModelProfile(STUB_NAMES[0], 40.0, 30.0, 2.0),
            ModelProfile(STUB_NAMES[1], 80.0, 60.0, 4.0),
        ]
    )


def stub_scheduler(t_sla_ms: float = 1_000.0, seed: int = 0, **kw):
    reg = stub_registry()
    ondevice = ModelProfile("stub-hedge", 35.0, 20.0, 2.0)
    return MDInferenceScheduler(
        reg, ondevice, SchedulerConfig(t_sla_ms=t_sla_ms, seed=seed, **kw)
    )
