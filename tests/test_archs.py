"""Per-architecture smoke tests: reduced config, one train + serve step.

The FULL configs are only exercised via the dry-run (ShapeDtypeStruct, no
allocation); these reduced configs share the family's block structure.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer as T


def make_batch(cfg, B=2, S=64, seed=0, labels=True):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        b = {
            "frames": jnp.asarray(
                rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32
            )
        }
        lab_s = S
    elif cfg.frontend == "vision":
        P = cfg.num_prefix_tokens
        b = {
            "patches": jnp.asarray(
                rng.normal(size=(B, P, cfg.frontend_dim)), jnp.float32
            ),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            ),
        }
        lab_s = S
    else:
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
        lab_s = S
    if labels:
        b["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, lab_s)), jnp.int32
        )
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced(arch)
    params = T.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    grads = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all())
    # Output (hidden) shapes.
    x, _, _ = T.forward_hidden(cfg, params, batch)
    exp_s = 64 + (cfg.num_prefix_tokens if cfg.frontend == "vision" else 0)
    assert x.shape == (2, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(x).any())


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if "hubert" not in a])
def test_smoke_serve_step(arch):
    cfg = reduced(arch)
    params = T.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, labels=False)
    B, S = 2, 64
    cache, logits = T.prefill(cfg, params, batch, max_len=128)
    assert logits.shape == (B, cfg.vocab_size)
    pos0 = S + (cfg.num_prefix_tokens if cfg.frontend == "vision" else 0)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(3):
        pos = jnp.full((B,), pos0 + step, jnp.int32)
        logits, cache = T.decode_step(cfg, params, cache, tok, pos)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims(arch):
    """The full configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    }[arch]
    got = (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected


def test_param_counts_plausible():
    # Dense params ~ headline sizes (embedding included); MoE totals exceed
    # active.  Loose sanity bounds, not exact matches.
    import repro.configs.archs as A

    c = A.get_config("llama3-8b")
    assert 7.5e9 < c.param_count() < 9e9
    c = A.get_config("qwen3-14b")
    assert 12e9 < c.param_count() < 16.5e9
    moe = A.get_config("llama4-scout-17b-a16e")
    assert moe.param_count() > 5 * moe.param_count(active_only=True) > 0
    x = A.get_config("xlstm-350m")
    assert 2.0e8 < x.param_count() < 6e8


def test_param_axes_match_params():
    for arch in ARCH_IDS:
        cfg = reduced(arch)
        params = T.init_params(cfg, jax.random.key(0))
        axes = T.param_axes(cfg)
        pt = jax.tree.structure(params)
        def is_axes(x):
            return (isinstance(x, tuple) and len(x) > 0 and all(
                isinstance(e, (str, type(None))) for e in x))
        at = jax.tree.structure(axes, is_leaf=is_axes)
        assert pt == at, arch
        # Every axes tuple matches its array rank.
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes, is_leaf=is_axes)
        for p, a in zip(flat_p, flat_a):
            assert len(a) == p.ndim, (arch, p.shape, a)
