"""Fault tolerance end-to-end: failure injection + bit-identical resume."""
import io
import re
from contextlib import redirect_stdout

import pytest

from repro.launch.train import main as train_main

ARGS = [
    "--arch", "gemma-2b", "--d-model", "64", "--layers", "2",
    "--steps", "12", "--batch", "2", "--seq", "32", "--ckpt-every", "4",
    "--log-every", "1",
]


def run_driver(extra, capture=True):
    buf = io.StringIO()
    code = 0
    try:
        with redirect_stdout(buf):
            train_main(ARGS + extra)
    except SystemExit as e:
        code = e.code or 0
    return code, buf.getvalue()


def losses_from(log):
    return {
        int(m.group(1)): float(m.group(2))
        for m in re.finditer(r"step\s+(\d+)\s+loss\s+([\d.]+)", log)
    }


def test_failure_injection_and_bit_identical_resume(tmp_path):
    ck = str(tmp_path / "ck")
    # Uninterrupted reference run.
    code, ref_log = run_driver(["--ckpt-dir", str(tmp_path / "ref")])
    assert code == 0
    ref = losses_from(ref_log)

    # Crash at step 8 (after the step-8 checkpoint)...
    code, log1 = run_driver(["--ckpt-dir", ck, "--inject-failure", "8"])
    assert code == 42  # injected crash
    # ...then relaunch: must resume from step 8 and match the reference
    # losses exactly (deterministic data pipeline + exact state restore).
    code, log2 = run_driver(["--ckpt-dir", ck])
    assert code == 0
    assert "resumed from checkpoint at step 8" in log2
    resumed = losses_from(log2)
    for step in range(8, 12):
        assert resumed[step] == pytest.approx(ref[step], abs=1e-6), step


def test_train_reduces_loss():
    code, log = run_driver([])
    assert code == 0
    losses = losses_from(log)
    assert losses[11] < losses[0]
