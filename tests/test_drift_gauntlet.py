"""Drift gauntlet: closed-loop admission control vs static-tuned oracles.

Four named drift scenarios — diurnal arrival swing, a 30x service-time
spike, a flapping replica, and an LTE<->university network swap — each
driven through the real serving stack (``ServingLoop.drain_trace`` with a
service-coupled clock) twice: once with a statically *mistuned*
:class:`AdmissionConfig` plus an :class:`AdmissionController` closing the
loop, and once per candidate in a small grid of static configs, the best
of which is the scenario's **static-tuned oracle**.  The gauntlet's
acceptance bar (ROADMAP item 4): the adaptive run holds interactive p99
within 1.25x of the oracle in at least 3 of the 4 scenarios, without
giving up goodput.

Everything here is deterministic: execution is a :class:`FixedWallBackend`
that *reports* configured wall times instead of sleeping (so latencies are
exact functions of the seed), arrivals/network are seeded draws, and
``dispatch="sync"`` serializes collection.  Two runs of any scenario are
byte-identical — the seeded-twin test pins that, controller on and off.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.network import SwitchedNetwork, lte_trace, university_trace
from repro.serving.admission import AdmissionConfig
from repro.serving.backend import ExecutionBackend, Variant
from repro.serving.cluster import ClusterBackend, ReplicaSpec
from repro.serving.controller import AdmissionController, ControllerConfig
from repro.serving.loadgen import (
    DiurnalArrivals,
    PoissonArrivals,
    SpikeArrivals,
    make_trace,
)
from repro.serving.loop import ServingLoop

from loop_stubs import STUB_NAMES, stub_scheduler

SLA_MS = 1_000.0
WINDOW_MS = 50.0
SERVICE_MS_PER_ROW = 6.0  # per-row service cost: ~166 req/s of capacity
WALLS = {"stub-a": 30.0, "stub-b": 60.0}  # reported (not slept) exec walls


class FixedWallBackend(ExecutionBackend):
    """Execution stub that *reports* a configured wall time, no sleep.

    The gauntlet needs hundreds of ticks per scenario and exact
    reproducibility; real ``time.sleep`` stubs give neither.  ``scale``
    is the drift knob — the spike scenario multiplies it mid-run so the
    backend's reported walls (and every EWMA fed from them) genuinely
    drift.
    """

    def __init__(self, scale: float = 1.0):
        super().__init__()
        self.scale = float(scale)

    def register(self, v):
        self.variants[v.name] = v

    def generate(self, name, tokens, n_steps):
        out = np.zeros((np.shape(tokens)[0], n_steps), dtype=np.int32)
        return out, float(WALLS[name]) * self.scale

    def run_batch(self, name, batch, n_steps):
        return self.generate(name, batch, n_steps)


def _register_zoo(backend) -> None:
    for name, quality in zip(STUB_NAMES, (40.0, 80.0)):
        backend.register(Variant(name, None, None, quality))


@dataclasses.dataclass
class ScenarioRun:
    metrics: object  # RequestMetrics
    completions: list
    controller: object  # AdmissionController | None
    loop: ServingLoop

    @property
    def p99(self) -> float:
        return float(self.metrics.p99_latency_ms)

    @property
    def goodput(self) -> float:
        return float(self.metrics.goodput)


class Scenario:
    """One named drift scenario: a seeded trace + backend + service model.

    ``run(admission, controller)`` drives a fresh loop over a fresh
    backend; the static grid and the adaptive run therefore never share
    state.  ``static_grid`` is the oracle's search space — a handful of
    plausible hand-tunings; ``adaptive_start`` is the deliberately
    mistuned config the controller starts from.
    """

    name = "base"
    n = 400
    seed = 0
    static_grid = (8, 16, 32, 64)
    adaptive_start = 64
    controller_cfg = ControllerConfig(
        target_wait_frac=0.1, wait_alpha=0.7, max_pending=64
    )

    def make_trace(self):
        raise NotImplementedError

    def make_backend(self):
        backend = FixedWallBackend()
        _register_zoo(backend)
        return backend

    def service_model(self, backend, trace):
        return lambda res: SERVICE_MS_PER_ROW * res.stats.max_replica_rows

    def on_tick(self, backend, trace):
        return None

    def static(self, max_pending: int) -> AdmissionConfig:
        return AdmissionConfig(
            max_pending=max_pending, max_chunk=16, policy="shed"
        )

    def run(self, admission, controller=None) -> ScenarioRun:
        trace = self.make_trace()
        backend = self.make_backend()
        scheduler = stub_scheduler(t_sla_ms=SLA_MS, seed=self.seed)
        loop = ServingLoop(
            scheduler,
            backend,
            None,
            dispatch="sync",
            admission=admission,
            controller=controller,
        )
        done, metrics = loop.drain_trace(
            trace,
            WINDOW_MS,
            tokens_for=lambda i: np.zeros(4, np.int32),
            n_steps=2,
            service_model=self.service_model(backend, trace),
            on_tick=self.on_tick(backend, trace),
        )
        assert metrics is not None
        return ScenarioRun(metrics, done, controller, loop)

    # -- the two gauntlet arms ------------------------------------------------
    def run_adaptive(self) -> ScenarioRun:
        return self.run(
            self.static(self.adaptive_start),
            AdmissionController(self.controller_cfg),
        )

    def run_oracle(self) -> ScenarioRun:
        """Best static tuning over the grid: lowest p99 among candidates
        whose goodput is within 10% of the grid's best goodput (a static
        config that sheds almost everything gets a great p99 for free —
        the oracle has to actually serve)."""
        runs = [self.run(self.static(mp)) for mp in self.static_grid]
        best_goodput = max(r.goodput for r in runs)
        eligible = [r for r in runs if r.goodput >= 0.9 * best_goodput]
        return min(eligible, key=lambda r: r.p99)


class DiurnalScenario(Scenario):
    """Arrival-rate swing: trough -> 3.6x-capacity peak -> trough."""

    name = "diurnal"
    n = 1_200

    def make_trace(self):
        return make_trace(
            self.n,
            DiurnalArrivals(trough_rps=20.0, peak_rps=600.0),
            university_trace(),
            seed=5 + self.seed,
        )


class SpikeScenario(Scenario):
    """30x service-time spike over the middle fifth of the run.

    The backend's reported walls scale with the spike too, so the
    controller's service estimate (replica wall EWMAs / scheduler mu)
    sees the drift the moment it lands.
    """

    name = "spike"
    n = 800
    spike = SpikeArrivals(
        rate_rps=100.0, spike_factor=30.0, spike_start=0.4, spike_stop=0.6
    )

    def make_trace(self):
        trace = make_trace(
            self.n, self.spike, university_trace(), seed=7 + self.seed
        )
        self._horizon_ms = float(trace.arrival_ms[-1])
        return trace

    def make_backend(self):
        backend = super().make_backend()
        self._factor = 1.0
        return backend

    def on_tick(self, backend, trace):
        def tick(t_ms, result):
            # Factor for the *next* tick: one window of detection lag,
            # deterministic either way.
            self._factor = self.spike.service_factor(t_ms, self._horizon_ms)
            backend.scale = self._factor

        return tick

    def service_model(self, backend, trace):
        return lambda res: (
            SERVICE_MS_PER_ROW * self._factor * res.stats.max_replica_rows
        )


class FlapScenario(Scenario):
    """A heterogeneous 2-replica pool whose fast replica flaps.

    Replica 0 is the fast box (weight 2), replica 1 a half-speed box
    (``service_scale=2``, weight 1).  Mid-run the fast replica drains —
    pool capacity drops 3x — then rejoins.  The service model charges the
    real heterogeneous makespan: the busiest replica's rows times its
    service scale.
    """

    name = "flap"
    n = 800
    scales = (1.0, 2.0)

    def make_trace(self):
        trace = make_trace(
            self.n, PoissonArrivals(140.0), university_trace(),
            seed=11 + self.seed,
        )
        self._horizon_ms = float(trace.arrival_ms[-1])
        return trace

    def make_backend(self):
        cluster = ClusterBackend(
            [FixedWallBackend(scale=s) for s in self.scales],
            router="least_inflight",
            specs=[
                ReplicaSpec(weight=2.0),
                ReplicaSpec(weight=1.0, service_scale=2.0),
            ],
            seed=0,
        )
        _register_zoo(cluster)
        return cluster

    def on_tick(self, backend, trace):
        def tick(t_ms, result):
            frac = t_ms / self._horizon_ms
            drained = backend.pool.replicas[0].health.draining
            if 0.3 <= frac < 0.6:
                if not drained:
                    backend.drain(0)
            elif drained:
                backend.rejoin(0)

        return tick

    def service_model(self, backend, trace):
        def service(res):
            rows = res.stats.replica_rows
            if not rows:
                return SERVICE_MS_PER_ROW * res.stats.n_requests
            return max(
                SERVICE_MS_PER_ROW * r * self.scales[rid]
                for rid, r in rows.items()
            )

        return service


class NetworkSwapScenario(Scenario):
    """University -> LTE mid-run: the network under the client drifts.

    Load sits just above service capacity, so the static queue matters;
    after the swap the per-request network leg jumps ~10x (and grows a 2%
    multi-second tail), eating the latency budget the queue wait used to
    fit in.
    """

    name = "network_swap"
    n = 800

    def make_trace(self):
        return make_trace(
            self.n,
            PoissonArrivals(180.0),
            SwitchedNetwork(university_trace(), lte_trace(), 0.5),
            seed=13 + self.seed,
        )


SCENARIOS = [
    DiurnalScenario(),
    SpikeScenario(),
    FlapScenario(),
    NetworkSwapScenario(),
]
RATIO_BAR = 1.25  # adaptive p99 <= 1.25x oracle, in >= 3 of 4 scenarios


def _gauntlet():
    out = {}
    for sc in SCENARIOS:
        adaptive = sc.run_adaptive()
        oracle = sc.run_oracle()
        out[sc.name] = (adaptive, oracle)
    return out


@pytest.fixture(scope="module")
def gauntlet():
    return _gauntlet()


# ---------------------------------------------------------------------------
# The acceptance bar (ROADMAP item 4).
# ---------------------------------------------------------------------------
def test_adaptive_holds_p99_near_oracle_in_three_of_four(gauntlet):
    ratios = {
        name: adaptive.p99 / oracle.p99
        for name, (adaptive, oracle) in gauntlet.items()
    }
    held = [name for name, r in ratios.items() if r <= RATIO_BAR]
    assert len(held) >= 3, f"controller held only {held} (ratios {ratios})"


@pytest.mark.parametrize("scenario", SCENARIOS, ids=lambda s: s.name)
def test_scenario_sanity(gauntlet, scenario):
    adaptive, oracle = gauntlet[scenario.name]
    # Per-scenario generous bound: even the scenario the combined bar
    # tolerates losing must stay within 2.5x of its oracle.
    assert adaptive.p99 <= 2.5 * oracle.p99
    # The controller cannot buy latency by refusing to serve.  (It *is*
    # allowed to trade some goodput for a large p99 win — the diurnal
    # scenario's adaptive arm sheds harder at the peak than the
    # goodput-constrained oracle and lands a ~2.5x better tail.)
    assert adaptive.goodput >= 0.7 * oracle.goodput
    # The law actually engaged: the mistuned start was retuned.
    assert adaptive.controller.n_retunes > 0
    assert adaptive.controller.log  # and left evidence
    # Conservation across the adaptive run.
    m = adaptive.metrics
    assert m.n_requests + m.n_rejected == scenario.n


# ---------------------------------------------------------------------------
# Stress soak: the combined bar is not a single-seed fluke.  Reruns the
# whole gauntlet under fresh arrival/network seeds (scenario classes keep
# their per-scenario seeds as offsets).
# ---------------------------------------------------------------------------
@pytest.mark.stress
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_gauntlet_holds_across_seeds(seed):
    held = 0
    for proto in SCENARIOS:
        sc = type(proto)()
        sc.seed = seed
        adaptive = sc.run_adaptive()
        oracle = sc.run_oracle()
        if adaptive.p99 <= RATIO_BAR * oracle.p99:
            held += 1
        assert adaptive.p99 <= 2.5 * oracle.p99
        assert adaptive.goodput >= 0.7 * oracle.goodput
    assert held >= 3


# ---------------------------------------------------------------------------
# Seeded-twin determinism: two fresh runs are identical, controller on/off.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("with_controller", [False, True], ids=["static", "adaptive"])
def test_seeded_twin_runs_are_identical(with_controller):
    sc = DiurnalScenario()

    def once():
        if with_controller:
            return sc.run_adaptive()
        return sc.run(sc.static(16))

    a, b = once(), once()
    assert a.metrics == b.metrics
    assert len(a.completions) == len(b.completions)
    for ca, cb in zip(a.completions, b.completions):
        assert ca.rid == cb.rid
        assert ca.model_name == cb.model_name
        assert ca.latency_ms == cb.latency_ms
        assert ca.queue_wait_ms == cb.queue_wait_ms
        assert ca.race_resolution == cb.race_resolution
    if with_controller:
        assert a.controller.log == b.controller.log


# ---------------------------------------------------------------------------
# controller=None compatibility: attaching a controller that never fires
# is invisible — same completions, same metrics (the observe/apply seam
# has no side effects on the serving path).
# ---------------------------------------------------------------------------
def test_inert_controller_is_invisible():
    sc = NetworkSwapScenario()
    silent = AdmissionController(
        ControllerConfig(hysteresis=10_000)  # never completes a streak
    )
    plain = sc.run(sc.static(16), None)
    inert = sc.run(sc.static(16), silent)
    assert silent.n_retunes == 0 and silent.log == []
    assert silent.n_ticks > 0  # it watched every tick...
    assert plain.metrics == inert.metrics  # ...and changed nothing
    assert [c.rid for c in plain.completions] == [
        c.rid for c in inert.completions
    ]
    assert [c.latency_ms for c in plain.completions] == [
        c.latency_ms for c in inert.completions
    ]
