"""Integration tests: the simulator reproduces the paper's headline results."""
import numpy as np
import pytest

from repro.configs.mdinference_zoo import ablation_zoo, paper_zoo
from repro.core import (
    FixedCVNetwork,
    NoisyEstimator,
    residential_trace,
    university_trace,
)
from repro.core.simulator import SimConfig, run_simulation

ZOO = paper_zoo()
NET = FixedCVNetwork(100.0, 0.5)  # the paper's 100ms +- 50ms default


def run(alg, sla, *, net=NET, dup=False, seed=0, zoo=ZOO, n=8000, **kw):
    return run_simulation(
        SimConfig(
            registry=zoo,
            algorithm=alg,
            t_sla_ms=sla,
            n_requests=n,
            network=net,
            duplication=dup,
            seed=seed,
            **kw,
        )
    )


# -- Fig 3: MDInference vs static greedy ------------------------------------
def test_fig3_greedy_violates_at_low_sla():
    g = run("static_greedy", 150)
    m = run("mdinference", 150)
    assert g.metrics.sla_attainment < 0.3
    assert m.metrics.sla_attainment > 0.75


def test_fig3_latency_reduction_vs_greedy():
    g = run("static_greedy", 115)
    m = run("mdinference", 115)
    reduction = 1.0 - m.metrics.mean_latency_ms / g.metrics.mean_latency_ms
    assert reduction > 0.30  # paper: up to 42-43 %


def test_fig3_accuracy_converges_at_250():
    g = run("static_greedy", 250)
    m = run("mdinference", 250)
    assert m.metrics.aggregate_accuracy > 80.0
    assert abs(g.metrics.aggregate_accuracy - m.metrics.aggregate_accuracy) < 3.0


def test_fig3b_low_sla_uses_fastest_model():
    m = run("mdinference", 25)
    assert m.metrics.model_usage.get("MobileNetV1 0.25", 0.0) > 0.90


def test_fig3b_high_sla_uses_nasnet_large():
    m = run("mdinference", 300)
    assert m.metrics.model_usage.get("NasNet Large", 0.0) > 0.5


def test_inceptionresnet_never_selected():
    # Paper Fig 3b observation: dominated by InceptionV3 (more accurate AND
    # faster), so it should never be the base; exploration can only reach it
    # via mu-window overlap, which Table III spacing rules out.
    m = run("mdinference", 300)
    assert m.metrics.model_usage.get("InceptionResNetV2", 0.0) < 0.01


# -- Fig 4: CV sweep ----------------------------------------------------------
def test_fig4_sla100_low_attainment_on_stable_network():
    m = run("mdinference", 100, net=FixedCVNetwork(100.0, 0.0))
    assert m.metrics.sla_attainment < 0.5


def test_fig4_sla100_attainment_grows_with_cv():
    lo = run("mdinference", 100, net=FixedCVNetwork(100.0, 0.2))
    hi = run("mdinference", 100, net=FixedCVNetwork(100.0, 1.0))
    assert hi.metrics.sla_attainment > lo.metrics.sla_attainment


def test_fig4_sla250_high_accuracy_across_cv():
    for cv in [0.0, 0.5, 1.0]:
        m = run("mdinference", 250, net=FixedCVNetwork(100.0, cv))
        assert m.metrics.aggregate_accuracy > 75.0, cv


# -- Fig 6: stage ablation ----------------------------------------------------
def test_fig6_ordering():
    zoo = ablation_zoo()
    res = {
        alg: run(alg, 250, zoo=zoo).metrics.aggregate_accuracy
        for alg in ["pure_random", "related_random", "related_accurate", "mdinference"]
    }
    assert res["related_accurate"] >= res["related_random"]
    assert res["mdinference"] >= res["related_random"]
    assert res["related_random"] > res["pure_random"] - 2.0


def test_fig6_pure_random_flat_latency():
    a = run("pure_random", 100)
    b = run("pure_random", 300)
    assert abs(a.metrics.mean_latency_ms - b.metrics.mean_latency_ms) < 5.0


# -- Table IV: duplication on measured traces ---------------------------------
@pytest.mark.parametrize(
    "trace,md_acc,md_rel,sa_acc,sa_rel",
    [
        (university_trace(), 82.39, 0.0026, 81.09, 0.0367),
        (residential_trace(), 80.43, 0.0316, 73.11, 0.2303),
    ],
    ids=["university", "residential"],
)
def test_table4(trace, md_acc, md_rel, sa_acc, sa_rel):
    md = run("mdinference", 250, net=trace, dup=True)
    sa = run("static_accuracy", 250, net=trace, dup=True)
    assert md.metrics.sla_attainment == 1.0  # duplication bounds latency
    assert sa.metrics.sla_attainment == 1.0
    assert abs(md.metrics.aggregate_accuracy - md_acc) < 1.5
    assert abs(md.metrics.ondevice_reliance - md_rel) < 0.01
    assert abs(sa.metrics.aggregate_accuracy - sa_acc) < 1.5
    assert abs(sa.metrics.ondevice_reliance - sa_rel) < 0.03
    # MDInference beats static accuracy on both networks (paper: +1.3 / +7.3).
    assert md.metrics.aggregate_accuracy > sa.metrics.aggregate_accuracy


def test_duplication_never_violates_sla():
    for sla in [100.0, 150.0, 250.0]:
        m = run("mdinference", sla, net=residential_trace(), dup=True)
        assert m.metrics.sla_attainment == 1.0, sla


def test_aggregate_accuracy_gain_over_ondevice_only():
    # Paper abstract: >39-40 % aggregate accuracy gain vs purely on-device
    # (the 41.4 %-accurate duplicate model).
    md = run("mdinference", 250, net=university_trace(), dup=True)
    assert md.metrics.aggregate_accuracy - 41.4 > 39.0


# -- estimators ---------------------------------------------------------------
def test_noisy_estimator_degrades_gracefully():
    exact = run("mdinference", 250)
    noisy = run("mdinference", 250, estimator=NoisyEstimator(0.3))
    # Noise costs some attainment but not a collapse.
    assert noisy.metrics.sla_attainment > 0.9 * exact.metrics.sla_attainment


def test_seed_determinism():
    a = run("mdinference", 250, seed=7)
    b = run("mdinference", 250, seed=7)
    assert np.array_equal(a.model_index, b.model_index)
    assert a.metrics == b.metrics
