"""Transport-layer tests: inline fault surface + real process workers.

Process-mode tests spawn genuine worker processes; the backend factory
(``transport_stubs``) imports only numpy, so the children stay jax-free
and the spawns are cheap enough for tier-1 CI.
"""
import threading
import time

import numpy as np
import pytest

from repro.serving.transport import (
    FailedBatchHandle,
    ProcessTransportBackend,
    RemoteExecutionError,
    ReplicaDied,
    TransportError,
)
from transport_stubs import (
    ExplodingWorkerBackend,
    HangingWorkerBackend,
    SlowWorkerBackend,
    StubVariant,
    StubWorkerBackend,
)


def expected_tokens(batch, n_steps):
    base = np.asarray(batch)[:, :1].astype(np.int32)
    return base + np.arange(n_steps, dtype=np.int32)[None, :]


# -- FailedBatchHandle ---------------------------------------------------------


def test_failed_handle_polls_true_and_wait_raises():
    err = ReplicaDied("gone")
    h = FailedBatchHandle("m", 4, err)
    assert h.poll()
    assert h.n_rows == 4
    with pytest.raises(ReplicaDied, match="gone"):
        h.wait()


# -- inline mode ---------------------------------------------------------------


def test_inline_roundtrip_delegates_to_inner_backend():
    t = ProcessTransportBackend(StubWorkerBackend, mode="inline")
    t.register(StubVariant("m"))
    assert "m" in t.variants  # the parent-side mirror
    batch = np.array([[3, 0], [7, 0]])
    out, wall_ms = t.run_batch("m", batch, 4)
    np.testing.assert_array_equal(out, expected_tokens(batch, 4))
    assert wall_ms >= 0.0


def test_inline_injected_failures_then_recovery():
    t = ProcessTransportBackend(StubWorkerBackend, mode="inline")
    t.register(StubVariant("m"))
    t.inject_failures(2, reason="synthetic")
    batch = np.array([[1, 0]])
    for _ in range(2):
        with pytest.raises(RemoteExecutionError, match="synthetic"):
            t.run_batch("m", batch, 2)
    # The worker "survived": the next batch succeeds.
    out, _ = t.run_batch("m", batch, 2)
    np.testing.assert_array_equal(out, expected_tokens(batch, 2))


def test_inline_kill_then_restart():
    t = ProcessTransportBackend(StubWorkerBackend, mode="inline")
    t.register(StubVariant("m"))
    t.kill("chaos test")
    assert not t.alive
    with pytest.raises(ReplicaDied, match="chaos test"):
        t.run_batch("m", np.array([[1, 0]]), 2)
    t.restart()
    assert t.alive
    out, _ = t.run_batch("m", np.array([[1, 0]]), 2)
    np.testing.assert_array_equal(out, expected_tokens(np.array([[1, 0]]), 2))


def test_inject_failures_rejected_in_process_mode():
    t = ProcessTransportBackend(StubWorkerBackend, timeout_s=10.0)
    try:
        with pytest.raises(ValueError, match="inline-mode fault hook"):
            t.inject_failures(1)
    finally:
        t.close()


# -- accounting reconcile (satellite: inflight must not leak on failure) -------


def test_sync_submit_failure_reconciles_inflight():
    t = ProcessTransportBackend(StubWorkerBackend, mode="inline")
    t.register(StubVariant("m"))
    t.inject_failures(1)
    with pytest.raises(RemoteExecutionError):
        t.submit_batch("m", np.array([[1, 0], [2, 0]]), 2, sync=True)
    assert t.inflight_rows == 0  # the failed rows drained out
    assert t.dispatched_rows == 2
    # EWMA untouched by the failure; a later success still seeds it.
    assert t.ewma_wall_ms is None
    t.submit_batch("m", np.array([[1, 0]]), 2, sync=True).wait()
    assert t.inflight_rows == 0
    assert t.ewma_wall_ms is not None


def test_threaded_submit_failure_reconciles_inflight():
    t = ProcessTransportBackend(StubWorkerBackend, mode="inline")
    t.register(StubVariant("m"))
    t.inject_failures(1)
    h = t.submit_batch("m", np.array([[1, 0]]), 2, sync=False)
    with pytest.raises(RemoteExecutionError):
        h.wait(timeout=5.0)
    assert t.inflight_rows == 0


# -- process mode --------------------------------------------------------------


def test_process_roundtrip_crosses_the_boundary():
    t = ProcessTransportBackend(StubWorkerBackend, timeout_s=30.0)
    try:
        t.register(StubVariant("m"))
        batch = np.array([[5, 0], [9, 0], [2, 0]])
        out, wall_ms = t.run_batch("m", batch, 3)
        np.testing.assert_array_equal(out, expected_tokens(batch, 3))
        assert wall_ms >= 0.0
        # Several sequential batches demultiplex correctly.
        for k in range(3):
            b = np.array([[k, 0]])
            out, _ = t.run_batch("m", b, 2)
            np.testing.assert_array_equal(out, expected_tokens(b, 2))
    finally:
        t.close()


def test_process_remote_error_counts_but_worker_survives():
    t = ProcessTransportBackend(ExplodingWorkerBackend, timeout_s=30.0)
    try:
        t.register(StubVariant("boom"))
        t.register(StubVariant("ok"))
        with pytest.raises(RemoteExecutionError, match="synthetic execution"):
            t.run_batch("boom", np.array([[1, 0]]), 2)
        assert t.alive  # the worker outlived the batch failure
        out, _ = t.run_batch("ok", np.array([[4, 0]]), 2)
        np.testing.assert_array_equal(out, expected_tokens(np.array([[4, 0]]), 2))
    finally:
        t.close()


def test_process_kill_fails_inflight_and_restart_reregisters():
    t = ProcessTransportBackend(SlowWorkerBackend, timeout_s=30.0)
    try:
        t.register(StubVariant("m"))
        # Warm the worker so the in-flight batch below is mid-execution
        # (not stuck behind child start-up) when the kill lands.
        t.run_batch("m", np.array([[0, 0]]), 1)
        h = t.submit_batch("m", np.array([[1, 0], [2, 0]]), 2, sync=False)
        time.sleep(0.05)  # let the submit reach the worker
        t.kill("fault injection")
        with pytest.raises(ReplicaDied):
            h.wait(timeout=10.0)
        assert not t.alive
        assert t.inflight_rows == 0  # accounting reconciled on the way out
        with pytest.raises(ReplicaDied, match="replica is down"):
            t.run_batch("m", np.array([[1, 0]]), 2)

        t.restart()  # respawns and replays registration from the mirror
        assert t.alive
        out, _ = t.run_batch("m", np.array([[6, 0]]), 2)
        np.testing.assert_array_equal(out, expected_tokens(np.array([[6, 0]]), 2))
        assert t.inflight_rows == 0
    finally:
        t.close()


def test_process_worker_death_surfaces_as_replica_died():
    t = ProcessTransportBackend(SlowWorkerBackend, timeout_s=30.0)
    try:
        t.register(StubVariant("m"))
        t.run_batch("m", np.array([[0, 0]]), 1)  # worker is up and serving
        errors = []

        def submit():
            try:
                t.run_batch("m", np.array([[1, 0]]), 2)
            except TransportError as e:
                errors.append(e)

        th = threading.Thread(target=submit)
        th.start()
        time.sleep(0.05)
        t._proc.terminate()  # the worker dies out from under the batch
        th.join(timeout=10.0)
        assert not th.is_alive()
        assert len(errors) == 1 and isinstance(errors[0], ReplicaDied)
        assert not t.alive
    finally:
        t.close()


def test_process_batch_timeout_kills_the_worker():
    t = ProcessTransportBackend(HangingWorkerBackend, timeout_s=0.5)
    try:
        t.register(StubVariant("m"))
        with pytest.raises(ReplicaDied, match="timeout"):
            t.run_batch("m", np.array([[1, 0]]), 2)
        assert not t.alive  # a wedged worker is treated as dead
    finally:
        t.close()
