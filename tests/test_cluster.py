"""Replicated execution cluster: routing invariants, placement rules,
per-replica accounting, the replicas=1 compatibility pin, and goodput
scaling under overload (the PR's acceptance bar).
"""
import math

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.sla import summarize
from repro.serving.admission import AdmissionConfig
from repro.serving.backend import OnDeviceBackend, Variant
from repro.serving.cluster import (
    ClusterBackend,
    NoHealthyReplica,
    Replica,
    make_router,
    shard_slices,
)
from repro.serving.health import BreakerConfig
from repro.serving.lifecycle import QueuedRequest, RequestState
from repro.serving.loadgen import LoadTrace
from repro.serving.loop import ServingLoop
from repro.serving.transport import FailedBatchHandle

from loop_stubs import (
    STUB_NAMES,
    StubHedgeBackend,
    StubRemoteBackend,
    stub_cluster,
    stub_fault_cluster,
    stub_scheduler,
)

GEN = 2


def _request(rid, arrival_ms=0.0, nw=10.0):
    return QueuedRequest(
        rid=rid, tokens=np.zeros(4, np.int32), n_steps=GEN,
        t_nw_est_ms=nw, t_nw_actual_ms=nw, arrival_ms=arrival_ms,
    )


class _FakeBackend:
    """Minimal load-accounting carrier for driving routers directly."""

    def __init__(self, inflight=0, dispatched=0, ewma=None):
        self.variants = {}
        self.inflight_rows = inflight
        self.dispatched_rows = dispatched
        self.ewma_wall_ms = ewma


def _pool(states):
    return [Replica(i, _FakeBackend(*s)) for i, s in enumerate(states)]


# ---------------------------------------------------------------------------
# Routing policies.
# ---------------------------------------------------------------------------
def test_round_robin_cycles_the_eligible_set():
    router = make_router("round_robin")
    reps = _pool([(0,), (0,), (0,)])
    picks = [router.pick(reps).replica_id for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    # Partial eligibility keeps cycling over what is eligible.
    picks = [router.pick(reps[1:]).replica_id for _ in range(4)]
    assert set(picks) == {1, 2}


def test_round_robin_stays_fair_under_dynamic_membership():
    """Regression: the old global-counter rotation (``counter % len``)
    skewed the moment the eligible set changed size between picks; the
    identity-keyed rotation stays fair under shrink and grow."""
    router = make_router("round_robin")
    reps = _pool([(0,), (0,), (0,)])
    assert [router.pick(reps).replica_id for _ in range(3)] == [0, 1, 2]
    # Shrink: replica 1 leaves mid-rotation — the survivors alternate
    # strictly (no survivor is repeatedly skipped).
    survivors = [reps[0], reps[2]]
    picks = [router.pick(survivors).replica_id for _ in range(6)]
    assert picks == [0, 2, 0, 2, 0, 2]
    # Grow: replica 1 rejoins — the rotation folds it back in, and a full
    # window over the restored set is exactly fair.
    picks = [router.pick(reps).replica_id for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_least_inflight_picks_the_minimum_deterministic():
    router = make_router("least_inflight")
    reps = _pool([(5,), (2,), (9,), (2,)])
    # Minimum inflight wins; ties break on dispatched_rows then id.
    assert router.pick(reps).replica_id == 1
    reps[1].backend.dispatched_rows = 100
    assert router.pick(reps).replica_id == 3


def test_least_inflight_balances_under_serialized_dispatch():
    """With sync dispatch inflight is always 0 at pick time; the
    cumulative-work tie-break must still spread load instead of pinning
    every batch to replica 0."""
    router = make_router("least_inflight")
    reps = _pool([(0,), (0,), (0,)])
    counts = [0, 0, 0]
    for _ in range(9):
        r = router.pick(reps)
        counts[r.replica_id] += 1
        r.backend.dispatched_rows += 4  # the batch completed inline
    assert counts == [3, 3, 3]


@settings(max_examples=200, deadline=None)
@given(
    inflight=st.lists(st.integers(0, 50), min_size=1, max_size=8),
    dispatched=st.lists(st.integers(0, 1000), min_size=8, max_size=8),
)
def test_least_inflight_never_picks_a_strictly_longer_queue(
    inflight, dispatched
):
    reps = [
        Replica(i, _FakeBackend(q, dispatched[i]))
        for i, q in enumerate(inflight)
    ]
    pick = make_router("least_inflight").pick(reps)
    assert pick.inflight_rows == min(inflight)


@settings(max_examples=100, deadline=None)
@given(
    ewmas=st.lists(
        st.floats(0.1, 1e3, allow_nan=False), min_size=2, max_size=6
    ),
    seed=st.integers(0, 1000),
)
def test_power_of_two_picks_the_faster_of_its_sample(ewmas, seed):
    reps = [Replica(i, _FakeBackend(0, 0, e)) for i, e in enumerate(ewmas)]
    router = make_router("power_of_two", seed=seed)
    for _ in range(10):
        pick = router.pick(reps)
        # Whatever pair was sampled, the winner is never the pool's
        # strictly slowest replica unless both candidates were it (it is
        # unique, so: the pick can't be the unique maximum when any other
        # replica was available in the pair).
        slowest = max(ewmas)
        if ewmas.count(slowest) == 1 and len(reps) == 2:
            assert pick.ewma_wall_ms != slowest


def test_power_of_two_prefers_unprobed_then_measured_fast():
    # Two replicas: with only two, p2c compares both every time.  All
    # picks favor the measured-faster replica except the bounded probes
    # (every probe_every-th pick re-measures the loser so its EWMA can't
    # go permanently stale — the starvation guard).
    fast, slow = _FakeBackend(0, 0, 10.0), _FakeBackend(0, 0, 100.0)
    reps = [Replica(0, slow), Replica(1, fast)]
    router = make_router("power_of_two", seed=0)
    picks = [router.pick(reps).replica_id for _ in range(32)]
    assert picks.count(0) == 32 // router.probe_every  # probes only
    assert picks.count(1) == 32 - picks.count(0)
    # An unprobed replica (EWMA None) counts as 0 — it gets explored.
    reps.append(Replica(2, _FakeBackend(0, 0, None)))
    n = 64
    picks = [router.pick(reps).replica_id for _ in range(n)]
    assert 2 in picks
    # The measured-slowest replica surfaces at most via probes.
    assert picks.count(0) <= n // router.probe_every + 1


def _simulate_router_p99(router_name, service_ms, n_jobs, gap_ms, seed):
    """Deterministic queueing sim over real Router/Replica objects: jobs
    arrive every ``gap_ms``; replica r serves one job in ``service_ms[r]``
    (one slow replica skews the pool).  Returns the p99 latency."""
    router = make_router(router_name, seed=seed)
    reps = _pool([(0,)] * len(service_ms))
    free_at = [0.0] * len(reps)
    outstanding = [[] for _ in reps]  # finish times of inflight jobs
    lat = []
    for j in range(n_jobs):
        t = j * gap_ms
        for r, fl in enumerate(outstanding):
            for f in (f for f in fl if f <= t):
                reps[r].backend.inflight_rows -= 1
                e = reps[r].backend.ewma_wall_ms
                s = service_ms[r]
                reps[r].backend.ewma_wall_ms = (
                    s if e is None else 0.75 * e + 0.25 * s
                )
            outstanding[r] = [f for f in fl if f > t]
        pick = router.pick(reps)
        rid = pick.replica_id
        finish = max(t, free_at[rid]) + service_ms[rid]
        free_at[rid] = finish
        outstanding[rid].append(finish)
        pick.backend.inflight_rows += 1
        pick.backend.dispatched_rows += 1
        lat.append(finish - t)
    return float(np.percentile(lat, 99))


@pytest.mark.parametrize("seed", [0, 7, 11])
def test_power_of_two_tail_lands_between_round_robin_and_jsq(seed):
    """On a seeded skewed-service pool (3 nominal replicas + 1 slow) at
    near-capacity load, the routers' p99 order is the textbook one:
    load-blind round_robin >= sampled power_of_two >= full-information
    least_inflight."""
    kw = dict(service_ms=[6.0, 6.0, 6.0, 12.0], n_jobs=250, gap_ms=2.0)
    p99_rr = _simulate_router_p99("round_robin", seed=seed, **kw)
    p99_p2 = _simulate_router_p99("power_of_two", seed=seed, **kw)
    p99_ji = _simulate_router_p99("least_inflight", seed=seed, **kw)
    assert p99_rr >= p99_p2 >= p99_ji, (p99_rr, p99_p2, p99_ji)


def test_make_router_rejects_unknown():
    with pytest.raises(ValueError, match="router must be one of"):
        make_router("weighted-magic")


@pytest.mark.parametrize(
    "router", ["round_robin", "least_inflight", "power_of_two"]
)
def test_routers_raise_typed_error_on_empty_eligible_set(router):
    """Regression: an empty eligible set used to surface as a bare
    IndexError / ZeroDivisionError from inside the policy — it must be the
    typed NoHealthyReplica the loop's degrade path catches."""
    with pytest.raises(NoHealthyReplica):
        make_router(router).pick([])


# ---------------------------------------------------------------------------
# Placement: zoo slices, registration, hosted masks.
# ---------------------------------------------------------------------------
def test_shard_slices_cover_the_zoo():
    names = [f"m{i}" for i in range(7)]
    slices = shard_slices(names, 3)
    assert len(slices) == 3
    flat = [n for s in slices for n in s]
    assert sorted(flat) == sorted(names)  # disjoint cover (overlap=1)
    slices2 = shard_slices(names, 3, overlap=2)
    flat2 = [n for s in slices2 for n in s]
    assert len(flat2) == 2 * len(names)
    for n in names:  # every variant on exactly `overlap` replicas
        assert sum(n in s for s in slices2) == 2
    with pytest.raises(ValueError, match="overlap"):
        shard_slices(names, 3, overlap=4)


def test_register_places_on_admitting_replicas_only():
    slices = shard_slices(STUB_NAMES, 2)  # disjoint: one variant each
    cluster = stub_cluster(2, slices=slices)
    for replica, sl in zip(cluster.replicas, slices):
        assert sorted(replica.backend.variants) == sorted(sl)
    assert cluster.hosted_mask(STUB_NAMES).all()
    assert not cluster.hosted_mask(["stub-a", "nope"])[1]
    # fan_out reflects the hosting set, not the pool size.
    assert cluster.fan_out(STUB_NAMES[0]) == 1


def test_register_rejects_variant_no_slice_admits():
    from repro.serving.backend import Variant

    cluster = stub_cluster(2, slices=[["stub-a"], ["stub-b"]])
    with pytest.raises(ValueError, match="no replica slice admits"):
        cluster.register(Variant("outsider", None, None, 50.0))


def test_routing_never_leaves_the_hosting_set():
    cluster = stub_cluster(2, slices=shard_slices(STUB_NAMES, 2))
    for name in STUB_NAMES:
        for _ in range(6):
            assert cluster.route(name).hosts(name)
    with pytest.raises(ValueError, match="no replica hosts"):
        cluster.route("outsider")


def test_nested_cluster_is_not_a_routable_replica():
    """A nested pool would report inflight 0 / EWMA None to the outer
    router (its accounting lives on its replicas) — rejected up front."""
    inner = stub_cluster(2)
    with pytest.raises(ValueError, match="nested ClusterBackend"):
        ClusterBackend([inner])


def test_ondevice_backend_is_not_a_routable_replica():
    """The hedge tier is a device-side singleton: a pool must refuse it."""
    hedge = StubHedgeBackend(0.0)
    # The stub hedge is not an OnDeviceBackend subclass — build a real one
    # cheaply to exercise the guard.
    real = OnDeviceBackend.__new__(OnDeviceBackend)  # no jit/init needed
    with pytest.raises(ValueError, match="not a routable replica"):
        ClusterBackend([real])
    # And the stub hedge composes fine *outside* the pool.
    cluster = stub_cluster(2)
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(sched, cluster, hedge, dispatch="sync")
    for i in range(4):
        loop.submit(_request(i))
    res = loop.tick()
    assert len(res.completions) == 4
    # Hedge executions never carry a replica id (not pool work).
    for c in res.completions:
        if not c.used_remote:
            continue
        assert c.replica in (0, 1)


# ---------------------------------------------------------------------------
# The loop over a cluster: fan-out, threading, conservation.
# ---------------------------------------------------------------------------
def test_completions_carry_replica_ids_and_fan_out_spreads_rows():
    cluster = stub_cluster(2, router="round_robin")
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(sched, cluster, dispatch="sync")
    for i in range(8):
        loop.submit(_request(i))
    res = loop.tick()
    assert len(res.completions) == 8
    replicas_used = {c.replica for c in res.completions}
    assert replicas_used <= {0, 1}
    assert len(replicas_used) == 2  # the tick fanned out across the pool
    for c in res.completions:
        assert c.replica_inflight >= 1  # own rows count at dispatch
    # TickStats per-replica rows account for every remote row once.
    assert sum(res.stats.replica_rows.values()) == 8
    assert set(res.stats.replica_rows) == replicas_used
    assert res.stats.max_replica_rows <= 8
    # And the metrics carry per-replica rows with sane aggregates.
    rows = res.metrics.replica_rows
    assert set(rows) == replicas_used
    assert sum(r.share for r in rows.values()) == pytest.approx(1.0)
    assert max(r.utilization for r in rows.values()) == 1.0


def test_conservation_per_replica_and_aggregate():
    cluster = stub_cluster(4, router="least_inflight")
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(sched, cluster, dispatch="sync")
    futures = [loop.submit(_request(i, arrival_ms=float(i))) for i in range(24)]
    cancelled = [f for f in futures[::5] if f.cancel()]
    results = loop.flush()
    done = [c for r in results for c in r.completions]
    n_resolved = sum(1 for f in futures if f.state is RequestState.RESOLVED)
    n_cancelled = sum(1 for f in futures if f.state is RequestState.CANCELLED)
    # Aggregate conservation: every submitted future reached exactly one
    # terminal state, and completions match the resolved count.
    assert n_resolved + n_cancelled == len(futures)
    assert n_cancelled == len(cancelled)
    assert len(done) == n_resolved
    # Per-replica conservation: summed per-replica completions == total,
    # and each replica's backend retired every row it was ever handed.
    per_replica = {r.replica_id: 0 for r in cluster.replicas}
    for c in done:
        per_replica[c.replica] += 1
    assert sum(per_replica.values()) == n_resolved
    for replica in cluster.replicas:
        assert replica.inflight_rows == 0  # nothing stuck in flight
    assert (
        sum(r.dispatched_rows for r in cluster.replicas)
        == sum(sum(b) for b in [r.backend.batch_rows for r in cluster.replicas])
    )


def test_sharded_slices_constrain_selection_and_execution():
    """With a variant hosted nowhere, selection masks it out — every
    completion uses a hosted variant and every stub backend only ever
    executed names from its own slice."""
    # Host only stub-a: stub-b exists in the scheduler's registry but has
    # no replica, so eligibility must exclude it.
    cluster = stub_cluster(2, slices=[["stub-a"], ["stub-a"]])
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(sched, cluster, dispatch="sync")
    for i in range(12):
        loop.submit(_request(i))
    res = loop.tick()
    assert len(res.completions) == 12
    assert {c.model_name for c in res.completions} == {"stub-a"}
    for replica in cluster.replicas:
        assert set(replica.backend.batch_names) <= {"stub-a"}


# ---------------------------------------------------------------------------
# Dynamic membership: breakers, drain, kill/rejoin, lost-batch recovery.
# ---------------------------------------------------------------------------
def test_breaker_opens_and_membership_updates_the_same_tick():
    cluster = stub_fault_cluster(
        2, breaker=BreakerConfig(failure_threshold=1, cooldown_ms=100.0)
    )
    cluster.advance_clock(10.0)
    assert cluster.hosted_mask(STUB_NAMES).all()
    cluster.note_failure(0, "exploded", fatal=True)
    snap = cluster.snapshot()[0]
    assert snap.health == "open" and snap.reason == "exploded"
    # Same tick: replica 0 left the routable set; the mask stays up only
    # because replica 1 still hosts everything.
    assert cluster.fan_out("stub-a") == 1
    assert {cluster.route("stub-a").replica_id for _ in range(4)} == {1}
    assert cluster.hosted_mask(STUB_NAMES).all()
    cluster.note_failure(1, "exploded too", fatal=True)
    # Whole pool dark — the mask reflects it the same tick, and routing
    # raises the typed operational error naming each replica's state.
    assert not cluster.hosted_mask(STUB_NAMES).any()
    with pytest.raises(NoHealthyReplica, match="exploded"):
        cluster.route("stub-a")
    # Placement errors stay distinct from operational outages.
    with pytest.raises(ValueError, match="no replica hosts"):
        cluster.route("outsider")


def test_half_open_probe_single_slot_then_close_or_reopen():
    cluster = stub_fault_cluster(
        2, breaker=BreakerConfig(failure_threshold=1, cooldown_ms=100.0)
    )
    cluster.advance_clock(0.0)
    cluster.note_failure(0, "flaky", fatal=True)
    cluster.note_failure(1, "flaky", fatal=True)
    cluster.advance_clock(150.0)  # both cooldowns elapsed -> half-open
    a = cluster.route("stub-a")
    b = cluster.route("stub-a")
    assert {a.replica_id, b.replica_id} == {0, 1}
    assert cluster.snapshot()[0].health == "half_open"
    # Each half-open breaker admits exactly one probe: both slots are now
    # claimed, so a third route finds nobody.
    with pytest.raises(NoHealthyReplica):
        cluster.route("stub-a")
    # Probe outcomes drive the lifecycle: success closes, failure re-opens
    # with the backed-off cooldown.
    cluster.note_success(a.replica_id)
    cluster.note_failure(b.replica_id, "still flaky")
    snaps = cluster.snapshot()
    assert snaps[a.replica_id].health == "closed"
    assert snaps[b.replica_id].health == "open"
    assert snaps[b.replica_id].open_until_ms == 150.0 + 200.0
    assert {
        cluster.route("stub-a").replica_id for _ in range(3)
    } == {a.replica_id}


def test_killed_replica_is_never_routed_and_rejoin_restarts_it():
    cluster = stub_fault_cluster(2)
    cluster.advance_clock(0.0)
    cluster.kill_replica(0, reason="chaos kill")
    assert not cluster.replicas[0].backend.alive
    snap = cluster.snapshot()[0]
    assert snap.health == "open"
    assert snap.reason == "chaos kill"
    assert snap.open_until_ms == math.inf
    # A permanent trip never half-opens: even far in the future the
    # breaker stays open and routing avoids the replica.
    cluster.advance_clock(1e12)
    assert {cluster.route("stub-a").replica_id for _ in range(6)} == {1}
    cluster.rejoin(0)
    assert cluster.replicas[0].backend.alive  # transport restarted
    assert cluster.snapshot()[0].health == "closed"
    assert {cluster.route("stub-a").replica_id for _ in range(4)} == {0, 1}


def test_drain_stops_routing_inflight_finishes_rejoin_restores():
    cluster = stub_fault_cluster(2, delay_s=0.01)
    cluster.advance_clock(0.0)
    h = cluster.submit_batch("stub-a", np.zeros((2, 4), np.int32), GEN, sync=False)
    assert h.replica == 0
    cluster.drain(0)
    assert cluster.snapshot()[0].draining
    # Nothing new routes to the draining replica...
    assert {cluster.route("stub-a").replica_id for _ in range(4)} == {1}
    # ...but its in-flight batch completes normally (drain is graceful).
    out, wall_ms = h.wait(timeout=5.0)
    assert out.shape[0] == 2 and wall_ms > 0.0
    assert cluster.replicas[0].inflight_rows == 0
    cluster.rejoin(0)
    assert not cluster.snapshot()[0].draining
    assert {cluster.route("stub-a").replica_id for _ in range(4)} == {0, 1}


def test_failed_batch_reconciles_accounting_and_routing_recovers():
    """Satellite regression: a failed batch's rows must leave the
    replica's inflight count (and its EWMA must stay unpoisoned) so the
    load-aware routers treat the recovered replica on par — no phantom
    inflight permanently deprioritizing it."""
    cluster = stub_fault_cluster(
        2, router="least_inflight",
        breaker=BreakerConfig(failure_threshold=1, cooldown_ms=100.0),
    )
    cluster.advance_clock(0.0)
    cluster.replicas[0].backend.inject_failures(1)
    h = cluster.submit_batch("stub-a", np.zeros((4, 4), np.int32), GEN, sync=True)
    assert isinstance(h, FailedBatchHandle)
    assert h.replica == 0
    # The failed rows drained out of the inflight accounting...
    assert cluster.replicas[0].inflight_rows == 0
    assert cluster.replicas[0].ewma_wall_ms is None  # no bogus wall time
    # ...and the breaker tripped at dispatch (threshold 1).
    assert cluster.snapshot()[0].health == "open"
    # Cooldown elapses; the probe succeeds; post-recovery both replicas
    # share work again.
    cluster.advance_clock(200.0)
    served = []
    for _ in range(8):
        g = cluster.submit_batch(
            "stub-a", np.zeros((2, 4), np.int32), GEN, sync=True
        )
        g.wait()
        cluster.note_success(g.replica)
        served.append(g.replica)
    assert set(served) == {0, 1}
    assert abs(served.count(0) - served.count(1)) <= 2
    assert all(r.inflight_rows == 0 for r in cluster.replicas)


def test_lost_batch_requeues_and_resolves_after_recovery():
    """Tentpole behavior: rows on a batch a replica failure loses go back
    through admission and resolve on a surviving replica — zero lost
    requests, conservation intact."""
    cluster = stub_fault_cluster(
        2, router="least_inflight",
        breaker=BreakerConfig(failure_threshold=1, cooldown_ms=1e6),
    )
    cluster.replicas[0].backend.inject_failures(50)
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(sched, cluster, dispatch="sync")
    futures = [loop.submit(_request(i)) for i in range(8)]
    r1 = loop.tick(now_ms=0.0)
    assert r1.stats.n_lost > 0
    assert r1.stats.n_requeued == r1.stats.n_lost  # no hedge tier: all back
    assert len(r1.completions) == 8 - r1.stats.n_lost
    assert loop.pending == r1.stats.n_requeued  # back in admission, front
    r2 = loop.tick(now_ms=100.0)
    assert r2.stats.n_lost == 0
    assert len(r2.completions) == r1.stats.n_requeued
    assert {c.replica for c in r2.completions} == {1}  # survivor served them
    assert all(f.state is RequestState.RESOLVED for f in futures)
    assert sum(1 for f in futures if f.requeues) == r1.stats.n_requeued
    assert all(r.inflight_rows == 0 for r in cluster.replicas)


def test_hedged_rows_fail_over_to_the_measured_duplicate():
    """With a real hedge tier, a lost remote batch is not a lost request:
    the hedged rows resolve through their measured on-device duplicate
    (race_resolution='remote_failed') instead of requeueing."""
    cluster = stub_fault_cluster(
        1, breaker=BreakerConfig(failure_threshold=1, cooldown_ms=1e6)
    )
    cluster.replicas[0].backend.inject_failures(10)
    hedge = StubHedgeBackend(0.0)
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(sched, cluster, hedge, dispatch="sync")
    futures = [loop.submit(_request(i)) for i in range(4)]
    res = loop.tick(now_ms=0.0)
    assert res.stats.n_lost == 4
    assert res.stats.n_requeued == 0
    assert len(res.completions) == 4
    for c in res.completions:
        assert c.race_resolution == "remote_failed"
        assert not c.used_remote
        assert c.hedged and c.hedge_measured
        assert np.isfinite(c.latency_ms)
    assert all(f.state is RequestState.RESOLVED for f in futures)


def test_whole_pool_outage_diverts_the_chunk_to_the_degrade_lane():
    cluster = stub_fault_cluster(2)
    hedge = StubHedgeBackend(0.0)
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(sched, cluster, hedge, dispatch="sync")
    cluster.kill_replica(0, reason="rack down")
    cluster.kill_replica(1, reason="rack down")
    futures = [loop.submit(_request(i)) for i in range(5)]
    res = loop.tick(now_ms=0.0)
    # decide_batch never sees an all-False eligibility mask: the whole
    # chunk is served by the on-device tier instead of crashing the tick.
    assert res.stats.n_degraded == 5
    assert res.stats.n_lost == 0
    assert len(res.completions) == 5
    assert {c.race_resolution for c in res.completions} == {"degraded"}
    assert {c.model_name for c in res.completions} == {hedge.hedge_name}
    assert all(f.state is RequestState.RESOLVED for f in futures)
    # Rejoin brings the pool back: the next tick serves remotely again.
    cluster.rejoin(0)
    loop.submit(_request(99, arrival_ms=10.0))
    res2 = loop.tick(now_ms=10.0)
    assert res2.stats.n_degraded == 0
    assert res2.completions[0].replica == 0


def _overload_trace(n, window_ms, per_window):
    """Deterministic overload: `per_window` arrivals per window."""
    arrival = np.repeat(
        np.arange(n // per_window + 1) * window_ms, per_window
    )[:n]
    nw = np.full(n, 10.0)
    return LoadTrace(arrival_ms=arrival, t_nw_ms=nw, t_nw_est_ms=nw)


@pytest.mark.parametrize("router", ["round_robin", "least_inflight"])
def test_goodput_scales_monotonically_with_replicas(router):
    """The acceptance bar, in-test: the same 2x-overload trace served by
    1/2/4 stub replicas under a service-coupled clock yields monotonically
    increasing goodput (and non-increasing p99)."""
    n, window_ms, service_ms = 120, 100.0, 10.0
    # One replica retires 10 rows per window; 20 arrive: sustained 2x.
    trace = _overload_trace(n, window_ms, per_window=20)
    goodputs, p99s = [], []
    for n_replicas in (1, 2, 4):
        cluster = stub_cluster(n_replicas, router=router)
        sched = stub_scheduler(t_sla_ms=500.0, profile_ewma=0.0)
        loop = ServingLoop(sched, cluster, dispatch="sync")
        done, metrics = loop.drain_trace(
            trace, window_ms,
            tokens_for=lambda i: np.zeros(4, np.int32), n_steps=GEN,
            service_model=lambda res: service_ms * res.stats.max_replica_rows,
        )
        assert len(done) == n
        goodputs.append(metrics.goodput)
        p99s.append(metrics.p99_latency_ms)
    assert goodputs[0] <= goodputs[1] <= goodputs[2], goodputs
    assert goodputs[2] > goodputs[0], goodputs  # scaling, not a plateau
    assert p99s[2] <= p99s[0], p99s


# ---------------------------------------------------------------------------
# replicas=1 compatibility pin (real backends).
# ---------------------------------------------------------------------------
def test_one_replica_round_robin_is_identical_to_single_backend():
    """The regression pin: a 1-replica round_robin pool serves a seeded
    trace exactly like the plain single-backend loop — same decisions,
    same tokens, same loop-clock timings."""
    import jax

    from repro.configs import reduced
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.serving.backend import JitBackend, Variant
    from repro.serving.engine import ServingEngine
    from repro.serving.loadgen import PoissonArrivals, make_trace
    from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

    prompt, n, window_ms = 8, 24, 50.0
    max_len = 32
    cfg = reduced(
        "gemma-2b", d_model=32, n_layers=2,
        n_heads=2, n_kv_heads=1, head_dim=16,
    )
    variants = [
        Variant("small", cfg, T.init_params(cfg, jax.random.key(0)), 40.0),
        Variant("large", cfg, T.init_params(cfg, jax.random.key(1)), 80.0),
    ]

    def build(clustered: bool):
        backend = (
            ClusterBackend([JitBackend(max_len)], router="round_robin")
            if clustered
            else JitBackend(max_len)
        )
        engine = ServingEngine(max_len=max_len, backend=backend)
        for v in variants:  # identical params on both stacks
            engine.register(v)
        return engine

    trace = make_trace(
        n, PoissonArrivals(120.0), LognormalNetwork(40.0, 0.5), seed=21
    )
    prompts = np.random.default_rng(21).integers(0, 64, (n, prompt))
    registry = build(False).measure_profiles(
        prompt_len=prompt, gen_tokens=GEN, trials=2
    )
    scfg = SchedulerConfig(t_sla_ms=5_000.0, seed=4, profile_ewma=0.0)

    outcomes = []
    for clustered in (False, True):
        engine = build(clustered)
        sched = MDInferenceScheduler(registry, registry[0], scfg)
        loop = engine.make_loop(sched, dispatch="sync")
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=GEN
        )
        outcomes.append((done, metrics))
    (done_a, metrics_a), (done_b, metrics_b) = outcomes

    assert [c.rid for c in done_a] == [c.rid for c in done_b]
    for a, b in zip(done_a, done_b):
        assert a.model_index == b.model_index
        assert a.hedged == b.hedged
        assert a.used_remote == b.used_remote
        assert a.accuracy == b.accuracy
        assert a.race_resolution == b.race_resolution
        assert a.queue_wait_ms == b.queue_wait_ms
        assert a.time_to_schedule_ms == b.time_to_schedule_ms
        np.testing.assert_array_equal(a.tokens, b.tokens)
        # The only difference: the cluster stamps its single replica.
        assert a.replica is None and b.replica == 0
    assert metrics_a.model_usage == metrics_b.model_usage
    assert metrics_a.aggregate_accuracy == metrics_b.aggregate_accuracy
    assert metrics_b.replica_rows[0].share == 1.0
    assert metrics_b.replica_rows[0].utilization == 1.0


# ---------------------------------------------------------------------------
# Per-replica metric rows (summarize-level).
# ---------------------------------------------------------------------------
def test_summarize_replica_rows_aggregates():
    m = summarize(
        accuracy_used=np.asarray([80.0, 80.0, 40.0, 40.0]),
        latency_ms=np.asarray([10.0, 400.0, 20.0, 30.0]),
        t_sla_ms=250.0,
        model_names=["a", "b"],
        model_index=np.asarray([0, 0, 1, 1]),
        replica=np.asarray([0, 0, 1, -1]),
        replica_inflight=np.asarray([4, 8, 2, 0]),
    )
    rows = m.replica_rows
    assert set(rows) == {0, 1}  # -1 (unrouted) gets no row
    assert rows[0].share == pytest.approx(0.5)
    assert rows[1].share == pytest.approx(0.25)
    # 3 attained total (10, 20, 30ms); replica 0 contributed one.
    assert rows[0].goodput_share == pytest.approx(1 / 3)
    assert rows[1].goodput_share == pytest.approx(1 / 3)
    assert rows[0].utilization == 1.0 and rows[1].utilization == 0.5
    assert rows[0].p99_inflight == pytest.approx(
        np.percentile([4, 8], 99)
    )


def test_summarize_replica_rows_empty_batch_safe():
    m = summarize(
        accuracy_used=np.zeros(0),
        latency_ms=np.zeros(0),
        t_sla_ms=250.0,
        model_names=["a"],
        model_index=np.zeros(0, np.int64),
        n_rejected=3,
        replica=np.zeros(0, np.int64),
        replica_inflight=np.zeros(0, np.int64),
    )
    assert m.replica_rows == {}
    assert m.n_requests == 0 and m.n_rejected == 3


# ---------------------------------------------------------------------------
# Overload soak (non-blocking CI stress job).
# ---------------------------------------------------------------------------
@pytest.mark.stress
@pytest.mark.parametrize("router", ["round_robin", "least_inflight", "power_of_two"])
def test_four_replica_overload_soak_no_starvation(router):
    """4-replica pool under a sustained 2x overload soak: every request
    resolves, conservation holds, and no replica starves — the busiest /
    quietest per-replica served ratio stays bounded.

    The balance bound is tight for the deterministic routers; the
    power-of-two sampler only has to stay clear of starvation (its picks
    ride a noisy wall-time EWMA, so exact balance is not its contract).
    """
    n, window_ms, service_ms = 800, 100.0, 2.0
    trace = _overload_trace(n, window_ms, per_window=40)
    cluster = stub_cluster(4, delay_s=0.001, router=router, seed=3)
    sched = stub_scheduler(t_sla_ms=2_000.0, profile_ewma=0.0)
    loop = ServingLoop(sched, cluster, dispatch="sync")
    done, metrics = loop.drain_trace(
        trace, window_ms,
        tokens_for=lambda i: np.zeros(4, np.int32), n_steps=GEN,
        service_model=lambda res: service_ms * res.stats.max_replica_rows,
    )
    assert len(done) == n  # conservation: nothing lost under soak
    served = {r.replica_id: 0 for r in cluster.replicas}
    for c in done:
        assert c.replica in served
        served[c.replica] += 1
    assert all(v > 0 for v in served.values()), (router, served)
    ratio = max(served.values()) / min(served.values())
    assert ratio <= (25.0 if router == "power_of_two" else 2.0), (
        router, served,
    )
    for replica in cluster.replicas:
        assert replica.inflight_rows == 0


@pytest.mark.stress
def test_kill_rejoin_soak_under_overload_conserves_every_request():
    """Fault-injection soak: kill one of three replicas mid-2x-overload,
    inject transient faults on a survivor, rejoin the dead replica — and
    every submitted request still reaches exactly one terminal state
    (resolved + rejected == submitted, zero lost), the requeue path really
    fired, and the rejoined replica serves again."""
    n, window_ms, service_ms = 600, 100.0, 2.0
    trace = _overload_trace(n, window_ms, per_window=30)
    cluster = stub_fault_cluster(
        3, router="least_inflight",
        breaker=BreakerConfig(failure_threshold=2, cooldown_ms=200.0),
    )
    sched = stub_scheduler(t_sla_ms=2_000.0, profile_ewma=0.0)
    loop = ServingLoop(
        sched, cluster, dispatch="sync",
        admission=AdmissionConfig(policy="shed", max_pending=64, max_chunk=32),
    )
    kill_at, rejoin_at = 400.0, 900.0
    fault = {"killed": False, "rejoined": False}

    def on_tick(t, res):
        if not fault["killed"] and t >= kill_at:
            cluster.kill_replica(0, reason="soak chaos")
            cluster.replicas[1].backend.inject_failures(6)
            fault["killed"] = True
        if not fault["rejoined"] and t >= rejoin_at:
            cluster.rejoin(0)
            fault["rejoined"] = True

    done, metrics = loop.drain_trace(
        trace, window_ms,
        tokens_for=lambda i: np.zeros(4, np.int32), n_steps=GEN,
        on_tick=on_tick,
        service_model=lambda res: service_ms * res.stats.max_replica_rows,
    )
    assert fault["killed"] and fault["rejoined"]
    # Conservation under faults: every request resolved or rejected, none
    # lost or double-resolved.
    assert len(done) + loop.admission.n_rejected == n
    assert len({c.rid for c in done}) == len(done)
    assert loop.admission.n_requeued > 0  # losses recovered via requeue
    # The rejoined replica serves post-rejoin arrivals again.
    assert any(
        c.replica == 0 and trace.arrival_ms[c.rid] > rejoin_at for c in done
    )
    for replica in cluster.replicas:
        assert replica.inflight_rows == 0
    assert metrics is not None and metrics.goodput > 0.0


# ---------------------------------------------------------------------------
# Heterogeneous replica pools (PR 9): per-replica weight / max_concurrency
# / service_scale, weight-aware routing, and the homogeneous-default pin.
# ---------------------------------------------------------------------------
def test_replica_spec_validation_and_parsing():
    from repro.serving.cluster import ReplicaSpec, parse_replica_specs

    assert ReplicaSpec() == ReplicaSpec(
        weight=1.0, max_concurrency=None, service_scale=1.0
    )
    with pytest.raises(ValueError):
        ReplicaSpec(weight=0.0)
    with pytest.raises(ValueError):
        ReplicaSpec(max_concurrency=0)
    with pytest.raises(ValueError):
        ReplicaSpec(service_scale=-1.0)

    specs = parse_replica_specs("2:8:0.5,1,::2", 3)
    assert specs[0] == ReplicaSpec(
        weight=2.0, max_concurrency=8, service_scale=0.5
    )
    assert specs[1] == ReplicaSpec()  # bare weight-only entry
    assert specs[2] == ReplicaSpec(service_scale=2.0)  # empty fields default
    with pytest.raises(ValueError):
        parse_replica_specs("1,1", 3)  # count mismatch
    with pytest.raises(ValueError):
        parse_replica_specs("1:2:3:4", 1)  # too many fields


def test_least_inflight_splits_proportionally_to_weight():
    from repro.serving.cluster import ReplicaSpec

    router = make_router("least_inflight")
    reps = [
        Replica(0, _FakeBackend(), spec=ReplicaSpec(weight=3.0)),
        Replica(1, _FakeBackend(), spec=ReplicaSpec(weight=1.0)),
    ]
    counts = [0, 0]
    for _ in range(40):
        r = router.pick(reps)
        counts[r.replica_id] += 1
        r.backend.inflight_rows += 4  # rows stay in flight
    # Normalized queue depth (inflight / weight): the 3x box carries 3x.
    assert counts == [30, 10]


def test_power_of_two_normalizes_its_queue_tiebreak_by_weight():
    from repro.serving.cluster import ReplicaSpec

    # Equal EWMAs force the inflight tie-break: 30 rows on a weight-3 box
    # is a *shorter* normalized queue than 20 rows on a weight-1 box.
    router = make_router("power_of_two", seed=0)
    reps = [
        Replica(
            0, _FakeBackend(30, ewma=50.0), spec=ReplicaSpec(weight=3.0)
        ),
        Replica(
            1, _FakeBackend(20, ewma=50.0), spec=ReplicaSpec(weight=1.0)
        ),
    ]
    # Stay under probe_every: the periodic anti-starvation probe is the
    # only thing that would ever take the slower candidate here.
    picks = {router.pick(reps).replica_id for _ in range(10)}
    assert picks == {0}


def test_max_concurrency_is_a_soft_routing_cap():
    from repro.serving.cluster import ReplicaSpec

    cluster = ClusterBackend(
        [StubRemoteBackend(0.0), StubRemoteBackend(0.0)],
        router="least_inflight",
        specs=[ReplicaSpec(max_concurrency=4), ReplicaSpec()],
    )
    for name, quality in zip(STUB_NAMES, (40.0, 80.0)):
        cluster.register(Variant(name, None, None, quality))
    # Saturate replica 0 past its cap: routing prefers the uncapped box.
    cluster.pool.replicas[0].backend.inflight_rows = 4
    for _ in range(5):
        assert cluster.route(STUB_NAMES[0]).replica_id == 1
    # An uncapped replica is always eligible, however deep its queue.
    cluster.pool.replicas[1].backend.inflight_rows = 500
    assert cluster.route(STUB_NAMES[0]).replica_id == 1

    # The cap is *soft*: with every replica at its cap the pool degrades
    # to best-effort routing over the saturated set — never
    # NoHealthyReplica (saturation is backpressure, not an outage).
    capped = ClusterBackend(
        [StubRemoteBackend(0.0), StubRemoteBackend(0.0)],
        router="least_inflight",
        specs=[ReplicaSpec(max_concurrency=4), ReplicaSpec(max_concurrency=4)],
    )
    for name, quality in zip(STUB_NAMES, (40.0, 80.0)):
        capped.register(Variant(name, None, None, quality))
    capped.pool.replicas[0].backend.inflight_rows = 9
    capped.pool.replicas[1].backend.inflight_rows = 4
    assert capped.route(STUB_NAMES[0]).replica_id == 1  # least saturated


def test_homogeneous_specs_are_byte_identical_to_default():
    """The regression pin: an all-default spec list must produce exactly
    the routing decisions of a pool with no specs at all."""
    from repro.serving.cluster import ReplicaSpec

    def route_sequence(specs):
        cluster = ClusterBackend(
            [StubRemoteBackend(0.0) for _ in range(3)],
            router="least_inflight",
            specs=specs,
        )
        for name, quality in zip(STUB_NAMES, (40.0, 80.0)):
            cluster.register(Variant(name, None, None, quality))
        picks = []
        for i in range(12):
            r = cluster.route(STUB_NAMES[i % 2])
            r.backend.inflight_rows += 3 + (i % 4)
            picks.append(r.replica_id)
        return picks

    assert route_sequence(None) == route_sequence(
        [ReplicaSpec() for _ in range(3)]
    )


def test_snapshot_carries_the_replica_spec():
    from repro.serving.cluster import ReplicaSpec

    cluster = ClusterBackend(
        [StubRemoteBackend(0.0), StubRemoteBackend(0.0)],
        specs=[
            ReplicaSpec(weight=2.0, max_concurrency=8, service_scale=0.5),
            ReplicaSpec(),
        ],
    )
    snaps = {s.replica_id: s for s in cluster.snapshot()}
    assert snaps[0].weight == 2.0
    assert snaps[0].max_concurrency == 8
    assert snaps[0].service_scale == 0.5
    assert snaps[1].weight == 1.0
    assert snaps[1].max_concurrency is None
    assert snaps[1].service_scale == 1.0
