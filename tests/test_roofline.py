"""Roofline derivation units: HLO collective parsing, term combination."""
import pytest

from repro.launch import roofline as rf

HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[16,4096,2048]{2,1,0} all-gather(%x), dimensions={0}
  %ar = f32[256,1024]{1,0} all-reduce(%y), to_apply=%sum
  %rs = f32[16,64]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(%w), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(%v), source_target_pairs={{0,1}}
  %ar2 = f32[256,1024]{1,0} all-reduce(%y2), to_apply=%sum
  %tup = (f32[8,8]{1,0}, bf16[4,4]{1,0}) all-gather(%p, %q), dimensions={0}
}
"""


def test_collective_bytes_parsing():
    out = rf.collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 4096 * 2048 * 2 + (8 * 8 * 4 + 4 * 4 * 2)
    assert out["all-reduce"] == 2 * 256 * 1024 * 4
    assert out["reduce-scatter"] == 16 * 64 * 4
    assert out["all-to-all"] == 8 * 128 * 2
    assert out["collective-permute"] == 4 * 4 * 4


def test_collective_bytes_empty():
    assert rf.collective_bytes("ENTRY %main { %r = f32[2] add(%a, %b) }") == {}


def test_combine_components_scales_by_multiplier():
    comps = [
        rf.Component("layer", flops=10.0, bytes_accessed=100.0,
                     coll_bytes={"all-reduce": 5}, multiplier=32),
        rf.Component("ends", flops=7.0, bytes_accessed=3.0,
                     coll_bytes={"all-gather": 2}, multiplier=1),
    ]
    tot = rf.combine_components(comps)
    assert tot["flops"] == 10 * 32 + 7
    assert tot["bytes"] == 100 * 32 + 3
    assert tot["coll_bytes"] == 5 * 32 + 2
    assert tot["coll_by_kind"] == {"all-reduce": 160.0, "all-gather": 2.0}


def test_cost_terms_units():
    terms = rf.cost_terms({"flops": rf.HW["peak_flops"], "bytes": rf.HW["hbm_bw"],
                           "coll_bytes": rf.HW["ici_bw"]}, chips=256)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(1.0)
    assert terms["collective_s"] == pytest.approx(1.0)


def test_cell_report_dominant_and_ratio():
    rep = rf.CellReport(
        arch="a", shape="s", mesh="m", chips=4,
        terms_s={"compute_s": 0.5, "memory_s": 2.0, "collective_s": 0.1},
        totals={"flops": 100.0, "bytes": 1.0, "coll_bytes": 0.0},
        model_flops=300.0,
        bytes_per_device=None,
        coll_census={},
    )
    assert rep.dominant == "memory_s"
    assert rep.useful_ratio == pytest.approx(300.0 / 400.0)
    j = rep.to_json()
    assert j["dominant"] == "memory_s"
