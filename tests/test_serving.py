"""Serving layer: scheduler policy, engine execution, fault-tolerance paths."""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.mdinference_zoo import paper_zoo
from repro.core.duplication import HedgePolicy
from repro.core.registry import ModelProfile, ModelRegistry
from repro.models import transformer as T
from repro.serving.engine import ServingEngine, Variant
from repro.serving.profiles import ONDEVICE_TIER, estimate_ms, lm_zoo_registry
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig


def test_decide_budgets_against_network():
    sched = MDInferenceScheduler(
        paper_zoo(), ONDEVICE_TIER, SchedulerConfig(t_sla_ms=250.0)
    )
    fast_net = sched.decide(20.0)  # big budget -> accurate model
    slow_net = sched.decide(240.0)  # 10ms budget -> a fast model
    dead_net = sched.decide(249.5)  # sub-ms budget -> nothing fits: fallback
    assert sched.accuracy[fast_net.model_index] > sched.accuracy[slow_net.model_index]
    assert not slow_net.fallback
    assert dead_net.fallback


def test_observe_tracks_drift():
    """Queueing transients (paper §V-A motivation): observed slowdowns shift
    the live profile and selection adapts away from the degraded model."""
    reg = ModelRegistry(
        [
            ModelProfile("fast", 50.0, 10.0, 0.5),
            ModelProfile("big", 90.0, 100.0, 1.0),
        ]
    )
    sched = MDInferenceScheduler(
        reg, ONDEVICE_TIER, SchedulerConfig(t_sla_ms=250.0, profile_ewma=0.3)
    )
    i_big = 1
    assert sched.decide(100.0).model_index == i_big  # budget 150 fits 'big'
    for _ in range(30):
        sched.observe(i_big, 400.0)  # sustained queueing delay
    assert sched.mu[i_big] > 250.0
    assert sched.decide(100.0).model_index == 0  # now picks 'fast'


def test_run_trace_bounds_latency():
    sched = MDInferenceScheduler(
        paper_zoo(), ONDEVICE_TIER, SchedulerConfig(t_sla_ms=250.0, seed=1)
    )
    rng = np.random.default_rng(0)
    t_nw = np.abs(rng.normal(100, 80, 300)) + 1
    m = sched.run_trace(t_nw)
    assert m.sla_attainment == 1.0  # hedged => bounded
    assert m.aggregate_accuracy > 60.0


def test_hedge_policy_off_allows_violations():
    sched = MDInferenceScheduler(
        paper_zoo(),
        ONDEVICE_TIER,
        SchedulerConfig(
            t_sla_ms=250.0,
            # Never hedge (headroom -inf): outage requests must violate.
            hedge=HedgePolicy(always=False, deadline_headroom_ms=-1e12),
            seed=1,
        ),
    )
    rng = np.random.default_rng(0)
    t_nw = np.concatenate([np.full(50, 100.0), np.full(10, 400.0)])  # outages
    m = sched.run_trace(t_nw)
    assert m.sla_attainment < 1.0  # un-hedged outage requests violate


def test_estimate_ms_roofline_max():
    # Compute-bound case.
    assert estimate_ms(197e12, 1.0, 0.0, chips=1) == pytest.approx(1000.0)
    # Memory-bound case.
    assert estimate_ms(1.0, 819e9, 0.0, chips=1) == pytest.approx(1000.0)
    # Collective-bound case.
    assert estimate_ms(0.0, 0.0, 50e9, chips=1) == pytest.approx(1000.0)


def test_lm_zoo_registry_ordering():
    reg = lm_zoo_registry(chips=8)
    assert len(reg) == 8
    # Quality-sorted; xlstm is cheapest, llama4-scout highest quality.
    assert reg[0].accuracy <= reg[-1].accuracy
    mus = {p.name: p.mu_ms for p in reg}
    assert mus["xlstm-350m"] < mus["qwen3-14b"]
    assert all(p.mu_ms > 0 for p in reg)


def test_engine_generates_and_profiles():
    engine = ServingEngine(max_len=48)
    cfg = reduced("gemma-2b", n_layers=2)
    params = T.init_params(cfg, jax.random.key(0))
    engine.register(Variant("tiny", cfg, params, 42.0))
    out, ms = engine.generate("tiny", np.zeros((2, 16), np.int32), 4)
    assert out.shape == (2, 4)
    assert ms > 0
    reg = engine.measure_profiles(prompt_len=16, gen_tokens=2, trials=2)
    assert reg[0].mu_ms > 0
