"""Serving layer: scheduler policy, engine execution, fault-tolerance paths."""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.mdinference_zoo import paper_zoo
from repro.core.duplication import HedgePolicy
from repro.core.network import FixedCVNetwork, lte_trace
from repro.core.registry import ModelProfile, ModelRegistry
from repro.models import transformer as T
from repro.serving.engine import QueuedRequest, ServingEngine, Variant
from repro.serving.loadgen import (
    BurstyArrivals,
    PoissonArrivals,
    iter_windows,
    make_trace,
)
from repro.serving.profiles import ONDEVICE_TIER, estimate_ms, lm_zoo_registry
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig


def test_decide_budgets_against_network():
    sched = MDInferenceScheduler(
        paper_zoo(), ONDEVICE_TIER, SchedulerConfig(t_sla_ms=250.0)
    )
    fast_net = sched.decide(20.0)  # big budget -> accurate model
    slow_net = sched.decide(240.0)  # 10ms budget -> a fast model
    dead_net = sched.decide(249.5)  # sub-ms budget -> nothing fits: fallback
    assert sched.accuracy[fast_net.model_index] > sched.accuracy[slow_net.model_index]
    assert not slow_net.fallback
    assert dead_net.fallback


def test_observe_tracks_drift():
    """Queueing transients (paper §V-A motivation): observed slowdowns shift
    the live profile and selection adapts away from the degraded model."""
    reg = ModelRegistry(
        [
            ModelProfile("fast", 50.0, 10.0, 0.5),
            ModelProfile("big", 90.0, 100.0, 1.0),
        ]
    )
    sched = MDInferenceScheduler(
        reg, ONDEVICE_TIER, SchedulerConfig(t_sla_ms=250.0, profile_ewma=0.3)
    )
    i_big = 1
    assert sched.decide(100.0).model_index == i_big  # budget 150 fits 'big'
    for _ in range(30):
        sched.observe(i_big, 400.0)  # sustained queueing delay
    assert sched.mu[i_big] > 250.0
    assert sched.decide(100.0).model_index == 0  # now picks 'fast'


def test_run_trace_bounds_latency():
    sched = MDInferenceScheduler(
        paper_zoo(), ONDEVICE_TIER, SchedulerConfig(t_sla_ms=250.0, seed=1)
    )
    rng = np.random.default_rng(0)
    t_nw = np.abs(rng.normal(100, 80, 300)) + 1
    m = sched.run_trace(t_nw)
    assert m.sla_attainment == 1.0  # hedged => bounded
    assert m.aggregate_accuracy > 60.0


def test_hedge_policy_off_allows_violations():
    sched = MDInferenceScheduler(
        paper_zoo(),
        ONDEVICE_TIER,
        SchedulerConfig(
            t_sla_ms=250.0,
            # Never hedge (headroom -inf): outage requests must violate.
            hedge=HedgePolicy(always=False, deadline_headroom_ms=-1e12),
            seed=1,
        ),
    )
    t_nw = np.concatenate([np.full(50, 100.0), np.full(10, 400.0)])  # outages
    m = sched.run_trace(t_nw)
    assert m.sla_attainment < 1.0  # un-hedged outage requests violate


def test_estimate_ms_roofline_max():
    # Compute-bound case.
    assert estimate_ms(197e12, 1.0, 0.0, chips=1) == pytest.approx(1000.0)
    # Memory-bound case.
    assert estimate_ms(1.0, 819e9, 0.0, chips=1) == pytest.approx(1000.0)
    # Collective-bound case.
    assert estimate_ms(0.0, 0.0, 50e9, chips=1) == pytest.approx(1000.0)


def test_lm_zoo_registry_ordering():
    reg = lm_zoo_registry(chips=8)
    assert len(reg) == 8
    # Quality-sorted; xlstm is cheapest, llama4-scout highest quality.
    assert reg[0].accuracy <= reg[-1].accuracy
    mus = {p.name: p.mu_ms for p in reg}
    assert mus["xlstm-350m"] < mus["qwen3-14b"]
    assert all(p.mu_ms > 0 for p in reg)


def test_engine_generates_and_profiles():
    engine = ServingEngine(max_len=48)
    cfg = reduced("gemma-2b", n_layers=2)
    params = T.init_params(cfg, jax.random.key(0))
    engine.register(Variant("tiny", cfg, params, 42.0))
    out, ms = engine.generate("tiny", np.zeros((2, 16), np.int32), 4)
    assert out.shape == (2, 4)
    assert ms > 0
    reg = engine.measure_profiles(prompt_len=16, gen_tokens=2, trials=2)
    assert reg[0].mu_ms > 0


def test_engine_generate_zero_steps():
    """Regression: n_steps=0 used to crash on np.stack([])."""
    engine = ServingEngine(max_len=32)
    cfg = reduced("gemma-2b", n_layers=2)
    params = T.init_params(cfg, jax.random.key(0))
    engine.register(Variant("tiny", cfg, params, 42.0))
    out, ms = engine.generate("tiny", np.zeros((3, 8), np.int32), 0)
    assert out.shape == (3, 0)
    assert out.dtype == np.int32
    assert ms == 0.0


def _two_tier_engine(seed=0):
    engine = ServingEngine(max_len=48)
    for name, width, quality in (("small", 32, 40.0), ("large", 64, 80.0)):
        cfg = reduced(
            "gemma-2b", d_model=width, n_layers=2,
            n_heads=2, n_kv_heads=1, head_dim=width // 2,
        )
        params = T.init_params(cfg, jax.random.key(seed))
        engine.register(Variant(name, cfg, params, quality))
    return engine


def test_serve_queue_continuous_batching():
    engine = _two_tier_engine()
    registry = engine.measure_profiles(prompt_len=8, gen_tokens=2, trials=2)
    sched = MDInferenceScheduler(
        registry, registry[0], SchedulerConfig(t_sla_ms=5_000.0, seed=0)
    )
    rng = np.random.default_rng(1)
    requests = [
        QueuedRequest(
            rid=i,
            tokens=rng.integers(0, 64, 8),
            n_steps=2,
            t_nw_est_ms=float(50.0 + 10 * i),
            t_nw_actual_ms=float(50.0 + 10 * i),
        )
        for i in range(6)
    ]
    done, metrics = engine.serve_queue(sched, requests)
    assert [c.rid for c in done] == [0, 1, 2, 3, 4, 5]
    assert metrics.n_requests == 6
    for c in done:
        assert c.tokens.shape == (2,)
        assert c.exec_ms > 0
        assert c.latency_ms <= 5_000.0 + 1e-9  # hedged => bounded
        assert c.model_name in {"small", "large"}
    # Requests scheduled onto the same variant share one batch wall time.
    by_model = {}
    for c in done:
        by_model.setdefault(c.model_name, set()).add(c.exec_ms)
    for times in by_model.values():
        assert len(times) == 1


def test_serve_queue_empty_chunk():
    engine = _two_tier_engine()
    registry = engine.measure_profiles(prompt_len=8, gen_tokens=2, trials=2)
    sched = MDInferenceScheduler(registry, registry[0], SchedulerConfig())
    done, metrics = engine.serve_queue(sched, [])
    assert done == [] and metrics is None


# ---------------------------------------------------------------------------
# Load generation.
# ---------------------------------------------------------------------------
def test_poisson_arrivals_hit_target_rate():
    rng = np.random.default_rng(0)
    arr = PoissonArrivals(rate_rps=200.0).sample_arrivals_ms(rng, 20_000)
    assert np.all(np.diff(arr) >= 0)
    measured_rps = len(arr) / (arr[-1] / 1e3)
    assert abs(measured_rps - 200.0) / 200.0 < 0.05


def test_bursty_arrivals_are_burstier_than_poisson():
    rng = np.random.default_rng(0)
    poisson = np.diff(PoissonArrivals(100.0).sample_arrivals_ms(rng, 20_000))
    rng = np.random.default_rng(0)
    bursty = np.diff(
        BurstyArrivals(100.0, burst_factor=10.0).sample_arrivals_ms(rng, 20_000)
    )
    # MMPP gap distribution has a higher CV than exponential (CV ~= 1).
    assert bursty.std() / bursty.mean() > poisson.std() / poisson.mean()


def test_make_trace_and_windows_partition_requests():
    trace = make_trace(
        500, PoissonArrivals(100.0), FixedCVNetwork(100.0, 0.3), seed=4
    )
    assert len(trace) == 500
    assert np.all(trace.t_nw_ms > 0)
    np.testing.assert_array_equal(trace.t_nw_est_ms, trace.t_nw_ms)
    seen = np.concatenate(list(iter_windows(trace, 50.0)))
    np.testing.assert_array_equal(seen, np.arange(500))  # exactly once, in order
    for w in iter_windows(trace, 50.0):
        assert len(w) > 0
        buckets = trace.arrival_ms[w] // 50.0
        assert len(set(buckets)) == 1  # one scheduling tick per window


def test_lte_trace_is_heavier_tailed_than_university():
    from repro.core.network import university_trace

    lte = np.asarray(lte_trace().trace_ms)
    uni = np.asarray(university_trace().trace_ms)
    assert np.mean(lte > 246.8) > np.mean(uni > 246.8)
    assert lte.mean() > uni.mean()
