"""Distribution plumbing: axis rules, specs, mesh builders, dry-run proxy."""
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, reduced
from repro.distributed.api import (
    RULES_1D,
    RULES_2D,
    RULES_3D,
    AxisRules,
    axis_rules,
    constrain,
)
from repro.launch.mesh import make_elastic_mesh, make_mesh
from repro.models import transformer as T


def test_rules_translate_specs():
    r = AxisRules(None, RULES_2D)
    assert r.spec(("batch", None, "heads")) == P(("data",), None, "model")
    assert r.spec((None,)) == P(None)
    r3 = AxisRules(None, RULES_3D)
    assert r3.spec(("batch",)) == P(("pod", "data"))
    assert r3.spec(("moe_groups",)) == P(("pod", "data"))


def test_unknown_logical_axis_raises():
    r = AxisRules(None, RULES_2D)
    with pytest.raises(KeyError):
        r.spec(("nonexistent",))


def test_constrain_is_noop_without_rules():
    x = jax.numpy.ones((4, 4))
    y = constrain(x, "batch", None)
    np.testing.assert_array_equal(x, y)


def test_constrain_applies_under_rules():
    mesh = make_mesh((1,), ("data",))
    table = dict(RULES_1D)
    table["batch"] = "data"
    with axis_rules(AxisRules(mesh, table)):
        y = jax.jit(lambda x: constrain(x, "batch", None))(jax.numpy.ones((4, 4)))
    assert y.shape == (4, 4)


def test_param_axes_cover_rules():
    """Every logical axis used by any arch has a rule in every table."""
    used = set()
    for arch in ARCH_IDS:
        axes = T.param_axes(reduced(arch))
        for leaf in jax.tree.leaves(
            axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        ):
            used.update(a for a in leaf if a)
        for leaf in jax.tree.leaves(
            T.cache_axes(reduced(arch)),
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        ):
            used.update(a for a in leaf if a)
    for table in (RULES_1D, RULES_2D, RULES_3D):
        missing = used - set(table)
        assert not missing, missing


def test_elastic_mesh_single_device():
    mesh = make_elastic_mesh(model_parallel=16)
    assert int(np.prod(mesh.devices.shape)) == len(jax.devices())
    assert mesh.axis_names == ("data", "model")


def test_cache_axes_structure_matches_cache():
    for arch in ("llama3-8b", "recurrentgemma-2b", "xlstm-350m"):
        cfg = reduced(arch)
        cache = T.init_cache(cfg, 2, 16)
        axes = T.cache_axes(cfg)
        def is_axes(x):
            return (isinstance(x, tuple) and len(x) > 0 and all(
                isinstance(e, (str, type(None))) for e in x))
        ct = jax.tree.structure(cache)
        at = jax.tree.structure(axes, is_leaf=is_axes)
        assert ct == at, arch
        flat_c = jax.tree.leaves(cache)
        flat_a = jax.tree.leaves(axes, is_leaf=is_axes)
        for c, a in zip(flat_c, flat_a):
            assert len(a) == c.ndim, (arch, c.shape, a)


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """One real dry-run cell: 512 fake devices, production mesh, compile.

    Subprocess because the 512-device XLA flag must be set before jax init
    (the test process itself sees 1 device, as required).
    """
    repo = Path(__file__).resolve().parent.parent
    out = repo / "results" / "test_cell.json"
    if out.exists():
        out.unlink()
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "xlstm-350m", "--shape", "decode_32k",
            "--mesh", "single", "--no-components", "--out", str(out),
        ],
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True,
        text=True,
        timeout=560,
        cwd=repo,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json

    cells = json.loads(out.read_text())["cells"]
    assert cells[0]["status"] == "ok"
    assert cells[0]["chips"] == 256
    out.unlink()
