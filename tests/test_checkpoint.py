"""Checkpointing: atomicity, pruning, async, resharding, fault tolerance."""
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import reduced
from repro.training import init_train_state


def tiny_state(seed=0):
    cfg = reduced("gemma-2b")
    return init_train_state(cfg, jax.random.key(seed))


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    state = tiny_state()
    mgr = CheckpointManager(tmp_path)
    mgr.save(10, state, extra={"loss": 1.5})
    restored, step = mgr.restore(state)
    assert step == 10
    assert_trees_equal(state, restored)
    assert mgr.manifest(10)["extra"]["loss"] == 1.5


def test_latest_and_pruning(tmp_path):
    state = tiny_state()
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_keep_steps_survive_pruning(tmp_path):
    state = tiny_state()
    mgr = CheckpointManager(tmp_path, keep=1, keep_steps=(1,))
    for s in (1, 2, 3):
        mgr.save(s, state)
    assert 1 in mgr.all_steps()


def test_tmp_dirs_are_invisible(tmp_path):
    """A crash mid-save (leftover .tmp dir) must not corrupt restore."""
    state = tiny_state()
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, state)
    # Simulate a crashed save at a later step.
    crashed = Path(tmp_path) / "step_00000009.tmp"
    crashed.mkdir()
    (crashed / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 5
    restored, step = mgr.restore(state)
    assert step == 5


def test_async_save(tmp_path):
    state = tiny_state()
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(7, state)
    mgr.wait()
    restored, step = mgr.restore(state)
    assert step == 7
    assert_trees_equal(state, restored)


def test_restore_specific_step(tmp_path):
    s0, s1 = tiny_state(0), tiny_state(1)
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, s0)
    mgr.save(2, s1)
    restored, step = mgr.restore(s0, step=1)
    assert step == 1
    assert_trees_equal(s0, restored)


def test_reshard_on_restore(tmp_path):
    """Restore with explicit shardings (elastic restart path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state = tiny_state()
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, state)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    shardings = jax.tree.map(lambda _: sh, state)
    restored, step = mgr.restore(state, shardings=shardings)
    assert_trees_equal(state, restored)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding == sh


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(tiny_state())
