"""Model-level correctness: decode == teacher forcing, attention oracles,
recurrent-block equivalences, MoE routing semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import reduced
from repro.models import moe as moe_mod
from repro.models import rglru, transformer as T, xlstm
from repro.models.attention import (
    attention_reference,
    decode_attention,
    flash_attention,
)

jax.config.update("jax_default_matmul_precision", "highest")


# ---------------------------------------------------------------------------
# Flash attention vs naive oracle (also the Pallas kernel's reference).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "kw",
    [
        dict(),
        dict(causal=False),
        dict(window=48),
        dict(window=16),
    ],
)
@pytest.mark.parametrize("nq,nkv", [(4, 4), (4, 2), (4, 1)])
def test_flash_vs_reference(kw, nq, nkv):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    B, S, HD = 2, 128, 16
    q = jax.random.normal(k1, (B, S, nq, HD))
    k = jax.random.normal(k2, (B, S, nkv, HD))
    v = jax.random.normal(k3, (B, S, nkv, HD))
    out = flash_attention(q, k, v, chunk=32, **kw)
    ref = attention_reference(q, k, v, **kw)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_gradients_vs_reference():
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    B, S, NQ, NKV, HD = 2, 96, 4, 2, 16
    q = jax.random.normal(k1, (B, S, NQ, HD))
    k = jax.random.normal(k2, (B, S, NKV, HD))
    v = jax.random.normal(k3, (B, S, NKV, HD))

    def f(impl):
        def inner(q, k, v):
            o = impl(q, k, v)
            return jnp.sum(jnp.sin(o))
        return inner

    gf = jax.grad(f(lambda *a: flash_attention(*a, chunk=32)), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f(lambda *a: attention_reference(*a)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(a, b, atol=5e-5)


def test_flash_prefix_lm():
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    B, S, NQ, NKV, HD = 2, 64, 4, 2, 16
    q = jax.random.normal(k1, (B, S, NQ, HD))
    k = jax.random.normal(k2, (B, S, NKV, HD))
    v = jax.random.normal(k3, (B, S, NKV, HD))
    pl = jnp.array([8, 24])
    out = flash_attention(q, k, v, prefix_len=pl, chunk=32)
    ref = attention_reference(q, k, v, prefix_len=pl)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@given(
    st.integers(1, 3),  # batch
    st.sampled_from([16, 32, 48, 64]),  # seq
    st.sampled_from([(2, 1), (2, 2), (4, 2)]),  # heads
    st.sampled_from([8, 16]),  # head dim
    st.sampled_from([16, 32]),  # chunk
    st.booleans(),  # causal
)
@settings(max_examples=25, deadline=None)
def test_flash_property(B, S, heads, HD, chunk, causal):
    NQ, NKV = heads
    keys = jax.random.split(jax.random.key(S * HD + NQ), 3)
    q = jax.random.normal(keys[0], (B, S, NQ, HD))
    k = jax.random.normal(keys[1], (B, S, NKV, HD))
    v = jax.random.normal(keys[2], (B, S, NKV, HD))
    out = flash_attention(q, k, v, causal=causal, chunk=chunk)
    ref = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=3e-5)


def test_decode_attention_matches_last_position():
    """Decoding position S-1 with a cache == row S-1 of full attention."""
    keys = jax.random.split(jax.random.key(3), 3)
    B, S, NQ, NKV, HD = 2, 32, 4, 2, 16
    q = jax.random.normal(keys[0], (B, S, NQ, HD))
    k = jax.random.normal(keys[1], (B, S, NKV, HD))
    v = jax.random.normal(keys[2], (B, S, NKV, HD))
    full = attention_reference(q, k, v, causal=True)
    slot_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = decode_attention(
        q[:, -1:], k, v, slot_pos, jnp.full((B,), S - 1)
    )
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=2e-5)


def test_decode_attention_ring_buffer_window():
    """A windowed ring buffer gives the same result as full-cache windowed."""
    keys = jax.random.split(jax.random.key(4), 3)
    B, S, NQ, NKV, HD, W = 1, 64, 2, 1, 8, 16
    q = jax.random.normal(keys[0], (B, S, NQ, HD))
    k = jax.random.normal(keys[1], (B, S, NKV, HD))
    v = jax.random.normal(keys[2], (B, S, NKV, HD))
    full = attention_reference(q, k, v, causal=True, window=W)
    # Ring buffer holding the last W entries for position S-1.
    pos = S - 1
    slots = jnp.arange(W)
    ring_positions = (pos - W + 1) + ((slots - (pos - W + 1)) % W)  # absolute
    kr = k[:, ring_positions % S][:, :W]
    # simpler: place each stored position at slot p % W
    store = jnp.arange(S - W, S)
    kr = jnp.zeros((B, W, NKV, HD)).at[:, store % W].set(k[:, store])
    vr = jnp.zeros((B, W, NKV, HD)).at[:, store % W].set(v[:, store])
    sp = jnp.zeros((B, W), jnp.int32).at[:, store % W].set(
        jnp.broadcast_to(store, (B, W))
    )
    out = decode_attention(q[:, -1:], kr, vr, sp, jnp.full((B,), pos), window=W)
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=2e-5)


# ---------------------------------------------------------------------------
# Decode == teacher forcing (the serving-correctness invariant), per family.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "arch",
    ["llama3-8b", "gemma-2b", "recurrentgemma-2b", "xlstm-350m", "olmoe-1b-7b"],
)
def test_decode_matches_teacher_forcing(arch):
    cfg = reduced(arch)
    if cfg.n_experts:
        # Routing must be deterministic & capacity generous for exactness.
        import dataclasses

        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = T.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # Teacher-forced logits for every position.
    x, _, _ = T.forward_hidden(cfg, params, {"tokens": tokens})
    full_logits = T._unembed(cfg, params, x)  # (B, S, V)

    # Recurrent-state archs accumulate fp32 recurrences down 24 layers; the
    # chunkwise and stepwise orders differ in rounding, so tolerances are
    # looser there (the isolated cells match to 1e-7 — see the cell tests).
    tol = dict(rtol=3e-4, atol=3e-4)
    if arch in ("xlstm-350m", "recurrentgemma-2b"):
        tol = dict(rtol=2e-2, atol=5e-2)

    # Prefill on the first half, decode the second half token by token.
    half = S // 2
    cache, logits = T.prefill(cfg, params, {"tokens": tokens[:, :half]}, max_len=S)
    np.testing.assert_allclose(logits, full_logits[:, half - 1], **tol)
    for t in range(half, S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = T.decode_step(cfg, params, cache, tokens[:, t], pos)
        np.testing.assert_allclose(
            logits, full_logits[:, t], err_msg=f"{arch} step {t}", **tol
        )


def test_decode_matches_teacher_forcing_paligemma():
    cfg = reduced("paligemma-3b")
    params = T.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    P = cfg.num_prefix_tokens
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    patches = jnp.asarray(rng.normal(size=(B, P, cfg.frontend_dim)), jnp.float32)
    inputs = {"patches": patches, "tokens": tokens}
    x, _, _ = T.forward_hidden(cfg, params, inputs)
    full_logits = T._unembed(cfg, params, x)  # (B, P+S, V)

    half = S // 2
    cache, logits = T.prefill(
        cfg, params, {"patches": patches, "tokens": tokens[:, :half]}, max_len=P + S
    )
    np.testing.assert_allclose(logits, full_logits[:, P + half - 1], rtol=2e-4, atol=2e-4)
    for t in range(half, S):
        pos = jnp.full((B,), P + t, jnp.int32)
        logits, cache = T.decode_step(cfg, params, cache, tokens[:, t], pos)
        np.testing.assert_allclose(
            logits, full_logits[:, P + t], rtol=3e-4, atol=3e-4
        )


def test_local_attention_ring_decode_long():
    """RecurrentGemma-style decode beyond the window stays exact."""
    cfg = reduced("recurrentgemma-2b", window=8)
    params = T.init_params(cfg, jax.random.key(0))
    B, S = 1, 48
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    x, _, _ = T.forward_hidden(cfg, params, {"tokens": tokens})
    full_logits = T._unembed(cfg, params, x)
    half = 16
    # max_len deliberately smaller than S: ring buffers must wrap.
    cache, logits = T.prefill(cfg, params, {"tokens": tokens[:, :half]}, max_len=S)
    for t in range(half, S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = T.decode_step(cfg, params, cache, tokens[:, t], pos)
        np.testing.assert_allclose(
            logits, full_logits[:, t], rtol=3e-4, atol=3e-4, err_msg=f"step {t}"
        )


# ---------------------------------------------------------------------------
# Recurrent cells.
# ---------------------------------------------------------------------------
def test_rglru_scan_equals_stepwise():
    cfg = reduced("recurrentgemma-2b")
    p = {
        k: jax.random.normal(jax.random.fold_in(jax.random.key(9), i), s[0]) * 0.2
        for i, (k, s) in enumerate(rglru.rglru_init_spec(cfg).items())
    }
    B, S = 2, 24
    x = jax.random.normal(jax.random.key(10), (B, S, cfg.d_model)) * 0.5
    full, (h, tail) = rglru.rglru_apply(cfg, p, x)
    cache = rglru.rglru_init_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = rglru.rglru_decode_step(cfg, p, x[:, t : t + 1], cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full, atol=1e-5)
    np.testing.assert_allclose(cache["h"], h, atol=1e-5)


def test_mlstm_chunkwise_equals_recurrent():
    cfg = reduced("xlstm-350m", xlstm_chunk=8)
    p = {
        k: jax.random.normal(jax.random.fold_in(jax.random.key(11), i), s[0]) * 0.2
        for i, (k, s) in enumerate(xlstm.mlstm_init_spec(cfg).items())
    }
    B, S = 2, 32
    x = jax.random.normal(jax.random.key(12), (B, S, cfg.d_model)) * 0.5
    full, carry = xlstm.mlstm_apply(cfg, p, x)
    cache = xlstm.mlstm_init_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = xlstm.mlstm_decode_step(cfg, p, x[:, t : t + 1], cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full, atol=1e-5)


@given(st.integers(0, 10_000), st.sampled_from([8, 16, 32]))
@settings(max_examples=20, deadline=None)
def test_rglru_state_bounded(seed, S):
    """RG-LRU normalizer keeps |h| bounded for arbitrary inputs."""
    cfg = reduced("recurrentgemma-2b")
    p = {
        k: jax.random.normal(jax.random.fold_in(jax.random.key(13), i), s[0]) * 0.3
        for i, (k, s) in enumerate(rglru.rglru_init_spec(cfg).items())
    }
    x = jax.random.normal(jax.random.key(seed), (1, S, cfg.d_model)) * 3.0
    _, (h, _) = rglru.rglru_apply(cfg, p, x)
    assert bool(jnp.isfinite(h).all())
    assert float(jnp.abs(h).max()) < 1e3


# ---------------------------------------------------------------------------
# MoE semantics.
# ---------------------------------------------------------------------------
def _moe_cfg(**kw):
    base = dict(
        name="m", family="moe", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=0, vocab_size=64, pattern=("moe",), n_experts=4, top_k=2,
        expert_d_ff=32, moe_groups=1,
    )
    base.update(kw)
    from repro.models.config import ModelConfig

    return ModelConfig(**base)


def _moe_params(cfg, seed=3):
    return {
        k: jax.random.normal(jax.random.fold_in(jax.random.key(seed), i), s[0]) * 0.2
        for i, (k, s) in enumerate(moe_mod.moe_init_spec(cfg).items())
    }


def test_moe_matches_dense_loop():
    cfg = _moe_cfg(capacity_factor=100.0)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.key(4), (2, 8, 16)) * 0.5
    out, _ = moe_mod.moe_apply(cfg, p, x)
    logits = x.reshape(-1, 16) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tg, ti = jax.lax.top_k(probs, 2)
    tg = tg / tg.sum(-1, keepdims=True)
    ref = np.zeros((16, 16), np.float32)
    xt = np.asarray(x.reshape(-1, 16))
    for t in range(16):
        for s in range(2):
            e = int(ti[t, s])
            h = jax.nn.silu(xt[t] @ p["wi"][e]) * (xt[t] @ p["wg"][e])
            ref[t] += float(tg[t, s]) * np.asarray(h @ p["wo"][e])
    np.testing.assert_allclose(np.asarray(out).reshape(16, 16), ref, atol=1e-5)


def test_moe_capacity_drops_tokens_not_nans():
    cfg = _moe_cfg(capacity_factor=0.25, moe_groups=2)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.key(5), (2, 8, 16))
    out, aux = moe_mod.moe_apply(cfg, p, x)
    assert bool(jnp.isfinite(out).all())
    assert float(aux) > 0


def test_moe_group_invariance():
    """Grouping must not change results when capacity is generous."""
    p = _moe_params(_moe_cfg())
    x = jax.random.normal(jax.random.key(6), (2, 8, 16)) * 0.5
    outs = []
    for g in (1, 2, 4):
        cfg = _moe_cfg(capacity_factor=100.0, moe_groups=g)
        out, _ = moe_mod.moe_apply(cfg, p, x)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


def test_moe_sigmoid_router_top1_shared_expert():
    cfg = _moe_cfg(top_k=1, router_type="sigmoid", n_shared_experts=1,
                   capacity_factor=100.0)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.key(7), (1, 8, 16)) * 0.5
    out, _ = moe_mod.moe_apply(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    # Shared expert contributes: zeroing it changes the output.
    p2 = dict(p, shared_wo=jnp.zeros_like(p["shared_wo"]))
    out2, _ = moe_mod.moe_apply(cfg, p2, x)
    assert float(jnp.abs(out - out2).max()) > 1e-4


def test_int8_kv_cache_decode_close_to_fp():
    """int8 KV cache (per-entry scales): decode tracks fp teacher forcing."""
    import dataclasses

    cfg = dataclasses.replace(reduced("llama3-8b"), kv_cache_quant=True)
    params = T.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    x, _, _ = T.forward_hidden(cfg, params, {"tokens": tokens})
    full = T._unembed(cfg, params, x)
    half = S // 2
    cache, logits = T.prefill(cfg, params, {"tokens": tokens[:, :half]}, max_len=S)
    # Quantization noise bound: logits O(1-10), int8 error ~0.5.
    np.testing.assert_allclose(logits, full[:, half - 1], atol=1.0)
    agree = 0
    for t in range(half, S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = T.decode_step(cfg, params, cache, tokens[:, t], pos)
        np.testing.assert_allclose(logits, full[:, t], atol=1.0)
        agree += int((jnp.argmax(logits, -1) == jnp.argmax(full[:, t], -1)).all())
    assert agree >= (S - half) - 2  # top-1 agreement nearly everywhere
