"""Cross-tick continuous batching: fixed-shape entries + block-paged slots.

The PR's correctness contract, bottom-up:

* The block-paged slot cache conserves pages and slots — every graft is
  matched by exactly one release, ``freed == resolved + hedge_win + cancel``.
* ``ContinuousBatchingBackend.generate`` is token-exact with ``JitBackend``
  at every ladder batch size *and* every padded partial size (masked ladder
  rows and trash-page writes never leak into real rows).
* A request joining the persistent decode batch mid-flight produces the
  same tokens as whole-batch execution, with TTFT stamped at graft.
* After ``warmup`` the jit caches never grow: zero post-warmup recompiles,
  counter-asserted across all traffic shapes.
* The stepped serving loop surfaces the tier's accounting: per-tick
  ``n_joined``/``n_recycled``/``compile_count`` in ``TickStats``, per-row
  ``ttft_ms`` on completions, and the scheduler's mid-flight-join EWMA.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.mdinference_zoo import SERVING_GEOMETRY, ServingGeometry
from repro.models import transformer as T
from repro.serving.backend import (
    ContinuousBatchingBackend,
    JitBackend,
    OnDeviceBackend,
    Variant,
)
from repro.serving.block_cache import BlockPagedSlotCache, NoFreeSlot
from repro.serving.engine import QueuedRequest, ServingEngine

PROMPT, GEN = 8, 4
GEO = ServingGeometry(
    max_len=32, prompt_width=PROMPT, bs_ladder=(1, 2, 4), n_slots=8,
    page_size=8, max_steps=8,
)


def _variant(name="m", width=64, quality=80.0, seed=0):
    cfg = reduced(
        "gemma-2b", d_model=width, n_layers=2,
        n_heads=2, n_kv_heads=1, head_dim=width // 2,
    )
    return Variant(name, cfg, T.init_params(cfg, jax.random.key(seed)), quality)


@pytest.fixture(scope="module")
def variant():
    return _variant()


@pytest.fixture(scope="module")
def backend(variant):
    be = ContinuousBatchingBackend(GEO)
    be.register(variant)
    be.warmup()
    be.compiles_after_warmup = be.compile_count
    return be


@pytest.fixture(scope="module")
def jit_backend(variant):
    jb = JitBackend(max_len=GEO.max_len)
    jb.register(variant)
    return jb


def _prompts(n, seed=3):
    return np.random.default_rng(seed).integers(
        0, 64, (n, PROMPT)
    ).astype(np.int32)


# ---------------------------------------------------------------------------
# Block-paged slot cache.
# ---------------------------------------------------------------------------
def test_block_cache_lifecycle_and_conservation():
    cache = BlockPagedSlotCache(
        n_slots=2, n_pages=5, page_size=4, pages_per_slot=2
    )
    a = cache.begin_prefill(prompt_len=4, n_steps=4)
    b = cache.begin_prefill(prompt_len=4, n_steps=4)
    with pytest.raises(NoFreeSlot):
        cache.begin_prefill(prompt_len=4, n_steps=4)
    cache.commit_graft(a.index)
    cache.commit_graft(b.index)
    # Trash-padded tables: every entry is a real page id or the trash page.
    table = cache.page_table(a.index)
    assert table.dtype == np.int32 and table.shape == (2,)
    assert (table > 0).sum() == cache.pages_needed(4, 4)
    cache.release(a.index, "resolved")
    cache.release(b.index, "hedge_win")
    c = cache.begin_prefill(prompt_len=4, n_steps=4)  # slot recycles
    cache.commit_graft(c.index)
    cache.release(c.index, "cancel")
    stats = cache.stats()
    assert stats["grafted"] == 3 and stats["freed"] == 3
    assert stats["freed_resolved"] == 1
    assert stats["freed_hedge_win"] == 1
    assert stats["freed_cancel"] == 1
    cache.check_conservation()
    assert len(cache.free_slots) == 2


def test_block_cache_never_hands_out_trash_page():
    cache = BlockPagedSlotCache(
        n_slots=4, n_pages=9, page_size=4, pages_per_slot=2
    )
    seen = set()
    for _ in range(4):
        s = cache.begin_prefill(prompt_len=4, n_steps=4)
        pages = set(int(p) for p in cache.page_table(s.index) if p != 0)
        assert 0 not in pages
        assert not (pages & seen)  # disjoint reservations
        seen |= pages


# ---------------------------------------------------------------------------
# Generate equivalence: every ladder size + padded partials.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B", [1, 2, 3, 4, 5, 6])
def test_generate_matches_jit_backend(backend, jit_backend, B):
    """Ladder sizes (1, 2, 4) and partial chunks (3 -> 2+1, 5 -> 4+1,
    6 -> 4+2) — padded rows and trash writes never touch real outputs."""
    toks = _prompts(B, seed=B)
    out_c, _ = backend.generate("m", toks, GEN)
    out_j, _ = jit_backend.generate("m", toks, GEN)
    np.testing.assert_array_equal(out_c, out_j)


def test_single_step_and_zero_step(backend, jit_backend):
    toks = _prompts(2)
    out_c, _ = backend.generate("m", toks, 1)  # retires at graft
    out_j, _ = jit_backend.generate("m", toks, 1)
    np.testing.assert_array_equal(out_c, out_j)
    h = backend.submit_batch("m", toks, 0)
    assert h.poll() and h.result().shape == (2, 0)


def test_shape_validation(backend):
    wide = np.zeros((1, GEO.prompt_width + 1), np.int32)
    with pytest.raises(ValueError):
        backend.submit_batch("m", wide, GEN)
    with pytest.raises(ValueError):
        backend.submit_batch("m", _prompts(1), GEO.max_steps + 1)


# ---------------------------------------------------------------------------
# Mid-flight join.
# ---------------------------------------------------------------------------
def test_midflight_join_token_exact(backend, jit_backend):
    toks = _prompts(5, seed=9)
    h1 = backend.submit_batch("m", toks[:3], GEN, sync=False)
    backend.pump()
    backend.pump()  # h1 is mid-decode...
    h2 = backend.submit_batch("m", toks[3:], GEN, sync=False)  # ...h2 joins
    assert all(t is not None for t in h2.ttft_wall_ms)
    out1, _ = h1.wait()
    out2, _ = h2.wait()
    ref, _ = jit_backend.generate("m", toks, GEN)
    np.testing.assert_array_equal(np.vstack([out1, out2]), ref)


def test_early_release_recycles_slots(backend, jit_backend):
    toks = _prompts(4, seed=11)
    free_before = len(backend._engines["m"].cache_mgr.free_slots)
    h = backend.submit_batch("m", toks, GEN, sync=False)
    backend.pump()
    h.release_rows([0], "hedge_win")
    h.release_rows([2], "cancel")
    assert h.released_rows == {0: "hedge_win", 2: "cancel"}
    out, _ = h.wait()
    assert len(backend._engines["m"].cache_mgr.free_slots) == free_before
    # Surviving rows still decode to the whole-batch reference.
    ref, _ = jit_backend.generate("m", toks, GEN)
    np.testing.assert_array_equal(out[[1, 3]], ref[[1, 3]])
    # Released rows keep their tokens up to the release point, zero after.
    assert np.array_equal(out[0, :2], ref[0, :2]) and (out[0, 2:] == 0).all()
    backend.check_conservation()


# ---------------------------------------------------------------------------
# The two counter invariants.
# ---------------------------------------------------------------------------
def test_zero_recompiles_after_warmup(backend):
    """Runs after the traffic above (module order): every shape the tier
    has seen — all ladder sizes, partials, joins, releases — and the jit
    caches hold exactly the warmup executables."""
    for B in (1, 3, 5):
        backend.generate("m", _prompts(B), GEN)
    assert backend.compile_count == backend.compiles_after_warmup


def test_slot_recycle_conservation(backend):
    """freed == hedge wins + cancels + resolutions, pool fully drained."""
    stats = backend.slot_stats("m")
    assert stats["freed"] == (
        stats["freed_resolved"]
        + stats["freed_hedge_win"]
        + stats["freed_cancel"]
    )
    assert stats["grafted"] == stats["freed"]  # nothing in flight leaks
    assert stats["freed_hedge_win"] >= 1 and stats["freed_cancel"] >= 1
    assert stats["free_slots"] == GEO.n_slots
    backend.check_conservation()
    assert backend.joined_total == backend.recycled_total == stats["grafted"]


# ---------------------------------------------------------------------------
# Satellite: the max_len knob comes from the zoo geometry.
# ---------------------------------------------------------------------------
def test_backend_max_len_defaults_to_geometry():
    assert JitBackend().max_len == SERVING_GEOMETRY.max_len
    assert JitBackend(max_len=48).max_len == 48
    assert OnDeviceBackend.from_zoo().max_len == SERVING_GEOMETRY.max_len


# ---------------------------------------------------------------------------
# The stepped serving loop.
# ---------------------------------------------------------------------------
def test_loop_stepped_tick_accounting(variant):
    from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

    hedge = OnDeviceBackend.from_zoo(max_len=GEO.max_len)
    engine = ServingEngine(
        hedge_backend=hedge, continuous=True, geometry=GEO
    )
    engine.register(variant)
    assert engine.dispatch == "stepped"
    registry = engine.measure_profiles(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    ondevice = hedge.measure_profile(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    # Pre-warm the hedge at the tick's pow2 batch shape so its inline
    # compile cannot burn the SLA budget mid-race.
    for N in (2, 4):
        hedge.run_batch(hedge.hedge_name, np.zeros((N, PROMPT), np.int32), GEN)
    engine.backend.warmup()
    compiles = engine.backend.compile_count
    joined_before = engine.backend.joined_total

    sched = MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=2000.0, seed=0)
    )
    loop = engine.make_loop(sched)
    toks = _prompts(4, seed=21)
    for i in range(4):
        loop.submit(
            QueuedRequest(
                rid=i, tokens=toks[i], n_steps=GEN,
                t_nw_est_ms=50.0, t_nw_actual_ms=50.0,
            )
        )
    assert loop.tick(now_ms=100.0, wait=False) is None
    results = []
    for _ in range(200):
        results = loop.poll()
        if results:
            break
    assert len(results) == 1
    res = results[0]
    assert len(res.completions) == 4
    assert res.stats.n_joined == 4
    assert res.stats.n_recycled == 4
    assert res.stats.compile_count == compiles  # no tick-time recompiles
    assert engine.backend.joined_total - joined_before == 4
    for c in res.completions:
        assert c.ttft_ms is not None and 0.0 < c.ttft_ms < 1e4
    # Mid-flight-join EWMA observed every joined row.
    assert int(sched.join_count.sum()) == 4
    mu = sched.join_ttft_mu[~np.isnan(sched.join_ttft_mu)]
    assert mu.size >= 1 and (mu > 0).all()
    engine.backend.check_conservation()


# ---------------------------------------------------------------------------
# Streaming: every decode token pushed before resolution, TTFT-stamped.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dispatch", ["stepped", "sync"])
def test_stream_yields_every_decode_token_before_resolution(variant, dispatch):
    from repro.core.duplication import HedgePolicy
    from repro.serving.client import InferenceClient
    from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

    hedge = OnDeviceBackend.from_zoo(max_len=GEO.max_len)
    engine = ServingEngine(
        hedge_backend=hedge, continuous=True, geometry=GEO, dispatch=dispatch
    )
    engine.register(variant)
    registry = engine.measure_profiles(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    ondevice = hedge.measure_profile(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    engine.backend.warmup()
    # Selective hedging with a huge SLA: the duplicate never engages, so
    # the remote decode stream deterministically runs to completion.
    sched = MDInferenceScheduler(
        registry, ondevice,
        SchedulerConfig(
            t_sla_ms=60_000.0, seed=0,
            hedge=HedgePolicy(always=False, deadline_headroom_ms=0.0),
        ),
    )
    loop = engine.make_loop(sched)
    fut = InferenceClient(loop).submit(
        _prompts(1, seed=9)[0], n_steps=GEN, t_nw_est_ms=10.0
    )
    chunks, done_at_yield = [], []
    for chunk in fut.stream():
        chunks.append(chunk)
        done_at_yield.append(fut.done())
    c = fut.result(timeout=0)  # already resolved when the stream ends
    assert c.used_remote and not c.hedged
    # Every decode token streamed, in order, with monotone emission stamps
    # (distinct pushes, not the no-channel one-burst fallback).
    assert [ch.index for ch in chunks] == list(range(GEN))
    np.testing.assert_array_equal([ch.token for ch in chunks], c.tokens)
    assert all(a.wall_ms <= b.wall_ms for a, b in zip(chunks, chunks[1:]))
    # The first chunk shares the backend's TTFT stamp exactly.
    assert c.ttft_ms is not None
    assert chunks[0].wall_ms - fut.tier_dispatch_wall_ms["remote"] == (
        pytest.approx(c.ttft_ms, abs=1e-6)
    )
    if dispatch == "stepped":
        # Stepped polling surfaces tokens incrementally: the early tokens
        # arrive while the request is still in flight.
        assert not done_at_yield[0]
    engine.backend.check_conservation()


# ---------------------------------------------------------------------------
# Adaptive stack: the slot ledger stays conserved while the admission
# queue's max_pending is retuned mid-run (PR 9's controller surface).
# ---------------------------------------------------------------------------
def test_slot_ledger_conserved_under_midrun_retunes(variant):
    from repro.serving.admission import AdmissionConfig
    from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

    engine = ServingEngine(continuous=True, geometry=GEO, dispatch="sync")
    engine.register(variant)
    registry = engine.measure_profiles(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    ondevice = registry.profiles[0]
    sched = MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=60_000.0, seed=0)
    )
    loop = engine.make_loop(
        sched,
        admission=AdmissionConfig(max_pending=4, max_chunk=4, policy="shed"),
    )
    joined_before = engine.backend.joined_total  # profiling grafts rows too
    toks = _prompts(10, seed=33)
    futures, t = [], 0.0
    # Three waves, with the capacity knobs moving between every tick —
    # including a shrink below the current backlog.
    for wave, (mp, headroom) in enumerate(
        [(4, 0.0), (2, 50.0), (6, 0.0)]
    ):
        loop.admission.retune(max_pending=mp, shed_headroom_ms=headroom)
        for i in range(3):
            rid = wave * 3 + i
            futures.append(
                loop.submit(
                    QueuedRequest(
                        rid=rid, tokens=toks[rid], n_steps=GEN,
                        t_nw_est_ms=10.0, t_nw_actual_ms=10.0, arrival_ms=t,
                    )
                )
            )
        t += 100.0
        loop.tick(now_ms=t)
    while loop.backlog:
        t += 100.0
        if loop.tick(now_ms=t) is None:
            break
    # Request conservation across the retuned run...
    from repro.serving.lifecycle import RequestState

    resolved = sum(f.state is RequestState.RESOLVED for f in futures)
    assert resolved + loop.admission.n_rejected == len(futures)
    # ...and the block-paged slot ledger balances: every graft recycled.
    engine.backend.check_conservation()
    assert engine.backend.joined_total == engine.backend.recycled_total
    assert engine.backend.joined_total - joined_before == resolved
