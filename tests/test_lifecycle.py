"""Request lifecycle: InferenceFuture states, cancel/timeout, per-request SLA.

Pure-logic paths run on sleep-based stub backends (deterministic, no XLA);
the client-facing integration paths run on real tiny variants.
"""
import numpy as np
import pytest

import jax

from repro.configs import reduced
from repro.models import transformer as T
from repro.serving.backend import OnDeviceBackend
from repro.serving.client import InferenceClient
from repro.serving.engine import ServingEngine, Variant
from repro.serving.lifecycle import (
    InferenceFuture,
    QueuedRequest,
    RequestCancelled,
    RequestState,
)
from repro.serving.loop import ServingLoop
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

from loop_stubs import StubHedgeBackend, StubRemoteBackend, stub_scheduler

MAX_LEN = 48
PROMPT, GEN = 8, 2


@pytest.fixture(scope="module")
def real_loop_parts():
    """(engine, registry, ondevice profile) over real tiny variants."""
    hedge = OnDeviceBackend.from_zoo(max_len=MAX_LEN)
    engine = ServingEngine(max_len=MAX_LEN, hedge_backend=hedge)
    for name, width, quality in (("small", 32, 40.0), ("large", 64, 80.0)):
        cfg = reduced(
            "gemma-2b", d_model=width, n_layers=2,
            n_heads=2, n_kv_heads=1, head_dim=width // 2,
        )
        engine.register(
            Variant(name, cfg, T.init_params(cfg, jax.random.key(0)), quality)
        )
    registry = engine.measure_profiles(prompt_len=PROMPT, gen_tokens=GEN, trials=2)
    ondevice = hedge.measure_profile(prompt_len=PROMPT, gen_tokens=GEN, trials=2)
    return engine, registry, ondevice


def _client(real_loop_parts, t_sla_ms=5_000.0, seed=0, dispatch="async"):
    engine, registry, ondevice = real_loop_parts
    sched = MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=t_sla_ms, seed=seed)
    )
    loop = engine.make_loop(sched, dispatch=dispatch)
    return InferenceClient(loop), loop, sched


def _prompt(seed=1):
    return np.random.default_rng(seed).integers(0, 64, PROMPT)


# ---------------------------------------------------------------------------
# State machine + timestamps.
# ---------------------------------------------------------------------------
def test_future_walks_the_lifecycle(real_loop_parts):
    client, loop, _ = _client(real_loop_parts)
    f = client.submit(_prompt(), GEN, t_nw_est_ms=50.0)
    assert f.state is RequestState.QUEUED
    assert not f.done()
    assert f.time_to_schedule_ms is None

    res = loop.tick(now_ms=30.0)
    assert f.state is RequestState.RESOLVED
    assert f.done() and not f.cancelled()
    assert f.scheduled_ms == 30.0
    assert f.time_to_schedule_ms == pytest.approx(30.0)
    # Both tiers' dispatch/done wall stamps were recorded (hedged request).
    assert set(f.tier_dispatch_wall_ms) == {"remote", "ondevice"}
    assert set(f.tier_done_wall_ms) == {"remote", "ondevice"}
    for tier in ("remote", "ondevice"):
        assert f.tier_done_wall_ms[tier] >= f.tier_dispatch_wall_ms[tier]

    c = f.result()
    assert c is res.completions[0]
    assert c.time_to_schedule_ms == pytest.approx(30.0)
    assert f.resolved_ms == pytest.approx(c.latency_ms)  # arrival was 0


def test_result_drives_the_loop_single_threaded(real_loop_parts):
    client, loop, _ = _client(real_loop_parts)
    f1 = client.submit(_prompt(1), GEN, t_nw_est_ms=40.0)
    f2 = client.submit(_prompt(2), GEN, t_nw_est_ms=40.0)
    c1 = f1.result()  # no one ticked the loop: result() must flush it
    assert c1.rid == f1.rid
    assert f2.done()  # same tick served the whole pending chunk
    assert f2.result().race_resolution in ("remote_won", "ondevice_won")


def test_result_timeout_raises_on_detached_future():
    f = InferenceFuture(
        QueuedRequest(
            rid=0, tokens=np.zeros(4, np.int32), n_steps=1,
            t_nw_est_ms=0.0, t_nw_actual_ms=0.0,
        )
    )
    with pytest.raises(TimeoutError):
        f.result(timeout=0.02)


# ---------------------------------------------------------------------------
# Cancellation.
# ---------------------------------------------------------------------------
def test_cancel_queued_request_never_dispatches(real_loop_parts):
    client, loop, _ = _client(real_loop_parts)
    f_live = client.submit(_prompt(1), GEN, t_nw_est_ms=40.0)
    f_dead = client.submit(_prompt(2), GEN, t_nw_est_ms=40.0)
    assert f_dead.cancel() is True
    assert f_dead.state is RequestState.CANCELLED
    assert f_dead.done() and f_dead.cancelled()
    res = loop.tick()
    assert [c.rid for c in res.completions] == [f_live.rid]
    assert res.metrics.n_requests == 1
    with pytest.raises(RequestCancelled):
        f_dead.result()
    assert f_dead.cancel() is False  # already settled


def test_cancelled_hedged_request_frees_its_ondevice_slot():
    """Satellite: a QUEUED cancel releases the duplicate-batch slot; an
    in-flight cancel discards the result but still folds the EWMA."""
    sched = stub_scheduler(t_sla_ms=1_000.0)
    remote = StubRemoteBackend(delay_s=0.01)
    hedge = StubHedgeBackend(delay_s=0.01)
    loop = ServingLoop(sched, remote, hedge, dispatch="async")
    futures = [
        loop.submit(
            QueuedRequest(
                rid=i, tokens=np.zeros(4, np.int32), n_steps=GEN,
                t_nw_est_ms=10.0, t_nw_actual_ms=10.0,
            )
        )
        for i in range(3)
    ]
    futures[1].cancel()  # QUEUED: freed before the duplicate batch is built
    mu0 = sched.ondevice_mu
    res = loop.tick()
    # The duplicate batch only carried the two live rows.
    assert res.stats.hedge_rows == 2
    assert hedge.batch_rows == [2]  # pow2-padded rows actually executed
    assert [c.rid for c in res.completions] == [0, 2]
    assert sched.ondevice_mu != mu0  # measured hedge folded into the EWMA


def test_inflight_cancel_discards_result_but_folds_ewma():
    sched = stub_scheduler(t_sla_ms=1_000.0)
    remote = StubRemoteBackend(delay_s=0.05)
    hedge = StubHedgeBackend(delay_s=0.05)
    loop = ServingLoop(sched, remote, hedge, dispatch="async")
    futures = [
        loop.submit(
            QueuedRequest(
                rid=i, tokens=np.zeros(4, np.int32), n_steps=GEN,
                t_nw_est_ms=10.0, t_nw_actual_ms=10.0,
            )
        )
        for i in range(2)
    ]
    mu0 = sched.ondevice_mu
    assert loop.tick(wait=False) is None  # dispatched, not collected
    assert all(f.state is RequestState.EXECUTING for f in futures)
    assert futures[0].cancel() is False  # batched execution can't be recalled
    results = loop.drain()
    assert len(results) == 1
    res = results[0]
    # The cancelled request's result is discarded; the other resolves.
    assert [c.rid for c in res.completions] == [1]
    assert futures[0].cancelled()
    with pytest.raises(RequestCancelled):
        futures[0].result()
    assert futures[1].result().rid == 1
    # Its tier really executed: the measurement still folded into the EWMA.
    assert sched.ondevice_mu != mu0
    assert res.metrics.n_requests == 1


# ---------------------------------------------------------------------------
# Per-request SLA.
# ---------------------------------------------------------------------------
def test_per_request_sla_races_and_budgets(real_loop_parts):
    client, loop, sched = _client(real_loop_parts, t_sla_ms=5_000.0)
    # Same network; one request carries a 10ms SLA the remote tier cannot
    # meet (network alone is 50ms), one inherits the loop's generous SLA.
    f_tight = client.submit(_prompt(1), GEN, sla=10.0, t_nw_est_ms=50.0)
    f_loose = client.submit(_prompt(2), GEN, t_nw_est_ms=50.0)
    tight, loose = f_tight.result(), f_loose.result()
    assert tight.race_resolution == "ondevice_won"
    assert not tight.used_remote
    # Resolution raced the per-request SLA: expiry or the duplicate finish.
    assert tight.latency_ms == pytest.approx(max(tight.ondevice_ms, 10.0))
    assert loose.race_resolution == "remote_won"
    assert loose.latency_ms == pytest.approx(loose.remote_ms)


def test_per_request_sla_tightens_the_budget():
    """A tighter per-request SLA must steer selection to cheaper variants.

    Stub profiles pin the feasibility boundary: stub-a mu=30ms, stub-b
    mu=60ms.  A 55ms SLA minus the 10ms network estimate leaves a 45ms
    budget — stub-b can never fit, while the loop-wide 1s SLA fits both.
    """
    picks = {}
    for sla in (None, 55.0):
        sched = stub_scheduler(t_sla_ms=1_000.0, seed=3)
        loop = ServingLoop(
            sched, StubRemoteBackend(0.001), StubHedgeBackend(0.001),
            dispatch="sync",
        )
        client = InferenceClient(loop)
        futures = [
            client.submit(np.zeros(4, np.int32), GEN, sla=sla, t_nw_est_ms=10.0)
            for _ in range(8)
        ]
        picks[sla] = [f.result().model_index for f in futures]
    assert all(p == 0 for p in picks[55.0])  # stub-b infeasible at 45ms
    assert any(p == 1 for p in picks[None])  # generous budget uses stub-b
    assert np.mean(picks[None]) > np.mean(picks[55.0])
