"""Benchmark result plumbing: the ``results/BENCH_<bench>.json`` schema,
merge-by-row-name semantics for partial re-runs, and round-tripping.
"""
import json

import pytest

from benchmarks.common import (
    RESULTS,
    SCHEMA_VERSION,
    emit,
    reset_results,
    write_results,
)


@pytest.fixture(autouse=True)
def _clean_accumulator():
    reset_results()
    yield
    reset_results()


def _read(path):
    with open(path) as f:
        return json.load(f)


def test_write_results_schema(tmp_path, capsys):
    emit("serving/x", 12.34, "note=a")
    emit("serving/y", 5.0, "note=b")
    path = write_results("serving", out_dir=str(tmp_path))
    payload = _read(path)
    assert payload["schema_version"] == SCHEMA_VERSION
    assert payload["bench"] == "serving"
    assert [r["name"] for r in payload["rows"]] == ["serving/x", "serving/y"]
    assert payload["rows"][0] == {
        "name": "serving/x", "us_per_call": 12.34, "derived": "note=a"
    }
    # The accumulator was flushed.
    assert RESULTS == []
    # emit also printed the CSV line.
    out = capsys.readouterr().out
    assert "serving/x,12.3,note=a" in out


def test_partial_rerun_merges_by_row_name(tmp_path):
    emit("serving/x", 1.0, "v1")
    emit("serving/y", 2.0, "v1")
    emit("serving/z", 3.0, "v1")
    write_results("serving", out_dir=str(tmp_path))

    # A partial re-run: updates one existing row, appends one new row.
    emit("serving/y", 20.0, "v2")
    emit("serving/new", 4.0, "v2")
    path = write_results("serving", out_dir=str(tmp_path))

    rows = {r["name"]: r for r in _read(path)["rows"]}
    assert set(rows) == {"serving/x", "serving/y", "serving/z", "serving/new"}
    assert rows["serving/x"]["derived"] == "v1"  # untouched rows survive
    assert rows["serving/y"]["us_per_call"] == 20.0  # fresh rows win
    assert rows["serving/new"]["derived"] == "v2"
    # File row order stays stable for the pre-existing names.
    names = [r["name"] for r in _read(path)["rows"]]
    assert names[:3] == ["serving/x", "serving/y", "serving/z"]


def test_round_trip_preserves_rows_exactly(tmp_path):
    emit("serving/a", 0.0, "zero-cost row")
    emit("serving/b", 123.456, "p99=1.0ms")
    path = write_results("serving", out_dir=str(tmp_path))
    first = _read(path)

    # Writing an empty accumulator round-trips the file unchanged.
    path2 = write_results("serving", out_dir=str(tmp_path))
    assert path2 == path
    assert _read(path) == first


def test_mismatched_or_corrupt_existing_file_is_overwritten(tmp_path):
    path = tmp_path / "BENCH_serving.json"
    path.write_text("{not json")
    emit("serving/x", 1.0, "v")
    write_results("serving", out_dir=str(tmp_path))
    assert [r["name"] for r in _read(path)["rows"]] == ["serving/x"]

    # A different bench's file never merges into this one's rows.
    emit("other/row", 2.0, "v")
    other = write_results("other", out_dir=str(tmp_path))
    assert other != str(path)
    assert [r["name"] for r in _read(other)["rows"]] == ["other/row"]
