"""Jax-free, picklable stub backends for transport-layer tests.

A spawned transport worker unpickles its backend *factory* and imports
whatever that factory's module imports — these stubs import only numpy
and time, so process-transport tests skip the child-side jax import
entirely (cheap enough for tier-1 CI).

The worker protocol is duck-typed: it needs only ``register`` and
``run_batch`` (``repro.serving.transport_worker``), so the stubs do not
subclass :class:`repro.serving.backend.ExecutionBackend`.
"""
import time

import numpy as np


class StubVariant:
    """Picklable variant stand-in (the transport only reads ``.name``)."""

    def __init__(self, name: str, quality: float = 50.0):
        self.name = name
        self.quality = quality


class StubWorkerBackend:
    """Deterministic echo backend: token ``(i, j)`` is ``batch[i, 0] + j``,
    so the parent can verify a batch crossed the boundary intact."""

    def __init__(self, delay_s: float = 0.0):
        self.variants = {}
        self.delay_s = delay_s

    def register(self, v):
        self.variants[v.name] = v

    def run_batch(self, name, batch, n_steps):
        t0 = time.perf_counter()
        if self.delay_s:
            time.sleep(self.delay_s)
        base = np.asarray(batch)[:, :1].astype(np.int32)
        out = base + np.arange(n_steps, dtype=np.int32)[None, :]
        return out, (time.perf_counter() - t0) * 1e3

    def generate(self, name, tokens, n_steps):
        return self.run_batch(name, tokens, n_steps)


class SlowWorkerBackend(StubWorkerBackend):
    """Every batch takes 0.2s — long enough to kill a worker mid-batch."""

    def __init__(self):
        super().__init__(delay_s=0.2)


class HangingWorkerBackend(StubWorkerBackend):
    """Every batch wedges far past any test timeout (the timeout path)."""

    def __init__(self):
        super().__init__(delay_s=60.0)


class ExplodingWorkerBackend(StubWorkerBackend):
    """Raises on every batch of the variant named ``"boom"``."""

    def run_batch(self, name, batch, n_steps):
        if name == "boom":
            raise ValueError("synthetic execution failure")
        return super().run_batch(name, batch, n_steps)
