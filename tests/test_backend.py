"""Two-tier hedged execution: backend layer + measured-hedge resolution.

The tentpole's correctness contract: hedged requests resolve on *measured*
on-device wall time when an ``OnDeviceBackend`` is attached, while the
sampled-hedge simulation (no hedge backend) and ``chunk_size=1`` remain the
scalar references — the sampled path must stay bit-identical to driving
the scheduler's ``decide/observe/resolve`` chunk API directly.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.configs.mdinference_zoo import ONDEVICE_HEDGE
from repro.core.duplication import resolve_duplication
from repro.models import transformer as T
from repro.serving.backend import JitBackend, OnDeviceBackend, build_hedge_variant
from repro.serving.engine import QueuedRequest, ServingEngine, Variant
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

MAX_LEN = 48
PROMPT, GEN = 8, 2


def _tiny_variant(name, width, quality, seed=0):
    cfg = reduced(
        "gemma-2b", d_model=width, n_layers=2,
        n_heads=2, n_kv_heads=1, head_dim=width // 2,
    )
    return Variant(name, cfg, T.init_params(cfg, jax.random.key(seed)), quality)


@pytest.fixture(scope="module")
def hedge_backend():
    return OnDeviceBackend.from_zoo(max_len=MAX_LEN)


@pytest.fixture(scope="module")
def engine_pair(hedge_backend):
    """(measured-hedge engine, sampled-hedge engine) sharing variants."""
    measured = ServingEngine(max_len=MAX_LEN, hedge_backend=hedge_backend)
    sampled = ServingEngine(max_len=MAX_LEN)
    for name, width, quality in (("small", 32, 40.0), ("large", 64, 80.0)):
        v = _tiny_variant(name, width, quality)
        measured.register(v)
        sampled.register(v)
    return measured, sampled


def _scheduler(engine, t_sla_ms, seed=0, **kw):
    registry = engine.measure_profiles(prompt_len=PROMPT, gen_tokens=GEN, trials=2)
    ondevice = (
        engine.hedge_backend.measure_profile(
            prompt_len=PROMPT, gen_tokens=GEN, trials=2
        )
        if engine.hedge_backend is not None
        else registry[0]
    )
    return MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=t_sla_ms, seed=seed, **kw)
    )


def _requests(n=6, seed=1, nw=50.0):
    rng = np.random.default_rng(seed)
    return [
        QueuedRequest(
            rid=i,
            tokens=rng.integers(0, 64, PROMPT),
            n_steps=GEN,
            t_nw_est_ms=float(nw + 10 * i),
            t_nw_actual_ms=float(nw + 10 * i),
        )
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Backend layer.
# ---------------------------------------------------------------------------
def test_engine_delegates_to_jit_backend():
    backend = JitBackend(max_len=MAX_LEN)
    engine = ServingEngine(max_len=MAX_LEN, backend=backend)
    engine.register(_tiny_variant("tiny", 32, 42.0))
    assert engine.variants is backend.variants
    tokens = np.zeros((2, PROMPT), np.int32)
    out_e, _ = engine.generate("tiny", tokens, GEN)
    out_b, _ = backend.generate("tiny", tokens, GEN)
    np.testing.assert_array_equal(out_e, out_b)  # greedy decode: deterministic


def test_run_batch_warms_once():
    backend = JitBackend(max_len=MAX_LEN)
    backend.register(_tiny_variant("tiny", 32, 42.0))
    batch = np.zeros((2, PROMPT), np.int32)
    assert not backend._warmed_shapes
    backend.run_batch("tiny", batch, GEN)
    assert ("tiny", 2, PROMPT, GEN) in backend._warmed_shapes


def test_ondevice_backend_hosts_one_hedge_variant(hedge_backend):
    assert hedge_backend.hedge_name == ONDEVICE_HEDGE.name
    assert list(hedge_backend.variants) == [ONDEVICE_HEDGE.name]
    with pytest.raises(ValueError):
        hedge_backend.register(_tiny_variant("other", 32, 10.0))
    out, wall = hedge_backend.hedge(np.zeros((2, PROMPT), np.int32), GEN)
    assert out.shape == (2, GEN)
    assert wall > 0


def test_ondevice_profile_carries_zoo_quality(hedge_backend):
    prof = hedge_backend.measure_profile(prompt_len=PROMPT, gen_tokens=GEN, trials=2)
    assert prof.accuracy == ONDEVICE_HEDGE.quality
    assert prof.mu_ms > 0


# ---------------------------------------------------------------------------
# Measured-hedge resolution (the tentpole).
# ---------------------------------------------------------------------------
def test_measured_hedge_uses_real_wall_time(engine_pair):
    engine, _ = engine_pair
    sched = _scheduler(engine, t_sla_ms=5_000.0)
    mu0 = sched.ondevice_mu
    done, _ = engine.serve_queue(sched, _requests())
    hedged = [c for c in done if c.hedged]
    assert hedged, "paper's default policy hedges every request"
    for c in hedged:
        assert c.hedge_measured
        assert c.ondevice_ms is not None and c.ondevice_ms > 0
    # All duplicates rode one hedge batch: one shared measured wall time.
    assert len({c.ondevice_ms for c in hedged}) == 1
    # The measurement folded into the live on-device EWMA profile.
    assert sched.ondevice_mu != mu0


def test_measured_hedge_wins_when_remote_misses_sla(engine_pair):
    engine, _ = engine_pair
    # Network alone (>=50ms) exceeds the 20ms SLA: every remote result is
    # late, so the on-device duplicate must answer every request.
    sched = _scheduler(engine, t_sla_ms=20.0)
    done, metrics = engine.serve_queue(sched, _requests())
    hedge = engine.hedge_backend
    for c in done:
        assert not c.used_remote
        assert c.accuracy == hedge.variants[hedge.hedge_name].quality
        # Resolution on measured times: SLA expiry or the (measured)
        # duplicate finish, whichever is later.
        assert c.latency_ms == pytest.approx(max(c.ondevice_ms, 20.0))
        assert c.tokens.shape == (GEN,)
    assert metrics.ondevice_reliance == 1.0


def test_hedge_winner_returns_hedge_tier_tokens(engine_pair):
    engine, _ = engine_pair
    sched = _scheduler(engine, t_sla_ms=20.0)
    reqs = _requests(n=2)
    done, _ = engine.serve_queue(sched, reqs)
    hedge = engine.hedge_backend
    # Reproduce the duplicate's batch to check the returned tokens really
    # came from the hedge variant (greedy decode is deterministic).
    width = max(len(r.tokens) for r in reqs)
    batch = np.zeros((2, width), np.int32)
    for row, r in enumerate(reqs):
        batch[row, : len(r.tokens)] = r.tokens
    expected, _ = hedge.generate(hedge.hedge_name, batch, GEN)
    for row, c in enumerate(done):
        np.testing.assert_array_equal(c.tokens, expected[row, :GEN])


def test_resolve_chunk_measured_path_skips_rng(engine_pair):
    """Measured ondevice_ms must not consume the sampling rng stream."""
    engine, _ = engine_pair
    sched = _scheduler(engine, t_sla_ms=100.0)
    d = sched.decide_batch(np.full(4, 50.0))
    state0 = sched.rng.bit_generator.state
    measured = np.full(4, 7.5)
    acc, lat, used, ondev = sched.resolve_chunk(d, np.full(4, 200.0), measured)
    assert sched.rng.bit_generator.state == state0
    np.testing.assert_array_equal(ondev, measured)
    np.testing.assert_array_equal(lat, np.full(4, 100.0))  # SLA-bounded
    # The sampled fallback consumes the stream.
    sched.resolve_chunk(d, np.full(4, 200.0))
    assert sched.rng.bit_generator.state != state0


# ---------------------------------------------------------------------------
# Equivalence: sampled-hedge simulation stays the scalar reference.
# ---------------------------------------------------------------------------
def test_sampled_fallback_matches_direct_scheduler_replay(engine_pair):
    """serve_queue without a hedge backend == driving the scheduler's chunk
    API by hand with the same seed: the engine adds real execution but no
    extra randomness."""
    _, engine = engine_pair
    reqs = _requests()
    sched = _scheduler(engine, t_sla_ms=2_000.0, seed=7)
    ref = MDInferenceScheduler(sched.base_registry, sched.ondevice, sched.cfg)
    done, _ = engine.serve_queue(sched, reqs)

    est = np.asarray([r.t_nw_est_ms for r in reqs])
    d = ref.decide_batch(est)  # zero queue wait: arrival_ms unset
    np.testing.assert_array_equal(d.model_index, [c.model_index for c in done])
    exec_ms = np.asarray([c.exec_ms for c in done])
    ref.observe_batch(d.model_index, exec_ms)
    remote = np.asarray([r.t_nw_actual_ms for r in reqs]) + exec_ms
    acc, lat, used, ondev = ref.resolve_chunk(d, remote)
    np.testing.assert_allclose(lat, [c.latency_ms for c in done])
    np.testing.assert_allclose(acc, [c.accuracy for c in done])
    np.testing.assert_array_equal(used, [c.used_remote for c in done])
    for c, o in zip(done, ondev):
        assert c.ondevice_ms == pytest.approx(o)
        assert not c.hedge_measured


def test_sampled_fallback_matches_resolve_duplication_reference(engine_pair):
    """The sampled path's draws equal mu + sigma*z from the scheduler's own
    rng — pinned so the measured path can be diffed against simulation."""
    _, engine = engine_pair
    sched = _scheduler(engine, t_sla_ms=300.0, seed=11)
    twin = np.random.default_rng(11)
    d = sched.decide_batch(np.full(5, 40.0))
    twin.random(5)  # decide_batch consumed 5 selection uniforms
    remote = np.full(5, 500.0)
    acc, lat, used, ondev = sched.resolve_chunk(d, remote)
    expected_ondev = np.maximum(
        sched.ondevice_mu + sched.ondevice_sigma * twin.standard_normal(5), 0.1
    )
    np.testing.assert_allclose(ondev, expected_ondev)
    out = resolve_duplication(
        remote, sched.accuracy[d.model_index], expected_ondev,
        sched.ondevice.accuracy, 300.0,
    )
    np.testing.assert_allclose(lat, out.latency_ms)
    np.testing.assert_allclose(acc, out.accuracy)


def test_queue_wait_charges_the_duplicate_race_clock(engine_pair):
    """Both tiers launch at the dispatch tick: a queue wait above the SLA
    must show up as a real violation, not get clamped away by the hedge."""
    engine, _ = engine_pair
    sched = _scheduler(engine, t_sla_ms=20.0)
    reqs = _requests(n=2)
    done, metrics = engine.serve_queue(sched, reqs, dispatch_ms=60.0)
    for c in done:
        assert c.queue_wait_ms == 60.0
        assert not c.used_remote  # network alone busts the 20ms SLA
        # Duplicate's from-arrival latency includes the wait...
        assert c.ondevice_ms > 60.0
        # ...so the resolved latency cannot pretend to meet the SLA.
        assert c.latency_ms == pytest.approx(c.ondevice_ms)
    assert metrics.sla_attainment == 0.0


def test_queue_wait_recorded_and_surfaced(engine_pair):
    _, engine = engine_pair
    sched = _scheduler(engine, t_sla_ms=5_000.0)
    reqs = _requests(n=4)
    for i, r in enumerate(reqs):
        r.arrival_ms = 10.0 * i
    done, metrics = engine.serve_queue(sched, reqs, dispatch_ms=100.0)
    waits = [c.queue_wait_ms for c in done]
    np.testing.assert_allclose(waits, [100.0, 90.0, 80.0, 70.0])
    assert metrics.mean_queue_wait_ms == pytest.approx(np.mean(waits))
    assert metrics.p99_queue_wait_ms == pytest.approx(
        np.percentile(waits, 99)
    )


def test_build_hedge_variant_is_tiny():
    v = build_hedge_variant()
    assert v.cfg.d_model == ONDEVICE_HEDGE.d_model
    assert v.cfg.n_layers == ONDEVICE_HEDGE.n_layers
    assert v.quality == ONDEVICE_HEDGE.quality
