"""ServingLoop event loop: shim equivalence, non-blocking poll, and the
async-hedge overlap + race-clock guarantees (the PR's acceptance bar).
"""
import time

import numpy as np
import pytest

import jax

from repro.configs import reduced
from repro.core.network import LognormalNetwork
from repro.models import transformer as T
from repro.serving.admission import AdmissionConfig
from repro.serving.backend import OnDeviceBackend
from repro.serving.engine import QueuedRequest, ServingEngine, Variant
from repro.serving.lifecycle import RequestState
from repro.serving.loadgen import PoissonArrivals, iter_windows, make_trace
from repro.serving.loop import ServingLoop
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

from loop_stubs import StubHedgeBackend, StubRemoteBackend, stub_scheduler

MAX_LEN = 64
PROMPT, GEN = 8, 2


def _tiny_variant(name, width, quality, seed=0):
    cfg = reduced(
        "gemma-2b", d_model=width, n_layers=2,
        n_heads=2, n_kv_heads=1, head_dim=width // 2,
    )
    return Variant(name, cfg, T.init_params(cfg, jax.random.key(seed)), quality)


@pytest.fixture(scope="module")
def sampled_engine():
    engine = ServingEngine(max_len=MAX_LEN)
    engine.register(_tiny_variant("small", 32, 40.0))
    engine.register(_tiny_variant("large", 64, 80.0))
    return engine


@pytest.fixture(scope="module")
def hedged_engine():
    engine = ServingEngine(
        max_len=MAX_LEN, hedge_backend=OnDeviceBackend.from_zoo(max_len=MAX_LEN)
    )
    engine.register(_tiny_variant("small", 32, 40.0))
    engine.register(_tiny_variant("large", 64, 80.0))
    return engine


def _scheduler(engine, t_sla_ms, seed=0, **kw):
    registry = engine.measure_profiles(prompt_len=PROMPT, gen_tokens=GEN, trials=2)
    ondevice = (
        engine.hedge_backend.measure_profile(
            prompt_len=PROMPT, gen_tokens=GEN, trials=2
        )
        if engine.hedge_backend is not None
        else registry[0]
    )
    return MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=t_sla_ms, seed=seed, **kw)
    )


# ---------------------------------------------------------------------------
# Shim equivalence on a seeded loadgen trace.
# ---------------------------------------------------------------------------
def test_serve_queue_shim_equals_loop_on_seeded_trace(sampled_engine):
    """serve_queue windows == ServingLoop.drain_trace on the same trace:
    same completions and same RequestMetrics up to timing fields.
    (profile_ewma=0 freezes the profiles, so the two passes' decisions
    cannot drift apart through measured wall-time noise.)
    """
    n, window_ms = 40, 50.0
    trace = make_trace(
        n, PoissonArrivals(100.0), LognormalNetwork(40.0, 0.5), seed=9
    )
    prompts = np.random.default_rng(9).integers(0, 64, (n, PROMPT))

    def request(i):
        return QueuedRequest(
            rid=int(i),
            tokens=prompts[i],
            n_steps=GEN,
            t_nw_est_ms=float(trace.t_nw_est_ms[i]),
            t_nw_actual_ms=float(trace.t_nw_ms[i]),
            arrival_ms=float(trace.arrival_ms[i]),
        )

    # One measured registry for BOTH passes: profiles are wall-clock
    # measurements, so re-measuring would hand the passes different priors.
    registry = sampled_engine.measure_profiles(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    cfg = SchedulerConfig(t_sla_ms=5_000.0, seed=5, profile_ewma=0.0)

    sched_a = MDInferenceScheduler(registry, registry[0], cfg)
    done_shim = []
    for window in iter_windows(trace, window_ms):
        tick = (trace.arrival_ms[window[0]] // window_ms + 1) * window_ms
        done_shim.extend(
            sampled_engine.serve_queue(
                sched_a, [request(i) for i in window], dispatch_ms=tick
            )[0]
        )

    sched_b = MDInferenceScheduler(registry, registry[0], cfg)
    loop = ServingLoop(
        sched_b, sampled_engine.backend, dispatch="async"
    )
    done_loop, metrics = loop.drain_trace(
        trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=GEN
    )

    assert [c.rid for c in done_shim] == [c.rid for c in done_loop]
    for a, b in zip(done_shim, done_loop):
        assert a.model_index == b.model_index
        assert a.hedged == b.hedged
        assert a.used_remote == b.used_remote
        assert a.accuracy == b.accuracy
        assert a.race_resolution == b.race_resolution
        assert a.queue_wait_ms == pytest.approx(b.queue_wait_ms)
        assert a.time_to_schedule_ms == pytest.approx(b.time_to_schedule_ms)
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert metrics.n_requests == n
    usage_shim = {}
    for c in done_shim:
        usage_shim[c.model_name] = usage_shim.get(c.model_name, 0) + 1 / n
    assert metrics.model_usage == pytest.approx(usage_shim)
    assert metrics.aggregate_accuracy == pytest.approx(
        np.mean([c.accuracy for c in done_shim])
    )


def test_unbounded_admission_is_byte_identical_to_the_shim(sampled_engine):
    """Regression pin for the admission refactor: with ``max_pending=None``
    and no overload policy, an *explicitly* unbounded admission queue
    reproduces the PR 3 equivalence reference (the serve_queue shim)
    byte-for-byte on decision-level fields and loop-clock timings.
    """
    n, window_ms = 24, 50.0
    trace = make_trace(
        n, PoissonArrivals(120.0), LognormalNetwork(40.0, 0.5), seed=13
    )
    prompts = np.random.default_rng(13).integers(0, 64, (n, PROMPT))
    registry = sampled_engine.measure_profiles(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    cfg = SchedulerConfig(t_sla_ms=5_000.0, seed=8, profile_ewma=0.0)

    sched_a = MDInferenceScheduler(registry, registry[0], cfg)
    done_shim = []
    for window in iter_windows(trace, window_ms):
        tick = (trace.arrival_ms[window[0]] // window_ms + 1) * window_ms
        requests = [
            QueuedRequest(
                rid=int(i), tokens=prompts[i], n_steps=GEN,
                t_nw_est_ms=float(trace.t_nw_est_ms[i]),
                t_nw_actual_ms=float(trace.t_nw_ms[i]),
                arrival_ms=float(trace.arrival_ms[i]),
            )
            for i in window
        ]
        done_shim.extend(
            sampled_engine.serve_queue(sched_a, requests, dispatch_ms=tick)[0]
        )

    sched_b = MDInferenceScheduler(registry, registry[0], cfg)
    loop = ServingLoop(
        sched_b, sampled_engine.backend, dispatch="async",
        admission=AdmissionConfig(
            max_pending=None, max_chunk=None, policy="unbounded"
        ),
    )
    done_loop, metrics = loop.drain_trace(
        trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=GEN
    )
    assert [c.rid for c in done_shim] == [c.rid for c in done_loop]
    for a, b in zip(done_shim, done_loop):
        assert a.model_index == b.model_index
        assert a.hedged == b.hedged
        assert a.used_remote == b.used_remote
        assert a.accuracy == b.accuracy
        assert a.race_resolution == b.race_resolution
        assert a.queue_wait_ms == b.queue_wait_ms
        assert a.time_to_schedule_ms == b.time_to_schedule_ms
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert metrics.n_rejected == 0 and metrics.shed_rate == 0.0
    assert metrics.goodput == metrics.sla_attainment


# ---------------------------------------------------------------------------
# Async dispatch protocol.
# ---------------------------------------------------------------------------
def test_batch_handle_poll_never_blocks():
    backend = StubRemoteBackend(delay_s=0.2)
    handle = backend.submit_batch("stub-a", np.zeros((2, 4), np.int32), GEN)
    polls = 0
    while not handle.poll():
        t0 = time.perf_counter()
        handle.poll()
        assert time.perf_counter() - t0 < 0.05  # poll returns immediately
        polls += 1
        time.sleep(0.002)
    assert polls > 0  # the 200ms batch was genuinely in flight
    out, wall = handle.wait()
    assert out.shape == (2, GEN)
    assert wall >= 200.0 * 0.9
    assert handle.poll()  # stays done


def test_sync_submit_is_a_completed_handle():
    backend = StubRemoteBackend(delay_s=0.01)
    handle = backend.submit_batch(
        "stub-a", np.zeros((2, 4), np.int32), GEN, sync=True
    )
    assert handle.poll()  # already executed inline
    out, wall = handle.wait()
    assert out.shape == (2, GEN) and wall > 0
    assert handle.done_wall_ms >= handle.dispatch_wall_ms


def test_stub_tiers_overlap_deterministically():
    """Sleep-based tiers: async span ~= max(tiers), sync span ~= sum."""
    delay = 0.08
    for dispatch, check in (
        ("sync", lambda s: s.span_wall_ms >= s.serialized_wall_ms * 0.99),
        ("async", lambda s: s.span_wall_ms < s.serialized_wall_ms * 0.8),
    ):
        sched = stub_scheduler(t_sla_ms=1_000.0)
        loop = ServingLoop(
            sched, StubRemoteBackend(delay), StubHedgeBackend(delay),
            dispatch=dispatch,
        )
        for i in range(2):
            loop.submit(
                QueuedRequest(
                    rid=i, tokens=np.zeros(4, np.int32), n_steps=GEN,
                    t_nw_est_ms=10.0, t_nw_actual_ms=10.0,
                )
            )
        stats = loop.tick().stats
        assert stats.hedge_wall_ms is not None
        assert check(stats), (dispatch, stats)


# ---------------------------------------------------------------------------
# The acceptance bar: real two-tier batches demonstrably overlap.
# ---------------------------------------------------------------------------
def test_real_hedge_batches_overlap_remote_execution():
    """With a real hedge backend and async dispatch, a hedged tick's
    end-to-end wall time is strictly below the sum of the two tiers'
    individual wall times."""
    steps = 24
    engine = ServingEngine(
        max_len=PROMPT + steps + 8,
        hedge_backend=OnDeviceBackend.from_zoo(max_len=PROMPT + steps + 8),
    )
    # One remote variant: selection cannot split the chunk, so every tick
    # reuses one (remote, hedge) shape pair and the warm-up tick below
    # absorbs all XLA compiles.
    engine.register(_tiny_variant("remote", 64, 80.0))
    registry = engine.measure_profiles(prompt_len=PROMPT, gen_tokens=2, trials=2)
    ondevice = engine.hedge_backend.measure_profile(
        prompt_len=PROMPT, gen_tokens=2, trials=2
    )

    def hedged_tick(dispatch):
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=5_000.0, seed=0)
        )
        loop = engine.make_loop(sched, dispatch=dispatch)
        rng = np.random.default_rng(0)
        for i in range(4):
            loop.submit(
                QueuedRequest(
                    rid=i, tokens=rng.integers(0, 64, PROMPT), n_steps=steps,
                    t_nw_est_ms=50.0, t_nw_actual_ms=50.0,
                )
            )
        return loop.tick().stats

    hedged_tick("sync")  # warm both tiers' shapes (compile absorbed)
    stats = hedged_tick("async")
    assert stats.n_hedged == 4
    assert stats.hedge_wall_ms is not None and stats.hedge_wall_ms > 0
    # The acceptance assertion: overlapped span < serialized tier sum.
    assert stats.span_wall_ms < stats.serialized_wall_ms, stats
    # And the serialized fallback really is the degenerate case.
    sync_stats = hedged_tick("sync")
    assert sync_stats.span_wall_ms >= sync_stats.serialized_wall_ms


def test_race_clocks_start_at_the_dispatch_tick(hedged_engine):
    """Regression for the sequential-hedge accounting bug: the duplicate's
    race clock must start at the dispatch tick — wait charged once, wall
    dispatch not delayed behind the remote batch."""
    sched = _scheduler(hedged_engine, t_sla_ms=5_000.0)
    loop = hedged_engine.make_loop(sched, dispatch="async")
    rng = np.random.default_rng(2)
    futures = [
        loop.submit(
            QueuedRequest(
                rid=i, tokens=rng.integers(0, 64, PROMPT), n_steps=GEN,
                t_nw_est_ms=50.0, t_nw_actual_ms=50.0, arrival_ms=10.0 * i,
            )
        )
        for i in range(3)
    ]
    res = loop.tick(now_ms=100.0)
    stats = res.stats
    # Wall clocks: the duplicate was dispatched alongside the remote batch,
    # not after it finished (the old serialized behavior).
    assert stats.hedge_dispatched_before_remote_done is True
    assert stats.dispatch_spread_wall_ms < stats.span_wall_ms
    for f, c in zip(futures, res.completions):
        assert f.state is RequestState.RESOLVED
        both = f.tier_dispatch_wall_ms
        assert set(both) == {"remote", "ondevice"}
        # Dispatch stamps differ by submit overhead, not by a batch wall.
        assert abs(both["ondevice"] - both["remote"]) <= stats.dispatch_spread_wall_ms + 1e-6
        # Accounting clocks: the same queue wait charges both race clocks.
        assert c.queue_wait_ms == pytest.approx(100.0 - f.request.arrival_ms)
        assert c.remote_ms - c.exec_ms - 50.0 == pytest.approx(c.queue_wait_ms)
        assert c.ondevice_ms - stats.hedge_wall_ms == pytest.approx(c.queue_wait_ms)


def test_tick_wait_false_resolves_via_poll():
    sched = stub_scheduler(t_sla_ms=1_000.0)
    loop = ServingLoop(
        sched, StubRemoteBackend(0.05), StubHedgeBackend(0.05), dispatch="async"
    )
    f = loop.submit(
        QueuedRequest(
            rid=0, tokens=np.zeros(4, np.int32), n_steps=GEN,
            t_nw_est_ms=10.0, t_nw_actual_ms=10.0,
        )
    )
    assert loop.tick(wait=False) is None
    assert f.state is RequestState.EXECUTING
    assert loop.inflight == 1
    deadline = time.perf_counter() + 5.0
    results = []
    while not results and time.perf_counter() < deadline:
        results = loop.poll()  # non-blocking: [] until the batches finish
        time.sleep(0.005)
    assert len(results) == 1
    assert f.state is RequestState.RESOLVED
    assert loop.inflight == 0
    assert results[0].completions[0].rid == 0


def test_empty_tick_returns_none(sampled_engine):
    sched = _scheduler(sampled_engine, t_sla_ms=1_000.0)
    loop = sampled_engine.make_loop(sched)
    assert loop.tick() is None
    assert loop.poll() == []
    assert loop.drain() == []
    assert loop.flush() == []
