"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rglru_scan import rglru_scan_fwd
from repro.kernels.rmsnorm import rms_norm_fwd

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,NQ,NKV,S,D,bq,bk",
    [
        (2, 4, 2, 256, 64, 64, 128),
        (1, 4, 1, 256, 64, 128, 64),  # MQA
        (2, 2, 2, 128, 32, 64, 64),  # MHA
        (1, 8, 2, 512, 128, 256, 512),  # production-ish tile
        (1, 2, 2, 128, 128, 128, 128),
    ],
)
def test_flash_kernel_sweep(B, NQ, NKV, S, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.key(S + NQ + D), 3)
    q = rand(ks[0], (B, NQ, S, D), dtype)
    k = rand(ks[1], (B, NKV, S, D), dtype)
    v = rand(ks[2], (B, NKV, S, D), dtype)
    out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), atol=ATOL[dtype]
    )


@pytest.mark.parametrize("window", [32, 96])
def test_flash_kernel_window(window):
    ks = jax.random.split(jax.random.key(7), 3)
    q = rand(ks[0], (1, 4, 256, 64), jnp.float32)
    k = rand(ks[1], (1, 1, 256, 64), jnp.float32)
    v = rand(ks[2], (1, 1, 256, 64), jnp.float32)
    out = flash_attention_fwd(
        q, k, v, window=window, block_q=64, block_k=64, interpret=True
    )
    expect = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, expect, atol=2e-5)


def test_flash_kernel_bidirectional():
    ks = jax.random.split(jax.random.key(8), 3)
    q = rand(ks[0], (2, 2, 128, 64), jnp.float32)
    k = rand(ks[1], (2, 2, 128, 64), jnp.float32)
    v = rand(ks[2], (2, 2, 128, 64), jnp.float32)
    out = flash_attention_fwd(
        q, k, v, causal=False, block_q=64, block_k=64, interpret=True
    )
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=2e-5)


# ---------------------------------------------------------------------------
# Decode attention.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,NKV,G,S,D,bk,window",
    [
        (2, 2, 2, 256, 64, 128, 0),
        (1, 1, 8, 512, 128, 256, 0),  # MQA big group
        (2, 2, 1, 256, 64, 64, 64),  # windowed ring
        (1, 4, 2, 128, 32, 128, 0),
    ],
)
def test_decode_kernel_sweep(B, NKV, G, S, D, bk, window, dtype):
    ks = jax.random.split(jax.random.key(S + G), 3)
    q = rand(ks[0], (B, NKV, G, D), dtype)
    kc = rand(ks[1], (B, NKV, S, D), dtype)
    vc = rand(ks[2], (B, NKV, S, D), dtype)
    # Ring-buffer positions: slots filled up to `pos`, some wrapped.
    pos = jnp.full((B,), S + S // 2, jnp.int32)
    slot_pos = jnp.broadcast_to(
        (pos[:, None] - S + 1) + (jnp.arange(S) + S // 2) % S, (B, S)
    ).astype(jnp.int32)
    out = decode_attention_fwd(
        q, kc, vc, slot_pos, pos, window=window, block_k=bk, interpret=True
    )
    expect = ref.decode_attention_ref(q, kc, vc, slot_pos, pos, window=window)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), atol=ATOL[dtype]
    )


def test_decode_kernel_empty_slots():
    ks = jax.random.split(jax.random.key(3), 3)
    B, NKV, G, S, D = 2, 2, 2, 128, 32
    q = rand(ks[0], (B, NKV, G, D), jnp.float32)
    kc = rand(ks[1], (B, NKV, S, D), jnp.float32)
    vc = rand(ks[2], (B, NKV, S, D), jnp.float32)
    # Only the first 10 slots are valid.
    slot_pos = jnp.where(jnp.arange(S) < 10, jnp.arange(S), -1)
    slot_pos = jnp.broadcast_to(slot_pos, (B, S)).astype(jnp.int32)
    pos = jnp.full((B,), 9, jnp.int32)
    out = decode_attention_fwd(q, kc, vc, slot_pos, pos, block_k=64, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, slot_pos, pos)
    np.testing.assert_allclose(out, expect, atol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,W,bs,bw",
    [
        (2, 256, 128, 64, 64),
        (1, 512, 256, 128, 256),
        (3, 128, 64, 128, 64),
    ],
)
def test_rglru_kernel_sweep(B, S, W, bs, bw, dtype):
    ks = jax.random.split(jax.random.key(S + W), 3)
    # decays in (0, 1), inputs small — the RG-LRU regime.
    a = jax.nn.sigmoid(rand(ks[0], (B, S, W), jnp.float32) * 2.0).astype(dtype)
    b = (rand(ks[1], (B, S, W), jnp.float32) * 0.1).astype(dtype)
    h0 = rand(ks[2], (B, W), jnp.float32) * 0.1
    out = rglru_scan_fwd(a, b, h0, block_s=bs, block_w=bw, interpret=True)
    expect = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32),
        atol=ATOL[dtype], rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


def test_rglru_kernel_carries_state_across_blocks():
    """A block boundary must not reset the recurrence."""
    B, S, W = 1, 256, 64
    a = jnp.full((B, S, W), 0.99, jnp.float32)
    b = jnp.ones((B, S, W), jnp.float32) * 0.01
    h0 = jnp.zeros((B, W), jnp.float32)
    out = rglru_scan_fwd(a, b, h0, block_s=64, block_w=64, interpret=True)
    expect = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)
    # Monotone accumulation sanity: later h larger than early h.
    assert float(out[0, -1, 0]) > float(out[0, 0, 0])


# ---------------------------------------------------------------------------
# RMSNorm.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("offset", [False, True])
@pytest.mark.parametrize("shape", [(4, 128, 256), (3, 7, 512), (1, 1, 64)])
def test_rmsnorm_kernel_sweep(shape, offset, dtype):
    ks = jax.random.split(jax.random.key(shape[-1]), 2)
    x = rand(ks[0], shape, dtype)
    w = rand(ks[1], (shape[-1],), jnp.float32)
    out = rms_norm_fwd(x, w, offset=offset, block_rows=64, interpret=True)
    expect = ref.rms_norm_ref(x, w, offset=offset)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), atol=ATOL[dtype]
    )


# ---------------------------------------------------------------------------
# Flash attention backward kernels.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize(
    "B,NQ,NKV,S,D,bq,bk,causal,window",
    [
        (1, 2, 2, 128, 32, 64, 64, True, 0),
        (1, 4, 2, 128, 32, 64, 64, True, 0),  # GQA group sum
        (1, 4, 1, 128, 32, 32, 64, True, 0),  # MQA
        (1, 2, 2, 128, 32, 64, 64, False, 0),  # bidirectional
        (1, 2, 1, 128, 32, 32, 32, True, 48),  # windowed
    ],
)
def test_flash_bwd_kernel_vs_ref_grads(B, NQ, NKV, S, D, bq, bk, causal, window, dtype):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.kernels.flash_attention_bwd import flash_attention_bwd

    ks = jax.random.split(jax.random.key(S + NQ + window), 4)
    q = rand(ks[0], (B, NQ, S, D), dtype)
    k = rand(ks[1], (B, NKV, S, D), dtype)
    v = rand(ks[2], (B, NKV, S, D), dtype)
    dout = rand(ks[3], (B, NQ, S, D), dtype)

    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=True, return_lse=True,
    )
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, dout, lse, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=True,
    )

    def loss(q, k, v):
        o = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        return jnp.sum(o * dout)

    rdq, rdk, rdv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(dq, rdq, atol=3e-5)
    np.testing.assert_allclose(dk, rdk, atol=3e-5)
    np.testing.assert_allclose(dv, rdv, atol=3e-5)


def test_flash_fwd_lse_matches_logsumexp():
    from repro.kernels.flash_attention import flash_attention_fwd

    ks = jax.random.split(jax.random.key(5), 3)
    B, NQ, S, D = 1, 2, 128, 32
    q = rand(ks[0], (B, NQ, S, D), jnp.float32)
    k = rand(ks[1], (B, NQ, S, D), jnp.float32)
    v = rand(ks[2], (B, NQ, S, D), jnp.float32)
    _, lse = flash_attention_fwd(
        q, k, v, block_q=64, block_k=64, interpret=True, return_lse=True
    )
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (D**-0.5)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    expect = jax.nn.logsumexp(s, axis=-1)
    np.testing.assert_allclose(lse, expect, atol=2e-5)


# ---------------------------------------------------------------------------
# Paged decode attention (the continuous-batching substrate).
# ---------------------------------------------------------------------------
BS_LADDER = (1, 2, 4, 8)


def _paged_case(key, B, NKV=2, G=2, D=32, page=8, NB=3, dtype=jnp.float32):
    """One pool + per-row page tables; page 0 is the reserved trash page."""
    P = 1 + B * NB
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (B, NKV, G, D), dtype)
    kp = rand(ks[1], (P, NKV, page, D), dtype)
    vp = rand(ks[2], (P, NKV, page, D), dtype)
    tables = (1 + jnp.arange(B * NB, dtype=jnp.int32)).reshape(B, NB)
    pos = (3 + 5 * jnp.arange(B, dtype=jnp.int32)) % (NB * page)
    return q, kp, vp, tables, pos


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B", BS_LADDER)
def test_paged_decode_every_ladder_size(B, dtype):
    from repro.kernels.decode_attention import decode_attention_paged_fwd

    q, kp, vp, tables, pos = _paged_case(jax.random.key(B), B, dtype=dtype)
    out = decode_attention_paged_fwd(q, kp, vp, tables, pos, interpret=True)
    expect = ref.decode_attention_paged_ref(q, kp, vp, tables, pos)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), atol=ATOL[dtype]
    )


@pytest.mark.parametrize("window", [0, 8])
def test_paged_decode_windowed(window):
    from repro.kernels.decode_attention import decode_attention_paged_fwd

    q, kp, vp, tables, pos = _paged_case(jax.random.key(17), 4)
    out = decode_attention_paged_fwd(
        q, kp, vp, tables, pos, window=window, interpret=True
    )
    expect = ref.decode_attention_paged_ref(
        q, kp, vp, tables, pos, window=window
    )
    np.testing.assert_allclose(out, expect, atol=2e-5)


@pytest.mark.parametrize("n_real", [1, 3, 5, 7])
def test_paged_decode_masked_rows_inert(n_real):
    """Padded partial batches: inactive rows (pos=0, all-trash table) must
    not perturb real-row outputs — bitwise — and must not produce NaN."""
    from repro.kernels.decode_attention import decode_attention_paged_fwd

    B = 8  # the padded ladder shape every partial chunk rides in
    q, kp, vp, tables, pos = _paged_case(jax.random.key(n_real), B)
    # Rows >= n_real are masked: all-trash tables, position 0.
    tables = tables.at[n_real:].set(0)
    pos = pos.at[n_real:].set(0)
    padded = decode_attention_paged_fwd(q, kp, vp, tables, pos, interpret=True)
    assert not bool(jnp.isnan(padded).any())
    # The same real rows as their own (smaller) batch: exact equality.
    alone = decode_attention_paged_fwd(
        q[:n_real], kp, vp, tables[:n_real], pos[:n_real], interpret=True
    )
    np.testing.assert_array_equal(
        np.asarray(padded[:n_real]), np.asarray(alone)
    )
    expect = ref.decode_attention_paged_ref(
        q[:n_real], kp, vp, tables[:n_real], pos[:n_real]
    )
    np.testing.assert_allclose(padded[:n_real], expect, atol=2e-5)
