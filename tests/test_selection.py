"""Unit + property tests for the three-stage selection algorithm (§V-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.mdinference_zoo import ablation_zoo, paper_zoo
from repro.core.registry import ModelProfile, ModelRegistry
from repro.core.selection import (
    compute_budget,
    select_batch,
    select_ref,
    selection_probabilities,
)

ZOO = paper_zoo()


def test_budget():
    assert compute_budget(250.0, 100.0) == 150.0


# ---------------------------------------------------------------------------
# Stage 1: greedy base model.
# ---------------------------------------------------------------------------
def test_stage1_picks_most_accurate_fitting():
    rng = np.random.default_rng(0)
    # Budget 60ms: InceptionV4 (mu+sig=59.43) fits, NasNet Large does not.
    r = select_ref(ZOO, 60.0, rng)
    assert ZOO[r.base_index].name == "InceptionV4"
    assert not r.fallback


def test_stage1_fallback_to_fastest():
    rng = np.random.default_rng(0)
    r = select_ref(ZOO, 1.0, rng)  # nothing fits in 1ms
    assert r.fallback
    assert ZOO[r.index].name == "MobileNetV1 0.25"
    assert r.exploration_set == ()


def test_stage1_negative_budget():
    rng = np.random.default_rng(0)
    r = select_ref(ZOO, -50.0, rng)
    assert r.fallback and ZOO[r.index].name == "MobileNetV1 0.25"


def test_stage1_boundary_is_strict():
    # mu + sigma < budget is strict: budget exactly mu+sigma excludes.
    reg = ModelRegistry([ModelProfile("a", 50.0, 10.0, 1.0)])
    rng = np.random.default_rng(0)
    assert select_ref(reg, 11.0, rng).fallback
    assert not select_ref(reg, 11.0001, rng).fallback


# ---------------------------------------------------------------------------
# Stage 2: exploration set.
# ---------------------------------------------------------------------------
def test_stage2_exploration_contains_base():
    rng = np.random.default_rng(0)
    for budget in [10.0, 30.0, 60.0, 120.0, 200.0]:
        r = select_ref(ZOO, budget, rng)
        if not r.fallback:
            assert r.base_index in r.exploration_set


def test_stage2_nasnet_pair_in_exploration_set():
    # Ablation zoo: NasNet Large & Fictional share mu -> both in M_E.
    reg = ablation_zoo()
    rng = np.random.default_rng(0)
    r = select_ref(reg, 150.0, rng)
    names = {reg[i].name for i in r.exploration_set}
    assert names == {"NasNet Large", "NasNet Fictional"}


# ---------------------------------------------------------------------------
# Stage 3: utility weighting.
# ---------------------------------------------------------------------------
def test_stage3_prefers_accuracy_within_pair():
    reg = ablation_zoo()
    rng = np.random.default_rng(0)
    r = select_ref(reg, 150.0, rng)
    probs = dict(zip(r.exploration_set, r.probabilities))
    i_large = reg.index_of("NasNet Large")
    i_fict = reg.index_of("NasNet Fictional")
    # Same mu/sigma => probability ratio == accuracy ratio (82.6 : 50).
    assert probs[i_large] > probs[i_fict]
    np.testing.assert_allclose(
        probs[i_large] / probs[i_fict], 82.6 / 50.0, rtol=1e-5
    )


def test_stage3_negative_utilities_clamped():
    # A model in M_E that violates the budget must get zero probability.
    reg = ModelRegistry(
        [
            ModelProfile("base", 70.0, 10.0, 5.0),  # fits at budget 16
            ModelProfile("slowtwin", 90.0, 14.9, 2.0),  # in M_E, violates
        ]
    )
    rng = np.random.default_rng(0)
    r = select_ref(reg, 16.0, rng)
    probs = dict(zip(r.exploration_set, r.probabilities))
    assert probs[reg.index_of("slowtwin")] == 0.0
    assert r.index == reg.index_of("base")


def test_utility_power_sharpens():
    reg = ablation_zoo()
    acc, mu, sig = (
        jnp.asarray(reg.accuracy),
        jnp.asarray(reg.mu),
        jnp.asarray(reg.sigma),
    )
    p1, _, _ = selection_probabilities(acc, mu, sig, jnp.asarray([150.0]))
    p4, _, _ = selection_probabilities(
        acc, mu, sig, jnp.asarray([150.0]), utility_power=4.0
    )
    i = reg.index_of("NasNet Large")
    assert float(p4[0, i]) > float(p1[0, i])


# ---------------------------------------------------------------------------
# Vectorized == reference.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("zoo", [paper_zoo(), ablation_zoo()])
def test_batch_matches_ref(zoo):
    rng = np.random.default_rng(1)
    budgets = np.linspace(-30.0, 320.0, 351)
    probs, base, fb = selection_probabilities(
        jnp.asarray(zoo.accuracy),
        jnp.asarray(zoo.mu),
        jnp.asarray(zoo.sigma),
        jnp.asarray(budgets, dtype=jnp.float32),
    )
    probs, base, fb = np.asarray(probs), np.asarray(base), np.asarray(fb)
    for i, b in enumerate(budgets):
        r = select_ref(zoo, float(b), rng)
        assert bool(fb[i]) == r.fallback, f"fallback mismatch at budget {b}"
        if r.fallback:
            assert np.argmax(probs[i]) == zoo.fastest_index
            continue
        assert int(base[i]) == r.base_index, f"base mismatch at budget {b}"
        dense = np.zeros(len(zoo))
        for j, p in zip(r.exploration_set, r.probabilities):
            dense[j] = p
        if sum(r.probabilities) == 0.0:  # all-clamped => one-hot base
            dense[r.base_index] = 1.0
        np.testing.assert_allclose(probs[i], dense, atol=1e-5)


def test_select_batch_samples_from_probs():
    key = jax.random.key(0)
    sel = select_batch(
        key,
        jnp.asarray(ZOO.accuracy),
        jnp.asarray(ZOO.mu),
        jnp.asarray(ZOO.sigma),
        jnp.full((4000,), 150.0),
    )
    # Budget 150 -> base NasNet Large, singleton M_E -> always NasNet Large.
    assert np.all(np.asarray(sel.index) == ZOO.index_of("NasNet Large"))


# ---------------------------------------------------------------------------
# Property-based invariants.
# ---------------------------------------------------------------------------
profile_lists = st.lists(
    st.tuples(
        st.floats(1.0, 100.0),  # accuracy
        st.floats(0.5, 500.0),  # mu
        st.floats(0.01, 50.0),  # sigma
    ),
    min_size=1,
    max_size=16,
)


@given(profile_lists, st.floats(-100.0, 1000.0), st.integers(0, 2**31 - 1))
@settings(max_examples=200, deadline=None)
def test_selection_invariants(raw, budget, seed):
    reg = ModelRegistry(
        [ModelProfile(f"m{i}", a, m, s) for i, (a, m, s) in enumerate(raw)]
    )
    rng = np.random.default_rng(seed)
    r = select_ref(reg, budget, rng)
    # The selected model is always a real model.
    assert 0 <= r.index < len(reg)
    if r.fallback:
        # Fallback == fastest model, and nothing fits the budget.
        assert r.index == reg.fastest_index
        assert all(not p.fits(budget) for p in reg)
    else:
        p_base = reg[r.base_index]
        # Base model satisfies the stage-1 constraint.
        assert p_base.fits(budget)
        # Everything in M_E is within +-sigma_b of the base's mu.
        for i in r.exploration_set:
            assert (
                p_base.mu_ms - p_base.sigma_ms
                <= reg[i].mu_ms
                <= p_base.mu_ms + p_base.sigma_ms
            )
        # Probabilities form a (sub)distribution and selection is supported.
        total = sum(r.probabilities)
        assert total <= 1.0 + 1e-6
        if total > 0:
            assert abs(total - 1.0) < 1e-6
        # The chosen model never has zero probability (unless degenerate).
        probs = dict(zip(r.exploration_set, r.probabilities))
        if total > 0:
            assert probs[r.index] > 0.0


@given(
    profile_lists,
    st.lists(st.floats(-100.0, 1000.0), min_size=1, max_size=32),
)
@settings(max_examples=100, deadline=None)
def test_batch_probs_match_ref_structure(raw, budgets):
    reg = ModelRegistry(
        [ModelProfile(f"m{i}", a, m, s) for i, (a, m, s) in enumerate(raw)]
    )
    probs, base, fb = selection_probabilities(
        jnp.asarray(reg.accuracy),
        jnp.asarray(reg.mu),
        jnp.asarray(reg.sigma),
        jnp.asarray(budgets, dtype=jnp.float32),
    )
    probs = np.asarray(probs, dtype=np.float64)
    # Rows are distributions.
    np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-4)
    rng = np.random.default_rng(0)
    for i, b in enumerate(budgets):
        r = select_ref(reg, float(b), rng)
        assert bool(np.asarray(fb)[i]) == r.fallback
