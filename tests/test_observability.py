"""Observability layer (PR 10 tentpole): tracer, metrics, exporters, and
the regression pin — with the handle detached (the default) the stack is
byte-identical to the pre-observability loop; attached, it records span
trees that conserve requests and metrics that match the loop's counters.
"""
import json
import math
import types

import numpy as np
import pytest

from repro.observability import (
    Observability,
    Tracer,
    chrome_trace,
    prometheus_text,
    quantile,
    request_conservation,
)
from repro.observability.metrics import (
    BUCKET_LO_MS,
    N_BUCKETS,
    Histogram,
    MetricsRegistry,
    bucket_index,
    bucket_lower_ms,
    bucket_upper_ms,
)
from repro.observability.quantile import percentiles
from repro.serving.admission import AdmissionConfig, AdmissionQueue
from repro.serving.controller import AdmissionController, ControllerConfig
from repro.serving.health import BreakerConfig
from repro.serving.lifecycle import QueuedRequest, RequestState
from repro.serving.loop import ServingLoop

from loop_stubs import (
    StubHedgeBackend,
    StubRemoteBackend,
    stub_fault_cluster,
    stub_scheduler,
)

GEN = 2


def _request(rid, arrival_ms=0.0, nw=10.0, tenant=None):
    return QueuedRequest(
        rid=rid, tokens=np.zeros(4, np.int32), n_steps=GEN,
        t_nw_est_ms=nw, t_nw_actual_ms=nw, arrival_ms=arrival_ms,
        tenant=tenant,
    )


def _stub_loop(obs=None, *, hedge=False, admission=None, **kw):
    backend = StubRemoteBackend(0.0)
    from repro.serving.backend import Variant

    for name, quality in (("stub-a", 40.0), ("stub-b", 80.0)):
        backend.register(Variant(name, None, None, quality))
    return ServingLoop(
        stub_scheduler(t_sla_ms=1_000.0),
        backend,
        StubHedgeBackend(0.0) if hedge else None,
        dispatch="sync",
        admission=admission,
        observability=obs,
        **kw,
    )


# ---------------------------------------------------------------------------
# quantile helper (the one shared percentile convention)
# ---------------------------------------------------------------------------
def test_quantile_matches_numpy_and_is_empty_safe():
    vals = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
    for q in (0, 25, 50, 90, 99, 100):
        assert quantile(vals, q) == pytest.approx(np.percentile(vals, q))
    assert math.isnan(quantile([], 99))
    assert quantile([], 99, default=0.0) == 0.0
    assert percentiles(vals, [50, 99]) == pytest.approx(
        list(np.percentile(vals, [50, 99]))
    )
    assert percentiles([], [50, 99], default=-1.0) == [-1.0, -1.0]


# ---------------------------------------------------------------------------
# histogram: fixed grid, O(1) recording, merge, percentile
# ---------------------------------------------------------------------------
def test_bucket_layout_is_fixed_and_monotone():
    assert N_BUCKETS == 97  # ~O(100), shared by every histogram
    uppers = [bucket_upper_ms(i) for i in range(N_BUCKETS)]
    assert all(a < b for a, b in zip(uppers, uppers[1:]))
    assert math.isinf(uppers[-1])
    # Every value lands in the bucket whose (lower, upper] covers it.
    for v in (0.02, 0.5, 1.0, 3.7, 42.0, 999.0, 1e5):
        i = bucket_index(v)
        assert bucket_lower_ms(i) <= v <= bucket_upper_ms(i) * (1 + 1e-12)


def test_histogram_records_zero_and_underflow_into_bucket_zero():
    h = Histogram()
    h.record(0.0)  # loop_tick_wall_ms can legitimately be 0 on stub ticks
    h.record(-1.0)
    h.record(BUCKET_LO_MS / 2)
    assert h.counts[0] == 3 and h.count == 3


def test_histogram_percentile_within_bucket_resolution():
    h = Histogram()
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=3.0, sigma=1.0, size=5_000)  # ~20ms median
    for s in samples:
        h.record(float(s))
    for q in (50, 90, 99):
        exact = float(np.percentile(samples, q))
        approx = h.percentile(q)
        # Bucket resolution is one 1/12-decade step (~21% width).
        assert abs(approx - exact) / exact < 0.25
    assert h.mean == pytest.approx(float(np.mean(samples)))


def test_histogram_snapshots_merge_like_a_single_histogram():
    a, b, both = Histogram(), Histogram(), Histogram()
    rng = np.random.default_rng(1)
    for i, v in enumerate(rng.uniform(0.1, 500.0, 400)):
        (a if i % 2 else b).record(float(v))
        both.record(float(v))
    merged = a.snapshot().merge(b.snapshot())
    assert merged.counts == both.snapshot().counts
    assert merged.count == both.count
    assert merged.sum == pytest.approx(both.sum)
    assert merged.percentile(99) == pytest.approx(both.percentile(99))


def test_registry_keys_by_name_and_labels():
    reg = MetricsRegistry()
    reg.counter("x", tenant="ui").inc()
    reg.counter("x", tenant="batch").inc(3)
    reg.counter("x", tenant="ui").inc()  # same handle, not a new metric
    assert reg.get_value("counter", "x", tenant="ui") == 2.0
    assert reg.get_value("counter", "x", tenant="batch") == 3.0
    assert reg.get_value("counter", "x", tenant="nope") is None
    reg.gauge("g").set(7)
    reg.histogram("h").record(5.0)
    snap = reg.snapshot()
    assert {c["name"] for c in snap["counters"]} == {"x"}
    assert len(snap["counters"]) == 2  # one row per label set
    assert snap["histograms"][0]["count"] == 1
    assert len(snap["histograms"][0]["counts"]) == N_BUCKETS


# ---------------------------------------------------------------------------
# tracer: parentage, instants, ambient binding
# ---------------------------------------------------------------------------
def test_tracer_parent_links_and_instants():
    tr = Tracer()
    root = tr.start("request", cat="request", rid=1)
    child = tr.start("queued", parent=root)
    mark = tr.instant("resolve", parent=root, t_ms=123.0)
    tr.end(child)
    tr.end(root)
    assert child.parent_id == root.span_id
    assert mark.is_instant and mark.start_ms == 123.0
    assert not root.is_instant and root.end_ms >= root.start_ms
    assert [s.span_id for s in tr.children_of(root)] == [
        child.span_id, mark.span_id
    ]
    # End is idempotent: the first close wins.
    end0 = child.end_ms
    tr.end(child, t1_ms=end0 + 999.0)
    assert child.end_ms == end0
    # Ids are assigned in creation order (deterministic trees).
    assert [s.span_id for s in tr.spans] == [0, 1, 2]


def test_tracer_ambient_binding_is_per_thread_and_nested():
    tr = Tracer()
    outer = tr.start("tick")
    assert tr.ambient_id() is None
    with tr.bind(outer):
        assert tr.ambient_id() == outer.span_id
        inner = tr.start("batch:stub", parent=tr.ambient_id())
        with tr.bind(inner):
            assert tr.ambient_id() == inner.span_id
        assert tr.ambient_id() == outer.span_id

        import threading

        seen = []
        t = threading.Thread(target=lambda: seen.append(tr.ambient_id()))
        t.start()
        t.join()
        assert seen == [None]  # ambient state never leaks across threads
    assert tr.ambient_id() is None


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_chrome_trace_shape_tracks_and_units():
    tr = Tracer()
    a = tr.start("request", track="tenant:ui", t0_ms=10.0)
    tr.instant("resolve", parent=a, track="tenant:ui", t_ms=14.0)
    tr.end(a, t1_ms=14.0)
    tr.start("tick", track="loop", t0_ms=10.0)  # left open on purpose
    doc = chrome_trace(tr)
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    assert meta[0]["name"] == "process_name"
    tracks = {
        e["args"]["name"]: e["tid"]
        for e in meta
        if e["name"] == "thread_name"
    }
    assert set(tracks) == {"tenant:ui", "loop"}
    request = next(e for e in events if e["name"] == "request")
    assert request["ph"] == "X"
    assert request["ts"] == pytest.approx(10.0 * 1e3)  # µs
    assert request["dur"] == pytest.approx(4.0 * 1e3)
    assert request["args"]["span_id"] == a.span_id
    instant = next(e for e in events if e["name"] == "resolve")
    assert instant["ph"] == "i" and instant["s"] == "t"
    open_tick = next(e for e in events if e["name"] == "tick")
    assert open_tick["ph"] == "X" and open_tick["dur"] == 0.0
    json.dumps(doc)  # must be serializable as-is


def test_prometheus_text_counters_and_cumulative_buckets():
    reg = MetricsRegistry()
    reg.counter("loop_shed_total").inc(5)
    reg.gauge("loop_inflight_ticks", lane="x").set(2)
    h = reg.histogram("wait_ms")
    for v in (0.5, 0.5, 50.0):
        h.record(v)
    text = prometheus_text(reg)
    assert "# TYPE loop_shed_total counter" in text
    assert "loop_shed_total 5.0" in text
    assert 'loop_inflight_ticks{lane="x"} 2.0' in text
    assert "# TYPE wait_ms histogram" in text
    assert 'wait_ms_bucket{le="+Inf"} 3' in text
    assert "wait_ms_count 3" in text
    assert "wait_ms_sum 51.0" in text
    # Bucket series are cumulative: the 50ms bucket's line reads 3.
    lines = [ln for ln in text.splitlines() if ln.startswith("wait_ms_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
    assert counts == sorted(counts) and counts[-1] == 3


# ---------------------------------------------------------------------------
# loop integration: regression pin, span trees, conservation
# ---------------------------------------------------------------------------
def test_detached_default_keeps_futures_untraced():
    loop = _stub_loop(obs=None)
    f = loop.submit(_request(0))
    loop.tick(now_ms=0.0)
    assert loop.observability is None
    assert f.span is None and f._tracer is None
    assert f.state is RequestState.RESOLVED


def test_attached_run_is_a_decision_identical_twin():
    """The instrumentation observes, never steers: same completions, same
    model choices, same waits as the detached run on one seeded stream."""
    results = []
    for obs in (None, Observability()):
        loop = _stub_loop(obs)
        futures = [loop.submit(_request(i, arrival_ms=i * 5.0)) for i in range(12)]
        res = loop.tick(now_ms=100.0)
        results.append(
            [
                (c.rid, c.model_index, c.queue_wait_ms)
                for c in res.completions
            ]
        )
        assert all(f.state is RequestState.RESOLVED for f in futures)
    assert results[0] == results[1]


def test_request_span_tree_and_conservation_on_resolve():
    obs = Observability()
    loop = _stub_loop(obs, hedge=True)
    n = 6
    futures = [loop.submit(_request(i, tenant="ui")) for i in range(n)]
    loop.tick(now_ms=50.0)

    roots = obs.tracer.find("request")
    assert len(roots) == n
    assert all(r.track == "tenant:ui" for r in roots)
    for f, root in zip(futures, roots):
        names = [s.name for s in obs.tracer.children_of(root)]
        assert names.count("queued") == 1
        assert "scheduled" in names and "resolve" in names
        assert "remote" in names  # the tier leg replayed from wall stamps
        queued = next(
            s for s in obs.tracer.children_of(root) if s.name == "queued"
        )
        assert queued.end_ms is not None  # closed when the tick claimed it
        assert f.span is root

    audit = request_conservation(obs.tracer)
    assert audit["submitted"] == n and audit["resolved"] == n
    assert audit["open"] == 0 and audit["extra_terminals"] == 0

    # Tick + dispatch-group spans on the loop track.
    (tick_span,) = obs.tracer.find("tick")
    assert tick_span.track == "loop" and tick_span.end_ms is not None
    batch_spans = [
        s for s in obs.tracer.spans if s.name.startswith("batch:")
    ]
    assert batch_spans and all(
        s.parent_id == tick_span.span_id for s in batch_spans
    )
    assert any(s.name == "batch:hedge" for s in batch_spans)

    # Loop metric families line up with the trace.
    m = obs.metrics
    assert m.get_value("counter", "loop_submitted_total") == n
    assert m.get_value("counter", "loop_completions_total") == n
    assert m.get_value("histogram", "loop_tick_wall_ms") == 1
    assert m.get_value("counter", "loop_hedged_total") == n


def test_shed_requests_terminate_with_shed_and_close_queued_span():
    obs = Observability()
    loop = _stub_loop(
        obs,
        admission=AdmissionConfig(policy="shed", max_pending=2, max_chunk=2),
    )
    futures = [loop.submit(_request(i)) for i in range(6)]
    n_rejected = sum(1 for f in futures if f.state is RequestState.REJECTED)
    assert n_rejected == 4  # capacity 2: the rest shed at offer
    loop.tick(now_ms=0.0)
    audit = request_conservation(obs.tracer)
    assert audit["submitted"] == 6
    assert audit["rejected"] == n_rejected
    assert audit["resolved"] == 2
    assert audit["open"] == 0 and audit["extra_terminals"] == 0
    for s in obs.tracer.find("queued"):
        assert s.end_ms is not None
    assert obs.metrics.get_value(
        "counter", "admission_offers_total", disposition="rejected"
    ) == 4


def test_cancel_terminates_span_tree():
    obs = Observability()
    loop = _stub_loop(obs)
    f = loop.submit(_request(0))
    assert f.cancel()
    loop.tick(now_ms=0.0)
    audit = request_conservation(obs.tracer)
    assert audit["cancelled"] == 1 and audit["open"] == 0


def test_lost_batch_reopens_queued_span_and_conserves():
    """A replica failure requeues its rows: the request span gets a
    ``requeue`` instant plus a *second* queued span, and still ends in
    exactly one terminal once the survivor serves it."""
    obs = Observability()
    cluster = stub_fault_cluster(
        2, router="least_inflight",
        breaker=BreakerConfig(failure_threshold=1, cooldown_ms=1e6),
    )
    cluster.replicas[0].backend.inject_failures(50)
    loop = ServingLoop(
        stub_scheduler(t_sla_ms=1_000.0), cluster, dispatch="sync",
        observability=obs,
    )
    futures = [loop.submit(_request(i)) for i in range(8)]
    r1 = loop.tick(now_ms=0.0)
    assert r1.stats.n_lost > 0 and r1.stats.n_requeued == r1.stats.n_lost
    r2 = loop.tick(now_ms=100.0)
    assert r2.stats.n_lost == 0
    assert all(f.state is RequestState.RESOLVED for f in futures)

    requeued = [f for f in futures if f.requeues]
    assert len(requeued) == r1.stats.n_requeued
    for f in requeued:
        children = obs.tracer.children_of(f.span)
        names = [s.name for s in children]
        assert names.count("requeue") == 1
        assert names.count("queued") == 2  # original + reopened
        assert all(
            s.end_ms is not None for s in children if s.name == "queued"
        )

    audit = request_conservation(obs.tracer)
    assert audit["submitted"] == 8 and audit["resolved"] == 8
    assert audit["open"] == 0 and audit["extra_terminals"] == 0

    m = obs.metrics
    assert m.get_value("counter", "loop_lost_rows_total") == r1.stats.n_lost
    assert (
        m.get_value("counter", "loop_requeued_total") == r1.stats.n_requeued
    )
    assert m.get_value("counter", "loop_batches_lost_total") >= 1
    # The breaker trip left its mark on the control plane.
    assert obs.tracer.find("breaker.trip")
    trips = sum(
        obj.value
        for kind, name, labels, obj in m.items()
        if kind == "counter" and name == "breaker_trips_total"
    )
    assert trips >= 1


def test_transport_spans_nest_under_the_dispatch_group():
    obs = Observability()
    cluster = stub_fault_cluster(1)
    loop = ServingLoop(
        stub_scheduler(t_sla_ms=1_000.0), cluster, dispatch="sync",
        observability=obs,
    )
    loop.submit(_request(0))
    loop.tick(now_ms=0.0)
    roundtrips = obs.tracer.find("transport.roundtrip")
    assert roundtrips
    batch_ids = {
        s.span_id for s in obs.tracer.spans if s.name.startswith("batch:")
    }
    assert all(s.parent_id in batch_ids for s in roundtrips)
    for rt in roundtrips:
        execs = [
            s for s in obs.tracer.children_of(rt) if s.name == "worker.execute"
        ]
        assert len(execs) == 1
        ex = execs[0]
        # The worker leg sits inside the roundtrip envelope.
        assert rt.start_ms <= ex.start_ms and ex.end_ms <= rt.end_ms + 1e-6


# ---------------------------------------------------------------------------
# controller retunes as spans + metrics
# ---------------------------------------------------------------------------
def test_controller_retune_emits_instant_and_counters():
    obs = Observability()
    ctl = AdmissionController(
        ControllerConfig(target_wait_frac=0.1, hysteresis=1)
    )
    ctl.observability = obs
    queue = AdmissionQueue(
        AdmissionConfig(policy="shed", max_pending=16, max_chunk=16)
    )
    sched = types.SimpleNamespace(
        cfg=types.SimpleNamespace(t_sla_ms=100.0),
        mu=np.array([5.0]),
        join_ttft_mu=0.0,
    )
    comp = types.SimpleNamespace(queue_wait_ms=90.0)  # way over target
    result = types.SimpleNamespace(
        completions=[comp], stats=types.SimpleNamespace(n_shed=1)
    )
    ctl.observe(result, scheduler=sched, now_ms=123.0)
    assert ctl.apply(queue)
    retunes = obs.tracer.find("controller.retune")
    assert len(retunes) == 1 and retunes[0].is_instant
    assert retunes[0].args["direction"] == "tighten"
    assert retunes[0].args["max_pending"] == queue.cfg.max_pending
    m = obs.metrics
    assert m.get_value(
        "counter", "controller_retunes_total", direction="tighten"
    ) == 1
    assert m.get_value("gauge", "controller_max_pending") == (
        queue.cfg.max_pending
    )
    assert m.get_value("histogram", "controller_wait_ewma_ms") == 1
    assert len(ctl.log) == 1  # the serve --controller summary's source


# ---------------------------------------------------------------------------
# satellite: InferenceFuture.stream() chunk stamps + TickStats fields
# ---------------------------------------------------------------------------
def test_stream_chunks_carry_wall_stamps_and_token_instants():
    obs = Observability()
    loop = _stub_loop(obs)
    f = loop.submit(_request(0))
    # Backend-side pushes while EXECUTING: indexed in decode order with
    # the emission wall stamp (what TTFT accounting reads).
    f._push_chunk(7, 100.0)
    f._push_chunk(9, 105.0)
    assert [c.index for c in f.chunks] == [0, 1]
    assert [c.token for c in f.chunks] == [7, 9]
    assert [c.wall_ms for c in f.chunks] == [100.0, 105.0]
    marks = obs.tracer.find("stream.token")
    assert [m.start_ms for m in marks] == [100.0, 105.0]
    assert [m.args["index"] for m in marks] == [0, 1]
    assert all(m.parent_id == f.span.span_id for m in marks)
    loop.tick(now_ms=0.0)
    # The consumer sees the pushed chunks first, in order.
    streamed = list(f.stream())
    assert [c.token for c in streamed[:2]] == [7, 9]


def test_stream_degrades_to_burst_on_tokenless_tier():
    loop = _stub_loop()
    f = loop.submit(_request(0))
    loop.tick(now_ms=0.0)
    assert f.done() and not f.chunks  # stub tier has no token channel
    chunks = list(f.stream())
    comp = f.result(timeout=0)
    assert [c.token for c in chunks] == [
        int(t) for t in np.asarray(comp.tokens).ravel()
    ]
    assert [c.index for c in chunks] == list(range(len(chunks)))
    # Burst chunks share one consumption-time stamp.
    assert len({c.wall_ms for c in chunks}) == 1


def test_tickstats_defaults_and_loss_accounting():
    from repro.serving.loop import TickStats

    stats = TickStats(
        n_requests=0, n_hedged=0, remote_wall_ms=0.0, hedge_wall_ms=None,
        span_wall_ms=0.0, dispatch_spread_wall_ms=0.0,
        hedge_dispatched_before_remote_done=False,
    )
    assert stats.n_lost == 0 and stats.n_requeued == 0

    cluster = stub_fault_cluster(
        1, breaker=BreakerConfig(failure_threshold=1, cooldown_ms=1e6)
    )
    cluster.replicas[0].backend.inject_failures(10)
    hedge = StubHedgeBackend(0.0)
    loop = ServingLoop(stub_scheduler(t_sla_ms=1_000.0), cluster, hedge,
                       dispatch="sync")
    loop.submit(_request(0))
    loop.submit(_request(1))
    res = loop.tick(now_ms=0.0)
    # With a measured hedge duplicate, lost rows fail over instead of
    # requeueing: n_lost counts them, n_requeued stays 0.
    assert res.stats.n_lost == 2 and res.stats.n_requeued == 0
    assert len(res.completions) == 2
    assert all(c.race_resolution == "remote_failed" for c in res.completions)
