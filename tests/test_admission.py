"""Bounded admission queue: overload soak, conservation, policy semantics.

Driven through the sleep-tier stubs (``tests/loop_stubs.py``) so every test
is deterministic and compile-free.  The invariants under test:

* ``pending`` never exceeds ``max_pending`` under any overload policy;
* request conservation: ``resolved + rejected + cancelled == submitted``;
* shed decisions are monotone in queue wait (a request shed at wait *w*
  would also be shed at any wait > *w*);
* ``max_chunk`` caps every tick's batch, with leftovers persisting FIFO
  across ticks;
* the unbounded default is behaviorally identical to the pre-admission
  loop (the compat pin — the byte-identical reference lives in
  ``tests/test_loop.py``'s shim-equivalence test).
"""
import time

import numpy as np
import pytest

from repro.serving.admission import (
    AdmissionConfig,
    AdmissionQueue,
    sla_unreachable,
)
from repro.serving.lifecycle import (
    CompletedRequest,
    InferenceFuture,
    QueuedRequest,
    RequestRejected,
    RequestState,
)
from repro.serving.loop import ServingLoop

from hypothesis_compat import given, settings, st
from loop_stubs import StubHedgeBackend, StubRemoteBackend, stub_scheduler

TERMINAL = (
    RequestState.RESOLVED, RequestState.REJECTED, RequestState.CANCELLED
)
# The stub scheduler's fastest remote mu (the shed predicate's service
# floor: stub-a's mu_ms), its on-device tier's mu (the network-free hedge
# floor), and the stub est used by _request below.  The loop's shed
# predicate charges min(est + remote floor, ondevice floor).
STUB_FLOOR_MS, STUB_ONDEV_MS, STUB_EST_MS = 30.0, 20.0, 10.0
STUB_SHED_FLOOR_MS = min(STUB_EST_MS + STUB_FLOOR_MS, STUB_ONDEV_MS)


def _request(rid, arrival_ms=0.0, est=STUB_EST_MS, sla=None, n_steps=2):
    return QueuedRequest(
        rid=rid,
        tokens=np.zeros(4, np.int32),
        n_steps=n_steps,
        t_nw_est_ms=est,
        t_nw_actual_ms=est,
        arrival_ms=float(arrival_ms),
        sla_ms=sla,
    )


def _completion(rid):
    return CompletedRequest(
        rid=rid, model_name="stub", model_index=0,
        tokens=np.zeros(1, np.int32), exec_ms=1.0, remote_ms=1.0,
        latency_ms=1.0, accuracy=1.0, used_remote=True, hedged=False,
    )


def _loop(admission, *, t_sla_ms=1_000.0, delay_s=0.0, dispatch="sync", **kw):
    kw.setdefault("profile_ewma", 0.0)  # frozen profiles: fixed shed floor
    return ServingLoop(
        stub_scheduler(t_sla_ms=t_sla_ms, **kw),
        StubRemoteBackend(delay_s),
        StubHedgeBackend(delay_s),
        dispatch=dispatch,
        admission=admission,
    )


def _drive(loop, *, step_ms=50.0, max_pending=None, max_ticks=10_000):
    """Tick the loop dry, checking the pending bound at every step."""
    results = []
    t = loop.now_ms
    for _ in range(max_ticks):
        if not (loop.backlog or loop.inflight):
            return results
        t += step_ms
        r = loop.tick(now_ms=t)
        results.extend(loop.drain())
        if r is not None:
            results.append(r)
        if max_pending is not None:
            assert loop.pending <= max_pending
    raise AssertionError("loop did not drain within the tick budget")


def _state_counts(futures):
    resolved = sum(f.state is RequestState.RESOLVED for f in futures)
    rejected = sum(f.state is RequestState.REJECTED for f in futures)
    cancelled = sum(f.state is RequestState.CANCELLED for f in futures)
    return resolved, rejected, cancelled


# ---------------------------------------------------------------------------
# Overload soak: 4x capacity through every bounded policy.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["block", "shed", "degrade"])
def test_overload_soak_bounds_pending_and_conserves(policy):
    cap = 8
    loop = _loop(AdmissionConfig(max_pending=cap, max_chunk=4, policy=policy))
    futures = []
    for i in range(4 * cap):  # 4x capacity, all at once
        futures.append(loop.submit(_request(i, arrival_ms=0.0)))
        assert loop.pending <= cap
    results = _drive(loop, max_pending=cap)
    assert all(f.state in TERMINAL for f in futures)
    resolved, rejected, cancelled = _state_counts(futures)
    assert resolved + rejected + cancelled == len(futures)
    assert loop.admission.n_submitted == len(futures)
    assert rejected == loop.admission.n_rejected
    if policy == "shed":
        # Capacity tail-drop: everything past the bounded queue rejected
        # at submit (their waits were 0 — no deadline shedding possible).
        assert rejected == 3 * cap and resolved == cap
        with pytest.raises(RequestRejected):
            futures[-1].result(timeout=0)
    else:
        assert rejected == 0 and resolved == 4 * cap
    if policy == "degrade":
        degraded = [
            c for r in results for c in r.completions
            if c.race_resolution == "degraded"
        ]
        assert len(degraded) == 3 * cap  # the overflow went on-device


@pytest.mark.stress
@pytest.mark.parametrize("policy", ["block", "shed", "degrade"])
def test_overload_soak_stress(policy):
    """Wave-driven soak: 16 waves of 128 submissions against capacity 32.

    Runs in the non-blocking CI ``stress`` job; the wall-clock budget
    assertion keeps it an honest soak rather than an unbounded crawl.
    """
    t0 = time.perf_counter()
    cap, waves, per_wave = 32, 16, 128
    loop = _loop(
        AdmissionConfig(max_pending=cap, max_chunk=16, policy=policy),
        delay_s=0.0005,
    )
    futures, rid = [], 0
    t = 0.0
    for _ in range(waves):
        for _ in range(per_wave):
            futures.append(loop.submit(_request(rid, arrival_ms=t)))
            rid += 1
            assert loop.pending <= cap
        t += 50.0
        loop.tick(now_ms=t)
        assert loop.pending <= cap
    _drive(loop, max_pending=cap)
    assert all(f.state in TERMINAL for f in futures)
    resolved, rejected, cancelled = _state_counts(futures)
    assert resolved + rejected + cancelled == waves * per_wave
    assert rejected == loop.admission.n_rejected
    if policy != "shed":
        assert resolved == waves * per_wave
    assert time.perf_counter() - t0 < 90.0  # wall-clock soak budget


# ---------------------------------------------------------------------------
# Policy semantics.
# ---------------------------------------------------------------------------
def test_block_policy_backpressures_then_admits_fifo():
    cap = 2
    loop = _loop(AdmissionConfig(max_pending=cap, max_chunk=2, policy="block"))
    fs = [loop.submit(_request(i)) for i in range(5)]
    assert [f.admitted for f in fs] == [True, True, False, False, False]
    assert loop.pending == 2 and loop.blocked == 3
    assert all(f.admitted_wall_ms is not None for f in fs[:2])
    assert all(f.admitted_wall_ms is None for f in fs[2:])

    loop.tick(now_ms=50.0)  # serves the chunk; freed slots refill FIFO
    assert fs[2].admitted and fs[3].admitted and not fs[4].admitted
    assert fs[2].admitted_wall_ms >= fs[0].admitted_wall_ms
    assert loop.blocked == 1

    loop.flush()  # drives the overflow room dry too
    assert loop.backlog == 0
    assert all(f.state is RequestState.RESOLVED for f in fs)
    # FIFO: completion order == submission order.
    assert [f.result(timeout=0).rid for f in fs] == [0, 1, 2, 3, 4]


def test_blocked_future_result_drives_the_loop():
    loop = _loop(AdmissionConfig(max_pending=1, max_chunk=1, policy="block"))
    first = loop.submit(_request(0))
    blocked = loop.submit(_request(1))
    assert not blocked.admitted
    # result() on a backpressured future flushes the loop through the
    # overflow room — a single-threaded caller never deadlocks.
    assert blocked.result().rid == 1
    assert first.state is RequestState.RESOLVED


def test_client_wait_admission_blocks_until_slot():
    from repro.serving.client import InferenceClient

    loop = _loop(AdmissionConfig(max_pending=1, max_chunk=1, policy="block"))
    client = InferenceClient(loop)
    client.submit(np.zeros(4, np.int32), n_steps=2)
    f = client.submit(np.zeros(4, np.int32), n_steps=2, wait_admission=True)
    assert f.admitted  # submit ticked the loop until capacity freed


def test_shed_deadline_rejects_unreachable_sla():
    loop = _loop(
        AdmissionConfig(max_pending=8, max_chunk=8, policy="shed"),
        t_sla_ms=200.0,
    )
    # The loop's shed floor is min(est + fastest remote mu, ondevice mu)
    # = min(10 + 30, 20) = 20: the network-free duplicate is the cheapest
    # path, so a request sheds only once wait + 20 exceeds its SLA.
    ok = loop.submit(_request(0, arrival_ms=90.0))  # wait 100: 120 <= 200
    late = loop.submit(_request(1, arrival_ms=0.0))  # wait 190: 210 > 200
    tight = loop.submit(_request(2, arrival_ms=150.0, sla=50.0))  # 60 > 50
    res = loop.tick(now_ms=190.0)
    assert [c.rid for c in res.completions] == [0]
    assert res.stats.n_shed == 2
    assert late.state is RequestState.REJECTED
    assert tight.state is RequestState.REJECTED
    assert ok.state is RequestState.RESOLVED
    with pytest.raises(RequestRejected):
        late.result(timeout=0)
    assert late.done() and late.rejected() and not late.cancelled()
    # Overload accounting on the tick metrics.
    assert res.metrics.n_rejected == 2
    assert res.metrics.shed_rate == pytest.approx(2 / 3)
    assert res.metrics.goodput == pytest.approx(1 / 3)
    assert res.metrics.sla_attainment == 1.0  # the served one attained


def test_all_shed_tick_surfaces_rejection_accounting():
    loop = _loop(
        AdmissionConfig(max_pending=8, max_chunk=8, policy="shed"),
        t_sla_ms=100.0,
    )
    fs = [loop.submit(_request(i, arrival_ms=0.0)) for i in range(3)]
    res = loop.tick(now_ms=500.0)  # wait 500 >> sla: everything shed
    assert res is not None and res.completions == []
    assert res.stats.n_shed == 3 and res.stats.n_requests == 0
    assert res.metrics.n_rejected == 3
    assert res.metrics.shed_rate == 1.0 and res.metrics.goodput == 0.0
    assert all(f.state is RequestState.REJECTED for f in fs)
    assert loop.backlog == 0
    assert loop.tick(now_ms=600.0) is None  # truly empty tick stays None


def test_drain_trace_metrics_survive_total_shedding():
    from repro.core.network import FixedCVNetwork
    from repro.serving.loadgen import PoissonArrivals, make_trace

    n = 20
    trace = make_trace(n, PoissonArrivals(100.0), FixedCVNetwork(10.0, 0.0), seed=2)
    # Even the cheapest path (the network-free on-device duplicate,
    # mu 20) exceeds the SLA: every request is shed at wait 0.
    loop = _loop(
        AdmissionConfig(max_pending=8, max_chunk=8, policy="shed"),
        t_sla_ms=15.0,
    )
    done, metrics = loop.drain_trace(
        trace, 50.0, tokens_for=lambda i: np.zeros(4, np.int32), n_steps=2
    )
    assert done == []
    assert metrics is not None  # overload accounting survives total shed
    assert metrics.n_requests == 0 and metrics.n_rejected == n
    assert metrics.shed_rate == 1.0 and metrics.goodput == 0.0


def test_degrade_policy_routes_overflow_ondevice_only():
    loop = _loop(AdmissionConfig(max_pending=2, max_chunk=8, policy="degrade"))
    fs = [loop.submit(_request(i)) for i in range(6)]
    res = loop.tick(now_ms=100.0)
    comps = {c.rid: c for c in res.completions}
    assert len(comps) == 6 and all(f.state is RequestState.RESOLVED for f in fs)
    assert res.stats.n_requests == 2 and res.stats.n_degraded == 4
    for rid in (0, 1):  # admitted: the normal two-tier path
        assert comps[rid].race_resolution != "degraded"
    for rid in (2, 3, 4, 5):  # overflow: on-device tier alone
        c = comps[rid]
        assert c.race_resolution == "degraded"
        assert not c.used_remote and not c.hedged
        assert c.model_name == "stub-hedge"
        assert c.accuracy == 35.0  # the stub on-device tier's quality
        assert c.hedge_measured  # the duplicate really executed
        assert c.latency_ms == pytest.approx(
            c.queue_wait_ms + c.exec_ms
        )  # no network leg
    assert res.metrics.race_resolution["degraded"] == pytest.approx(4 / 6)
    assert res.metrics.model_usage["stub-hedge"] == pytest.approx(4 / 6)


# ---------------------------------------------------------------------------
# Chunk capping + multi-tick persistence + inflight gating.
# ---------------------------------------------------------------------------
def test_max_chunk_persists_leftovers_fifo_across_ticks():
    loop = _loop(AdmissionConfig(max_chunk=3))
    fs = [loop.submit(_request(i, arrival_ms=float(i))) for i in range(10)]
    sizes, rids = [], []
    t = 10.0
    while loop.backlog:
        t += 50.0
        r = loop.tick(now_ms=t)
        sizes.append(r.stats.n_requests)
        rids.extend(c.rid for c in r.completions)
    assert sizes == [3, 3, 3, 1]
    assert rids == list(range(10))  # FIFO across ticks
    assert all(f.state is RequestState.RESOLVED for f in fs)
    # Later ticks charge the persistent queue's wait honestly.
    waits = {c.rid: c.queue_wait_ms for r in _drive(loop) for c in r.completions}
    assert waits == {}  # already drained


def test_max_inflight_ticks_gates_dispatch():
    loop = _loop(
        AdmissionConfig(max_chunk=2, max_inflight_ticks=1),
        delay_s=0.05,
        dispatch="async",
    )
    fs = [loop.submit(_request(i)) for i in range(4)]
    assert loop.tick(now_ms=1.0, wait=False) is None  # dispatched 2
    assert loop.inflight == 2 and loop.pending == 2
    # The gate: a second tick dispatches nothing while one is in flight.
    assert loop.tick(now_ms=2.0, wait=False) is None
    assert loop.inflight == 2 and loop.pending == 2
    assert fs[2].state is RequestState.QUEUED
    deadline = time.perf_counter() + 5.0
    while not loop.poll() and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert loop.inflight == 0
    assert loop.tick(now_ms=3.0, wait=False) is None  # gate reopened
    assert loop.inflight == 2
    loop.drain()
    assert all(f.state is RequestState.RESOLVED for f in fs)


def test_cancelled_blocked_future_frees_nothing_and_conserves():
    loop = _loop(AdmissionConfig(max_pending=1, max_chunk=1, policy="block"))
    kept = loop.submit(_request(0))
    dropped = loop.submit(_request(1))
    assert dropped.cancel()  # still QUEUED in the overflow room
    assert dropped.state is RequestState.CANCELLED
    loop.flush()
    assert kept.state is RequestState.RESOLVED
    resolved, rejected, cancelled = _state_counts([kept, dropped])
    assert (resolved, rejected, cancelled) == (1, 0, 1)
    assert resolved + rejected + cancelled == loop.admission.n_submitted


# ---------------------------------------------------------------------------
# Unbounded default == pre-admission behavior (regression pin; the
# byte-identical serve_queue reference lives in test_loop.py).
# ---------------------------------------------------------------------------
def test_unbounded_default_matches_explicit_unbounded_config():
    def serve(admission):
        loop = _loop(admission, t_sla_ms=1_000.0, seed=3)
        for i in range(12):
            loop.submit(_request(i, arrival_ms=7.0 * i))
        res = loop.tick(now_ms=100.0)
        assert len(res.completions) == 12  # one tick drains everything
        return [
            (c.rid, c.model_index, c.hedged, c.used_remote, c.accuracy,
             c.queue_wait_ms, c.time_to_schedule_ms, c.race_resolution)
            for c in res.completions
        ], res.metrics

    rows_default, m_default = serve(None)
    rows_explicit, m_explicit = serve(AdmissionConfig())
    rows_nocap, m_nocap = serve(
        AdmissionConfig(max_pending=None, max_chunk=None, policy="unbounded")
    )
    assert rows_default == rows_explicit == rows_nocap
    for m in (m_default, m_explicit, m_nocap):
        assert m.n_rejected == 0 and m.shed_rate == 0.0
        assert m.goodput == m.sla_attainment
        assert m.model_usage == m_default.model_usage


# ---------------------------------------------------------------------------
# Service-coupled clock: overload builds real wait; shed bounds it.
# ---------------------------------------------------------------------------
def test_service_coupled_overload_wait_grows_and_shed_bounds_it():
    from repro.core.network import FixedCVNetwork
    from repro.serving.loadgen import PoissonArrivals, make_trace

    sla, n = 300.0, 80
    trace = make_trace(n, PoissonArrivals(100.0), FixedCVNetwork(10.0, 0.0), seed=4)

    def serve(admission):
        loop = _loop(admission, t_sla_ms=sla)
        done, metrics = loop.drain_trace(
            trace, 50.0, tokens_for=lambda i: np.zeros(4, np.int32), n_steps=2,
            # 20ms of service per scheduled request vs ~10ms offered
            # inter-arrival: a sustained 2x overload.
            service_model=lambda res: 20.0 * res.stats.n_requests,
        )
        return done, metrics

    done_u, m_u = serve(None)
    done_s, m_s = serve(
        AdmissionConfig(max_pending=16, max_chunk=8, policy="shed")
    )
    assert len(done_u) == n  # unbounded serves everything...
    assert m_u.p99_queue_wait_ms > 2 * sla  # ...with divergent queue wait
    assert m_s.n_rejected > 0 and len(done_s) + m_s.n_rejected == n
    # Shed keeps every *served* request's wait under the reachability bar
    # (the cheapest path is the network-free on-device duplicate).
    max_wait = sla - STUB_SHED_FLOOR_MS
    assert all(c.queue_wait_ms <= max_wait + 1e-6 for c in done_s)
    assert m_s.p99_queue_wait_ms <= max_wait + 1e-6
    assert m_s.shed_rate == pytest.approx(m_s.n_rejected / n)


# ---------------------------------------------------------------------------
# Conservation + monotonicity: seeded deterministic twins of the
# hypothesis properties (so tier-1 exercises them without hypothesis).
# ---------------------------------------------------------------------------
def _check_conservation(arrival_gaps, policy, max_pending, max_chunk):
    cfg = AdmissionConfig(
        max_pending=None if policy == "unbounded" else max_pending,
        max_chunk=max_chunk,
        policy=policy,
    )
    q = AdmissionQueue(cfg)
    futures, t = [], 0.0
    for i, gap in enumerate(arrival_gaps):
        t += float(gap)
        f = InferenceFuture(_request(i, arrival_ms=t))
        q.offer(f)
        futures.append(f)
        if cfg.bounded:
            assert q.pending <= max_pending
    now = t
    for _ in range(10_000):
        if not q.backlog:
            break
        now += 25.0
        batch = q.take(now, default_sla_ms=1e9)  # no deadline shedding
        for f in batch.chunk + batch.degraded:
            assert f._try_schedule(batch.now_ms)
            f._mark_resolved(_completion(f.request.rid))
        if cfg.bounded:
            assert q.pending <= max_pending
        if not batch and not batch.shed:
            raise AssertionError("admission queue stalled with a backlog")
    assert q.backlog == 0
    resolved, rejected, cancelled = _state_counts(futures)
    assert resolved + rejected + cancelled == len(futures) == q.n_submitted
    assert rejected == q.n_rejected
    # No admitted request is ever lost.
    assert all(
        f.state is RequestState.RESOLVED for f in futures if f.admitted
    )


@pytest.mark.parametrize("policy", ["unbounded", "block", "shed", "degrade"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_conservation_seeded(policy, seed):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(10.0, size=30)
    _check_conservation(gaps, policy, max_pending=4, max_chunk=3)


@given(
    gaps=st.lists(
        st.floats(min_value=0.0, max_value=100.0), min_size=1, max_size=40
    ),
    policy=st.sampled_from(["unbounded", "block", "shed", "degrade"]),
    max_pending=st.integers(min_value=1, max_value=6),
    max_chunk=st.one_of(st.none(), st.integers(min_value=1, max_value=5)),
)
@settings(deadline=None, max_examples=60)
def test_conservation_property(gaps, policy, max_pending, max_chunk):
    _check_conservation(gaps, policy, max_pending, max_chunk)


def test_shed_monotone_in_queue_wait_seeded():
    # For a fixed request, sweep the tick clock: the shed decision must
    # flip at most once, from "keep" to "shed", as the wait grows.
    decisions = []
    for now in np.linspace(0.0, 400.0, 81):
        q = AdmissionQueue(
            AdmissionConfig(max_pending=4, policy="shed")
        )
        q.offer(InferenceFuture(_request(0, arrival_ms=0.0)))
        batch = q.take(
            float(now), default_sla_ms=200.0, service_floor_ms=STUB_FLOOR_MS
        )
        decisions.append(bool(batch.shed))
    assert decisions == sorted(decisions)  # monotone: False... then True...
    assert decisions[0] is False and decisions[-1] is True


@given(
    wait=st.floats(min_value=0.0, max_value=1e4),
    delta=st.floats(min_value=0.0, max_value=1e4),
    sla=st.floats(min_value=0.0, max_value=1e4),
    est=st.floats(min_value=0.0, max_value=1e3),
    floor=st.floats(min_value=0.0, max_value=1e3),
    headroom=st.floats(min_value=0.0, max_value=1e3),
    ondev=st.one_of(st.none(), st.floats(min_value=0.0, max_value=1e3)),
)
@settings(deadline=None, max_examples=200)
def test_shed_monotone_property(wait, delta, sla, est, floor, headroom, ondev):
    if sla_unreachable(wait, sla, est, floor, headroom, ondev):
        assert sla_unreachable(wait + delta, sla, est, floor, headroom, ondev)


def test_shed_floor_considers_the_network_free_hedge_path():
    # A terrible network (est 300 > sla 250) must NOT get a request shed
    # when the on-device duplicate (no network leg) still attains the SLA.
    assert sla_unreachable(0.0, 250.0, 300.0, 30.0)  # remote-only: hopeless
    assert not sla_unreachable(0.0, 250.0, 300.0, 30.0, ondevice_floor_ms=20.0)
    # ...and through the loop: the request is served, not rejected.
    loop = _loop(
        AdmissionConfig(max_pending=8, max_chunk=8, policy="shed"),
        t_sla_ms=250.0,
    )
    f = loop.submit(_request(0, arrival_ms=0.0, est=300.0))
    res = loop.tick(now_ms=50.0)
    assert f.state is RequestState.RESOLVED
    assert res.stats.n_shed == 0 and len(res.completions) == 1


# ---------------------------------------------------------------------------
# Requeue (lost-batch recovery): front re-insert, honest wait, shed-on-late.
# ---------------------------------------------------------------------------
def test_requeue_reinserts_at_front_ahead_of_younger_arrivals():
    q = AdmissionQueue(AdmissionConfig(max_chunk=4))
    fs = [InferenceFuture(_request(i, arrival_ms=float(i))) for i in range(6)]
    for f in fs:
        q.offer(f)
    batch = q.take(10.0, default_sla_ms=1e9)
    assert [f.request.rid for f in batch.chunk] == [0, 1, 2, 3]
    # Rows 0-1 lost to a replica fault: they re-enter at the head, in
    # order, ahead of the younger arrivals still queued.
    q.requeue(batch.chunk[:2])
    assert q.n_requeued == 2
    nxt = q.take(20.0, default_sla_ms=1e9)
    assert [f.request.rid for f in nxt.chunk] == [0, 1, 4, 5]


def test_requeue_bypasses_capacity_and_keeps_the_arrival_stamp():
    cap = 4
    q = AdmissionQueue(
        AdmissionConfig(max_pending=cap, max_chunk=cap, policy="shed")
    )
    fs = [InferenceFuture(_request(i, arrival_ms=0.0)) for i in range(cap)]
    for f in fs:
        q.offer(f)
    batch = q.take(50.0, default_sla_ms=1e9)
    assert len(batch.chunk) == cap
    # The freed capacity refills with younger arrivals...
    for i in range(cap):
        g = InferenceFuture(_request(10 + i, arrival_ms=60.0))
        assert q.offer(g) == "admitted"
    # ...then the dispatched batch is lost: requeue re-enters *above*
    # max_pending (the rows already held slots once) with zero rejects.
    q.requeue(batch.chunk)
    assert q.pending == 2 * cap and q.n_rejected == 0
    # Their arrival stamp is untouched: queue wait stays charged from the
    # first admission, not the requeue (honest wait accounting).
    assert all(f.request.arrival_ms == 0.0 for f in batch.chunk)
    nxt = q.take(70.0, default_sla_ms=1e9)
    assert [f.request.rid for f in nxt.chunk] == [0, 1, 2, 3]


def test_requeued_past_sla_is_shed_not_redispatched():
    q = AdmissionQueue(AdmissionConfig(max_pending=8, policy="shed"))
    f = InferenceFuture(_request(0, arrival_ms=0.0))
    assert q.offer(f) == "admitted"
    assert q.take(10.0, default_sla_ms=1e9).chunk == [f]
    q.requeue([f])
    # By the next tick the wait — charged from the original arrival —
    # has made the SLA unreachable: the row sheds instead of re-dispatching.
    nxt = q.take(500.0, default_sla_ms=200.0, service_floor_ms=STUB_FLOOR_MS)
    assert nxt.chunk == [] and nxt.shed == [f]
    assert f.state is RequestState.REJECTED
    assert q.n_rejected == 1


# ---------------------------------------------------------------------------
# Config validation.
# ---------------------------------------------------------------------------
def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(policy="drop-everything")
    with pytest.raises(ValueError):
        AdmissionConfig(policy="shed")  # bounded policy needs max_pending
    with pytest.raises(ValueError):
        AdmissionConfig(max_pending=0, policy="block")
    with pytest.raises(ValueError):
        AdmissionConfig(max_chunk=-1)
    assert not AdmissionConfig().bounded
    assert AdmissionConfig(max_pending=4, policy="block").bounded


# ---------------------------------------------------------------------------
# Mid-run retuning (the adaptive controller's surface): atomic capacity
# swaps, shrink-never-retro-sheds, conservation while knobs move.
# ---------------------------------------------------------------------------
def test_retune_swaps_capacity_knobs_atomically():
    q = AdmissionQueue(
        AdmissionConfig(max_pending=8, max_chunk=4, policy="shed")
    )
    cfg = q.retune(max_pending=2, shed_headroom_ms=50.0)
    assert cfg is q.cfg
    assert cfg.max_pending == 2 and cfg.shed_headroom_ms == 50.0
    assert cfg.max_chunk == 4 and cfg.policy == "shed"  # untouched knobs
    # The swap re-runs AdmissionConfig validation; a bad retune raises
    # and leaves the live config alone instead of wedging the queue.
    with pytest.raises(ValueError):
        q.retune(max_pending=0)
    with pytest.raises(ValueError):
        q.retune(max_pending=None)  # bounded policy needs a capacity
    assert q.cfg.max_pending == 2


def test_retune_shrink_capacity_never_retro_sheds():
    # Capacity is consulted on *offer* only: shrinking max_pending under a
    # full queue evicts nothing — the already-admitted requests all serve,
    # while new arrivals see the shrunk capacity immediately.
    q = AdmissionQueue(
        AdmissionConfig(max_pending=8, max_chunk=8, policy="shed")
    )
    fs = [InferenceFuture(_request(i, arrival_ms=0.0)) for i in range(8)]
    for f in fs:
        assert q.offer(f) == "admitted"
    q.retune(max_pending=2)
    assert q.pending == 8  # nobody evicted
    late = InferenceFuture(_request(99, arrival_ms=1.0))
    assert q.offer(late) == "rejected"  # new arrivals: shrunk capacity
    batch = q.take(10.0, default_sla_ms=1e9)
    assert [f.request.rid for f in batch.chunk] == list(range(8))
    assert batch.shed == []
    assert all(f.state is not RequestState.REJECTED for f in fs)


def test_shrinking_margin_never_retro_sheds():
    # sla_unreachable boundary under a *shrinking* margin.  The predicate
    # charges wait + (est + service floor) + headroom against the SLA:
    # with sla=200, est=10, floor=30 the shed bound is wait > 160 - headroom.
    # Pick a wait between the wide-margin bound (60) and the shrunk-margin
    # bound (160): the wide margin sheds it, the shrunk margin must not —
    # a smaller headroom sheds a strict subset of what the old margin did.
    sla, wide, wait = 200.0, 100.0, 120.0

    def outcome(headroom_at_take):
        q = AdmissionQueue(
            AdmissionConfig(
                max_pending=4, policy="shed", shed_headroom_ms=wide
            )
        )
        f = InferenceFuture(_request(0, arrival_ms=0.0))
        assert q.offer(f) == "admitted"  # admitted under the wide margin
        q.retune(shed_headroom_ms=headroom_at_take)
        batch = q.take(
            wait, default_sla_ms=sla, service_floor_ms=STUB_FLOOR_MS
        )
        return f, batch

    f_wide, batch_wide = outcome(wide)  # margin kept: the boundary is live
    assert batch_wide.shed == [f_wide]
    assert f_wide.state is RequestState.REJECTED
    f_shrunk, batch_shrunk = outcome(0.0)  # margin shrunk before the tick
    assert batch_shrunk.chunk == [f_shrunk] and batch_shrunk.shed == []
    assert f_shrunk.state is not RequestState.REJECTED


@given(
    wait=st.floats(min_value=0.0, max_value=1e4),
    sla=st.floats(min_value=0.0, max_value=1e4),
    est=st.floats(min_value=0.0, max_value=1e3),
    floor=st.floats(min_value=0.0, max_value=1e3),
    headroom=st.floats(min_value=0.0, max_value=1e3),
    shrink=st.floats(min_value=0.0, max_value=1e3),
    ondev=st.one_of(st.none(), st.floats(min_value=0.0, max_value=1e3)),
)
@settings(deadline=None, max_examples=200)
def test_shed_monotone_in_margin_property(
    wait, sla, est, floor, headroom, shrink, ondev
):
    # Monotone in the margin: anything shed under the smaller headroom
    # would also have been shed under the larger one — so shrinking the
    # margin never sheds a request the old margin admitted past.
    small = max(headroom - shrink, 0.0)
    if sla_unreachable(wait, sla, est, floor, small, ondev):
        assert sla_unreachable(wait, sla, est, floor, headroom, ondev)


def _check_conservation_retuned(arrival_gaps, policy, retunes):
    """Drain with a capacity retune before every tick; conservation and
    the capacity invariant must hold against the *live* config."""
    cfg = AdmissionConfig(max_pending=8, max_chunk=3, policy=policy)
    q = AdmissionQueue(cfg)
    futures, t = [], 0.0
    for i, gap in enumerate(arrival_gaps):
        t += float(gap)
        f = InferenceFuture(_request(i, arrival_ms=t))
        q.offer(f)
        futures.append(f)
    now, step = t, 0
    for _ in range(10_000):
        if not q.backlog:
            break
        now += 25.0
        mp, headroom = retunes[step % len(retunes)]
        step += 1
        q.retune(max_pending=mp, shed_headroom_ms=headroom)
        batch = q.take(now, default_sla_ms=1e9)  # no deadline shedding
        for f in batch.chunk + batch.degraded:
            assert f._try_schedule(batch.now_ms)
            f._mark_resolved(_completion(f.request.rid))
        if not batch and not batch.shed:
            raise AssertionError("admission queue stalled with a backlog")
    assert q.backlog == 0
    resolved, rejected, cancelled = _state_counts(futures)
    assert resolved + rejected + cancelled == len(futures) == q.n_submitted
    assert rejected == q.n_rejected
    assert all(
        f.state is RequestState.RESOLVED for f in futures if f.admitted
    )


@pytest.mark.parametrize("policy", ["block", "shed", "degrade"])
@pytest.mark.parametrize("seed", [0, 1])
def test_conservation_under_midrun_retunes_seeded(policy, seed):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(10.0, size=30)
    retunes = [(int(m), float(h)) for m, h in zip(
        rng.integers(1, 12, size=7), rng.uniform(0.0, 200.0, size=7)
    )]
    _check_conservation_retuned(gaps, policy, retunes)


@given(
    gaps=st.lists(
        st.floats(min_value=0.0, max_value=100.0), min_size=1, max_size=40
    ),
    policy=st.sampled_from(["block", "shed", "degrade"]),
    retunes=st.lists(
        st.tuples(
            st.integers(min_value=1, max_value=12),
            st.floats(min_value=0.0, max_value=200.0),
        ),
        min_size=1,
        max_size=8,
    ),
)
@settings(deadline=None, max_examples=60)
def test_conservation_under_midrun_retunes_property(gaps, policy, retunes):
    _check_conservation_retuned(gaps, policy, retunes)
