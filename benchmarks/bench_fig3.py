"""Fig 3a/3b: MDInference vs static greedy across SLA targets.

Paper claims validated here:
  * MDInference tracks the SLA from ~115 ms; static greedy violates until
    ~200-250 ms (Fig 3a).
  * Up to ~42 % lower mean end-to-end latency than static greedy.
  * Aggregate accuracy ~68 % at SLA 115 ms, converging to static greedy's
    ~82 % by SLA 250 ms.
  * Model usage shifts from MobileNetV1 0.25 to NasNet Large as the SLA
    grows; dominated models (InceptionResNetV2) are never chosen (Fig 3b).
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs.mdinference_zoo import paper_zoo
from repro.core import FixedCVNetwork
from repro.core.simulator import SimConfig, run_simulation

SLAS = [25, 50, 75, 100, 115, 150, 200, 250, 300]
NET = FixedCVNetwork(100.0, 0.5)


def run(n_requests: int = 10_000):
    zoo = paper_zoo()
    rows = {}
    for alg in ("mdinference", "static_greedy"):
        for sla in SLAS:
            cfg = SimConfig(
                registry=zoo, algorithm=alg, t_sla_ms=sla,
                n_requests=n_requests, network=NET, seed=3,
            )
            res, us = timed(run_simulation, cfg, repeats=1)
            m = res.metrics
            emit(
                f"fig3a/{alg}/sla{sla}",
                us / n_requests,
                f"lat={m.mean_latency_ms:.1f}ms acc={m.aggregate_accuracy:.2f}% "
                f"attain={m.sla_attainment*100:.1f}%",
            )
            rows[(alg, sla)] = m

    # Fig 3b: usage distribution at representative SLAs.
    for sla in (25, 150, 300):
        m = rows[("mdinference", sla)]
        top = sorted(m.model_usage.items(), key=lambda kv: -kv[1])[:3]
        emit(
            f"fig3b/usage/sla{sla}",
            0.0,
            " ".join(f"{k}:{v*100:.0f}%" for k, v in top),
        )

    # Headline derived claims.
    lat_red = 1 - rows[("mdinference", 115)].mean_latency_ms / rows[
        ("static_greedy", 115)
    ].mean_latency_ms
    emit("fig3/latency_reduction_at_115", 0.0, f"{lat_red*100:.1f}% (paper: up to 42%)")
    gap = (
        rows[("static_greedy", 250)].aggregate_accuracy
        - rows[("mdinference", 250)].aggregate_accuracy
    )
    emit("fig3/acc_gap_at_250", 0.0, f"{gap:.2f}pts (paper: ~0)")


if __name__ == "__main__":
    run()
