"""Kernel microbenchmarks: pure-JAX reference path wall-clock on CPU.

(Pallas kernels target TPU; interpret-mode timing is not meaningful, so we
time the reference implementations the models actually execute on CPU and
report the kernels' analytic VMEM tile footprints as the derived column.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ref


def run():
    key = jax.random.key(0)

    # Flash attention reference (B, NQ, S, D layout).
    B, NQ, NKV, S, D = 2, 8, 2, 1024, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, NQ, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, NKV, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, NKV, S, D), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    _, us = timed(lambda: jax.block_until_ready(fn(q, k, v)))
    tile_kb = (256 * D * 2 + 2 * 512 * D * 2 + 256 * D * 4 + 256 * 512 * 4) / 1024
    emit("kernels/flash_attention_ref", us, f"S={S} vmem_tile={tile_kb:.0f}KiB")

    # Decode attention.
    G = 4
    q1 = jax.random.normal(ks[0], (B, NKV, G, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, NKV, S, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, NKV, S, D), jnp.float32)
    sp = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    fn = jax.jit(lambda *a: ref.decode_attention_ref(*a))
    _, us = timed(lambda: jax.block_until_ready(fn(q1, kc, vc, sp, pos)))
    stream_mb = B * NKV * S * D * 2 * 2 / 2**20
    emit("kernels/decode_attention_ref", us, f"cache_stream={stream_mb:.1f}MiB/step")

    # RG-LRU scan.
    W = 512
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jnp.zeros((B, W))
    fn = jax.jit(lambda a, b, h0: ref.rglru_scan_ref(a, b, h0))
    _, us = timed(lambda: jax.block_until_ready(fn(a, b, h0)))
    emit("kernels/rglru_scan_ref", us, f"S={S} W={W} (assoc-scan; kernel=seq@HBM-bw)")

    # RMSNorm.
    x = jax.random.normal(ks[0], (B * S, 2048), jnp.float32)
    w = jnp.ones((2048,), jnp.float32)
    fn = jax.jit(lambda x, w: ref.rms_norm_ref(x, w))
    _, us = timed(lambda: jax.block_until_ready(fn(x, w)))
    emit("kernels/rms_norm_ref", us, "fused 1-pass in Pallas kernel")


if __name__ == "__main__":
    run()
