"""Fig 6: decomposition of the three-stage algorithm (ablation).

Zoo includes NasNet Fictional (the 50 %-accuracy twin of NasNet Large) so
the exploration set converges to {Large, Fictional} at high SLA.  Paper
claims: pure-random has flat latency/accuracy (SLA violations); related-
random degrades once the pair dominates M_E; MDInference and related-
accurate steadily improve.

Honesty note (recorded in EXPERIMENTS.md): with Eq. 4 taken literally the
{Large, Fictional} pair gets probabilities proportional to accuracy
(62/38), so faithful MDInference lands *between* related-random and
related-accurate at high SLA rather than matching related-accurate.  The
``utility_power`` knob (beyond-paper) sharpens selection; power=4 restores
the paper's "negligible difference" claim and is reported alongside.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs.mdinference_zoo import ablation_zoo
from repro.core import FixedCVNetwork
from repro.core.simulator import SimConfig, run_simulation

ALGS = ["pure_random", "related_random", "related_accurate", "mdinference"]
NET = FixedCVNetwork(100.0, 0.5)


def run(n_requests: int = 10_000):
    zoo = ablation_zoo()
    for sla in (100, 150, 250, 300):
        for alg in ALGS:
            cfg = SimConfig(
                registry=zoo, algorithm=alg, t_sla_ms=sla,
                n_requests=n_requests, network=NET, seed=5,
            )
            res, us = timed(run_simulation, cfg, repeats=1)
            m = res.metrics
            emit(
                f"fig6/{alg}/sla{sla}",
                us / n_requests,
                f"acc={m.aggregate_accuracy:.2f}% lat={m.mean_latency_ms:.1f}ms "
                f"attain={m.sla_attainment*100:.1f}%",
            )
        # Beyond-paper: sharpened utility (power=4).
        cfg = SimConfig(
            registry=zoo, algorithm="mdinference", t_sla_ms=sla,
            n_requests=n_requests, network=NET, seed=5, utility_power=4.0,
        )
        res, _ = timed(run_simulation, cfg, repeats=1)
        emit(
            f"fig6/mdinference_power4/sla{sla}",
            0.0,
            f"acc={res.metrics.aggregate_accuracy:.2f}%",
        )


if __name__ == "__main__":
    run()
