"""Fig 4 + Fig 5: adaptiveness to network variability (CV sweep).

Fixed mean network time of 100 ms, CV swept 0 -> 100 % at SLA targets of
100 ms and 250 ms.  Paper claims: at SLA 100 the attainment starts < 50 %
(network alone eats the budget) and *rises* with CV; at SLA 250 accuracy
holds ~80 % across the sweep; model diversity widens with CV (Fig 5).
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs.mdinference_zoo import paper_zoo
from repro.core import FixedCVNetwork
from repro.core.simulator import SimConfig, run_simulation

CVS = [0.0, 0.1, 0.25, 0.5, 0.74, 1.0]


def run(n_requests: int = 10_000):
    zoo = paper_zoo()
    for sla in (100, 250):
        for cv in CVS:
            cfg = SimConfig(
                registry=zoo,
                algorithm="mdinference",
                t_sla_ms=sla,
                n_requests=n_requests,
                network=FixedCVNetwork(100.0, cv),
                seed=4,
            )
            res, us = timed(run_simulation, cfg, repeats=1)
            m = res.metrics
            emit(
                f"fig4/sla{sla}/cv{int(cv*100)}",
                us / n_requests,
                f"acc={m.aggregate_accuracy:.2f}% attain={m.sla_attainment*100:.1f}%",
            )
            # Fig 5: number of distinct models serving >1% of requests.
            diverse = sum(1 for v in m.model_usage.values() if v > 0.01)
            top = max(m.model_usage.items(), key=lambda kv: kv[1])
            emit(
                f"fig5/sla{sla}/cv{int(cv*100)}",
                0.0,
                f"models>1%={diverse} top={top[0]}:{top[1]*100:.0f}%",
            )


if __name__ == "__main__":
    run()
