"""Table III analogue: latency profiles of the serving zoo.

Two parts:
  * the paper's own Table III profiles (transcribed constants, printed for
    the record), and
  * the TPU LM-zoo profiles measured the same way the paper measured its
    models — repeated timed executions — using real tiny variants on CPU,
    plus the roofline-estimated v5e profiles for the full configs.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs import reduced
from repro.configs.mdinference_zoo import TABLE_III
from repro.models import transformer as T
from repro.serving.engine import ServingEngine, Variant
from repro.serving.profiles import QUALITY, lm_zoo_registry


def run():
    for p in TABLE_III:
        emit(
            f"table3/paper/{p.name.replace(' ', '_')}",
            p.mu_ms * 1e3,
            f"acc={p.accuracy}% sigma={p.sigma_ms}ms",
        )

    # Roofline-estimated v5e profiles for the full LM zoo.
    reg = lm_zoo_registry(chips=8)
    for p in reg:
        emit(
            f"table3/v5e_estimate/{p.name}",
            p.mu_ms * 1e3,
            f"quality={p.accuracy} sigma={p.sigma_ms:.2f}ms",
        )

    # Measured (real execution, reduced configs, CPU) — the paper's
    # methodology: mean/std over repeated runs.
    engine = ServingEngine(max_len=96)
    for arch, width in (("gemma-2b", 64), ("llama3-8b", 128), ("qwen3-14b", 192)):
        cfg = reduced(arch, d_model=width, n_layers=4, n_heads=4, n_kv_heads=2,
                      head_dim=max(16, width // 4))
        params = T.init_params(cfg, jax.random.key(0))
        engine.register(Variant(arch + "-tiny", cfg, params, QUALITY[arch]))
    measured = engine.measure_profiles(prompt_len=32, gen_tokens=8, trials=3)
    for p in measured:
        emit(
            f"table3/measured_cpu/{p.name}",
            p.mu_ms * 1e3,
            f"quality={p.accuracy} sigma={p.sigma_ms:.2f}ms",
        )


if __name__ == "__main__":
    run()
