"""Beyond-paper: the §VII "Spanning Subsets" direction, made concrete.

The paper conjectures a small subset of models could serve nearly all
requests (cutting serving cost).  We compute it: for a workload (network
distribution x SLA mix), greedily pick the subset whose MDInference
aggregate accuracy stays within epsilon of the full zoo's.

Also answers the paper's "without resorting to empirical measurement"
challenge with a closed-form observation: a model can only be selected if
it is the accuracy-argmax for SOME budget, i.e. it lies on the Pareto
frontier of (mu+sigma, accuracy) — dominated models (DenseNet,
InceptionResNetV2, NasNet Mobile...) can be dropped a priori.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.configs.mdinference_zoo import paper_zoo
from repro.core import FixedCVNetwork
from repro.core.registry import ModelRegistry
from repro.core.simulator import SimConfig, run_simulation


def pareto_frontier(reg: ModelRegistry):
    """Models that are accuracy-argmax for some budget (undominated)."""
    keep = []
    for i, p in enumerate(reg):
        dominated = any(
            (q.mu_ms + q.sigma_ms <= p.mu_ms + p.sigma_ms)
            and q.accuracy > p.accuracy
            for q in reg
        )
        if not dominated:
            keep.append(i)
    return keep


def accuracy_of(subset_idx, reg, sla, net, n=6000, seed=13):
    sub = ModelRegistry([reg[i] for i in subset_idx])
    m = run_simulation(
        SimConfig(registry=sub, algorithm="mdinference", t_sla_ms=sla,
                  n_requests=n, network=net, duplication=True, seed=seed)
    ).metrics
    return m.aggregate_accuracy


def run():
    reg = paper_zoo()
    net = FixedCVNetwork(100.0, 0.5)
    slas = [100.0, 150.0, 250.0]

    frontier = pareto_frontier(reg)
    emit(
        "spanning/pareto_frontier",
        0.0,
        f"{len(frontier)}/{len(reg)} undominated: "
        + " ".join(reg.names[i] for i in frontier),
    )

    def workload_acc(subset):
        return float(np.mean([accuracy_of(subset, reg, s, net) for s in slas]))

    full_acc, us = timed(lambda: workload_acc(list(range(len(reg)))), repeats=1)
    emit("spanning/full_zoo", us, f"acc={full_acc:.2f} models={len(reg)}")

    # Greedy forward selection from the frontier.
    chosen: list[int] = []
    remaining = list(frontier)
    while remaining:
        best_gain, best_i = -1.0, None
        for i in remaining:
            acc = workload_acc(chosen + [i])
            if acc > best_gain:
                best_gain, best_i = acc, i
        chosen.append(best_i)
        remaining.remove(best_i)
        emit(
            f"spanning/greedy_k{len(chosen)}",
            0.0,
            f"acc={best_gain:.2f} (+{reg.names[best_i]})"
            f" gap={full_acc - best_gain:.2f}",
        )
        if best_gain >= full_acc - 0.25:
            break
    emit(
        "spanning/result",
        0.0,
        f"{len(chosen)} models within 0.25pt of the {len(reg)}-model zoo: "
        + " ".join(reg.names[i] for i in chosen),
    )


if __name__ == "__main__":
    run()
