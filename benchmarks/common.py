"""Shared benchmark plumbing: CSV emission in ``name,us_per_call,derived``."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Run fn repeatedly, return (result, mean_us)."""
    fn(*args, **kwargs)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us
