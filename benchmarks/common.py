"""Shared benchmark plumbing: CSV emission in ``name,us_per_call,derived``.

Besides the CSV line on stdout, every :func:`emit` call accumulates a
structured row; :func:`write_results` flushes them as
``results/BENCH_<bench>.json`` with a stable schema::

    {"schema_version": 1, "bench": "serving",
     "rows": [{"name": ..., "us_per_call": ..., "derived": ...}, ...]}

so CI and downstream tooling can diff benchmark output without parsing
stdout.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

SCHEMA_VERSION = 1

# Rows accumulated by emit() since the last write_results()/reset_results().
RESULTS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS.append(
        {"name": name, "us_per_call": float(us_per_call), "derived": derived}
    )


def reset_results():
    RESULTS.clear()


def write_results(bench: str, out_dir: str = "results") -> str:
    """Write accumulated rows to ``<out_dir>/BENCH_<bench>.json`` and clear
    the accumulator.  Returns the path written."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    payload = {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "rows": list(RESULTS),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    reset_results()
    return path


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Run fn repeatedly, return (result, mean_us)."""
    fn(*args, **kwargs)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us
