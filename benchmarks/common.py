"""Shared benchmark plumbing: CSV emission in ``name,us_per_call,derived``.

Besides the CSV line on stdout, every :func:`emit` call accumulates a
structured row; :func:`write_results` flushes them as
``results/BENCH_<bench>.json`` with a stable schema::

    {"schema_version": 1, "bench": "serving",
     "rows": [{"name": ..., "us_per_call": ..., "derived": ...}, ...]}

so CI and downstream tooling can diff benchmark output without parsing
stdout.  Re-running a *subset* of a bench merges by row ``name`` into the
existing file instead of overwriting it, so partial runs (``--smoke``, a
single family) never erase the other families' rows.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

SCHEMA_VERSION = 1

# Rows accumulated by emit() since the last write_results()/reset_results().
RESULTS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS.append(
        {"name": name, "us_per_call": float(us_per_call), "derived": derived}
    )


def reset_results():
    RESULTS.clear()


def _merge_rows(existing: List[Dict], fresh: List[Dict]) -> List[Dict]:
    """Merge by row ``name``: fresh rows replace same-named existing rows
    in place (keeping the file's row order stable across partial re-runs);
    new names append in emission order."""
    fresh_by_name = {row["name"]: row for row in fresh}
    merged = [fresh_by_name.pop(row["name"], row) for row in existing]
    merged.extend(row for row in fresh if row["name"] in fresh_by_name)
    return merged


def write_results(bench: str, out_dir: str = "results") -> str:
    """Write accumulated rows to ``<out_dir>/BENCH_<bench>.json`` and clear
    the accumulator.  Returns the path written.

    If the file already exists with the same schema and bench name, rows
    merge by ``name`` (fresh rows win) rather than clobbering the file —
    a partial run updates only the rows it produced.
    """
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    rows = list(RESULTS)
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = None
        if (
            isinstance(prev, dict)
            and prev.get("schema_version") == SCHEMA_VERSION
            and prev.get("bench") == bench
            and isinstance(prev.get("rows"), list)
        ):
            rows = _merge_rows(prev["rows"], rows)
    payload = {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    reset_results()
    return path


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Run fn repeatedly, return (result, mean_us)."""
    fn(*args, **kwargs)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us
