"""Validate the observability smoke's exported artifacts (CI trace job).

Stdlib-only: a hand-rolled JSON-Schema-*subset* validator (``type`` /
``required`` / ``properties`` / ``items`` / ``enum`` — exactly what the
checked-in schemas use) plus semantic checks no schema can express:

* the Chrome trace (validated against
  ``benchmarks/schemas/chrome_trace.schema.json``) contains request span
  events and per-track thread metadata;
* the span sink (``<trace>.spans.jsonl``) balances — every ``request``
  root carries exactly one ``resolve`` | ``shed`` | ``cancel`` terminal
  (the conservation audit, recomputed here from the raw JSONL so the
  gate does not trust the library that produced it);
* the Prometheus text (``<trace>.prom``) exposes the four required
  histogram families;
* the metrics snapshot (``<trace>.metrics.json``) matches
  ``benchmarks/schemas/metrics_snapshot.schema.json``.

Run:  python benchmarks/validate_obs.py results/trace_smoke.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

SCHEMA_DIR = os.path.join(os.path.dirname(__file__), "schemas")
REQUIRED_FAMILIES = (
    "admission_queue_wait_ms",
    "loop_tick_wall_ms",
    "cluster_batch_wall_ms",
    "controller_wait_ewma_ms",
)
TERMINALS = ("resolve", "shed", "cancel")

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


def _type_ok(value, name: str) -> bool:
    if name == "integer":
        return isinstance(value, int) and not isinstance(value, bool)
    if name == "number":
        return (
            isinstance(value, (int, float)) and not isinstance(value, bool)
        )
    return isinstance(value, _TYPES[name])


def validate(instance, schema: Dict, path: str = "$") -> List[str]:
    """Validate ``instance`` against the schema subset; returns error
    strings (empty = valid).  Collects every violation instead of
    stopping at the first."""
    errors: List[str] = []
    expected = schema.get("type")
    if expected is not None and not _type_ok(instance, expected):
        errors.append(
            f"{path}: expected {expected}, got {type(instance).__name__}"
        )
        return errors  # children would only cascade the same failure
    if "enum" in schema and instance not in schema["enum"]:
        errors.append(f"{path}: {instance!r} not in {schema['enum']}")
    for key in schema.get("required", ()):
        if not isinstance(instance, dict) or key not in instance:
            errors.append(f"{path}: missing required key {key!r}")
    if isinstance(instance, dict):
        for key, sub in schema.get("properties", {}).items():
            if key in instance:
                errors.extend(validate(instance[key], sub, f"{path}.{key}"))
    if isinstance(instance, list) and "items" in schema:
        for i, item in enumerate(instance):
            errors.extend(validate(item, schema["items"], f"{path}[{i}]"))
    return errors


def _load(path: str):
    with open(path) as f:
        return json.load(f)


def check_trace(path: str) -> List[str]:
    schema = _load(os.path.join(SCHEMA_DIR, "chrome_trace.schema.json"))
    trace = _load(path)
    errors = validate(trace, schema)
    if errors:
        return errors
    events = trace["traceEvents"]
    requests = [e for e in events if e.get("name") == "request"]
    if not requests:
        errors.append(f"{path}: no 'request' span events in the trace")
    tids = {e["tid"] for e in events if e["ph"] != "M"}
    named = {
        e["tid"]
        for e in events
        if e["ph"] == "M" and e.get("name") == "thread_name"
    }
    unnamed = tids - named
    if unnamed:
        errors.append(f"{path}: tracks without thread_name metadata: "
                      f"{sorted(unnamed)}")
    return errors


def check_spans(path: str) -> List[str]:
    errors: List[str] = []
    spans = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            try:
                spans.append(json.loads(line))
            except ValueError as e:
                errors.append(f"{path}:{i}: bad JSON ({e})")
    if errors:
        return errors
    # The conservation audit, recomputed from raw JSONL: one terminal
    # instant per request root, nothing open, nothing double-terminated.
    roots = [s for s in spans if s.get("name") == "request"]
    terminals: Dict[int, List[str]] = {}
    for s in spans:
        if s.get("name") in TERMINALS and s.get("parent_id") is not None:
            terminals.setdefault(s["parent_id"], []).append(s["name"])
    n_open = sum(1 for r in roots if not terminals.get(r["span_id"]))
    n_extra = sum(
        len(t) - 1 for t in terminals.values() if len(t) > 1
    )
    if not roots:
        errors.append(f"{path}: no request roots in the span sink")
    if n_open:
        errors.append(f"{path}: {n_open} request roots have no terminal")
    if n_extra:
        errors.append(f"{path}: {n_extra} surplus terminal instants")
    return errors


def check_prometheus(path: str) -> List[str]:
    with open(path) as f:
        text = f.read()
    errors = []
    if "# TYPE" not in text:
        errors.append(f"{path}: no '# TYPE' lines (not exposition format?)")
    for family in REQUIRED_FAMILIES:
        if f"# TYPE {family} histogram" not in text:
            errors.append(f"{path}: missing histogram family {family!r}")
    return errors


def check_metrics_snapshot(path: str) -> List[str]:
    schema = _load(
        os.path.join(SCHEMA_DIR, "metrics_snapshot.schema.json")
    )
    return validate(_load(path), schema)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "trace", help="Chrome trace path passed to bench_serving "
        "--trace-out (sibling .spans.jsonl / .prom / .metrics.json "
        "artifacts are validated too)"
    )
    args = ap.parse_args(argv)
    checks = (
        (args.trace, check_trace),
        (args.trace + ".spans.jsonl", check_spans),
        (args.trace + ".prom", check_prometheus),
        (args.trace + ".metrics.json", check_metrics_snapshot),
    )
    failed = False
    for path, check in checks:
        if not os.path.exists(path):
            print(f"FAIL {path}: missing")
            failed = True
            continue
        errors = check(path)
        if errors:
            failed = True
            for e in errors[:20]:
                print(f"FAIL {e}")
            if len(errors) > 20:
                print(f"... and {len(errors) - 20} more")
        else:
            print(f"ok   {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
