"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig3,table4]
"""
from __future__ import annotations

import argparse
import traceback

from benchmarks import (
    bench_fig3,
    bench_fig4_fig5,
    bench_fig6,
    bench_kernels,
    bench_roofline,
    bench_serving,
    bench_spanning,
    bench_table3,
    bench_table4,
)

SUITES = {
    "table3": bench_table3.run,
    "fig3": bench_fig3.run,
    "fig4_fig5": bench_fig4_fig5.run,
    "fig6": bench_fig6.run,
    "table4": bench_table4.run,
    "serving": bench_serving.run,
    "spanning": bench_spanning.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    print("name,us_per_call,derived")
    failed = []
    for name, fn in SUITES.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED suites: {failed}")
        return 1
    print("# all suites complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
