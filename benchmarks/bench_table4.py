"""Table IV + Fig 7 + Fig 8: duplication on measured-network traces.

University and residential traces (calibrated to the paper's reliance
quantiles — see repro.core.network), SLA 250 ms, duplication ON.

Paper numbers (aggregate accuracy / on-device reliance):
  university:  MDInference 82.39/0.26   static-acc 81.09/3.67
  residential: MDInference 80.43/3.16   static-acc 73.11/23.03
Plus: zero SLA violations, >40-pt gain over the on-device-only baseline.
"""
from __future__ import annotations


from benchmarks.common import emit, timed
from repro.configs.mdinference_zoo import paper_zoo
from repro.core import residential_trace, university_trace
from repro.core.simulator import SimConfig, run_simulation

ALGS = ["static_latency", "static_accuracy", "pure_random", "mdinference"]


def run(n_requests: int = 10_000):
    zoo = paper_zoo()
    results = {}
    for net_name, trace in (
        ("university", university_trace()),
        ("residential", residential_trace()),
    ):
        for alg in ALGS:
            cfg = SimConfig(
                registry=zoo, algorithm=alg, t_sla_ms=250.0,
                n_requests=n_requests, network=trace, duplication=True, seed=6,
            )
            res, us = timed(run_simulation, cfg, repeats=1)
            m = res.metrics
            results[(net_name, alg)] = m
            emit(
                f"table4/{net_name}/{alg}",
                us / n_requests,
                f"acc={m.aggregate_accuracy:.2f}% ondev={m.ondevice_reliance*100:.2f}% "
                f"attain={m.sla_attainment*100:.2f}%",
            )

    # Fig 7: accuracy + reliance across SLAs on residential.
    for sla in (100, 150, 200, 250, 300):
        cfg = SimConfig(
            registry=zoo, algorithm="mdinference", t_sla_ms=sla,
            n_requests=n_requests, network=residential_trace(),
            duplication=True, seed=7,
        )
        res, _ = timed(run_simulation, cfg, repeats=1)
        m = res.metrics
        emit(
            f"fig7/mdinference/sla{sla}",
            0.0,
            f"acc={m.aggregate_accuracy:.2f}% ondev={m.ondevice_reliance*100:.2f}%",
        )

    # Fig 8: 20 sampled request latency breakdowns (network vs exec).
    cfg = SimConfig(
        registry=zoo, algorithm="mdinference", t_sla_ms=250.0,
        n_requests=20, network=residential_trace(), duplication=True, seed=8,
    )
    res, _ = timed(run_simulation, cfg, repeats=1)
    for i in range(20):
        used = "remote" if res.used_remote[i] else "ONDEVICE"
        emit(
            f"fig8/request{i:02d}",
            0.0,
            f"nw={res.t_nw_ms[i]:.0f}ms exec={res.exec_ms[i]:.1f}ms "
            f"model={zoo.names[res.model_index[i]]} used={used}",
        )

    md = results[("university", "mdinference")]
    emit(
        "table4/headline",
        0.0,
        f"univ_acc={md.aggregate_accuracy:.2f}% (paper 82.39) "
        f"gain_vs_ondevice={md.aggregate_accuracy - 41.4:.1f}pts (paper >40)",
    )


if __name__ == "__main__":
    run()
