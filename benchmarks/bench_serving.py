"""Serving-tier benchmark (beyond paper): MDInference over the LM zoo.

The paper's experiment translated to the TPU serving stack: requests with a
latency SLO arrive over variable networks; the scheduler picks an LM tier
per request and hedges with the cheap tier.  Compares the same four
algorithms as Table IV on the roofline-profiled zoo, and measures the
scalar (``chunk_size=1``) vs batched scheduler throughput on a 10k-request
trace (the tentpole claim: chunked selection through the jitted policy
path is >=10x faster than per-request dispatch).

Run:  PYTHONPATH=src python -m benchmarks.run --only serving
      PYTHONPATH=src:. python benchmarks/bench_serving.py --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, timed
from repro.core import residential_trace, university_trace
from repro.core.duplication import HedgePolicy
from repro.serving.profiles import ONDEVICE_TIER, lm_zoo_registry
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig


def _throughput_comparison(reg, t_nw, *, batched_chunk: int = 512):
    """Time run_trace at chunk_size=1 (scalar path) vs a real chunk size."""

    def one(chunk):
        cfg = SchedulerConfig(t_sla_ms=250.0, seed=12, chunk_size=chunk)
        # Warm the jitted policy for this chunk shape, then time a fresh
        # scheduler (run_trace mutates profiles/rng state).
        MDInferenceScheduler(reg, ONDEVICE_TIER, cfg).run_trace(t_nw[:chunk])
        sched = MDInferenceScheduler(reg, ONDEVICE_TIER, cfg)
        t0 = time.perf_counter()
        m = sched.run_trace(t_nw)
        return m, (time.perf_counter() - t0) * 1e6

    n = len(t_nw)
    m_s, us_scalar = one(1)
    m_b, us_batched = one(batched_chunk)
    speedup = us_scalar / us_batched
    emit("serving/trace10k/scalar", us_scalar / n,
         f"quality={m_s.aggregate_accuracy:.2f} attain={m_s.sla_attainment*100:.2f}%")
    emit("serving/trace10k/batched", us_batched / n,
         f"quality={m_b.aggregate_accuracy:.2f} attain={m_b.sla_attainment*100:.2f}% "
         f"chunk={batched_chunk} speedup={speedup:.1f}x")
    return speedup


def run(n_requests: int = 2_000, smoke: bool = False):
    reg = lm_zoo_registry(chips=8)
    for p in reg:
        emit(f"serving/zoo/{p.name}", p.mu_ms * 1e3, f"quality={p.accuracy}")

    if smoke:
        n_requests = min(n_requests, 200)

    for net_name, trace in (
        ("university", university_trace()),
        ("residential", residential_trace()),
    ):
        rng = np.random.default_rng(11)
        t_nw = trace.sample(rng, n_requests)
        for power, label in ((1.0, "mdinference"), (4.0, "mdinference_p4")):
            sched = MDInferenceScheduler(
                reg, ONDEVICE_TIER,
                SchedulerConfig(t_sla_ms=250.0, utility_power=power, seed=12),
            )
            m, us = timed(lambda: sched.run_trace(t_nw), repeats=1)
            emit(
                f"serving/{net_name}/{label}",
                us / n_requests,
                f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
                f"hedge_used={m.ondevice_reliance*100:.2f}%",
            )

        # Energy/cost knob (paper §VII): hedge only when the budget is tight.
        sched = MDInferenceScheduler(
            reg, ONDEVICE_TIER,
            SchedulerConfig(
                t_sla_ms=250.0, seed=12,
                hedge=HedgePolicy(always=False, deadline_headroom_ms=60.0),
            ),
        )
        m, _ = timed(lambda: sched.run_trace(t_nw), repeats=1)
        hedged = sum(1 for r in sched.log if r["hedged"]) / len(sched.log)
        emit(
            f"serving/{net_name}/selective_hedge",
            0.0,
            f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
            f"hedge_rate={hedged*100:.1f}% (duplication cost saved)",
        )

    # Tentpole: scalar-vs-batched scheduler throughput on a 10k trace.
    rng = np.random.default_rng(11)
    t_nw = university_trace().sample(rng, 1_000 if smoke else 10_000)
    _throughput_comparison(reg, t_nw)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace sizes for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
