"""Serving-tier benchmark (beyond paper): MDInference over the LM zoo.

The paper's experiment translated to the TPU serving stack: requests with a
latency SLO arrive over variable networks; the scheduler picks an LM tier
per request and hedges with the cheap tier.  Compares the same four
algorithms as Table IV on the roofline-profiled zoo, measures the scalar
(``chunk_size=1``) vs batched scheduler throughput on a 10k-request trace,
and races the two hedge-resolution modes side by side: *measured* (real
``OnDeviceBackend`` execution of the duplicate) vs *sampled* (the
profile-sampled simulation fallback) on an identical request stream.

Run:  PYTHONPATH=src python -m benchmarks.run --only serving
      PYTHONPATH=src:. python benchmarks/bench_serving.py --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, timed
from repro.core import residential_trace, university_trace
from repro.core.duplication import HedgePolicy
from repro.serving.profiles import ONDEVICE_TIER, lm_zoo_registry
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig


def _throughput_comparison(reg, t_nw, *, batched_chunk: int = 512):
    """Time run_trace at chunk_size=1 (scalar path) vs a real chunk size."""

    def one(chunk):
        cfg = SchedulerConfig(t_sla_ms=250.0, seed=12, chunk_size=chunk)
        # Warm the jitted policy for this chunk shape, then time a fresh
        # scheduler (run_trace mutates profiles/rng state).
        MDInferenceScheduler(reg, ONDEVICE_TIER, cfg).run_trace(t_nw[:chunk])
        sched = MDInferenceScheduler(reg, ONDEVICE_TIER, cfg)
        t0 = time.perf_counter()
        m = sched.run_trace(t_nw)
        return m, (time.perf_counter() - t0) * 1e6

    n = len(t_nw)
    m_s, us_scalar = one(1)
    m_b, us_batched = one(batched_chunk)
    speedup = us_scalar / us_batched
    emit("serving/trace10k/scalar", us_scalar / n,
         f"quality={m_s.aggregate_accuracy:.2f} attain={m_s.sla_attainment*100:.2f}%")
    emit("serving/trace10k/batched", us_batched / n,
         f"quality={m_b.aggregate_accuracy:.2f} attain={m_b.sla_attainment*100:.2f}% "
         f"chunk={batched_chunk} speedup={speedup:.1f}x")
    return speedup


def _hedge_mode_comparison(*, n_requests: int, sla_ms: float, seed: int = 0):
    """Measured-hedge (real OnDeviceBackend) vs sampled-hedge on one stream.

    Builds a tiny two-tier engine, serves an identical open-loop trace with
    both hedge-resolution modes, and emits latency/accuracy side by side.
    """
    import jax

    from repro.configs import reduced
    from repro.models import transformer as T
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.engine import QueuedRequest, ServingEngine, Variant
    from repro.serving.loadgen import PoissonArrivals, iter_windows, make_trace
    from repro.core.network import LognormalNetwork

    prompt, gen, window_ms = 8, 2, 200.0
    # One hedge tier, one measured on-device profile, and one measured
    # remote registry for BOTH modes, so the rows differ only in how the
    # duplicate resolves (real execution vs profile samples), not in
    # profile priors.
    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    registry = None

    def build(measured: bool):
        nonlocal registry
        engine = ServingEngine(
            max_len=prompt + gen + 4, hedge_backend=hedge if measured else None
        )
        for name, width, quality in (("small", 32, 40.0), ("large", 64, 80.0)):
            cfg = reduced(
                "gemma-2b", d_model=width, n_layers=2,
                n_heads=2, n_kv_heads=1, head_dim=width // 2,
            )
            engine.register(
                Variant(name, cfg, T.init_params(cfg, jax.random.key(seed)), quality)
            )
        if registry is None:
            registry = engine.measure_profiles(
                prompt_len=prompt, gen_tokens=gen, trials=2
            )
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        return engine, sched

    trace = make_trace(
        n_requests, PoissonArrivals(50.0), LognormalNetwork(40.0, 0.6), seed=seed
    )
    for mode in ("measured", "sampled"):
        engine, sched = build(mode == "measured")
        rng = np.random.default_rng(seed)
        done = []
        t0 = time.perf_counter()
        for window in iter_windows(trace, window_ms):
            batch = [
                QueuedRequest(
                    rid=int(i),
                    tokens=rng.integers(0, 256, prompt),
                    n_steps=gen,
                    t_nw_est_ms=float(trace.t_nw_est_ms[i]),
                    t_nw_actual_ms=float(trace.t_nw_ms[i]),
                    arrival_ms=float(trace.arrival_ms[i]),
                )
                for i in window
            ]
            tick = (trace.arrival_ms[window[0]] // window_ms + 1) * window_ms
            done.extend(engine.serve_queue(sched, batch, dispatch_ms=tick)[0])
        us = (time.perf_counter() - t0) * 1e6
        lats = np.asarray([c.latency_ms for c in done])
        accs = np.asarray([c.accuracy for c in done])
        hedge_used = 1.0 - np.mean([c.used_remote for c in done])
        emit(
            f"serving/hedge/{mode}",
            us / len(done),
            f"quality={accs.mean():.2f} attain={np.mean(lats <= sla_ms)*100:.2f}% "
            f"p99={np.percentile(lats, 99):.1f}ms hedge_used={hedge_used*100:.2f}%",
        )


def run(n_requests: int = 2_000, smoke: bool = False):
    reg = lm_zoo_registry(chips=8)
    for p in reg:
        emit(f"serving/zoo/{p.name}", p.mu_ms * 1e3, f"quality={p.accuracy}")

    if smoke:
        n_requests = min(n_requests, 200)

    for net_name, trace in (
        ("university", university_trace()),
        ("residential", residential_trace()),
    ):
        rng = np.random.default_rng(11)
        t_nw = trace.sample(rng, n_requests)
        for power, label in ((1.0, "mdinference"), (4.0, "mdinference_p4")):
            sched = MDInferenceScheduler(
                reg, ONDEVICE_TIER,
                SchedulerConfig(t_sla_ms=250.0, utility_power=power, seed=12),
            )
            m, us = timed(lambda: sched.run_trace(t_nw), repeats=1)
            emit(
                f"serving/{net_name}/{label}",
                us / n_requests,
                f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
                f"hedge_used={m.ondevice_reliance*100:.2f}%",
            )

        # Energy/cost knob (paper §VII): hedge only when the budget is tight.
        sched = MDInferenceScheduler(
            reg, ONDEVICE_TIER,
            SchedulerConfig(
                t_sla_ms=250.0, seed=12,
                hedge=HedgePolicy(always=False, deadline_headroom_ms=60.0),
            ),
        )
        m, _ = timed(lambda: sched.run_trace(t_nw), repeats=1)
        hedged = sum(1 for r in sched.log if r["hedged"]) / len(sched.log)
        emit(
            f"serving/{net_name}/selective_hedge",
            0.0,
            f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
            f"hedge_rate={hedged*100:.1f}% (duplication cost saved)",
        )

    # Scalar-vs-batched scheduler throughput on a 10k trace (PR 1 tentpole).
    rng = np.random.default_rng(11)
    t_nw = university_trace().sample(rng, 1_000 if smoke else 10_000)
    _throughput_comparison(reg, t_nw)

    # Two-tier hedge: measured (real OnDeviceBackend) vs sampled resolution
    # on an identical stream (PR 2 tentpole).  The 150ms SLA makes some
    # queue-delayed requests miss remotely, so the duplicate actually wins.
    _hedge_mode_comparison(n_requests=24 if smoke else 120, sla_ms=150.0)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace sizes for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
