"""Serving-tier benchmark (beyond paper): MDInference over the LM zoo.

The paper's experiment translated to the TPU serving stack: requests with a
latency SLO arrive over variable networks; the scheduler picks an LM tier
per request and hedges with the cheap tier.  Compares the same four
algorithms as Table IV on the roofline-profiled zoo, measures the scalar
(``chunk_size=1``) vs batched scheduler throughput on a 10k-request trace,
and races the two hedge-resolution modes side by side: *measured* (real
``OnDeviceBackend`` execution of the duplicate) vs *sampled* (the
profile-sampled simulation fallback) on an identical request stream.

Run:  PYTHONPATH=src python -m benchmarks.run --only serving
      PYTHONPATH=src:. python benchmarks/bench_serving.py --smoke
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import emit, timed, write_results
from repro.core import residential_trace, university_trace
from repro.core.duplication import HedgePolicy
from repro.observability.quantile import quantile
from repro.serving.profiles import ONDEVICE_TIER, lm_zoo_registry
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig


def _throughput_comparison(reg, t_nw, *, batched_chunk: int = 512):
    """Time run_trace at chunk_size=1 (scalar path) vs a real chunk size."""

    def one(chunk):
        cfg = SchedulerConfig(t_sla_ms=250.0, seed=12, chunk_size=chunk)
        # Warm the jitted policy for this chunk shape, then time a fresh
        # scheduler (run_trace mutates profiles/rng state).
        MDInferenceScheduler(reg, ONDEVICE_TIER, cfg).run_trace(t_nw[:chunk])
        sched = MDInferenceScheduler(reg, ONDEVICE_TIER, cfg)
        t0 = time.perf_counter()
        m = sched.run_trace(t_nw)
        return m, (time.perf_counter() - t0) * 1e6

    n = len(t_nw)
    m_s, us_scalar = one(1)
    m_b, us_batched = one(batched_chunk)
    speedup = us_scalar / us_batched
    emit("serving/trace10k/scalar", us_scalar / n,
         f"quality={m_s.aggregate_accuracy:.2f} attain={m_s.sla_attainment*100:.2f}%")
    emit("serving/trace10k/batched", us_batched / n,
         f"quality={m_b.aggregate_accuracy:.2f} attain={m_b.sla_attainment*100:.2f}% "
         f"chunk={batched_chunk} speedup={speedup:.1f}x")
    return speedup


def _hedge_mode_comparison(
    *, n_requests: int, sla_ms: float, seed: int = 0, sync: bool = False
):
    """Measured-hedge (real OnDeviceBackend) vs sampled-hedge on one stream.

    Builds a tiny two-tier engine, serves an identical open-loop trace with
    both hedge-resolution modes through the event-loop front
    (``ServingLoop.drain_trace``), and emits latency/accuracy side by side.
    """
    import jax

    from repro.configs import reduced
    from repro.models import transformer as T
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import PoissonArrivals, make_trace
    from repro.core.network import LognormalNetwork

    prompt, gen, window_ms = 8, 2, 200.0
    dispatch = "sync" if sync else "async"
    # One hedge tier, one measured on-device profile, and one measured
    # remote registry for BOTH modes, so the rows differ only in how the
    # duplicate resolves (real execution vs profile samples), not in
    # profile priors.
    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    registry = None

    def build(measured: bool):
        nonlocal registry
        engine = ServingEngine(
            max_len=prompt + gen + 4,
            hedge_backend=hedge if measured else None,
            dispatch=dispatch,
        )
        for name, width, quality in (("small", 32, 40.0), ("large", 64, 80.0)):
            cfg = reduced(
                "gemma-2b", d_model=width, n_layers=2,
                n_heads=2, n_kv_heads=1, head_dim=width // 2,
            )
            engine.register(
                Variant(name, cfg, T.init_params(cfg, jax.random.key(seed)), quality)
            )
        if registry is None:
            registry = engine.measure_profiles(
                prompt_len=prompt, gen_tokens=gen, trials=2
            )
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        return engine, sched

    trace = make_trace(
        n_requests, PoissonArrivals(50.0), LognormalNetwork(40.0, 0.6), seed=seed
    )
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, 256, (n_requests, prompt))
    for mode in ("measured", "sampled"):
        engine, sched = build(mode == "measured")
        loop = engine.make_loop(sched)
        t0 = time.perf_counter()
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen
        )
        us = (time.perf_counter() - t0) * 1e6
        lats = np.asarray([c.latency_ms for c in done])
        emit(
            f"serving/hedge/{mode}",
            us / len(done),
            f"quality={metrics.aggregate_accuracy:.2f} "
            f"attain={np.mean(lats <= sla_ms)*100:.2f}% "
            f"p99={quantile(lats, 99):.1f}ms "
            f"hedge_used={metrics.ondevice_reliance*100:.2f}%",
        )


def _async_vs_serialized_hedge(
    *, n_requests: int, sla_ms: float, seed: int = 0, sync: bool = False
):
    """Concurrently-raced hedge dispatch vs the serialized fallback.

    One remote variant + the real on-device duplicate, identical request
    stream; compares the tick wall-clock span (first dispatch → last batch
    completion) between ``dispatch="sync"`` (duplicate runs after the
    remote batch — the pre-async accounting fiction) and
    ``dispatch="async"`` (both tiers dispatched at the tick).  ``sync=True``
    (the ``--sync`` CLI flag) keeps CI deterministic by running the
    comparison row on serialized dispatch too.
    """
    import jax

    from repro.configs import reduced
    from repro.models import transformer as T
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import PoissonArrivals, make_trace
    from repro.core.network import LognormalNetwork

    prompt, gen, window_ms = 8, 8, 400.0
    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    # A single remote variant: every tick is one remote batch + one
    # duplicate batch, so the span comparison isolates dispatch overlap.
    engine = ServingEngine(max_len=prompt + gen + 4, hedge_backend=hedge)
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    engine.register(
        Variant("remote", cfg, T.init_params(cfg, jax.random.key(seed)), 80.0)
    )
    registry = engine.measure_profiles(prompt_len=prompt, gen_tokens=gen, trials=2)

    trace = make_trace(
        n_requests, PoissonArrivals(50.0), LognormalNetwork(40.0, 0.6), seed=seed
    )
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, 256, (n_requests, prompt))

    def serve(dispatch: str):
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        stats = []
        loop = engine.make_loop(sched, dispatch=dispatch)
        loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
            on_tick=lambda t, res: stats.append(res.stats),
        )
        hedged = [s for s in stats if s.hedge_wall_ms is not None]
        span = sum(s.span_wall_ms for s in hedged)
        serial = sum(s.serialized_wall_ms for s in hedged)
        return span, serial, len(hedged)

    # One warm pass covers every shape of the timed passes: with a single
    # remote variant selection cannot resplit the windows, so both tiers'
    # (rows, width, steps) batches repeat identically — spans in the timed
    # passes therefore exclude XLA compiles (which run inside the span but
    # outside the timed wall otherwise, skewing overlap_saved negative).
    serve("sync")
    for mode, dispatch in (("serialized", "sync"),
                           ("async", "sync" if sync else "async")):
        span, serial, ticks = serve(dispatch)
        note = " (--sync fallback)" if sync and mode == "async" else ""
        emit(
            f"serving/hedge_dispatch/{mode}",
            span * 1e3 / max(ticks, 1),
            f"span={span:.1f}ms vs tier_sum={serial:.1f}ms "
            f"overlap_saved={(1 - span / serial) * 100:.1f}% "
            f"ticks={ticks}{note}",
        )


def _admission_comparison(
    *, n_requests: int, sla_ms: float = 250.0, seed: int = 0, sync: bool = False
):
    """Bounded admission vs unbounded under a sustained 2x overload.

    One remote variant + the real on-device hedge tier serve an identical
    2x-overload stream with a service-coupled loop clock (each tick keeps
    the server busy ``service_ms`` per scheduled request, so offered load
    beyond capacity builds real queue wait).  Five rows:

    * ``baseline`` — the same stack, uncongested (0.4x capacity): the
      reference p99.
    * ``unbounded`` — the pre-admission loop: the backlog's queue wait
      grows with the overload and p99 diverges.
    * ``block`` — bounded queue + client backpressure: server batches stay
      capped, but no work is dropped, so client-observed wait still grows.
    * ``shed`` — deadline-aware rejection: served requests keep bounded
      wait; p99 stays within 1.5x of the baseline (the PR's acceptance
      bar) at the cost of shed_rate.
    * ``degrade`` — overflow answered by the on-device tier alone: every
      request served with bounded latency, at the duplicate's accuracy.
    """
    import jax

    from repro.configs import reduced
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.serving.admission import AdmissionConfig
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import (
        OverloadArrivals,
        PoissonArrivals,
        make_trace,
    )

    prompt, gen, window_ms = 8, 2, 100.0
    service_ms = 6.0  # per scheduled request, coupled into the loop clock
    capacity_rps = 1e3 / service_ms  # ≈166 rps: what the server retires at
    # 100% utilization (16-17 requests per 100ms scheduling window)
    dispatch = "sync" if sync else "async"

    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    engine = ServingEngine(
        max_len=prompt + gen + 4, hedge_backend=hedge, dispatch=dispatch
    )
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    engine.register(
        Variant("remote", cfg, T.init_params(cfg, jax.random.key(seed)), 80.0)
    )
    registry = engine.measure_profiles(prompt_len=prompt, gen_tokens=gen, trials=2)

    overload = OverloadArrivals(
        rate_rps=capacity_rps, overload_factor=2.0,
        overload_start=0.0, overload_stop=1.0,
    )
    bounded = dict(max_pending=32, max_chunk=16)
    rows = (
        ("baseline", PoissonArrivals(0.4 * capacity_rps),
         max(n_requests // 2, 60), AdmissionConfig()),
        ("unbounded", overload, n_requests, AdmissionConfig()),
        ("block", overload, n_requests,
         AdmissionConfig(policy="block", **bounded)),
        ("shed", overload, n_requests,
         AdmissionConfig(policy="shed", **bounded)),
        ("degrade", overload, n_requests,
         AdmissionConfig(policy="degrade", **bounded)),
    )
    baseline_p99 = None
    for name, arrivals, n, admission in rows:
        trace = make_trace(n, arrivals, LognormalNetwork(80.0, 0.6), seed=seed)
        prompts = np.random.default_rng(seed).integers(0, 256, (n, prompt))
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        loop = engine.make_loop(sched, admission=admission)
        t0 = time.perf_counter()
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
            # Degraded rows (stats.n_degraded) are deliberately free here:
            # they execute on the device, which is exactly how the degrade
            # policy sheds *server* load.
            service_model=lambda res: service_ms * res.stats.n_requests,
        )
        us = (time.perf_counter() - t0) * 1e6
        p99 = metrics.p99_latency_ms
        if baseline_p99 is None:
            baseline_p99 = p99
        emit(
            f"serving/admission/{name}",
            us / max(len(done), 1),
            f"p99={p99:.1f}ms p99_vs_baseline={p99 / baseline_p99:.2f}x "
            f"mean_wait={metrics.mean_queue_wait_ms:.1f}ms "
            f"goodput={metrics.goodput*100:.2f}% "
            f"shed_rate={metrics.shed_rate*100:.2f}% "
            f"quality={metrics.aggregate_accuracy:.2f} "
            f"served={metrics.n_requests}/{n}",
        )


def _tenancy_comparison(
    *, n_requests: int, sla_ms: float = 250.0, seed: int = 0, sync: bool = False
):
    """Multi-tenant QoS lanes vs shared FIFO under a batch-tenant flood.

    An interactive tenant at 0.4x capacity shares the server with a batch
    tenant flooding 4x capacity (the PR-8 adversarial input), against the
    service-coupled loop clock.  Three rows:

    * ``baseline`` — the interactive stream alone, uncongested: the
      reference interactive p99.
    * ``fifo_flood`` — both tenants through the single shared FIFO (tags
      recorded, no lanes): the flood queues ahead of interactive requests
      and destroys their p99.
    * ``lanes_flood`` — weighted-fair tenant lanes (interactive weight 4 /
      batch weight 1, strict interactive-over-batch priority, batch lane
      capped at 32 pending): interactive p99 stays within 1.1x of the
      uncongested baseline (the PR's acceptance bar); the flood is
      absorbed by the batch lane's shed_rate instead.
    """
    import jax

    from repro.configs import reduced
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.serving.admission import AdmissionConfig
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import (
        MixedTenantArrivals,
        PoissonArrivals,
        make_trace,
    )
    from repro.serving.tenancy import TenantConfig

    prompt, gen, window_ms = 8, 2, 100.0
    service_ms = 6.0
    capacity_rps = 1e3 / service_ms
    dispatch = "sync" if sync else "async"

    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    engine = ServingEngine(
        max_len=prompt + gen + 4, hedge_backend=hedge, dispatch=dispatch
    )
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    engine.register(
        Variant("remote", cfg, T.init_params(cfg, jax.random.key(seed)), 80.0)
    )
    registry = engine.measure_profiles(prompt_len=prompt, gen_tokens=gen, trials=2)

    flood = MixedTenantArrivals(
        interactive_rps=0.4 * capacity_rps, batch_rps=4.0 * capacity_rps
    )
    lanes = (
        TenantConfig("interactive", weight=4.0),
        TenantConfig("batch", weight=1.0, priority="batch", max_pending=32),
    )
    bounded = dict(max_pending=32, max_chunk=16)
    rows = (
        ("baseline", PoissonArrivals(0.4 * capacity_rps),
         max(n_requests // 2, 60), AdmissionConfig(policy="shed", **bounded)),
        ("fifo_flood", flood, n_requests,
         AdmissionConfig(policy="shed", **bounded)),
        ("lanes_flood", flood, n_requests,
         AdmissionConfig(policy="shed", max_chunk=16, tenants=lanes)),
    )
    baseline_p99 = None
    for name, arrivals, n, admission in rows:
        trace = make_trace(n, arrivals, LognormalNetwork(80.0, 0.6), seed=seed)
        prompts = np.random.default_rng(seed).integers(0, 256, (n, prompt))
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        loop = engine.make_loop(sched, admission=admission)
        t0 = time.perf_counter()
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
            service_model=lambda res: service_ms * res.stats.n_requests,
        )
        us = (time.perf_counter() - t0) * 1e6
        row = metrics.tenant_rows.get("interactive")
        int_p99 = metrics.p99_latency_ms if row is None else row.p99_latency_ms
        if baseline_p99 is None:
            baseline_p99 = int_p99
        sheds = " ".join(
            f"{t}_shed={r.shed_rate*100:.1f}%"
            for t, r in sorted(metrics.tenant_rows.items())
        )
        emit(
            f"serving/tenancy/{name}",
            us / max(len(done), 1),
            f"interactive_p99={int_p99:.1f}ms "
            f"vs_baseline={int_p99 / baseline_p99:.2f}x "
            + (sheds + " " if sheds else "")
            + f"goodput={metrics.goodput*100:.2f}% "
            f"served={metrics.n_requests}/{n}",
        )


def _cluster_scaling(
    *, n_requests: int, sla_ms: float = 250.0, seed: int = 0, sync: bool = False
):
    """Replicated execution cluster: goodput scaling 1 -> 2 -> 4 replicas.

    The PR-4 overload setup (sustained 2x overload against a
    service-coupled loop clock) served by a ``ClusterBackend`` pool of
    1/2/4 JitBackend replicas under ``least_inflight`` routing.  The
    service model charges each tick the busiest replica's rows
    (``TickStats.max_replica_rows``) — replicas serve in parallel, so the
    same offered load that saturates one replica leaves a 4-replica pool
    half idle: queue wait stops building and goodput rises monotonically
    with the replica count.  The on-device hedge tier stays a single
    device-side singleton shared by every configuration (it is not a
    routable replica).
    """
    import jax

    from repro.configs import reduced
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.serving.backend import JitBackend, OnDeviceBackend
    from repro.serving.cluster import ClusterBackend
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import OverloadArrivals, make_trace

    prompt, gen, window_ms = 8, 2, 100.0
    service_ms = 6.0  # per row on one replica, coupled into the loop clock
    capacity_rps = 1e3 / service_ms  # one replica's retire rate
    dispatch = "sync" if sync else "async"

    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    params = T.init_params(cfg, jax.random.key(seed))

    # Base rate at 2x one replica's capacity: the 2x overload phase then
    # offers 4x one replica, a sustained 2x on the two-replica pool (the
    # PR-4 overload regime applied to the mid configuration), and exactly
    # the four-replica pool's capacity — so the three rows separate
    # strictly instead of saturating at the 2-replica point.
    overload = OverloadArrivals(
        rate_rps=capacity_rps * 2.0, overload_factor=2.0,
        overload_start=0.0, overload_stop=1.0,
    )
    trace = make_trace(
        n_requests, overload, LognormalNetwork(80.0, 0.6), seed=seed
    )
    prompts = np.random.default_rng(seed).integers(0, 256, (n_requests, prompt))

    registry = None
    goodputs = []
    for n_replicas in (1, 2, 4):
        backend = ClusterBackend(
            [JitBackend(prompt + gen + 4) for _ in range(n_replicas)],
            router="least_inflight", seed=seed,
        )
        engine = ServingEngine(
            max_len=prompt + gen + 4, backend=backend, hedge_backend=hedge,
            dispatch=dispatch,
        )
        engine.register(Variant("remote", cfg, params, 80.0))
        if registry is None:
            registry = engine.measure_profiles(
                prompt_len=prompt, gen_tokens=gen, trials=2
            )
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        loop = engine.make_loop(sched)
        t0 = time.perf_counter()
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
            service_model=lambda res: service_ms * res.stats.max_replica_rows,
        )
        us = (time.perf_counter() - t0) * 1e6
        goodputs.append(metrics.goodput)
        shares = "/".join(
            f"{row.share * 100:.0f}"
            for _, row in sorted(metrics.replica_rows.items())
        )
        emit(
            f"serving/cluster/{n_replicas}x",
            us / max(len(done), 1),
            f"goodput={metrics.goodput*100:.2f}% "
            f"p99={metrics.p99_latency_ms:.1f}ms "
            f"mean_wait={metrics.mean_queue_wait_ms:.1f}ms "
            f"shares={shares}% router=least_inflight",
        )
    monotone = all(a <= b + 1e-9 for a, b in zip(goodputs, goodputs[1:]))
    emit(
        "serving/cluster/scaling",
        0.0,
        "goodput " + " -> ".join(f"{g*100:.1f}%" for g in goodputs)
        + f" (1x -> 2x -> 4x replicas) monotone={monotone}",
    )


def _cluster_fault(
    *, n_requests: int, sla_ms: float = 250.0, seed: int = 0, sync: bool = False
):
    """Fault-injection: kill 1 of 2 replicas mid-overload, measure recovery.

    The cluster-scaling setup (2x-of-one-replica sustained overload, shed
    admission, service-coupled clock) served by a 2-replica pool whose
    backends sit behind the replica transport; replica 0 is killed halfway
    through the trace.  Acceptance (ROADMAP open item 1): post-kill
    goodput recovers to within 5% of the same trace served by a 1-replica
    pool from the start (the (N-1)-replica reference), and *zero*
    non-shed requests are lost — every submitted request resolves or is
    shed by admission (conservation), with lost batches requeued or
    failing over to their measured hedge duplicate.
    """
    import functools

    import jax

    from repro.configs import reduced
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.serving.admission import AdmissionConfig
    from repro.serving.backend import JitBackend, OnDeviceBackend
    from repro.serving.cluster import ClusterBackend
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import OverloadArrivals, make_trace
    from repro.serving.transport import ProcessTransportBackend

    prompt, gen, window_ms = 8, 2, 100.0
    service_ms = 6.0
    capacity_rps = 1e3 / service_ms  # one replica's retire rate
    dispatch = "sync" if sync else "async"
    max_len = prompt + gen + 4

    hedge = OnDeviceBackend.from_zoo(max_len=max_len)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    params = T.init_params(cfg, jax.random.key(seed))

    # Sustained 2x of ONE replica's capacity: exactly the 2-replica pool's
    # capacity before the kill, and a 2x overload on the survivor after.
    overload = OverloadArrivals(
        rate_rps=capacity_rps, overload_factor=2.0,
        overload_start=0.0, overload_stop=1.0,
    )
    trace = make_trace(
        n_requests, overload, LognormalNetwork(80.0, 0.6), seed=seed
    )
    prompts = np.random.default_rng(seed).integers(0, 256, (n_requests, prompt))
    kill_ms = float(trace.arrival_ms[-1]) * 0.5
    admission = AdmissionConfig(policy="shed", max_pending=32, max_chunk=16)

    def segment_goodput(done):
        """SLA-attained fraction of the requests arriving after the kill
        point (rid indexes the trace, so arrivals attribute exactly)."""
        seg = np.flatnonzero(trace.arrival_ms >= kill_ms)
        ok = sum(
            1
            for c in done
            if trace.arrival_ms[c.rid] >= kill_ms and c.latency_ms <= sla_ms
        )
        return ok / max(len(seg), 1)

    registry = None

    def serve(n_replicas, kill_at=None):
        nonlocal registry
        backend = ClusterBackend(
            [
                ProcessTransportBackend(
                    functools.partial(JitBackend, max_len),
                    mode="inline", max_len=max_len,
                )
                for _ in range(n_replicas)
            ],
            router="least_inflight", seed=seed,
        )
        engine = ServingEngine(
            max_len=max_len, backend=backend, hedge_backend=hedge,
            dispatch=dispatch,
        )
        engine.register(Variant("remote", cfg, params, 80.0))
        if registry is None:
            registry = engine.measure_profiles(
                prompt_len=prompt, gen_tokens=gen, trials=2
            )
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        loop = engine.make_loop(sched, admission=admission)
        fault = {"killed": False, "lost": 0, "requeued": 0}

        def on_tick(tick_ms, res):
            fault["lost"] += res.stats.n_lost
            fault["requeued"] += res.stats.n_requeued
            if kill_at is not None and not fault["killed"] and tick_ms >= kill_at:
                backend.kill_replica(0, reason="bench fault injection")
                fault["killed"] = True

        t0 = time.perf_counter()
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
            on_tick=on_tick,
            service_model=lambda res: service_ms * res.stats.max_replica_rows,
        )
        us = (time.perf_counter() - t0) * 1e6
        return done, metrics, fault, us, loop

    ref_done, ref_metrics, _, ref_us, _ = serve(1)
    ref_goodput = segment_goodput(ref_done)
    emit(
        "serving/cluster/fault/reference_1x",
        ref_us / max(len(ref_done), 1),
        f"post-kill-segment goodput={ref_goodput*100:.2f}% "
        f"shed_rate={ref_metrics.shed_rate*100:.2f}% (1 replica, no fault)",
    )

    done, metrics, fault, us, loop = serve(2, kill_at=kill_ms)
    goodput = segment_goodput(done)
    recovery = goodput / max(ref_goodput, 1e-9)
    # Conservation: every submitted request resolved or was shed — a lost
    # batch must never lose a request (requeue / hedge-failover recovered
    # all of them).
    n_lost_requests = n_requests - len(done) - loop.admission.n_rejected
    emit(
        "serving/cluster/fault/kill_mid",
        us / max(len(done), 1),
        f"post-kill-segment goodput={goodput*100:.2f}% "
        f"recovery={recovery:.2f}x-of-1x "
        f"(target >=0.95) lost_rows={fault['lost']} "
        f"requeued={fault['requeued']} "
        f"lost_requests={n_lost_requests} (must be 0) "
        f"shed_rate={metrics.shed_rate*100:.2f}%",
    )


def _continuous_batching(
    *, n_requests: int, sla_ms: float = 400.0, seed: int = 0
) -> int:
    """Continuous-batching tier (PR 7 tentpole): TTFT + recompile rows.

    One remote variant on a :class:`ContinuousBatchingBackend` (fixed-shape
    prefill/decode entry points over a block-paged slot cache).  Three rows:

    * ``join_ttft`` — a request joining the persistent decode batch
      mid-flight gets its first token in a fraction of one full batch's
      service time (the whole-batch tier's floor: a joiner waits for the
      batch to finish).
    * ``overload_ttft`` — the same claim under a sustained 2x overload
      driven through the stepped serving loop: TTFT p99 of every served
      request stays under 0.5x one full-batch service time (the PR's
      acceptance bar).
    * ``recompiles`` — the zero-post-warmup-recompile invariant: the jit
      cache count after all traffic equals the count right after warmup.

    Returns the post-warmup compile-count growth (0 = invariant holds) for
    the ``--check-compiles`` CI gate.
    """
    import jax

    from repro.configs import reduced
    from repro.configs.mdinference_zoo import ServingGeometry
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.serving.admission import AdmissionConfig
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import OverloadArrivals, make_trace

    prompt, gen, window_ms = 8, 8, 100.0
    service_ms = 6.0
    capacity_rps = 1e3 / service_ms
    geo = ServingGeometry(
        max_len=prompt + gen + 4, prompt_width=prompt, bs_ladder=(1, 2, 4, 8),
        n_slots=8, page_size=8, max_steps=8,
    )

    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    engine = ServingEngine(hedge_backend=hedge, continuous=True, geometry=geo)
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    engine.register(
        Variant("remote", cfg, T.init_params(cfg, jax.random.key(seed)), 80.0)
    )
    backend = engine.backend
    registry = engine.measure_profiles(prompt_len=prompt, gen_tokens=gen, trials=2)
    backend.warmup()
    compiles_after_warmup = backend.compile_count
    # Pre-warm the hedge tier at every pow2 batch shape a tick can produce:
    # its first inline compile otherwise burns real SLA budget mid-race.
    for N in (1, 2, 4, 8):
        hedge.run_batch(hedge.hedge_name, np.zeros((N, prompt), np.int32), gen)

    # -- join_ttft: mid-flight join vs one full-batch service time ----------
    rng = np.random.default_rng(seed)
    full = rng.integers(0, 256, (geo.n_slots, prompt)).astype(np.int32)
    backend.generate("remote", full, gen)  # absorb host-side first-call cost
    _, full_ms = backend.generate("remote", full, gen)
    h1 = backend.submit_batch("remote", full[: geo.n_slots - 1], gen, sync=False)
    backend.pump()  # the persistent batch is now mid-decode...
    backend.pump()
    h2 = backend.submit_batch("remote", full[-1:], gen, sync=False)
    join_ttft = float(h2.ttft_wall_ms[0])  # first token already emitted
    h1.wait()
    h2.wait()
    emit(
        "serving/continuous/join_ttft",
        join_ttft * 1e3,
        f"mid-flight join ttft={join_ttft:.2f}ms vs "
        f"full_batch={full_ms:.2f}ms ratio={join_ttft / full_ms:.3f} "
        "(target <0.5: a joiner no longer waits for the batch)",
    )

    # -- overload_ttft: TTFT p99 under sustained 2x overload ----------------
    sched = MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
    )
    loop = engine.make_loop(
        sched, admission=AdmissionConfig(max_chunk=geo.n_slots)
    )
    overload = OverloadArrivals(
        rate_rps=capacity_rps, overload_factor=2.0,
        overload_start=0.0, overload_stop=1.0,
    )
    trace = make_trace(
        n_requests, overload, LognormalNetwork(80.0, 0.6), seed=seed
    )
    prompts = rng.integers(0, 256, (n_requests, prompt))
    t0 = time.perf_counter()
    done, metrics = loop.drain_trace(
        trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
        service_model=lambda res: service_ms * res.stats.n_requests,
    )
    us = (time.perf_counter() - t0) * 1e6
    ttfts = np.asarray([c.ttft_ms for c in done if c.ttft_ms is not None])
    p99 = quantile(ttfts, 99)
    emit(
        "serving/continuous/overload_ttft",
        us / max(len(done), 1),
        f"ttft_p99={p99:.2f}ms vs full_batch={full_ms:.2f}ms "
        f"ratio={p99 / full_ms:.3f} (target <0.5 under 2x overload) "
        f"joined={len(ttfts)}/{len(done)} "
        f"recycled={backend.recycled_total}",
    )

    # -- recompiles: the fixed-shape invariant ------------------------------
    backend.check_conservation()
    growth = backend.compile_count - compiles_after_warmup
    emit(
        "serving/continuous/recompiles",
        0.0,
        f"compile_count={backend.compile_count} "
        f"post_warmup_growth={growth} (must be 0) "
        f"joined={backend.joined_total} recycled={backend.recycled_total} "
        "conservation=ok",
    )
    return growth


def _drift_gauntlet(
    *, n_requests: int, sla_ms: float = 250.0, seed: int = 0, sync: bool = False
):
    """Drift gauntlet rows (PR 9 tentpole): adaptive vs static-tuned oracle.

    Four drift scenarios — diurnal arrival swing, 30x service spike,
    flapping replica on a heterogeneous 2-replica pool, university→LTE
    network swap — each served twice: with the best static
    :class:`AdmissionConfig` from a small grid (the scenario's
    *static-tuned oracle*) and with a deliberately mistuned static config
    plus an :class:`AdmissionController` closing the loop.  The
    ``adaptive`` row's ``vs_oracle`` ratio is the acceptance signal
    (``<=1.25x`` in >=3 of 4 scenarios; the seeded deterministic twin of
    this comparison is asserted in ``tests/test_drift_gauntlet.py``).
    """
    import jax

    from repro.configs import reduced
    from repro.core.network import LognormalNetwork, SwitchedNetwork, lte_trace
    from repro.models import transformer as T
    from repro.serving.admission import AdmissionConfig
    from repro.serving.backend import JitBackend, OnDeviceBackend
    from repro.serving.cluster import ClusterBackend, ReplicaSpec
    from repro.serving.controller import AdmissionController, ControllerConfig
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import (
        DiurnalArrivals,
        PoissonArrivals,
        SpikeArrivals,
        make_trace,
    )

    prompt, gen, window_ms = 8, 2, 100.0
    service_ms = 6.0  # per row on one unit-scale replica
    capacity_rps = 1e3 / service_ms
    dispatch = "sync" if sync else "async"
    max_len = prompt + gen + 4

    hedge = OnDeviceBackend.from_zoo(max_len=max_len)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    params = T.init_params(cfg, jax.random.key(seed))

    flap_specs = [
        ReplicaSpec(weight=2.0), ReplicaSpec(weight=1.0, service_scale=2.0)
    ]
    registry = None
    engines = {}

    def get_engine(n_replicas):
        """One engine per pool shape, reused across every run (a fresh
        JitBackend per run would re-jit 12+ times for nothing)."""
        nonlocal registry
        if n_replicas not in engines:
            if n_replicas == 1:
                engine = ServingEngine(
                    max_len=max_len, hedge_backend=hedge, dispatch=dispatch
                )
            else:
                backend = ClusterBackend(
                    [JitBackend(max_len) for _ in range(n_replicas)],
                    router="least_inflight", seed=seed, specs=flap_specs,
                )
                engine = ServingEngine(
                    max_len=max_len, backend=backend, hedge_backend=hedge,
                    dispatch=dispatch,
                )
            engine.register(Variant("remote", cfg, params, 80.0))
            if registry is None:
                registry = engine.measure_profiles(
                    prompt_len=prompt, gen_tokens=gen, trials=2
                )
            engines[n_replicas] = engine
        return engines[n_replicas]

    spike = SpikeArrivals(
        rate_rps=0.8 * capacity_rps, spike_factor=30.0,
        spike_start=0.4, spike_stop=0.6,
    )
    scenarios = (
        ("diurnal",
         lambda n: make_trace(
             n, DiurnalArrivals(0.2 * capacity_rps, 3.0 * capacity_rps),
             LognormalNetwork(80.0, 0.6), seed=seed), 1),
        ("spike",
         lambda n: make_trace(
             n, spike, LognormalNetwork(80.0, 0.6), seed=seed), 1),
        ("flap",
         lambda n: make_trace(
             n, PoissonArrivals(1.2 * capacity_rps),
             LognormalNetwork(80.0, 0.6), seed=seed), 2),
        ("network_swap",
         lambda n: make_trace(
             n, PoissonArrivals(1.1 * capacity_rps),
             SwitchedNetwork(university_trace(), lte_trace(), 0.5),
             seed=seed), 1),
    )

    def serve(scenario, trace, prompts, admission, controller, n_replicas):
        engine = get_engine(n_replicas)
        backend = engine.backend
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        loop = engine.make_loop(
            sched, admission=admission, controller=controller
        )
        horizon = float(trace.arrival_ms[-1])
        state = {"factor": 1.0}
        scales = (
            [s.service_scale for s in flap_specs] if n_replicas > 1 else [1.0]
        )

        def on_tick(t_ms, res):
            if scenario == "spike":
                state["factor"] = spike.service_factor(t_ms, horizon)
            if scenario == "flap":
                drained = backend.pool.replicas[0].health.draining
                if 0.3 <= t_ms / horizon < 0.6:
                    if not drained:
                        backend.drain(0)
                elif drained:
                    backend.rejoin(0)

        def service_model(res):
            rows = res.stats.replica_rows
            busiest = (
                res.stats.n_requests
                if not rows
                else max(r * scales[rid] for rid, r in rows.items())
            )
            return service_ms * state["factor"] * busiest

        t0 = time.perf_counter()
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
            on_tick=on_tick, service_model=service_model,
        )
        us = (time.perf_counter() - t0) * 1e6
        if n_replicas > 1 and backend.pool.replicas[0].health.draining:
            backend.rejoin(0)  # leave the shared engine clean for reuse
        return done, metrics, us

    grid = (8, 64) if n_requests <= 160 else (8, 16, 64)
    controller_cfg = ControllerConfig(
        target_wait_frac=0.1, wait_alpha=0.7, max_pending=64
    )
    log_sizes = {}
    for scenario, mk_trace, n_replicas in scenarios:
        trace = mk_trace(n_requests)
        prompts = np.random.default_rng(seed).integers(
            0, 256, (n_requests, prompt)
        )
        static_runs = []
        for mp in grid:
            _, m, us = serve(
                scenario, trace, prompts,
                AdmissionConfig(policy="shed", max_pending=mp, max_chunk=16),
                None, n_replicas,
            )
            static_runs.append((mp, m, us))
        best_goodput = max(m.goodput for _, m, _ in static_runs)
        mp, oracle, us = min(
            (r for r in static_runs if r[1].goodput >= 0.9 * best_goodput),
            key=lambda r: r[1].p99_latency_ms,
        )
        emit(
            f"serving/drift/{scenario}/static_oracle",
            us / max(oracle.n_requests, 1),
            f"p99={oracle.p99_latency_ms:.1f}ms "
            f"goodput={oracle.goodput*100:.2f}% "
            f"shed_rate={oracle.shed_rate*100:.2f}% "
            f"max_pending={mp} (best of grid {grid})",
        )
        controller = AdmissionController(controller_cfg)
        _, adaptive, us = serve(
            scenario, trace, prompts,
            AdmissionConfig(policy="shed", max_pending=64, max_chunk=16),
            controller, n_replicas,
        )
        ratio = adaptive.p99_latency_ms / max(oracle.p99_latency_ms, 1e-9)
        log_sizes[scenario] = len(controller.log)
        emit(
            f"serving/drift/{scenario}/adaptive",
            us / max(adaptive.n_requests, 1),
            f"p99={adaptive.p99_latency_ms:.1f}ms "
            f"vs_oracle={ratio:.2f}x (target <=1.25x in 3/4) "
            f"goodput={adaptive.goodput*100:.2f}% "
            f"retunes={controller.n_retunes} "
            f"(mistuned start max_pending=64)",
        )

    # The controller's retune log is the gauntlet's evidence the adaptive
    # law actually moved the knobs: under a drifting trace it must be
    # non-empty (the static rows never touch it).
    nonempty = sum(1 for n in log_sizes.values() if n > 0)
    emit(
        "serving/drift/controller_log",
        0.0,
        "retune log entries "
        + " ".join(f"{s}={n}" for s, n in log_sizes.items())
        + f" nonempty={nonempty}/{len(log_sizes)} (must be >=1)",
    )
    if nonempty == 0:
        raise AssertionError(
            "AdmissionController.log stayed empty across every drift "
            f"scenario: {log_sizes}"
        )


def _adaptive_recompile_check(*, n_requests: int, seed: int = 0) -> int:
    """The controller must add zero recompiles on the continuous tier.

    Drives a controller-attached, bounded-admission overload trace through
    the continuous-batching backend and returns the post-warmup compile
    growth (0 = the adaptive path never perturbs batch shapes in a way
    that escapes the fixed-shape ladder) — folded into the
    ``--check-compiles`` CI gate.
    """
    import jax

    from repro.configs import reduced
    from repro.configs.mdinference_zoo import ServingGeometry
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.serving.admission import AdmissionConfig
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.controller import AdmissionController, ControllerConfig
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import OverloadArrivals, make_trace

    prompt, gen, window_ms = 8, 8, 100.0
    service_ms = 6.0
    capacity_rps = 1e3 / service_ms
    geo = ServingGeometry(
        max_len=prompt + gen + 4, prompt_width=prompt, bs_ladder=(1, 2, 4, 8),
        n_slots=8, page_size=8, max_steps=8,
    )
    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    engine = ServingEngine(hedge_backend=hedge, continuous=True, geometry=geo)
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    engine.register(
        Variant("remote", cfg, T.init_params(cfg, jax.random.key(seed)), 80.0)
    )
    registry = engine.measure_profiles(prompt_len=prompt, gen_tokens=gen, trials=2)
    backend = engine.backend
    backend.warmup()
    for N in (1, 2, 4, 8):
        hedge.run_batch(hedge.hedge_name, np.zeros((N, prompt), np.int32), gen)
    compiles_after_warmup = backend.compile_count

    sched = MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=400.0, seed=seed)
    )
    controller = AdmissionController(ControllerConfig(target_wait_frac=0.1))
    loop = engine.make_loop(
        sched,
        admission=AdmissionConfig(
            policy="shed", max_pending=16, max_chunk=geo.n_slots
        ),
        controller=controller,
    )
    trace = make_trace(
        n_requests,
        OverloadArrivals(
            rate_rps=capacity_rps, overload_factor=2.0,
            overload_start=0.0, overload_stop=1.0,
        ),
        LognormalNetwork(80.0, 0.6),
        seed=seed,
    )
    prompts = np.random.default_rng(seed).integers(0, 256, (n_requests, prompt))
    loop.drain_trace(
        trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
        service_model=lambda res: service_ms * res.stats.n_requests,
    )
    backend.check_conservation()
    growth = backend.compile_count - compiles_after_warmup
    emit(
        "serving/drift/recompiles",
        0.0,
        f"compile_count={backend.compile_count} "
        f"post_warmup_growth={growth} (must be 0) "
        f"retunes={controller.n_retunes} "
        "(controller-attached continuous tier)",
    )
    return growth


def _observability_smoke(
    *, n_requests: int, sla_ms: float = 250.0, seed: int = 0,
    sync: bool = False, trace_out=None,
):
    """Observability regression pin (PR 10 tentpole): twin + overhead rows.

    The same seeded overload stream (bounded shed admission + an
    :class:`AdmissionController`, one remote variant + the measured
    on-device hedge tier against the service-coupled loop clock) served
    twice: observability **detached** (the regression-pinned default) and
    **attached**.  Three asserted claims:

    * ``twin`` — the attached run makes identical decisions: same
      completion order, model selection, queue waits, and shed count as
      the detached run (instrumentation observes, never steers).
    * ``overhead`` — attached p99 latency stays within 1.05x of the
      detached p99 (the <=5% CI gate).
    * ``conservation`` — the span trees balance (every submitted request
      carries exactly one resolve/shed/cancel terminal, none left open)
      and the four required histogram families appear in the Prometheus
      export.

    With ``trace_out`` set, writes the Chrome trace, the JSONL span sink
    (``<trace_out>.spans.jsonl``), the Prometheus text
    (``<trace_out>.prom``), and the metrics snapshot
    (``<trace_out>.metrics.json``) for ``benchmarks/validate_obs.py``.
    """
    import jax

    from repro.configs import reduced
    from repro.core.network import LognormalNetwork
    from repro.models import transformer as T
    from repro.observability import (
        Observability,
        prometheus_text,
        request_conservation,
        write_chrome_trace,
        write_jsonl_spans,
        write_metrics_snapshot,
        write_prometheus,
    )
    from repro.serving.admission import AdmissionConfig
    from repro.serving.backend import OnDeviceBackend
    from repro.serving.controller import AdmissionController, ControllerConfig
    from repro.serving.engine import ServingEngine, Variant
    from repro.serving.loadgen import OverloadArrivals, make_trace

    prompt, gen, window_ms = 8, 2, 100.0
    service_ms = 6.0
    capacity_rps = 1e3 / service_ms
    dispatch = "sync" if sync else "async"

    hedge = OnDeviceBackend.from_zoo(max_len=prompt + gen + 4)
    ondevice = hedge.measure_profile(prompt_len=prompt, gen_tokens=gen, trials=2)
    engine = ServingEngine(
        max_len=prompt + gen + 4, hedge_backend=hedge, dispatch=dispatch
    )
    cfg = reduced(
        "gemma-2b", d_model=64, n_layers=2, n_heads=2, n_kv_heads=1, head_dim=32
    )
    engine.register(
        Variant("remote", cfg, T.init_params(cfg, jax.random.key(seed)), 80.0)
    )
    registry = engine.measure_profiles(prompt_len=prompt, gen_tokens=gen, trials=2)

    overload = OverloadArrivals(
        rate_rps=capacity_rps, overload_factor=2.0,
        overload_start=0.0, overload_stop=1.0,
    )
    trace = make_trace(
        n_requests, overload, LognormalNetwork(80.0, 0.6), seed=seed
    )
    prompts = np.random.default_rng(seed).integers(0, 256, (n_requests, prompt))
    admission = AdmissionConfig(policy="shed", max_pending=32, max_chunk=16)

    def serve(obs):
        sched = MDInferenceScheduler(
            registry, ondevice, SchedulerConfig(t_sla_ms=sla_ms, seed=seed)
        )
        controller = AdmissionController(
            ControllerConfig(target_wait_frac=0.1, wait_alpha=0.7, max_pending=64)
        )
        loop = engine.make_loop(
            sched, admission=admission, controller=controller,
            observability=obs,
        )
        t0 = time.perf_counter()
        done, metrics = loop.drain_trace(
            trace, window_ms, tokens_for=lambda i: prompts[i], n_steps=gen,
            service_model=lambda res: service_ms * res.stats.n_requests,
        )
        us = (time.perf_counter() - t0) * 1e6
        return done, metrics, loop, us

    serve(None)  # warm every jitted shape out of both timed passes
    done_off, _, loop_off, us_off = serve(None)
    obs = Observability()
    done_on, _, loop_on, us_on = serve(obs)

    # -- seeded twin: the attached run must make identical decisions -------
    twin = (
        [c.rid for c in done_off] == [c.rid for c in done_on]
        and [c.model_name for c in done_off] == [c.model_name for c in done_on]
        and loop_off.admission.n_rejected == loop_on.admission.n_rejected
        and np.allclose(
            [c.queue_wait_ms for c in done_off],
            [c.queue_wait_ms for c in done_on],
        )
    )

    lats_off = np.asarray([c.latency_ms for c in done_off])
    lats_on = np.asarray([c.latency_ms for c in done_on])
    p99_off, p99_on = quantile(lats_off, 99), quantile(lats_on, 99)
    ratio = p99_on / max(p99_off, 1e-9)
    emit(
        "serving/observability/disabled",
        us_off / max(len(done_off), 1),
        f"p99={p99_off:.1f}ms twin_identical={twin} "
        f"shed={loop_off.admission.n_rejected} (regression-pinned default)",
    )
    emit(
        "serving/observability/enabled",
        us_on / max(len(done_on), 1),
        f"p99={p99_on:.1f}ms overhead={ratio:.3f}x (gate <=1.05x) "
        f"spans={len(obs.tracer)}",
    )

    # -- conservation + required metric families ---------------------------
    audit = request_conservation(obs.tracer)
    balanced = (
        audit["open"] == 0
        and audit["extra_terminals"] == 0
        and audit["submitted"]
        == audit["resolved"] + audit["rejected"] + audit["cancelled"]
    )
    text = prometheus_text(obs.metrics)
    families = (
        "admission_queue_wait_ms",
        "loop_tick_wall_ms",
        "cluster_batch_wall_ms",
        "controller_wait_ewma_ms",
    )
    missing = [f for f in families if f not in text]
    emit(
        "serving/observability/trace",
        0.0,
        f"spans={len(obs.tracer)} submitted={audit['submitted']} "
        f"resolved={audit['resolved']} shed={audit['rejected']} "
        f"conservation={'ok' if balanced else 'VIOLATED'} "
        f"families_missing={missing if missing else 'none'}",
    )

    if trace_out is not None:
        out_dir = os.path.dirname(trace_out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        write_chrome_trace(trace_out, obs.tracer)
        write_jsonl_spans(trace_out + ".spans.jsonl", obs.tracer)
        write_prometheus(trace_out + ".prom", obs.metrics)
        write_metrics_snapshot(trace_out + ".metrics.json", obs.metrics)

    errors = []
    if not twin:
        errors.append("observability-attached run diverged from its "
                      "detached seeded twin")
    if ratio > 1.05:
        errors.append(
            f"observability overhead {ratio:.3f}x exceeds the 1.05x p99 gate"
        )
    if not balanced:
        errors.append(f"span conservation violated: {audit}")
    if missing:
        errors.append(f"prometheus export missing families: {missing}")
    if errors:
        raise AssertionError("; ".join(errors))


def run(
    n_requests: int = 2_000, smoke: bool = False, sync: bool = False,
    trace_out=None,
) -> int:
    reg = lm_zoo_registry(chips=8)
    for p in reg:
        emit(f"serving/zoo/{p.name}", p.mu_ms * 1e3, f"quality={p.accuracy}")

    if smoke:
        n_requests = min(n_requests, 200)

    for net_name, trace in (
        ("university", university_trace()),
        ("residential", residential_trace()),
    ):
        rng = np.random.default_rng(11)
        t_nw = trace.sample(rng, n_requests)
        for power, label in ((1.0, "mdinference"), (4.0, "mdinference_p4")):
            sched = MDInferenceScheduler(
                reg, ONDEVICE_TIER,
                SchedulerConfig(t_sla_ms=250.0, utility_power=power, seed=12),
            )
            m, us = timed(lambda: sched.run_trace(t_nw), repeats=1)
            emit(
                f"serving/{net_name}/{label}",
                us / n_requests,
                f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
                f"hedge_used={m.ondevice_reliance*100:.2f}%",
            )

        # Energy/cost knob (paper §VII): hedge only when the budget is tight.
        sched = MDInferenceScheduler(
            reg, ONDEVICE_TIER,
            SchedulerConfig(
                t_sla_ms=250.0, seed=12,
                hedge=HedgePolicy(always=False, deadline_headroom_ms=60.0),
            ),
        )
        m, _ = timed(lambda: sched.run_trace(t_nw), repeats=1)
        hedged = sum(1 for r in sched.log if r["hedged"]) / len(sched.log)
        emit(
            f"serving/{net_name}/selective_hedge",
            0.0,
            f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
            f"hedge_rate={hedged*100:.1f}% (duplication cost saved)",
        )

    # Scalar-vs-batched scheduler throughput on a 10k trace (PR 1 tentpole).
    rng = np.random.default_rng(11)
    t_nw = university_trace().sample(rng, 1_000 if smoke else 10_000)
    _throughput_comparison(reg, t_nw)

    # Two-tier hedge: measured (real OnDeviceBackend) vs sampled resolution
    # on an identical stream (PR 2 tentpole).  The 150ms SLA makes some
    # queue-delayed requests miss remotely, so the duplicate actually wins.
    _hedge_mode_comparison(
        n_requests=24 if smoke else 120, sla_ms=150.0, sync=sync
    )

    # Async vs serialized hedge dispatch on one stream (PR 3 tentpole):
    # with concurrent dispatch the tick span beats the sum of the tiers'
    # wall times.  --sync collapses the async row to the deterministic
    # serialized fallback (CI).
    _async_vs_serialized_hedge(
        n_requests=16 if smoke else 96, sla_ms=150.0, sync=sync
    )

    # Bounded admission under 2x overload (PR 4 tentpole): shed keeps p99
    # within 1.5x of the uncongested baseline, unbounded diverges.
    _admission_comparison(n_requests=240 if smoke else 600, sync=sync)

    # Multi-tenant QoS lanes (PR 8 tentpole): a batch tenant floods 4x
    # capacity; weighted-fair lanes keep the interactive tenant's p99
    # within 1.1x of its uncongested baseline while the shared FIFO lets
    # the flood destroy it.
    _tenancy_comparison(n_requests=240 if smoke else 600, sync=sync)

    # Replicated execution cluster (PR 5 tentpole): the same 2x overload
    # served by 1/2/4 pooled replicas under least_inflight routing —
    # goodput rises monotonically with the replica count.
    _cluster_scaling(n_requests=240 if smoke else 600, sync=sync)

    # Fault-tolerant pool (PR 6 tentpole): kill 1 of 2 replicas mid-trace;
    # the survivor's post-kill goodput recovers to the 1-replica reference
    # and conservation holds (zero lost non-shed requests).
    _cluster_fault(n_requests=240 if smoke else 600, sync=sync)

    # Cross-tick continuous batching (PR 7 tentpole): mid-flight joins get
    # their first token in a fraction of one full-batch service time, even
    # under 2x overload, with zero post-warmup recompiles.  Stepped dispatch
    # is thread-free, so the rows are deterministic with or without --sync.
    compile_growth = _continuous_batching(n_requests=48 if smoke else 160)

    # Drift gauntlet (PR 9 tentpole): diurnal / spike / flapping-replica /
    # network-swap scenarios, each served by the best static admission
    # config from a grid (the static-tuned oracle) and by a mistuned
    # static config + AdmissionController closing the loop — the adaptive
    # row's p99 tracks the oracle without per-scenario hand-tuning.
    _drift_gauntlet(n_requests=120 if smoke else 400, sync=sync)

    # The controller must be invisible to the compile caches: a
    # controller-attached bounded-admission run on the continuous tier
    # folds its post-warmup compile growth into the --check-compiles gate.
    compile_growth += _adaptive_recompile_check(n_requests=48 if smoke else 160)

    # Observability regression pin (PR 10 tentpole): the attached stack is
    # a decision-identical seeded twin of the detached one, p99 overhead
    # stays <=1.05x, span conservation balances, and the required metric
    # families export.  --trace-out additionally writes the Chrome trace /
    # span sink / Prometheus text / metrics snapshot for schema validation.
    _observability_smoke(
        n_requests=120 if smoke else 300, sync=sync, trace_out=trace_out
    )

    write_results("serving")
    return compile_growth


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace sizes for CI")
    ap.add_argument("--sync", action="store_true",
                    help="serialized-dispatch fallback: no worker threads, "
                    "deterministic rows (used by CI)")
    ap.add_argument("--check-compiles", action="store_true",
                    help="exit nonzero on any post-warmup recompile of the "
                    "continuous tier's fixed-shape entry points, with or "
                    "without an AdmissionController attached (CI gate)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the observability smoke's Chrome trace to "
                    "PATH (plus PATH.spans.jsonl / PATH.prom / "
                    "PATH.metrics.json) for benchmarks/validate_obs.py")
    ap.add_argument("--only-observability", action="store_true",
                    help="run just the observability smoke section (the CI "
                    "trace job's fast path)")
    args = ap.parse_args()
    if args.only_observability:
        _observability_smoke(
            n_requests=120 if args.smoke else 300, sync=args.sync,
            trace_out=args.trace_out,
        )
        write_results("serving")
        raise SystemExit(0)
    growth = run(smoke=args.smoke, sync=args.sync, trace_out=args.trace_out)
    if args.check_compiles and growth != 0:
        raise SystemExit(
            f"continuous tier recompiled after warmup (growth={growth})"
        )
