"""Serving-tier benchmark (beyond paper): MDInference over the LM zoo.

The paper's experiment translated to the TPU serving stack: requests with a
latency SLO arrive over variable networks; the scheduler picks an LM tier
per request and hedges with the cheap tier.  Compares the same four
algorithms as Table IV on the roofline-profiled zoo.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import residential_trace, university_trace
from repro.core.duplication import HedgePolicy
from repro.serving.profiles import ONDEVICE_TIER, lm_zoo_registry
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig


def run(n_requests: int = 2_000):
    reg = lm_zoo_registry(chips=8)
    for p in reg:
        emit(f"serving/zoo/{p.name}", p.mu_ms * 1e3, f"quality={p.accuracy}")

    for net_name, trace in (
        ("university", university_trace()),
        ("residential", residential_trace()),
    ):
        rng = np.random.default_rng(11)
        t_nw = trace.sample(rng, n_requests)
        for power, label in ((1.0, "mdinference"), (4.0, "mdinference_p4")):
            sched = MDInferenceScheduler(
                reg, ONDEVICE_TIER,
                SchedulerConfig(t_sla_ms=250.0, utility_power=power, seed=12),
            )
            m, us = timed(lambda: sched.run_trace(t_nw), repeats=1)
            emit(
                f"serving/{net_name}/{label}",
                us / n_requests,
                f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
                f"hedge_used={m.ondevice_reliance*100:.2f}%",
            )

        # Energy/cost knob (paper §VII): hedge only when the budget is tight.
        sched = MDInferenceScheduler(
            reg, ONDEVICE_TIER,
            SchedulerConfig(
                t_sla_ms=250.0, seed=12,
                hedge=HedgePolicy(always=False, deadline_headroom_ms=60.0),
            ),
        )
        m, _ = timed(lambda: sched.run_trace(t_nw), repeats=1)
        hedged = sum(1 for r in sched.log if r["hedged"]) / len(sched.log)
        emit(
            f"serving/{net_name}/selective_hedge",
            0.0,
            f"quality={m.aggregate_accuracy:.2f} attain={m.sla_attainment*100:.2f}% "
            f"hedge_rate={hedged*100:.1f}% (duplication cost saved)",
        )


if __name__ == "__main__":
    run()
