"""Roofline table from the dry-run JSON (EXPERIMENTS.md §Roofline source).

Reads results/roofline.json (produced by repro.launch.dryrun) and emits one
row per compiled (arch x shape) cell: the three terms, the dominant one,
and MODEL_FLOPS/HLO_FLOPs.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DEFAULT = Path(__file__).resolve().parent.parent / "results" / "roofline.json"


def run(path=DEFAULT):
    path = Path(path)
    if not path.exists():
        emit("roofline/missing", 0.0, f"run repro.launch.dryrun first ({path})")
        return
    cells = json.loads(path.read_text())["cells"]
    for c in cells:
        name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        if c["status"] == "skipped":
            emit(name, 0.0, f"SKIP: {c['note']}")
            continue
        if c["status"] == "error":
            emit(name, 0.0, f"ERROR: {c['note']}")
            continue
        mem = c["memory"]["per_device_total"] / 2**30
        if "terms_s" in c:
            t = c["terms_s"]
            step_us = max(t.values()) * 1e6
            emit(
                name,
                step_us,
                f"compute={t['compute_s']*1e3:.1f}ms memory={t['memory_s']*1e3:.1f}ms "
                f"collective={t['collective_s']*1e3:.1f}ms dom={c['dominant']} "
                f"useful={c['model_flops_over_hlo']*100:.0f}% mem/dev={mem:.2f}GiB",
            )
        else:
            emit(name, 0.0, f"compiled mem/dev={mem:.2f}GiB census={c['collective_census']}")


if __name__ == "__main__":
    run()
