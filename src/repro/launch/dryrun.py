import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers + compiles the real step function (train_step / prefill /
     decode_step) with NamedSharding-annotated inputs (ShapeDtypeStruct
     stand-ins — no allocation),
  3. prints ``memory_analysis()`` (fits-per-device proof) and
     ``cost_analysis()``, and takes a census of the collective schedule,
  4. (single-pod) compiles the roofline *cost components* — per-kind layer
     step, embed/loss ends, optimizer — and combines them into the three
     roofline terms (launch/roofline.py explains why components are needed:
     XLA counts scan bodies once).

Results stream into a JSON report consumed by EXPERIMENTS.md and by
``repro.serving.profiles`` (the MDInference zoo's latency priors).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out roofline.json
"""
import argparse
import dataclasses
import json
import time
import traceback
from collections import Counter
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, input_specs, skip_reason
from repro.distributed.api import axis_rules, named_sharding
from repro.launch.mesh import make_custom_mesh, make_production_mesh, make_rules
from repro.launch import roofline as rf
from repro.models import transformer as T
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state
from repro.training.train_loop import (
    TrainConfig,
    init_train_state,
    make_train_step,
    state_shardings,
)

OPT_CFG = OptimizerConfig()


# ---------------------------------------------------------------------------
# Step builders (full scanned step — the compile artifact).
# ---------------------------------------------------------------------------
def _tune_cfg(cfg, shape):
    """Execution knobs for production shapes (architecture unchanged)."""
    over = {"remat": True}
    if "moe" in cfg.pattern:
        # One group per batch row: groups stay sharded exactly like the batch
        # (a group layout that crosses the batch sharding makes GSPMD fall
        # back to full replication of the token array — measured +4 GiB/dev
        # per MoE layer).  The tensor axis parallelizes inside the experts.
        over["moe_groups"] = SHAPES[shape].global_batch
    return dataclasses.replace(cfg, **over)


def build_cell(cfg, shape, mesh, rules, microbatches=1):
    """Returns (jitted_fn, example_args) for the cell's step function."""
    cell = SHAPES[shape]
    specs = input_specs(cfg, shape)
    p_sh = jax.tree.map(
        lambda ax: named_sharding(mesh, rules, ax),
        T.param_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    params_sds = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
    batch_sh = named_sharding(mesh, rules, ("batch",))

    if cell.kind == "train":
        step = make_train_step(
            cfg, OPT_CFG, TrainConfig(microbatches=microbatches),
            mesh=mesh, rules=rules,
        )
        state_sds = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.key(0))
        )
        return step, (state_sds, specs["inputs"])

    if cell.kind == "prefill":
        def prefill_fn(params, inputs):
            with axis_rules(rules):
                return T.prefill(cfg, params, inputs, max_len=cell.seq_len)

        fn = jax.jit(prefill_fn, in_shardings=(p_sh, batch_sh))
        return fn, (params_sds, specs["inputs"])

    # decode
    c_sh = jax.tree.map(
        lambda ax: named_sharding(mesh, rules, ax),
        T.cache_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )

    def decode_fn(params, cache, token, pos):
        with axis_rules(rules):
            return T.decode_step(cfg, params, cache, token, pos)

    fn = jax.jit(
        decode_fn,
        in_shardings=(p_sh, c_sh, batch_sh, batch_sh),
        donate_argnums=(1,),
    )
    return fn, (params_sds, specs["cache"], specs["token"], specs["pos"])


# ---------------------------------------------------------------------------
# Cost components (single-pod roofline).
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _block_params_sds(cfg, kind):
    def leaf(path, spec):
        shape, _ = spec
        name = path[-1]
        dt = jnp.float32 if T._fp32_leaf(name) else jnp.dtype(cfg.dtype)
        return _sds(shape, dt)

    return T._walk_spec(T.block_spec(cfg, kind), leaf)


def _block_shardings(cfg, kind, mesh, rules):
    def leaf(path, spec):
        _, ax = spec
        return named_sharding(mesh, rules, ax)

    return T._walk_spec(T.block_spec(cfg, kind), leaf)


def cost_components(cfg, shape, mesh, rules):
    """[(name, compiled, multiplier)] for the roofline combination."""
    cell = SHAPES[shape]
    cfgu = dataclasses.replace(cfg, unroll_scans=True, remat=False)
    B = cell.global_batch
    S = cell.seq_len
    dtype = jnp.dtype(cfg.dtype)
    counts = Counter(cfg.layer_kinds())
    batch_sh = named_sharding(mesh, rules, ("batch",))
    x_sh = named_sharding(mesh, rules, ("batch", "seq_act", None))
    comps = []

    kind_mode = "train" if cell.kind == "train" else cell.kind
    pos_sds = _sds((B, 1 if cell.kind == "decode" else S), jnp.int32)

    for kind, count in counts.items():
        bp_sds = _block_params_sds(cfgu, kind)
        bp_sh = _block_shardings(cfgu, kind, mesh, rules)
        if cell.kind == "decode" and kind == "slstm":
            continue  # decode slstm cost covered by the generic path below
        if kind == "slstm" and cell.kind != "decode":
            # Sequential cell: compile ONE timestep, scale by S * count.
            from repro.models import xlstm

            def slstm_one(bp, xt, st):
                with axis_rules(rules):
                    st2 = xlstm._slstm_step(bp["cell"], cfgu.xlstm_heads, xt, st)
                    return sum(jnp.sum(v * v) for v in st2.values())

            xt_sds = _sds((B, cfgu.d_model), jnp.float32)
            st_sds = {k: _sds((B, cfgu.d_model), jnp.float32) for k in "cnhm"}
            fn = (
                jax.value_and_grad(slstm_one)
                if cell.kind == "train"
                else slstm_one
            )
            compiled = (
                jax.jit(fn, in_shardings=(bp_sh, x_sh if False else batch_sh, None))
                .lower(bp_sds, xt_sds, st_sds)
                .compile()
            )
            comps.append((f"slstm_step", compiled, float(S * count)))
            continue

        ctx_decode = cell.kind == "decode"
        s_len = 1 if ctx_decode else S
        mult = float(count)
        if kind == "mlstm" and not ctx_decode:
            # mLSTM cost is linear in chunk count (projections + fixed-size
            # quadratic chunks); compile a short sequence and scale, instead
            # of unrolling S/chunk (512 at 32k) chunk bodies.
            s_len = min(S, cfgu.xlstm_chunk * 8)
            mult = float(count) * (S / s_len)
        x_sds = _sds((B, s_len, cfgu.d_model), dtype)
        kpos_sds = _sds((B, s_len), jnp.int32)
        cache_sds = (
            jax.eval_shape(lambda: T._block_cache(cfgu, kind, B, S, dtype))
            if ctx_decode
            else None
        )

        def block_fn(bp, x, pos, cache=None, kind=kind):
            with axis_rules(rules):
                from repro.distributed.api import constrain

                ctx = T.SeqContext(positions=pos, decode=ctx_decode)
                out, _, aux = T.apply_block(cfgu, kind, bp, x, ctx, cache)
                if not ctx_decode:  # period-boundary layout (SP variants)
                    out = constrain(out, "batch", "seq_act", None)
                return jnp.sum(out.astype(jnp.float32) ** 2) + aux

        if cell.kind == "train":
            fn = jax.value_and_grad(block_fn)
        else:
            fn = block_fn
        in_sh = (bp_sh, x_sh, batch_sh) + ((None,) if ctx_decode else ())
        args = (bp_sds, x_sds, kpos_sds) + ((cache_sds,) if ctx_decode else ())
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        comps.append((f"block_{kind}", compiled, mult))

    # Ends: embedding + final norm + loss/logits with a 0-layer config.
    cfg0 = dataclasses.replace(cfgu, n_layers=0)
    p0_sds = jax.eval_shape(lambda: T.init_params(cfg0, jax.random.key(0)))
    p0_sh = jax.tree.map(
        lambda ax: named_sharding(mesh, rules, ax),
        T.param_axes(cfg0),
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    specs = input_specs(cfg0, shape)
    if cell.kind == "train":
        def ends_fn(p, b):
            with axis_rules(rules):
                return T.loss_fn(cfg0, p, b)[0]

        compiled = (
            jax.jit(jax.value_and_grad(ends_fn), in_shardings=(p0_sh, batch_sh))
            .lower(p0_sds, specs["inputs"])
            .compile()
        )
    elif cell.kind == "prefill":
        def ends_fn(p, b):
            with axis_rules(rules):
                return T.prefill(cfg0, p, b, max_len=cell.seq_len)

        compiled = (
            jax.jit(ends_fn, in_shardings=(p0_sh, batch_sh))
            .lower(p0_sds, specs["inputs"])
            .compile()
        )
    else:
        cache0 = jax.eval_shape(lambda: T.init_cache(cfg0, B, S))

        def ends_fn(p, c, tok, pos):
            with axis_rules(rules):
                return T.decode_step(cfg0, p, c, tok, pos)

        compiled = (
            jax.jit(ends_fn, in_shardings=(p0_sh, None, batch_sh, batch_sh))
            .lower(p0_sds, cache0, specs["token"], specs["pos"])
            .compile()
        )
    comps.append(("ends", compiled, 1.0))

    # Optimizer update (train only).
    if cell.kind == "train":
        params_sds = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
        opt_sds = jax.eval_shape(lambda: init_opt_state(params_sds))
        grads_sds = jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params_sds)

        def opt_fn(p, g, o):
            with axis_rules(rules):
                return adamw_update(OPT_CFG, p, g, o)[:2]

        p_sh = jax.tree.map(
            lambda ax: named_sharding(mesh, rules, ax),
            T.param_axes(cfg),
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
        compiled = (
            jax.jit(opt_fn, in_shardings=(p_sh, p_sh, {"mu": p_sh, "nu": p_sh, "step": None}))
            .lower(params_sds, grads_sds, opt_sds)
            .compile()
        )
        comps.append(("optimizer", compiled, 1.0))

    return comps


def model_flops(cfg, shape) -> float:
    cell = SHAPES[shape]
    n_active = cfg.param_count(active_only=True)
    tokens = cell.global_batch * (1 if cell.kind == "decode" else cell.seq_len)
    mult = 6 if cell.kind == "train" else 2
    return float(mult * n_active * tokens)


# ---------------------------------------------------------------------------
# Driver.
# ---------------------------------------------------------------------------
def run_cell(arch, shape, mesh_kind, *, with_components=True, verbose=True,
             seq_parallel=False, decode_opt=False, mesh_shape=None, variant="",
             microbatches=1, kv_quant=False):
    cfg = _tune_cfg(get_config(arch), shape)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    reason = skip_reason(cfg, shape)
    if reason:
        return {
            "arch": arch, "shape": shape, "mesh": mesh_kind,
            "status": "skipped", "note": reason, "variant": variant,
        }
    multi = mesh_kind == "multi_pod"
    if mesh_shape:
        mesh = make_custom_mesh(*mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi)
    rules = make_rules(mesh, seq_parallel=seq_parallel, decode_opt=decode_opt)
    # Small-batch decode (long_500k: global_batch=1): the batch dim cannot
    # cover the data axes; replicate it — seq_kv/TP carry the parallelism.
    cell = SHAPES[shape]
    data_size = int(
        np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"])
    )
    if cell.kind == "decode" and cell.global_batch < data_size:
        from repro.distributed.api import AxisRules

        table = dict(rules.table)
        table["batch"] = None
        table["moe_groups"] = None
        rules = AxisRules(mesh, table)
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    fn, args = build_cell(cfg, shape, mesh, rules, microbatches=microbatches)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
    }
    mem["per_device_total"] = (
        mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"]
        - mem["alias_bytes"]
    )
    hlo = compiled.as_text()
    census = dict(
        Counter(
            m.group(0)
            for m in __import__("re").finditer(
                r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b",
                hlo,
            )
        )
    )
    full_ca = rf.cost_analysis_dict(compiled)

    row = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "variant": variant,
        "chips": chips,
        "global_batch": SHAPES[shape].global_batch,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "collective_census": census,
        "full_step_cost_analysis": {
            "flops": float(full_ca.get("flops", 0)),
            "bytes": float(full_ca.get("bytes accessed", 0)),
        },
    }

    if with_components and mesh_kind == "single_pod":
        comps = []
        for name, compiled_c, mult in cost_components(cfg, shape, mesh, rules):
            comps.append(
                rf.component_from_compiled(name, compiled_c, multiplier=mult)
            )
        totals = rf.combine_components(comps)
        terms = rf.cost_terms(totals, chips)
        mf = model_flops(cfg, shape)
        row.update(
            {
                "terms_s": terms,
                "totals": {k: v for k, v in totals.items() if k != "coll_by_kind"},
                "coll_by_kind": totals["coll_by_kind"],
                "model_flops": mf,
                # cost_analysis is per-device (post-SPMD module)
                "model_flops_over_hlo": mf / max(totals["flops"] * chips, 1.0),
                "dominant": max(terms, key=lambda k: terms[k]),
                "components": [
                    {"name": c.name, "flops": c.flops, "mult": c.multiplier}
                    for c in comps
                ],
            }
        )
    if verbose:
        dom = row.get("dominant", "-")
        print(
            f"[{mesh_kind}] {arch:24s} {shape:12s} compile={t_compile:6.1f}s "
            f"mem/dev={mem['per_device_total']/2**30:6.2f}GiB "
            f"census={census} dom={dom}",
            flush=True,
        )
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--no-components", action="store_true")
    ap.add_argument("--sp", action="store_true", help="sequence-parallel rules")
    ap.add_argument("--decode-opt", action="store_true",
                    help="weight-stationary decode rules")
    ap.add_argument("--mesh-shape", default="", help="e.g. 64x4 (single pod)")
    ap.add_argument("--variant", default="", help="label recorded per row")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {
        "single": ["single_pod"],
        "multi": ["multi_pod"],
        "both": ["single_pod", "multi_pod"],
    }[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    cells = []
    if out_path.exists():
        cells = json.loads(out_path.read_text()).get("cells", [])
    done = {(c["arch"], c["shape"], c["mesh"], c.get("variant", "")) for c in cells}

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                if (arch, shape, mesh_kind, args.variant) in done:
                    continue
                try:
                    mesh_shape = None
                    if args.mesh_shape:
                        d, m = args.mesh_shape.split("x")
                        mesh_shape = (int(d), int(m))
                    row = run_cell(
                        arch, shape, mesh_kind,
                        with_components=not args.no_components,
                        seq_parallel=args.sp,
                        decode_opt=args.decode_opt,
                        mesh_shape=mesh_shape,
                        variant=args.variant,
                    )
                except Exception as e:  # record failures: they are bugs
                    traceback.print_exc()
                    row = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "variant": args.variant,
                        "status": "error", "note": f"{type(e).__name__}: {e}",
                    }
                cells.append(row)
                out_path.write_text(json.dumps({"cells": cells}, indent=1))

    ok = sum(1 for c in cells if c["status"] == "ok")
    skip = sum(1 for c in cells if c["status"] == "skipped")
    err = sum(1 for c in cells if c["status"] == "error")
    print(f"\n=== dry-run: {ok} ok / {skip} skipped / {err} errors -> {out_path}")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
