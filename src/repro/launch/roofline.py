"""Roofline-term derivation from compiled artifacts.

Terms per (arch, shape, mesh), in seconds (v5e constants):

    compute    = HLO_FLOPs / (chips * 197e12)
    memory     = HLO_bytes / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)

Methodology notes (verified empirically in this repo):
  * ``compiled.cost_analysis()`` counts a ``while`` (scan) body ONCE, so the
    full scanned-step compile cannot give total FLOPs.  We therefore compile
    *cost components* — one block per distinct layer kind (fwd or fwd+bwd,
    attention unrolled), the 0-layer ends (embed + final norm + loss/logits),
    and the optimizer update — and combine them weighted by layer counts.
    The full-step compile remains the memory/sharding/collective-schedule
    proof artifact.
  * HLO_FLOPs/bytes from cost_analysis are *global* (all devices); dividing
    by the chip count gives per-chip work assuming perfect balance, which the
    sharding rules guarantee up to GSPMD padding (visible in the
    MODEL_FLOPS/HLO ratio).
  * collective_bytes sums the result-shape bytes of every all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute in the
    post-SPMD HLO (per-device shapes), scaled by the same component weights.
    Dividing by link bandwidth approximates one-hop cost — a lower bound for
    multi-hop rings, stated as such in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

__all__ = [
    "HW",
    "collective_bytes",
    "cost_analysis_dict",
    "cost_terms",
    "CellReport",
    "combine_components",
]

HW = {
    "peak_flops": 197e12,  # bf16/chip
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(\w[\w\d]*)\[([\d,]*)\]\{?[^}]*\}?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from post-SPMD HLO text."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.groups()
        out[kind] = out.get(kind, 0) + _shape_bytes(dtype, dims)
    for m in _TUPLE_COLL_RE.finditer(hlo_text):
        shapes, kind = m.groups()
        total = sum(
            _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes)
        )
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class Component:
    """One compiled cost component with its multiplier."""

    name: str
    flops: float
    bytes_accessed: float
    coll_bytes: Dict[str, int]
    multiplier: float = 1.0


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions.

    Older jax returns a one-element list of per-module dicts; newer jax
    returns the dict directly.  Either way: a (possibly empty) dict.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def component_from_compiled(name: str, compiled, multiplier: float = 1.0) -> Component:
    ca = cost_analysis_dict(compiled)
    return Component(
        name=name,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=collective_bytes(compiled.as_text()),
        multiplier=multiplier,
    )


def combine_components(components) -> Dict[str, float]:
    flops = sum(c.flops * c.multiplier for c in components)
    byts = sum(c.bytes_accessed * c.multiplier for c in components)
    coll = 0.0
    coll_by_kind: Dict[str, float] = {}
    for c in components:
        for k, v in c.coll_bytes.items():
            coll_by_kind[k] = coll_by_kind.get(k, 0.0) + v * c.multiplier
            coll += v * c.multiplier
    return {"flops": flops, "bytes": byts, "coll_bytes": coll,
            "coll_by_kind": coll_by_kind}


def cost_terms(totals: Dict[str, float], chips: int) -> Dict[str, float]:
    """The three roofline terms in seconds.

    cost_analysis flops/bytes are already per-device (post-SPMD module), but
    we treat them as the per-chip stream directly; collective bytes are
    per-device link traffic.
    """
    return {
        "compute_s": totals["flops"] / HW["peak_flops"],
        "memory_s": totals["bytes"] / HW["hbm_bw"],
        "collective_s": totals["coll_bytes"] / HW["ici_bw"],
    }


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    terms_s: Dict[str, float]
    totals: Dict[str, float]
    model_flops: float
    bytes_per_device: Optional[int]
    coll_census: Dict[str, int]  # full-step compile: op kind -> count
    status: str = "ok"
    note: str = ""

    @property
    def dominant(self) -> str:
        return max(self.terms_s, key=lambda k: self.terms_s[k])

    @property
    def useful_ratio(self) -> float:
        hlo = self.totals["flops"] * self.chips
        return self.model_flops / hlo if hlo else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["model_flops_over_hlo"] = self.useful_ratio
        return d
