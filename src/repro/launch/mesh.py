"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get 512 placeholder host devices.
"""
from __future__ import annotations

import jax

from repro.distributed.api import (
    RULES_2D, RULES_2D_DEC, RULES_2D_SP, RULES_3D, RULES_3D_DEC, RULES_3D_SP,
    AxisRules,
)

__all__ = ["make_mesh", "make_production_mesh", "make_rules", "make_elastic_mesh"]


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer jax;
    older versions build Auto-mode meshes unconditionally, so omitting the
    argument there is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_rules(mesh, *, seq_parallel: bool = False,
               decode_opt: bool = False) -> AxisRules:
    if "pod" in mesh.axis_names:
        table = RULES_3D_SP if seq_parallel else (
            RULES_3D_DEC if decode_opt else RULES_3D)
    else:
        table = RULES_2D_SP if seq_parallel else (
            RULES_2D_DEC if decode_opt else RULES_2D)
    return AxisRules(mesh, table)


def make_custom_mesh(data: int, model: int):
    """Arbitrary (data, model) factorization of one pod (hillclimb lever)."""
    return make_mesh((data, model), ("data", "model"))


def make_elastic_mesh(model_parallel: int = 16):
    """Best mesh for *whatever devices are currently alive* (elastic restart).

    Keeps the tensor axis fixed (weights shard layout unchanged) and gives
    every remaining device to data parallelism — restoring a checkpoint onto
    this mesh is a pure re-shard (tests/test_checkpoint.py exercises it).
    """
    n = len(jax.devices())
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    return make_mesh((n // mp, mp), ("data", "model"))
