"""Render results/roofline.json into the EXPERIMENTS.md tables."""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_table(cells, variant=""):
    rows = []
    header = (
        "| arch | shape | mesh | compile | mem/dev | compute | memory | "
        "collective | dominant | useful |"
    )
    rows.append(header)
    rows.append("|" + "---|" * 10)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    sel = [c for c in cells if c.get("variant", "") == variant]
    sel.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9), c["mesh"]))
    for c in sel:
        if c["status"] == "skipped":
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | — | — "
                f"| N/A | {c['note'][:42]} |"
            )
            continue
        if c["status"] == "error":
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | ERR | — | — | — | — "
                f"| — | {c['note'][:42]} |"
            )
            continue
        mem = c["memory"]["per_device_total"] / 2**30
        if "terms_s" in c:
            t = c["terms_s"]
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['compile_s']:.0f}s "
                f"| {mem:.1f}G | {t['compute_s']*1e3:.0f}ms | {t['memory_s']*1e3:.0f}ms "
                f"| {t['collective_s']*1e3:.0f}ms | {c['dominant'].split('_')[0]} "
                f"| {c['model_flops_over_hlo']*100:.0f}% |"
            )
        else:
            census = ",".join(f"{k.split('-')[-1]}:{v}" for k, v in
                              sorted(c["collective_census"].items()))
            rows.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['compile_s']:.0f}s "
                f"| {mem:.1f}G | — | — | — | compiled | {census[:40]} |"
            )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/roofline.json")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()
    cells = json.loads(Path(args.json).read_text())["cells"]
    print(fmt_table(cells, args.variant))


if __name__ == "__main__":
    main()
