"""End-to-end training driver with fault tolerance.

Features exercised:
  * resume-from-checkpoint (atomic saves, async writer),
  * deterministic data resumption (counter-based pipeline keyed by step),
  * elastic restart (reshard-on-restore onto whatever mesh is alive),
  * failure injection (--inject-failure N kills the process at step N; a
    relaunch must continue bit-identically — tests/test_train_driver.py),
  * optional int8 gradient compression with error feedback.

CPU-scale by default (reduced configs); the same driver lowers the full
configs on the production mesh via --mesh production (see dryrun for the
compile-only path).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 100
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.launch.mesh import make_elastic_mesh, make_rules
from repro.training import (
    DataConfig,
    OptimizerConfig,
    TrainConfig,
    init_train_state,
    make_pipeline,
    make_train_step,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--d-model", type=int, default=256, help="reduced width")
    ap.add_argument("--layers", type=int, default=0, help="0 = family default")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.full_config:
        cfg = get_config(args.arch)
    else:
        over = dict(d_model=args.d_model, head_dim=max(32, args.d_model // 8))
        if args.layers:
            over["n_layers"] = args.layers
        cfg = reduced(args.arch, **over)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    opt_cfg = OptimizerConfig(
        learning_rate=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
        total_steps=args.steps,
    )
    train_cfg = TrainConfig(
        microbatches=args.microbatches, grad_compression=args.grad_compression
    )
    mesh = rules = None
    if len(jax.devices()) > 1:
        mesh = make_elastic_mesh()
        rules = make_rules(mesh)
    step_fn = make_train_step(cfg, opt_cfg, train_cfg, mesh=mesh, rules=rules)
    pipe = make_pipeline(
        DataConfig(batch_size=args.batch, seq_len=args.seq, seed=args.seed), cfg
    )

    state = init_train_state(cfg, jax.random.key(args.seed), train_cfg)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if mgr.latest_step() is not None:
            state, start_step = mgr.restore(state)
            print(f"resumed from checkpoint at step {start_step}")

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(
                f"step {step:5d}  loss {losses[-1]:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  {dt:.1f}s",
                flush=True,
            )
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, state)
        if args.inject_failure and step + 1 == args.inject_failure:
            print(f"!!! injected failure at step {step + 1}", flush=True)
            if mgr:
                mgr.wait()
            sys.exit(42)

    if mgr:
        mgr.save(args.steps, state)
        mgr.wait()
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"done: loss {first:.4f} -> {last:.4f} over {len(losses)} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
