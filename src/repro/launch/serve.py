"""End-to-end serving driver: MDInference over real model variants.

Builds N functionally-equivalent LM tiers (tiny reduced configs at
different widths/depths on CPU), measures their real latency profiles
(Table III methodology), then serves an open-loop request stream with
continuous batching: arrivals come from a Poisson (or bursty) load
generator over a network model, each scheduling window is decided in one
batched scheduler call, requests that picked the same tier execute as one
real ``generate`` batch, and the hedge tier bounds every response at the
SLA.

Two-tier execution: the remote tiers run on a ``JitBackend``; the hedge
duplicate runs *for real* on an ``OnDeviceBackend`` (the zoo's tiny
hedge-xs variant), so duplication resolves on measured wall time.
``--hedge sampled`` falls back to the profile-sampled simulation of the
duplicate (the pre-backend reference behavior).

The serving front is the event-loop API (``ServingLoop.drain_trace``):
each arrival window becomes one tick, and with ``--dispatch async`` (the
default) the remote batch and the on-device duplicate are dispatched
concurrently — the race resolves on overlapping wall clocks.
``--dispatch sync`` serializes the tiers (the deterministic fallback).

Overload hardening: ``--max-pending``/``--max-chunk`` put a bounded
admission queue in front of the loop and ``--overload-policy`` picks what
happens at capacity (``block`` backpressure, ``shed`` deadline-aware
rejection, ``degrade`` on-device-only service).  ``--overload 2
--service-ms 5`` drives a sustained 2x overload against a service-coupled
clock — the adversarial input that makes the policies differ.

Multi-tenant QoS: ``--tenants 'interactive:4,batch:1:batch:32'`` splits
admission into deficit-weighted-fair per-tenant lanes (strict
interactive-over-batch priority, per-lane capacity) and drives a tagged
two-lane traffic mix — the batch flood is absorbed by its own lane's shed
rate instead of the interactive tenant's p99.  ``--stream`` (with
``--continuous``) demonstrates token streaming: one request consumed chunk
by chunk as the persistent decode batch emits tokens.

Adaptivity under drift: ``--controller`` attaches an
``AdmissionController`` that retunes ``--max-pending`` and the shed
margin each tick from the live queue-wait/shed/service signals (clamped
AIMD with hysteresis); ``--replica-spec '2:8:0.5,1'`` declares a
heterogeneous pool (per-replica weight / soft concurrency cap / service
scale) that the load-aware routers account for.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --requests 50 --sla 2000
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import numpy as np

from repro.configs import reduced
from repro.core.network import NAMED_TRACES, LognormalNetwork
from repro.models import transformer as T
from repro.observability.quantile import quantile
from repro.serving.admission import OVERLOAD_POLICIES, AdmissionConfig
from repro.serving.backend import JitBackend, OnDeviceBackend
from repro.serving.cluster import (
    ROUTERS,
    ClusterBackend,
    parse_replica_specs,
    shard_slices,
)
from repro.serving.controller import AdmissionController, ControllerConfig
from repro.serving.transport import ProcessTransportBackend
from repro.serving.engine import ServingEngine, Variant
from repro.serving.loadgen import (
    BurstyArrivals,
    MixedTenantArrivals,
    OverloadArrivals,
    PoissonArrivals,
    make_trace,
)
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig
from repro.serving.tenancy import parse_tenant_spec

TIERS = (
    # (name, arch family, width, layers, quality-proxy)
    ("tier-s", "gemma-2b", 64, 2, 42.0),
    ("tier-m", "llama3-8b", 128, 4, 68.0),
    ("tier-l", "qwen3-14b", 256, 6, 77.0),
)


def _jit_backend_factory(max_len: int) -> JitBackend:
    """Top-level (picklable) backend factory for the process transport."""
    return JitBackend(max_len)


def _export_observability(obs, trace_out, metrics_out) -> None:
    """Write the run's trace/metrics exports (no-op with tracing off)."""
    if obs is None:
        return
    from repro.observability import (
        request_conservation,
        write_chrome_trace,
        write_jsonl_spans,
        write_prometheus,
    )

    if trace_out is not None:
        write_chrome_trace(trace_out, obs.tracer)
        write_jsonl_spans(trace_out + ".spans.jsonl", obs.tracer)
        audit = request_conservation(obs.tracer)
        balanced = (
            audit["open"] == 0
            and audit["extra_terminals"] == 0
            and audit["submitted"]
            == audit["resolved"] + audit["rejected"] + audit["cancelled"]
        )
        print(
            f"trace             : {len(obs.tracer)} spans -> {trace_out} "
            f"(conservation {'ok' if balanced else f'VIOLATED {audit}'})"
        )
    if metrics_out is not None:
        write_prometheus(metrics_out, obs.metrics)
        print(f"metrics           : prometheus text -> {metrics_out}")


def build_engine(
    max_len: int, seed: int = 0, measured_hedge: bool = True,
    dispatch: str = "async", replicas: int = 1, router: str = "round_robin",
    shard_zoo: bool = False, transport: str = "none",
    geometry=None, specs=None,
) -> ServingEngine:
    hedge = (
        OnDeviceBackend.from_zoo(max_len=max_len, seed=seed)
        if measured_hedge
        else None
    )
    if geometry is not None:
        # Continuous-batching remote tier: fixed-shape compiled
        # prefill/decode entries over a block-paged slot cache; requests
        # join the persistent decode batch at step boundaries.
        engine = ServingEngine(
            max_len=max_len, hedge_backend=hedge, dispatch=dispatch,
            continuous=True, geometry=geometry,
        )
        for name, arch, width, layers, quality in TIERS:
            cfg = reduced(
                arch, d_model=width, n_layers=layers,
                n_heads=4, n_kv_heads=2, head_dim=width // 4,
            )
            params = T.init_params(cfg, jax.random.key(seed))
            engine.register(Variant(name, cfg, params, quality))
        return engine
    # With --replicas > 1 (or --shard-zoo / --transport) the remote tier
    # becomes a replicated cluster behind the same execution protocol; the
    # hedge tier stays the device-side singleton outside the pool.
    backend = None
    if replicas > 1 or shard_zoo or transport != "none":
        slices = (
            shard_slices([t[0] for t in TIERS], replicas)
            if shard_zoo
            else None
        )

        def make_replica():
            if transport == "none":
                return JitBackend(max_len)
            # inline: same process, but with the transport's fault surface
            # (kill/inject); process: a real spawned worker per replica.
            return ProcessTransportBackend(
                functools.partial(_jit_backend_factory, max_len),
                mode=transport, max_len=max_len,
            )

        backend = ClusterBackend(
            [make_replica() for _ in range(replicas)],
            router=router, slices=slices, seed=seed, specs=specs,
        )
    engine = ServingEngine(
        max_len=max_len, backend=backend, hedge_backend=hedge,
        dispatch=dispatch,
    )
    for name, arch, width, layers, quality in TIERS:
        cfg = reduced(
            arch, d_model=width, n_layers=layers,
            n_heads=4, n_kv_heads=2, head_dim=width // 4,
        )
        params = T.init_params(cfg, jax.random.key(seed))
        engine.register(Variant(name, cfg, params, quality))
    return engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--sla", type=float, default=2000.0, help="ms")
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument(
        "--network", default="lognormal",
        choices=["lognormal", *NAMED_TRACES],
        help="network-time model for the trace",
    )
    ap.add_argument("--net-mean", type=float, default=300.0)
    ap.add_argument("--net-cv", type=float, default=0.6)
    ap.add_argument("--rate", type=float, default=20.0, help="arrival rate rps")
    ap.add_argument("--bursty", action="store_true", help="MMPP bursts")
    ap.add_argument("--overload", type=float, default=0.0, metavar="FACTOR",
                    help="sustained overload phase at FACTOR x the base "
                    "rate over the middle half of the stream")
    ap.add_argument("--window", type=float, default=200.0,
                    help="scheduling-tick window (ms)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bounded admission queue capacity (default: "
                    "unbounded, the pre-admission behavior)")
    ap.add_argument("--max-chunk", type=int, default=None,
                    help="per-tick scheduling cap; leftovers stay queued "
                    "across ticks")
    ap.add_argument("--overload-policy", default="unbounded",
                    choices=list(OVERLOAD_POLICIES),
                    help="what happens at max-pending capacity: block "
                    "(client backpressure), shed (deadline-aware REJECTED), "
                    "degrade (on-device tier alone); requires --max-pending")
    ap.add_argument("--service-ms", type=float, default=0.0,
                    help="per-request service-time model coupled into the "
                    "loop clock (0: uncoupled windows-only clock); makes "
                    "overload build real queue wait")
    ap.add_argument(
        "--hedge", default="measured", choices=["measured", "sampled"],
        help="resolve duplicates on real hedge-tier wall time (measured) "
        "or on-device profile samples (sampled)",
    )
    ap.add_argument(
        "--dispatch", default="async", choices=["async", "sync", "stepped"],
        help="dispatch the tiers' batches concurrently (async), "
        "serialized (sync, the deterministic fallback), or stepped "
        "(continuous-batching decode clock; implied by --continuous)",
    )
    ap.add_argument("--continuous", action="store_true",
                    help="serve the remote tier with cross-tick continuous "
                    "batching: fixed-shape compiled prefill/decode entry "
                    "points (no post-warmup recompiles) over a block-paged "
                    "slot cache; requests join the persistent decode batch "
                    "at step boundaries and slots recycle on early "
                    "resolution")
    ap.add_argument("--bs-ladder", default="1,2,4,8", metavar="N,N,...",
                    help="prefill batch-size ladder for --continuous: "
                    "sorted powers of two; submissions decompose onto "
                    "these pre-compiled shapes (default 1,2,4,8)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="remote-tier replica count: >1 serves through a "
                    "ClusterBackend pool with load-aware routing")
    ap.add_argument("--router", default="round_robin",
                    choices=list(ROUTERS),
                    help="cluster routing policy (with --replicas > 1): "
                    "round_robin, least_inflight (join-shortest-queue), "
                    "power_of_two (2 random replicas, pick by live "
                    "latency EWMA)")
    ap.add_argument("--replica-spec", default=None, metavar="SPEC",
                    help="heterogeneous replica pool (with --replicas > 1): "
                    "'weight[:max_concurrency[:service_scale]],...' — one "
                    "entry per replica, empty fields keep the default, e.g. "
                    "'2:8:0.5,1' (a double-weight box capped at 8 inflight "
                    "rows that runs 2x fast, next to a stock one).  Routers "
                    "normalize queue depth by weight and treat "
                    "max_concurrency as a soft routing cap")
    ap.add_argument("--controller", action="store_true",
                    help="close the loop on admission: an "
                    "AdmissionController reads each tick's queue-wait / "
                    "shed / service signals and retunes --max-pending and "
                    "the shed margin via a clamped AIMD law with "
                    "hysteresis (requires --max-pending; without this "
                    "flag the static config is served byte-identically)")
    ap.add_argument("--controller-target-frac", type=float, default=0.2,
                    metavar="FRAC",
                    help="controller setpoint: target queue wait as a "
                    "fraction of --sla (default 0.2)")
    ap.add_argument("--shard-zoo", action="store_true",
                    help="shard the model zoo across replicas (disjoint "
                    "slices, one backend per slice) instead of full "
                    "replication; selection is constrained to hosted "
                    "variants and routing respects placement")
    ap.add_argument("--transport", default="none",
                    choices=["none", "inline", "process"],
                    help="replica transport: none (in-process backends, "
                    "the default), inline (in-process with the transport's "
                    "kill/fault surface), process (each replica's backend "
                    "in a spawned worker — a real failure domain)")
    ap.add_argument("--kill-replica-at", type=float, default=None,
                    metavar="MS",
                    help="fault injection: kill one replica at this "
                    "loop-clock time; its breaker trips permanently, "
                    "in-flight rows requeue/fail over, routing continues "
                    "on the survivors (requires --replicas > 1 unless you "
                    "want the whole chunk degraded on-device)")
    ap.add_argument("--kill-replica", type=int, default=0, metavar="ID",
                    help="which replica --kill-replica-at kills")
    ap.add_argument("--rejoin-replica-at", type=float, default=None,
                    metavar="MS",
                    help="bring the killed replica back at this loop-clock "
                    "time (breaker reset + transport restart)")
    ap.add_argument("--tenants", default=None, metavar="SPEC",
                    help="multi-tenant QoS lanes: "
                    "'name[:weight[:class[:max_pending]]],...' (class is "
                    "interactive|batch), e.g. "
                    "'interactive:4,batch:1:batch:32'.  Admission drains "
                    "the lanes deficit-weighted-fair with strict "
                    "interactive-over-batch priority; the trace becomes a "
                    "tagged two-lane mix (interactive at --rate, a batch "
                    "flood at 4x --rate, or --overload x when higher)")
    ap.add_argument("--stream", action="store_true",
                    help="demonstrate token streaming before the trace: "
                    "submit one request and print each StreamChunk as the "
                    "continuous tier's decode steps emit it (requires "
                    "--continuous)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable tracing and write a Chrome trace_event "
                    "JSON timeline (chrome://tracing / Perfetto) of the "
                    "whole run to PATH; PATH.spans.jsonl gets the raw span "
                    "sink (without this flag — and --metrics-out — the "
                    "stack runs untraced, byte-identical to before)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable metrics and write a Prometheus-style text "
                    "exposition of every counter/gauge/histogram to PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    tenants = None
    if args.tenants:
        try:
            tenants = parse_tenant_spec(args.tenants)
        except ValueError as e:
            ap.error(f"--tenants: {e}")
    tenant_bounded = any(t.max_pending is not None for t in tenants or ())
    if (
        args.overload_policy != "unbounded"
        and args.max_pending is None
        and not tenant_bounded
    ):
        ap.error(
            f"--overload-policy {args.overload_policy} requires "
            "--max-pending (the capacity whose overflow it governs) or a "
            "--tenants spec with a per-lane max_pending"
        )
    if args.stream and not args.continuous:
        ap.error("--stream requires --continuous (the streaming decode tier)")

    if args.replicas < 1:
        ap.error("--replicas must be >= 1")

    specs = None
    if args.replica_spec is not None:
        if args.replicas <= 1:
            ap.error("--replica-spec needs a pool (--replicas > 1)")
        try:
            specs = parse_replica_specs(args.replica_spec, args.replicas)
        except ValueError as e:
            ap.error(f"--replica-spec: {e}")

    controller = None
    if args.controller:
        if args.max_pending is None and not tenant_bounded:
            ap.error(
                "--controller retunes a bounded queue; give it "
                "--max-pending (the knob it drives)"
            )
        try:
            controller = AdmissionController(
                ControllerConfig(target_wait_frac=args.controller_target_frac)
            )
        except ValueError as e:
            ap.error(f"--controller-target-frac: {e}")

    geometry = None
    dispatch = args.dispatch
    if args.continuous:
        if args.replicas > 1 or args.shard_zoo or args.transport != "none":
            ap.error(
                "--continuous replaces the remote tier with the "
                "continuous-batching backend; it cannot combine with "
                "--replicas/--shard-zoo/--transport"
            )
        from repro.configs.mdinference_zoo import ServingGeometry

        try:
            ladder = tuple(int(x) for x in args.bs_ladder.split(","))
        except ValueError:
            ap.error(f"--bs-ladder must be comma-separated ints, "
                     f"got {args.bs_ladder!r}")
        page = 8
        prompt_width = -(-args.prompt // page) * page  # round up to pages
        try:
            geometry = ServingGeometry(
                max_len=args.prompt + args.gen + 8,
                prompt_width=prompt_width,
                bs_ladder=ladder,
                n_slots=max(ladder),
                page_size=page,
                max_steps=args.gen,
            )
        except ValueError as e:
            ap.error(f"--bs-ladder: {e}")
        if dispatch == "async":
            dispatch = "stepped"  # the continuous tier's native clock

    measured = args.hedge == "measured"
    print("building + profiling tiers (real execution)...")
    engine = build_engine(
        max_len=args.prompt + args.gen + 8, seed=args.seed,
        measured_hedge=measured, dispatch=dispatch,
        replicas=args.replicas, router=args.router, shard_zoo=args.shard_zoo,
        transport=args.transport, geometry=geometry, specs=specs,
    )
    cluster = engine.backend if isinstance(engine.backend, ClusterBackend) else None
    if args.kill_replica_at is not None and cluster is None:
        ap.error("--kill-replica-at needs a cluster (--replicas/--transport)")
    if cluster is not None:
        print(
            f"cluster: {cluster.n_replicas} replicas, router={args.router}, "
            f"transport={args.transport}"
        )
        for snap in cluster.snapshot():
            hw = ""
            if specs is not None:
                cap = (
                    "inf" if snap.max_concurrency is None
                    else snap.max_concurrency
                )
                hw = (
                    f" weight={snap.weight:g} cap={cap} "
                    f"scale={snap.service_scale:g}"
                )
            print(f"  replica {snap.replica_id}: hosts {list(snap.hosts)}{hw}")
    registry = engine.measure_profiles(
        prompt_len=args.prompt, gen_tokens=args.gen, trials=3, seed=args.seed
    )
    for p in registry:
        print(f"  {p.name:8s} quality={p.accuracy:5.1f} "
              f"mu={p.mu_ms:8.1f}ms sigma={p.sigma_ms:6.1f}ms")
    if measured:
        ondevice = engine.hedge_backend.measure_profile(
            prompt_len=args.prompt, gen_tokens=args.gen, trials=3,
            seed=args.seed,
        )
        print(f"  hedge tier (on-device, real): {ondevice.name} "
              f"quality={ondevice.accuracy:5.1f} mu={ondevice.mu_ms:8.1f}ms")
    else:
        ondevice = registry[int(np.argmin(registry.mu))]
        print(f"  hedge tier (sampled profile): {ondevice.name}")

    compiles_after_warmup = 0
    if args.continuous:
        engine.backend.warmup()
        compiles_after_warmup = engine.backend.compile_count
        print(
            f"continuous tier: ladder={geometry.bs_ladder} "
            f"n_slots={geometry.n_slots} page_size={geometry.page_size} "
            f"compiled executables={compiles_after_warmup} (fixed from here)"
        )
        if measured:
            # Pre-warm the hedge tier at every pow2 tick shape it can see:
            # its first inline compile otherwise burns real wall-clock SLA
            # budget mid-race and spuriously releases hedged slots.
            N = 1
            while N <= geometry.n_slots:
                engine.hedge_backend.run_batch(
                    engine.hedge_backend.hedge_name,
                    np.zeros((N, args.prompt), np.int32), args.gen,
                )
                N *= 2

    sched = MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=args.sla, seed=args.seed)
    )
    if args.network == "lognormal":
        network = LognormalNetwork(args.net_mean, args.net_cv)
    else:
        network = NAMED_TRACES[args.network]()
    if tenants is not None:
        # Tagged two-lane mix: the first interactive-class tenant gets a
        # Poisson lane at the base rate; the first batch-class tenant (or
        # the last tenant) floods at 4x (or the --overload factor).
        interactive = next(
            (t.name for t in tenants if t.priority == "interactive"),
            tenants[0].name,
        )
        batch = next(
            (t.name for t in tenants if t.priority == "batch"),
            tenants[-1].name,
        )
        arrivals = MixedTenantArrivals(
            interactive_rps=args.rate,
            batch_rps=args.rate * max(args.overload, 4.0),
            interactive_tenant=interactive,
            batch_tenant=batch,
        )
    elif args.overload > 0:
        arrivals = OverloadArrivals(args.rate, overload_factor=args.overload)
    elif args.bursty:
        arrivals = BurstyArrivals(args.rate)
    else:
        arrivals = PoissonArrivals(args.rate)
    trace = make_trace(args.requests, arrivals, network, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, 256, (args.requests, args.prompt))

    # The event-loop serving front: each arrival window becomes one tick
    # (fired at the window's close — the wait until then is charged against
    # each request's budget and latency); within a tick every tier's batch
    # is dispatched before any is awaited.  --max-pending/--overload-policy
    # put the bounded admission queue with backpressure in front of it.
    policy = args.overload_policy
    if policy == "unbounded" and args.max_pending is not None:
        policy = "block"  # a bound without a policy means backpressure
    admission = AdmissionConfig(
        max_pending=args.max_pending,
        max_chunk=args.max_chunk,
        policy=policy,
        tenants=tenants,
    )

    if args.stream:
        # One request through its own loop (so the demo's completion does
        # not pollute the trace metrics), consumed chunk by chunk as the
        # decode steps emit tokens.
        from repro.serving.client import InferenceClient

        demo_loop = engine.make_loop(sched)
        fut = InferenceClient(demo_loop).submit(
            prompts[0], args.gen, sla=args.sla
        )
        print("streaming demo: tokens as the decode steps emit them")
        first_wall = None
        for chunk in fut.stream():
            if first_wall is None:
                first_wall = chunk.wall_ms
            print(
                f"  chunk[{chunk.index}] token={chunk.token:5d} "
                f"+{chunk.wall_ms - first_wall:7.2f}ms"
            )
        c = fut.result()
        ttft = "n/a" if c.ttft_ms is None else f"{c.ttft_ms:.2f}ms"
        print(
            f"  resolved: {len(fut.chunks)} chunks ttft={ttft} "
            f"exec={c.exec_ms:.1f}ms"
        )

    observability = None
    if args.trace_out is not None or args.metrics_out is not None:
        from repro.observability import Observability

        observability = Observability()

    loop = engine.make_loop(
        sched, admission=admission, controller=controller,
        observability=observability,
    )
    # Server service time covers the remote-scheduled rows only: the
    # degrade lane executes on the device, so it costs the device — not
    # the server's clock (that offload is the degrade policy's point).
    # Replicas serve in parallel, so a tick's makespan is the busiest
    # replica's rows (== the whole tick on a single backend).
    service_model = (
        (lambda res: args.service_ms * res.stats.max_replica_rows)
        if args.service_ms > 0
        else None
    )

    fault = {"killed": False, "rejoined": False}

    def drive_faults(tick_ms):
        # Loop-clock fault schedule: kill (and optionally rejoin) between
        # ticks, exactly where an operator action would land.
        if (
            args.kill_replica_at is not None
            and not fault["killed"]
            and tick_ms >= args.kill_replica_at
        ):
            cluster.kill_replica(args.kill_replica, reason="operator kill")
            fault["killed"] = True
            print(f"tick t={tick_ms:7.0f}ms !! killed replica {args.kill_replica}")
        if (
            args.rejoin_replica_at is not None
            and fault["killed"]
            and not fault["rejoined"]
            and tick_ms >= args.rejoin_replica_at
        ):
            cluster.rejoin(args.kill_replica)
            fault["rejoined"] = True
            print(f"tick t={tick_ms:7.0f}ms !! rejoined replica {args.kill_replica}")

    def on_tick(tick_ms, res):
        if cluster is not None:
            drive_faults(tick_ms)
        if res.stats.n_lost:
            print(
                f"tick t={tick_ms:7.0f}ms !! lost {res.stats.n_lost} rows "
                f"to a failed replica ({res.stats.n_requeued} requeued, "
                f"{res.stats.n_lost - res.stats.n_requeued} hedge-failover)"
            )
        if not res.completions:
            print(
                f"tick t={tick_ms:7.0f}ms batch=  0 "
                f"shed={res.stats.n_shed} (all rejected)"
            )
            return
        c = res.completions[0]
        overlap = ""
        if res.stats.hedge_wall_ms is not None:
            saved = 1.0 - res.stats.span_wall_ms / res.stats.serialized_wall_ms
            overlap = f" overlap={saved*100:4.0f}%"
        overload = ""
        if res.stats.n_shed or res.stats.n_degraded:
            overload = (
                f" shed={res.stats.n_shed} degraded={res.stats.n_degraded}"
            )
        print(
            f"tick t={tick_ms:7.0f}ms batch={len(res.completions):3d} "
            f"models={{{', '.join(sorted({d.model_name for d in res.completions}))}}} "
            f"first: wait+nw={c.remote_ms - c.exec_ms:5.0f}ms -> {c.model_name:8s} "
            f"exec={c.exec_ms:7.1f}ms "
            f"{'remote' if c.used_remote else 'HEDGED'}{overlap}{overload}"
        )

    t_start = time.time()
    completions, metrics = loop.drain_trace(
        trace, args.window,
        tokens_for=lambda i: prompts[i], n_steps=args.gen, on_tick=on_tick,
        service_model=service_model,
    )

    if not completions:  # every request shed: only overload accounting
        print(
            f"\nserved 0 of {args.requests} requests "
            f"(policy={policy}, shed_rate={metrics.shed_rate*100:.1f}%, "
            f"goodput={metrics.goodput*100:.1f}%) — every request was "
            "rejected by admission; loosen --sla or --max-pending"
        )
        _export_observability(observability, args.trace_out, args.metrics_out)
        return 0
    lats = np.asarray([c.latency_ms for c in completions])
    waits = np.asarray([c.queue_wait_ms for c in completions])
    hedge_note = (
        f"measured on-device wall (live profile mu={sched.ondevice_mu:.1f}ms)"
        if measured
        else "profile-sampled simulation"
    )
    races = " ".join(
        f"{k}={v*100:.0f}%" for k, v in metrics.race_resolution.items()
    )
    admission_note = ""
    if metrics.n_rejected or policy != "unbounded":
        admission_note = (
            f"admission         : policy={policy} "
            f"max_pending={args.max_pending} shed_rate={metrics.shed_rate*100:.1f}% "
            f"goodput={metrics.goodput*100:.1f}%\n"
        )
    controller_note = ""
    if controller is not None:
        cfg_now = loop.admission.cfg
        controller_note = (
            f"controller        : retunes={controller.n_retunes} "
            f"final max_pending={cfg_now.max_pending} "
            f"shed_headroom={cfg_now.shed_headroom_ms:.0f}ms "
            f"(setpoint {args.controller_target_frac:.2f}x sla)\n"
        )
    tenancy_note = ""
    if metrics.tenant_rows:
        lanes = "\n".join(
            f"  lane {name:12s} [{row.priority:11s}] "
            f"share={row.share*100:5.1f}% shed={row.shed_rate*100:5.1f}% "
            f"goodput={row.goodput*100:5.1f}% p99={row.p99_latency_ms:7.1f}ms"
            for name, row in sorted(metrics.tenant_rows.items())
        )
        p99s = " ".join(
            f"{cls}={v:.0f}ms" for cls, v in sorted(metrics.priority_p99.items())
        )
        tenancy_note = f"tenancy           : class p99 {p99s}\n{lanes}\n"
    cluster_note = ""
    if metrics.replica_rows:
        shares = " ".join(
            f"r{rid}={row.share*100:.0f}%(util={row.utilization:.2f})"
            for rid, row in sorted(metrics.replica_rows.items())
        )
        cluster_note = (
            f"cluster           : {args.replicas} replicas "
            f"router={args.router} served {shares}\n"
        )
    print(
        f"\nserved {len(completions)} requests in {time.time()-t_start:.1f}s wall "
        f"(offered {trace.offered_rps:.1f} rps, dispatch={dispatch})\n"
        f"aggregate quality : {metrics.aggregate_accuracy:.2f}\n"
        f"SLA attainment    : {np.mean(lats <= args.sla)*100:.1f}%  "
        f"(duplication bounds post-dispatch latency at the SLA; only queue "
        f"wait can breach it)\n"
        f"hedge reliance    : {metrics.ondevice_reliance*100:.1f}%  "
        f"[{hedge_note}]\n"
        f"race resolution   : {races}\n"
        f"{admission_note}"
        f"{controller_note}"
        f"{tenancy_note}"
        f"{cluster_note}"
        f"queue wait        : mean {waits.mean():.0f}ms  max {waits.max():.0f}ms  "
        f"(time-to-schedule mean {metrics.mean_time_to_schedule_ms:.0f}ms)\n"
        f"p50/p99 latency   : {quantile(lats, 50):.0f}/{quantile(lats, 99):.0f} ms"
    )
    if args.continuous:
        growth = engine.backend.compile_count - compiles_after_warmup
        ttfts = np.asarray(
            [c.ttft_ms for c in completions if c.ttft_ms is not None]
        )
        ttft_note = (
            f"ttft p50/p99={quantile(ttfts, 50):.1f}/"
            f"{quantile(ttfts, 99):.1f}ms "
            if ttfts.size
            else ""
        )
        engine.backend.check_conservation()
        print(
            f"continuous tier   : joined={engine.backend.joined_total} "
            f"recycled={engine.backend.recycled_total} {ttft_note}"
            f"post-warmup recompiles={growth} (conservation ok)"
        )
    _export_observability(observability, args.trace_out, args.metrics_out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
