"""End-to-end serving driver: MDInference over real model variants.

Builds N functionally-equivalent LM tiers (tiny reduced configs at
different widths/depths on CPU), measures their real latency profiles
(Table III methodology), then serves a Poisson request stream: per request
the scheduler estimates the network time, budgets, selects a tier
(3-stage algorithm), executes *real* generation on the selected tier, and
hedges with the fastest tier to bound latency at the SLA.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --requests 50 --sla 2000
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced
from repro.core.duplication import resolve_duplication
from repro.core.network import LognormalNetwork
from repro.models import transformer as T
from repro.serving.engine import ServingEngine, Variant
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

TIERS = (
    # (name, arch family, width, layers, quality-proxy)
    ("tier-s", "gemma-2b", 64, 2, 42.0),
    ("tier-m", "llama3-8b", 128, 4, 68.0),
    ("tier-l", "qwen3-14b", 256, 6, 77.0),
)


def build_engine(max_len: int, seed: int = 0) -> ServingEngine:
    engine = ServingEngine(max_len=max_len)
    for name, arch, width, layers, quality in TIERS:
        cfg = reduced(
            arch, d_model=width, n_layers=layers,
            n_heads=4, n_kv_heads=2, head_dim=width // 4,
        )
        params = T.init_params(cfg, jax.random.key(seed))
        engine.register(Variant(name, cfg, params, quality))
    return engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--sla", type=float, default=2000.0, help="ms")
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--net-mean", type=float, default=300.0)
    ap.add_argument("--net-cv", type=float, default=0.6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    print("building + profiling tiers (real execution)...")
    engine = build_engine(max_len=args.prompt + args.gen + 8, seed=args.seed)
    registry = engine.measure_profiles(
        prompt_len=args.prompt, gen_tokens=args.gen, trials=3, seed=args.seed
    )
    for p in registry:
        print(f"  {p.name:8s} quality={p.accuracy:5.1f} "
              f"mu={p.mu_ms:8.1f}ms sigma={p.sigma_ms:6.1f}ms")
    fastest = registry[int(np.argmin(registry.mu))]

    sched = MDInferenceScheduler(
        registry, fastest, SchedulerConfig(t_sla_ms=args.sla, seed=args.seed)
    )
    net = LognormalNetwork(args.net_mean, args.net_cv)
    rng = np.random.default_rng(args.seed)
    t_nw = net.sample(rng, args.requests)

    used_acc, lats, remote_used = [], [], 0
    t_start = time.time()
    for i in range(args.requests):
        decision = sched.decide(float(t_nw[i]))
        tokens = rng.integers(0, 256, (1, args.prompt))
        _, exec_ms = engine.generate(decision.model_name, tokens, args.gen)
        sched.observe(decision.model_index, exec_ms)
        remote_ms = t_nw[i] + exec_ms
        # Hedge: the fastest tier runs in parallel (its profile is its cost).
        ondev_ms = max(rng.normal(fastest.mu_ms, fastest.sigma_ms), 0.1)
        out = resolve_duplication(
            np.asarray([remote_ms]),
            np.asarray([sched.accuracy[decision.model_index]]),
            np.asarray([ondev_ms]),
            fastest.accuracy,
            args.sla,
        )
        used_acc.append(out.accuracy[0])
        lats.append(out.latency_ms[0])
        remote_used += int(out.used_remote[0])
        if i < 10 or i % 10 == 0:
            print(
                f"req {i:3d} nw={t_nw[i]:6.0f}ms -> {decision.model_name:8s} "
                f"exec={exec_ms:7.1f}ms {'remote' if out.used_remote[0] else 'HEDGED'}"
            )

    lats = np.asarray(lats)
    print(
        f"\nserved {args.requests} requests in {time.time()-t_start:.1f}s wall\n"
        f"aggregate quality : {np.mean(used_acc):.2f}\n"
        f"SLA attainment    : {np.mean(lats <= args.sla)*100:.1f}%  "
        f"(duplication bounds every response at the SLA)\n"
        f"hedge reliance    : {(1 - remote_used/args.requests)*100:.1f}%\n"
        f"p50/p99 latency   : {np.percentile(lats,50):.0f}/{np.percentile(lats,99):.0f} ms"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
