"""Pallas TPU decode-attention kernel (one new token vs. a ring-buffer cache).

Decode attention is purely memory-bound: each step streams the whole KV
cache from HBM once and does O(S * D) FLOPs.  The kernel tiles the cache
sequence dimension; the grid is (batch, kv_heads, n_k_blocks) with the
k-block dimension sequential, and the (G, D) query group plus the online
softmax state live in VMEM — so each cache byte is read exactly once
(HBM-roofline optimal).

Mask semantics match ``repro.models.attention.decode_attention``: slots carry
absolute positions (ring buffers), masked by validity / causality / window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["decode_attention_fwd"]

_NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, window: int, n_k_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, D)
    v = v_ref[0, 0].astype(jnp.float32)
    sp = sp_ref[0]  # (block_k,) absolute positions (-1 = empty)
    pos = pos_ref[0]  # scalar query position

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, block_k)
    ok = (sp >= 0) & (sp <= pos)
    if window:
        ok &= sp > (pos - window)
    s = jnp.where(ok[None, :], s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q, k_cache, v_cache, slot_pos, pos, *,
    window: int = 0,
    scale=None,
    block_k: int = 512,
    interpret: bool = False,
):
    """q: (B, NKV, G, D); caches: (B, NKV, S, D); slot_pos: (B, S); pos: (B,).

    Returns (B, NKV, G, D).
    """
    B, NKV, G, D = q.shape
    S = k_cache.shape[2]
    if scale is None:
        scale = D**-0.5
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_k = S // block_k

    kernel = functools.partial(
        _kernel, scale=scale, window=window, n_k_blocks=n_k
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, NKV, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, NKV, G, D), q.dtype),
        scratch_shapes=[
            _vmem((G, D), jnp.float32),
            _vmem((G,), jnp.float32),
            _vmem((G,), jnp.float32),
        ],
        compiler_params=_mosaic_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, q, k_cache, v_cache, slot_pos)
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _mosaic_params(semantics):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:  # pragma: no cover
        return None
