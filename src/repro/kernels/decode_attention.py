"""Pallas TPU decode-attention kernel (one new token vs. a ring-buffer cache).

Decode attention is purely memory-bound: each step streams the whole KV
cache from HBM once and does O(S * D) FLOPs.  The kernel tiles the cache
sequence dimension; the grid is (batch, kv_heads, n_k_blocks) with the
k-block dimension sequential, and the (G, D) query group plus the online
softmax state live in VMEM — so each cache byte is read exactly once
(HBM-roofline optimal).

Mask semantics match ``repro.models.attention.decode_attention``: slots carry
absolute positions (ring buffers), masked by validity / causality / window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["decode_attention_fwd", "decode_attention_paged_fwd"]

_NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, window: int, n_k_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, D)
    v = v_ref[0, 0].astype(jnp.float32)
    sp = sp_ref[0]  # (block_k,) absolute positions (-1 = empty)
    pos = pos_ref[0]  # scalar query position

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, block_k)
    ok = (sp >= 0) & (sp <= pos)
    if window:
        ok &= sp > (pos - window)
    s = jnp.where(ok[None, :], s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q, k_cache, v_cache, slot_pos, pos, *,
    window: int = 0,
    scale=None,
    block_k: int = 512,
    interpret: bool = False,
):
    """q: (B, NKV, G, D); caches: (B, NKV, S, D); slot_pos: (B, S); pos: (B,).

    Returns (B, NKV, G, D).
    """
    B, NKV, G, D = q.shape
    S = k_cache.shape[2]
    if scale is None:
        scale = D**-0.5
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_k = S // block_k

    kernel = functools.partial(
        _kernel, scale=scale, window=window, n_k_blocks=n_k
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, NKV, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, NKV, G, D), q.dtype),
        scratch_shapes=[
            _vmem((G, D), jnp.float32),
            _vmem((G,), jnp.float32),
            _vmem((G,), jnp.float32),
        ],
        compiler_params=_mosaic_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, q, k_cache, v_cache, slot_pos)
    return out


def _paged_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref,
                  *, scale: float, window: int, page: int, n_blocks: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (page, D)
    v = v_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[b]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, page)
    # Append-only paged layout: dense index == absolute position, so the
    # validity mask is just causality.  Trash-padded table entries sit past
    # the slot's reservation (dense index > pos by construction) and are
    # masked here without any per-slot bookkeeping.
    sp = ki * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    ok = sp <= pos
    if window:
        ok &= sp > (pos - window)
    s = jnp.where(ok[None, :], s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_paged_fwd(
    q, k_pool, v_pool, page_tables, pos, *,
    window: int = 0,
    scale=None,
    interpret: bool = False,
):
    """Paged decode attention over a shared physical page pool.

    q: (B, NKV, G, D); pools: (P, NKV, page, D); page_tables: (B, NB) int32
    page ids into the pool; pos: (B,) per-row absolute positions.  Returns
    (B, NKV, G, D).

    The page tables ride in as *scalar-prefetch* operands
    (:class:`pltpu.PrefetchScalarGridSpec`), so the k/v block index maps can
    DMA exactly the pages each row owns — the kernel never materializes a
    gathered dense cache, and each row streams only ``NB * page`` entries
    regardless of pool size.
    """
    B, NKV, G, D = q.shape
    P, _, page, _ = k_pool.shape
    NB = page_tables.shape[1]
    if scale is None:
        scale = D**-0.5

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _paged_kernel, scale=scale, window=window, page=page, n_blocks=NB
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_tables, pos
        grid=(B, NKV, NB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, tbl, pos: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, 1, page, D),
                lambda b, h, ki, tbl, pos: (tbl[b, ki], h, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, page, D),
                lambda b, h, ki, tbl, pos: (tbl[b, ki], h, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki, tbl, pos: (b, h, 0, 0)),
        scratch_shapes=[
            _vmem((G, D), jnp.float32),
            _vmem((G,), jnp.float32),
            _vmem((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, NKV, G, D), q.dtype),
        compiler_params=_mosaic_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_tables.astype(jnp.int32), pos.astype(jnp.int32), q, k_pool, v_pool)
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _mosaic_params(semantics):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:  # pragma: no cover
        return None
