"""Pallas TPU fused RMSNorm kernel.

Fuses the variance reduction, rsqrt, and scale into one VMEM pass (XLA often
emits separate reduce + broadcast-multiply HLOs with an HBM round-trip).
Rows are tiled (block_rows, d); d stays whole so the reduction is in-lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rms_norm_fwd"]


def _kernel(x_ref, w_ref, o_ref, *, eps: float, offset: bool):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    scale = (1.0 + w) if offset else w
    o_ref[...] = (y * scale).astype(o_ref.dtype)


def rms_norm_fwd(x, w, *, eps: float = 1e-6, offset: bool = False,
                 block_rows: int = 256, interpret: bool = False):
    """x: (..., D); w: (D,).  Returns RMSNorm(x) * scale in x.dtype."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    n_r = xr.shape[0] // block_rows

    kernel = functools.partial(_kernel, eps=eps, offset=offset)
    out = pl.pallas_call(
        kernel,
        grid=(n_r,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
