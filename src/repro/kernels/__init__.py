"""Pallas TPU kernels for the serving hot paths + pure-jnp oracles.

Kernels: flash attention (prefill), decode attention (memory-bound cache
streaming), RG-LRU scan (linear recurrence at HBM bandwidth), fused RMSNorm.
``ops.py`` dispatches kernel-vs-reference by backend.
"""
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention_bwd import flash_attention_bwd
from repro.kernels.rglru_scan import rglru_scan_fwd
from repro.kernels.rmsnorm import rms_norm_fwd

__all__ = [
    "ops", "ref",
    "flash_attention_fwd", "flash_attention_bwd", "decode_attention_fwd",
    "rglru_scan_fwd", "rms_norm_fwd",
]
