"""Jit'd dispatch wrappers: Pallas kernels on TPU, pure-JAX refs elsewhere.

The model code calls these; on the CPU-host dry-run Mosaic cannot lower, so
dispatch falls back to the references (identical math — the kernels are
validated against them in interpret mode by tests/test_kernels_*.py).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rglru_scan import rglru_scan_fwd
from repro.kernels.rmsnorm import rms_norm_fwd

__all__ = [
    "on_tpu",
    "flash_attention",
    "decode_attention",
    "rglru_scan",
    "rms_norm",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas"))
def flash_attention(q, k, v, *, causal=True, window=0, use_pallas=None):
    use = on_tpu() if use_pallas is None else use_pallas
    if use:
        return flash_attention_fwd(q, k, v, causal=causal, window=window)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("window", "use_pallas"))
def decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window=0, use_pallas=None):
    use = on_tpu() if use_pallas is None else use_pallas
    if use:
        return decode_attention_fwd(q, k_cache, v_cache, slot_pos, pos, window=window)
    return ref.decode_attention_ref(q, k_cache, v_cache, slot_pos, pos, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def rglru_scan(a, b, h0, *, use_pallas=None):
    use = on_tpu() if use_pallas is None else use_pallas
    if use:
        return rglru_scan_fwd(a, b, h0)
    return ref.rglru_scan_ref(a, b, h0)


@functools.partial(jax.jit, static_argnames=("eps", "offset", "use_pallas"))
def rms_norm(x, w, *, eps=1e-6, offset=False, use_pallas=None):
    use = on_tpu() if use_pallas is None else use_pallas
    if use:
        return rms_norm_fwd(x, w, eps=eps, offset=offset)
    return ref.rms_norm_ref(x, w, eps=eps, offset=offset)
