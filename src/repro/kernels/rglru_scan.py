"""Pallas TPU kernel for the RG-LRU linear recurrence.

Griffin's own TPU implementation observes that the scan is *memory-bound*
(~6 FLOPs per element streamed), so the right TPU shape is: tile the width
dimension across the vector lanes, keep the hidden state resident in VMEM,
and walk the sequence dimension sequentially — each (a, bx) element is read
from HBM exactly once and h is written once, i.e. the kernel runs at HBM
bandwidth.  We adopt exactly that structure: grid = (B, n_width_blocks,
n_seq_blocks) with the sequence dimension "arbitrary" (sequential), and an
in-kernel ``fori_loop`` over the rows of the current block while the carry
lives in VMEM scratch.

(The pure-JAX path uses ``associative_scan`` — O(log S) depth but ~2x the
HBM traffic; the trade is recorded in DESIGN.md and EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rglru_scan_fwd"]


def _kernel(a_ref, b_ref, h0_ref, o_ref, carry_ref, *, block_s: int, n_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (block_s, block_w)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, carry_ref[...])
    carry_ref[...] = h


def rglru_scan_fwd(a, b, h0, *, block_s: int = 128, block_w: int = 512,
                   interpret: bool = False):
    """h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, W); h0: (B, W).  Returns h: (B, S, W).
    """
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    assert S % block_s == 0 and W % block_w == 0
    n_s, n_w = S // block_s, W // block_w

    kernel = functools.partial(_kernel, block_s=block_s, n_s=n_s)
    out = pl.pallas_call(
        kernel,
        grid=(B, n_w, n_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b_, wi, si: (b_, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda b_, wi, si: (b_, si, wi)),
            pl.BlockSpec((1, block_w), lambda b_, wi, si: (b_, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda b_, wi, si: (b_, si, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[_vmem((block_w,), jnp.float32)],
        compiler_params=_mosaic_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _mosaic_params(semantics):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:  # pragma: no cover
        return None
