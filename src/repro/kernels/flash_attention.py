"""Pallas TPU flash-attention (forward) kernel.

Tiling: grid = (batch * q_heads, n_q_blocks, n_k_blocks) with the k-block
dimension sequential ("arbitrary"); the (block_q, head_dim) accumulator, the
running max and the running sum live in VMEM scratch and persist across
k-blocks.  Causal/windowed pairs outside the band are skipped at block
granularity with ``pl.when`` (no wasted MXU work), matching the pure-JAX
implementation's exact-causal FLOPs.

GQA: K/V are laid out (B, KV, S, D) and indexed by ``q_head // group``, so
grouped queries never materialize repeated K/V in HBM or VMEM.

Block sizes default to (256, 512): VMEM footprint per step ~=
  q (256x128x2) + k,v (512x128x2x2) + acc (256x128x4) + p (256x512x4) ~= 1 MB,
comfortably under the ~16 MB/core budget, with MXU-aligned (>=128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_fwd"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, block_q: int, block_k: int,
            n_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k
    needed = True
    if causal:
        needed = k_lo <= q_lo + block_q - 1
    if window:
        needed = needed & (k_lo + block_k - 1 > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= qpos >= kpos
        if window:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def flash_attention_fwd(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    scale=None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    return_lse: bool = False,
):
    """q: (B, NQ, S, D); k, v: (B, NKV, S, D) -> (B, NQ, S, D)
    (+ LSE (B, NQ, S) when ``return_lse``, for the backward kernels)."""
    B, NQ, S, D = q.shape
    NKV = k.shape[1]
    G = NQ // NKV
    if scale is None:
        scale = D**-0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    n_q, n_k = S // block_q, S // block_k
    bh = B * NQ

    qr = q.reshape(bh, S, D)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_k_blocks=n_k,
    )
    grid = (bh, n_q, n_k)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, qi, ki, NQ=NQ, G=G: (b // NQ, (b % NQ) // G, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, qi, ki, NQ=NQ, G=G: (b // NQ, (b % NQ) // G, ki, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, S, D), q.dtype),
            jax.ShapeDtypeStruct((bh, S), jnp.float32),
        ],
        scratch_shapes=[
            _vmem((block_q, D), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
        ],
        compiler_params=_mosaic_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, _strip_block(k), _strip_block(v))
    out = out.reshape(B, NQ, S, D)
    if return_lse:
        return out, lse.reshape(B, NQ, S)
    return out


def _strip_block(x):
    return x  # (B, NKV, S, D) is already the kernel layout


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _mosaic_params(semantics):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:  # pragma: no cover - older API fallback
        return None
