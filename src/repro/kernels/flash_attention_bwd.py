"""Pallas TPU flash-attention backward kernels.

Standard two-kernel formulation (recompute-from-LSE, no O(S^2) residuals):

  * ``_dq_kernel``   — grid (B*NQ, n_q, n_k), k-blocks sequential: per
    q-block, accumulate dq += ds @ k with ds = p * (dp - delta) * scale.
  * ``_dkv_kernel``  — grid (B*NQ, n_k, n_q), q-blocks sequential: per
    k-block, accumulate dv += p^T @ do and dk += ds^T @ q.

GQA: both kernels run per *query* head (K/V indexed by ``q_head // group``);
dk/dv come out per-query-head and are summed over the group outside (a tiny
jnp reduction) — this keeps the grid race-free without atomics.

``delta = rowsum(dout * out)`` and the forward LSE are computed outside
(delta is one fused elementwise reduce; LSE comes from the forward kernel).
Causal/window block-skipping mirrors the forward kernel exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_bwd"]

_NEG_INF = -1e30


def _masked_scores(q, k, q_lo, k_lo, scale, causal, window):
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= qpos >= kpos
    if window:
        ok &= (qpos - kpos) < window
    return jnp.where(ok, s, _NEG_INF)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, window, block_q, block_k, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo, k_lo = qi * block_q, ki * block_k
    needed = True
    if causal:
        needed = k_lo <= q_lo + block_q - 1
    if window:
        needed = needed & (k_lo + block_k - 1 > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]  # (block_q,)
        delta = delta_ref[0]
        s = _masked_scores(q, k, q_lo, k_lo, scale, causal, window)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == n_k - 1)
    def _finalize():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, window, block_q, block_k, n_q):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_lo, k_lo = qi * block_q, ki * block_k
    needed = True
    if causal:
        needed = k_lo <= q_lo + block_q - 1
    if window:
        needed = needed & (k_lo + block_k - 1 > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = _masked_scores(q, k, q_lo, k_lo, scale, causal, window)
        p = jnp.exp(s - lse[:, None])  # (block_q, block_k)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(qi == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q, k, v, out, dout, lse, *,
    causal: bool = True,
    window: int = 0,
    scale=None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
):
    """Backward pass.  q/out/dout: (B, NQ, S, D); k, v: (B, NKV, S, D);
    lse: (B, NQ, S).  Returns (dq, dk, dv) in input layouts."""
    B, NQ, S, D = q.shape
    NKV = k.shape[1]
    G = NQ // NKV
    if scale is None:
        scale = D**-0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    n_q, n_k = S // block_q, S // block_k
    bh = B * NQ

    delta = jnp.einsum(
        "bhsd,bhsd->bhs", dout.astype(jnp.float32), out.astype(jnp.float32)
    ).reshape(bh, S)
    qr = q.reshape(bh, S, D)
    dor = dout.reshape(bh, S, D)
    lser = lse.reshape(bh, S)

    common = dict(scale=scale, causal=causal, window=window,
                  block_q=block_q, block_k=block_k)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, n_k=n_k, **common),
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, qi, ki, NQ=NQ, G=G: (b // NQ, (b % NQ) // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, qi, ki, NQ=NQ, G=G: (b // NQ, (b % NQ) // G, ki, 0)),
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, S, D), q.dtype),
        scratch_shapes=[_vmem((block_q, D), jnp.float32)],
        compiler_params=_mosaic_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, k, v, dor, lser, delta)

    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, n_q=n_q, **common),
        grid=(bh, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, ki, qi: (b, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, ki, qi, NQ=NQ, G=G: (b // NQ, (b % NQ) // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, ki, qi, NQ=NQ, G=G: (b // NQ, (b % NQ) // G, ki, 0)),
            pl.BlockSpec((1, block_q, D), lambda b, ki, qi: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, ki, qi: (b, qi)),
            pl.BlockSpec((1, block_q), lambda b, ki, qi: (b, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, D), lambda b, ki, qi: (b, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, ki, qi: (b, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, S, D), k.dtype),
            jax.ShapeDtypeStruct((bh, S, D), v.dtype),
        ],
        scratch_shapes=[
            _vmem((block_k, D), jnp.float32),
            _vmem((block_k, D), jnp.float32),
        ],
        compiler_params=_mosaic_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, k, v, dor, lser, delta)

    # Per-query-head dk/dv -> sum over the GQA group.
    dq = dq.reshape(B, NQ, S, D)
    dk = dk_h.reshape(B, NKV, G, S, D).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, NKV, G, S, D).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _mosaic_params(semantics):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:  # pragma: no cover
        return None
