"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attention_reference as _attn_ref
from repro.models.attention import decode_attention as _decode_ref
from repro.models.attention import paged_decode_attention as _paged_decode_ref
from repro.models.layers import rms_norm as _rms_ref

__all__ = [
    "flash_attention_ref",
    "decode_attention_ref",
    "decode_attention_paged_ref",
    "rglru_scan_ref",
    "rms_norm_ref",
]


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B, NQ, S, D); k, v: (B, NKV, S, D) — kernel layout."""
    out = _attn_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        scale=scale,
    )
    return out.transpose(0, 2, 1, 3)


def decode_attention_ref(q, k_cache, v_cache, slot_pos, pos, *, window=0, scale=None):
    """q: (B, NKV, G, D); caches: (B, NKV, S, D) — kernel layout."""
    B, NKV, G, D = q.shape
    out = _decode_ref(
        q.reshape(B, 1, NKV * G, D),
        k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        slot_pos,
        pos,
        window=window,
        scale=scale,
    )
    return out.reshape(B, NKV, G, D)


def decode_attention_paged_ref(q, k_pool, v_pool, page_tables, pos, *,
                               window=0, scale=None):
    """q: (B, NKV, G, D); pools: (P, NKV, page, D) — kernel layout."""
    B, NKV, G, D = q.shape
    out = _paged_decode_ref(
        q.reshape(B, 1, NKV * G, D),
        k_pool.transpose(0, 2, 1, 3),
        v_pool.transpose(0, 2, 1, 3),
        page_tables,
        pos,
        window=window,
        scale=scale,
    )
    return out.reshape(B, NKV, G, D)


def rglru_scan_ref(a, b, h0):
    """h_t = a_t h_{t-1} + b_t via associative scan.  a, b: (B, S, W)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype)


def rms_norm_ref(x, w, *, eps=1e-6, offset=False):
    return _rms_ref(x, w, eps=eps, offset=offset)
