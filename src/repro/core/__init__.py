"""MDInference core: the paper's contribution as a composable library.

Public surface:
  * :class:`~repro.core.registry.ModelProfile` / ``ModelRegistry`` — the set
    of functionally-equivalent models ``M`` with ``A(m)``, ``mu(m)``,
    ``sigma(m)``.
  * :mod:`~repro.core.selection` — the three-stage probabilistic selection
    (reference + vectorized/jit-able).
  * :mod:`~repro.core.duplication` — SLA-bounding request duplication.
  * :mod:`~repro.core.network` — network models, traces, and estimators.
  * :mod:`~repro.core.simulator` — the paper's evaluation methodology.
  * :mod:`~repro.core.baselines` — every comparison algorithm from §VI.
"""
from repro.core.baselines import (
    ALGORITHMS,
    POLICY_PROBABILITIES,
    get_algorithm,
    get_policy_probabilities,
)
from repro.core.duplication import (
    DEFAULT_ON_DEVICE,
    DuplicationOutcome,
    HedgePolicy,
    resolve_duplication,
)
from repro.core.network import (
    EWMAEstimator,
    ExactEstimator,
    FixedCVNetwork,
    LognormalNetwork,
    NAMED_TRACES,
    NoisyEstimator,
    TraceNetwork,
    lte_trace,
    residential_trace,
    university_trace,
)
from repro.core.registry import ModelProfile, ModelRegistry
from repro.core.selection import (
    BatchSelection,
    SelectionResult,
    compute_budget,
    select_batch,
    select_ref,
    selection_probabilities,
)
from repro.core.simulator import SimConfig, SimResult, run_simulation
from repro.core.sla import RequestMetrics, summarize

__all__ = [
    "ALGORITHMS",
    "BatchSelection",
    "DEFAULT_ON_DEVICE",
    "DuplicationOutcome",
    "EWMAEstimator",
    "ExactEstimator",
    "FixedCVNetwork",
    "HedgePolicy",
    "LognormalNetwork",
    "ModelProfile",
    "ModelRegistry",
    "NAMED_TRACES",
    "NoisyEstimator",
    "POLICY_PROBABILITIES",
    "RequestMetrics",
    "SelectionResult",
    "SimConfig",
    "SimResult",
    "TraceNetwork",
    "compute_budget",
    "get_algorithm",
    "get_policy_probabilities",
    "lte_trace",
    "residential_trace",
    "resolve_duplication",
    "run_simulation",
    "select_batch",
    "select_ref",
    "selection_probabilities",
    "summarize",
    "university_trace",
]
