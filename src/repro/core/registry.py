"""Model registry: profiles of functionally-equivalent models.

A *profile* is what MDInference's selection algorithm consumes: an accuracy
(quality) score plus the mean/stddev of the model's execution latency
(Table I of the paper: ``A(m)``, ``mu(m)``, ``sigma(m)``).

The registry is the serving-side catalog.  In the faithful reproduction the
profiles come from the paper's Table III (measured on an EC2 p2.xlarge GPU
server); in the TPU serving integration they are derived from the roofline
analysis of the compiled LM zoo (see ``repro.serving.profiles``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "ModelProfile",
    "ModelRegistry",
]


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """One functionally-equivalent model variant.

    Attributes:
      name: human-readable identifier.
      accuracy: quality score in *percent* (paper uses top-1 %).
      mu_ms: mean execution latency in milliseconds.
      sigma_ms: standard deviation of execution latency in milliseconds.
    """

    name: str
    accuracy: float
    mu_ms: float
    sigma_ms: float

    def fits(self, budget_ms: float) -> bool:
        """Stage-1 eligibility: ``mu + sigma < T_budget`` (paper Eq. 2)."""
        return self.mu_ms + self.sigma_ms < budget_ms


class ModelRegistry:
    """An ordered collection of :class:`ModelProfile` with array views.

    The array views (``accuracy``, ``mu``, ``sigma``) are what the vectorized
    selection math consumes; the list view preserves identity for reporting.
    """

    def __init__(self, profiles: Iterable[ModelProfile]):
        self._profiles: list[ModelProfile] = list(profiles)
        if not self._profiles:
            raise ValueError("registry must contain at least one model")
        names = [p.name for p in self._profiles]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names in registry: {names}")

    # -- list-ish API -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._profiles)

    def __iter__(self):
        return iter(self._profiles)

    def __getitem__(self, idx: int) -> ModelProfile:
        return self._profiles[idx]

    @property
    def profiles(self) -> Sequence[ModelProfile]:
        return tuple(self._profiles)

    @property
    def names(self) -> list[str]:
        return [p.name for p in self._profiles]

    def index_of(self, name: str) -> int:
        return self.names.index(name)

    # -- array views --------------------------------------------------------
    @property
    def accuracy(self) -> np.ndarray:
        return np.asarray([p.accuracy for p in self._profiles], dtype=np.float32)

    @property
    def mu(self) -> np.ndarray:
        return np.asarray([p.mu_ms for p in self._profiles], dtype=np.float32)

    @property
    def sigma(self) -> np.ndarray:
        return np.asarray([p.sigma_ms for p in self._profiles], dtype=np.float32)

    # -- derived ------------------------------------------------------------
    @property
    def fastest_index(self) -> int:
        return int(np.argmin(self.mu))

    @property
    def most_accurate_index(self) -> int:
        return int(np.argmax(self.accuracy))

    def without(self, *names: str) -> "ModelRegistry":
        drop = set(names)
        return ModelRegistry([p for p in self._profiles if p.name not in drop])

    def with_profiles(self, extra: Iterable[ModelProfile]) -> "ModelRegistry":
        return ModelRegistry(list(self._profiles) + list(extra))

    def __repr__(self) -> str:  # pragma: no cover - debugging sugar
        rows = ", ".join(
            f"{p.name}(A={p.accuracy:.1f},mu={p.mu_ms:.2f})" for p in self._profiles
        )
        return f"ModelRegistry([{rows}])"
