"""Request duplication — the latency-bounding half of MDInference (§V-B).

Every request is executed twice: remotely (with the selected model) and
locally on a fast "on-device" model.  Whichever of the following happens
resolves the request:

* the remote response arrives before the SLA expires  -> remote result used;
* the SLA expires first                               -> on-device result used.

With an on-device model faster than the SLA this bounds *every* request's
latency at the SLA — the paper's "no SLA violations" claim.  In datacenter
terms this is hedged execution (Sparrow / power-of-two-choices [29, 30]) and
doubles as our straggler mitigation in the serving layer.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.core.registry import ModelProfile

__all__ = ["OnDeviceModel", "DuplicationOutcome", "resolve_duplication"]


# The paper's on-device duplicate: MobileNetV1_128 0.25, the model "most
# likely to complete within any SLA for all tested mobile devices"; top-1
# 41.4 % on ILSVRC-2012 (TFLite hosted-models table).  Mobile execution
# latency ~=30 ms on the devices of Fig 2.
DEFAULT_ON_DEVICE = ModelProfile(
    name="MobileNetV1_128 0.25 (on-device)", accuracy=41.4, mu_ms=30.0, sigma_ms=3.0
)

OnDeviceModel = ModelProfile  # alias: any profile may serve as the duplicate


class DuplicationOutcome(NamedTuple):
    """Vectorized resolution of duplicated requests.

    Carries the per-tier latencies the race was resolved on — with a real
    hedge tier these are *measured* wall times (one per execution tier),
    with the simulator they are profile samples.
    """

    used_remote: np.ndarray  # (R,) bool — remote result arrived within SLA
    accuracy: np.ndarray  # (R,) accuracy of the result actually used
    latency_ms: np.ndarray  # (R,) user-observed response latency
    violation: np.ndarray  # (R,) bool — SLA missed even with duplication
    remote_ms: np.ndarray  # (R,) remote tier's end-to-end latency
    ondevice_ms: np.ndarray  # (R,) on-device duplicate's latency


def resolve_duplication(
    remote_latency_ms: np.ndarray,
    remote_accuracy: np.ndarray,
    ondevice_latency_ms: np.ndarray,
    ondevice_accuracy: float,
    t_sla_ms: float,
) -> DuplicationOutcome:
    """Resolve each duplicated request.

    Args:
      remote_latency_ms: (R,) end-to-end remote latency (network + execution).
      remote_accuracy: (R,) accuracy of the remotely-selected models.
      ondevice_latency_ms: (R,) local execution latency of the duplicate —
        measured hedge-tier wall time on the serving path, a profile sample
        in simulation.
      ondevice_accuracy: accuracy of the on-device model.
      t_sla_ms: the response-time SLA.
    """
    remote_latency_ms = np.asarray(remote_latency_ms)
    ondevice_latency_ms = np.asarray(ondevice_latency_ms)
    used_remote = remote_latency_ms <= t_sla_ms
    accuracy = np.where(used_remote, remote_accuracy, ondevice_accuracy)
    # If the remote result misses, the framework returns the duplicate's
    # result when the SLA expires (or when the duplicate finishes, if later).
    fallback_latency = np.maximum(ondevice_latency_ms, t_sla_ms)
    latency = np.where(used_remote, remote_latency_ms, fallback_latency)
    # A violation with duplication requires the on-device model itself to be
    # slower than the SLA (possible only for SLAs below ~the duplicate's mu).
    violation = ~used_remote & (ondevice_latency_ms > t_sla_ms)
    return DuplicationOutcome(
        used_remote=used_remote,
        accuracy=accuracy,
        latency_ms=latency,
        violation=violation,
        remote_ms=remote_latency_ms,
        ondevice_ms=ondevice_latency_ms,
    )


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Serving-layer knob: when to issue the duplicate.

    ``always`` reproduces the paper.  ``deadline_headroom_ms`` is a
    beyond-paper energy/cost optimization (paper §VII "Energy Consumption"):
    skip the duplicate when the estimated budget leaves at least this much
    headroom over the base model's mu+3sigma, i.e. when the hedge is very
    unlikely to be needed.
    """

    always: bool = True
    deadline_headroom_ms: float = 0.0

    def should_hedge(
        self, t_budget_ms: np.ndarray, base_mu: np.ndarray, base_sigma: np.ndarray
    ) -> np.ndarray:
        if self.always:
            return np.ones(np.shape(t_budget_ms), dtype=bool)
        slack = np.asarray(t_budget_ms) - (base_mu + 3.0 * base_sigma)
        return slack < self.deadline_headroom_ms
