"""SLA / aggregate-accuracy metrics (paper §III "key metrics")."""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["RequestMetrics", "summarize"]


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    """Aggregated quality/latency metrics over a batch of requests."""

    n_requests: int
    aggregate_accuracy: float  # mean accuracy of the models that answered
    sla_attainment: float  # fraction of requests answered within the SLA
    ondevice_reliance: float  # fraction answered by the duplicate (0 w/o dup)
    mean_latency_ms: float
    std_latency_ms: float
    p50_latency_ms: float
    p99_latency_ms: float
    model_usage: Dict[str, float]  # model name -> fraction of requests
    mean_queue_wait_ms: float = 0.0  # scheduling-tick wait (0 when untracked)
    p99_queue_wait_ms: float = 0.0
    # Fraction of requests per race outcome ("remote_won" / "ondevice_won" /
    # "unhedged"); empty when the serving front doesn't track races.
    race_resolution: Dict[str, float] = dataclasses.field(default_factory=dict)
    mean_time_to_schedule_ms: float = 0.0  # admission -> scheduling tick

    def row(self) -> str:
        return (
            f"acc={self.aggregate_accuracy:6.2f}%  sla={self.sla_attainment*100:6.2f}%  "
            f"ondev={self.ondevice_reliance*100:5.2f}%  "
            f"lat={self.mean_latency_ms:7.1f}±{self.std_latency_ms:5.1f}ms  "
            f"p99={self.p99_latency_ms:7.1f}ms"
        )


def summarize(
    *,
    accuracy_used: np.ndarray,
    latency_ms: np.ndarray,
    t_sla_ms: float | np.ndarray,
    model_names: list[str],
    model_index: np.ndarray,
    used_remote: np.ndarray | None = None,
    queue_wait_ms: np.ndarray | None = None,
    race_resolution: np.ndarray | None = None,
    time_to_schedule_ms: np.ndarray | None = None,
) -> RequestMetrics:
    """Build :class:`RequestMetrics` from per-request outcomes.

    ``queue_wait_ms`` (per-request scheduling-tick wait),
    ``race_resolution`` (per-request "remote_won" / "ondevice_won" /
    "unhedged" strings), and ``time_to_schedule_ms`` are optional —
    trace-driven simulation has no queue or race bookkeeping, so their
    aggregates default to empty/0.  ``t_sla_ms`` may be a per-request
    vector when requests carry individual SLAs.
    """
    accuracy_used = np.asarray(accuracy_used, dtype=np.float64)
    latency_ms = np.asarray(latency_ms, dtype=np.float64)
    n = len(latency_ms)
    attained = float(np.mean(latency_ms <= t_sla_ms + 1e-9))
    reliance = 0.0 if used_remote is None else float(1.0 - np.mean(used_remote))

    usage: Dict[str, float] = {}
    counts = np.bincount(np.asarray(model_index), minlength=len(model_names))
    for name, c in zip(model_names, counts):
        if c:
            usage[name] = float(c) / n

    return RequestMetrics(
        n_requests=n,
        aggregate_accuracy=float(accuracy_used.mean()),
        sla_attainment=attained,
        ondevice_reliance=reliance,
        mean_latency_ms=float(latency_ms.mean()),
        std_latency_ms=float(latency_ms.std()),
        p50_latency_ms=float(np.percentile(latency_ms, 50)),
        p99_latency_ms=float(np.percentile(latency_ms, 99)),
        model_usage=usage,
        mean_queue_wait_ms=(
            0.0 if queue_wait_ms is None else float(np.mean(queue_wait_ms))
        ),
        p99_queue_wait_ms=(
            0.0 if queue_wait_ms is None else float(np.percentile(queue_wait_ms, 99))
        ),
        race_resolution=(
            {}
            if race_resolution is None
            else {
                outcome: float(np.mean(np.asarray(race_resolution) == outcome))
                for outcome in ("remote_won", "ondevice_won", "unhedged")
            }
        ),
        mean_time_to_schedule_ms=(
            0.0
            if time_to_schedule_ms is None
            else float(np.mean(time_to_schedule_ms))
        ),
    )
