"""SLA / aggregate-accuracy metrics (paper §III "key metrics")."""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.observability.quantile import quantile

__all__ = ["ReplicaRow", "TenantRow", "RequestMetrics", "summarize"]

# Lane name charged for untagged requests under tenancy — mirrors
# repro.serving.tenancy.DEFAULT_TENANT (core must not import serving).
_DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class ReplicaRow:
    """Per-replica aggregates for a replicated execution cluster."""

    share: float  # fraction of completions this replica served
    goodput_share: float  # fraction of all SLA-attained completions
    utilization: float  # rows served / rows on the busiest replica
    p99_inflight: float  # p99 queue depth (rows) at dispatch


@dataclasses.dataclass(frozen=True)
class TenantRow:
    """Per-tenant aggregates for a multi-tenant admission stage."""

    priority: str  # dominant priority class of the tenant's served rows
    share: float  # fraction of completions this tenant received
    shed_rate: float  # tenant rejects / tenant submits (served + rejected)
    goodput: float  # SLA-attained served / tenant submits
    p99_latency_ms: float
    n_requests: int = 0
    n_rejected: int = 0


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    """Aggregated quality/latency metrics over a batch of requests."""

    n_requests: int
    aggregate_accuracy: float  # mean accuracy of the models that answered
    sla_attainment: float  # fraction of requests answered within the SLA
    ondevice_reliance: float  # fraction answered by the duplicate (0 w/o dup)
    mean_latency_ms: float
    std_latency_ms: float
    p50_latency_ms: float
    p99_latency_ms: float
    model_usage: Dict[str, float]  # model name -> fraction of requests
    mean_queue_wait_ms: float = 0.0  # scheduling-tick wait (0 when untracked)
    p99_queue_wait_ms: float = 0.0
    # Fraction of requests per race outcome ("remote_won" / "ondevice_won" /
    # "unhedged" / "degraded"); empty when the front doesn't track races.
    race_resolution: Dict[str, float] = dataclasses.field(default_factory=dict)
    mean_time_to_schedule_ms: float = 0.0  # admission -> scheduling tick
    # Overload accounting (bounded admission): rejected requests are not in
    # n_requests — shed_rate is their fraction of everything *submitted*,
    # and goodput is the fraction of submitted requests answered within the
    # SLA (attainment over answered ∩ survived admission).  Without
    # rejections goodput == sla_attainment.
    n_rejected: int = 0
    shed_rate: float = 0.0
    goodput: float = 0.0
    # Per-replica rows (replicated execution cluster): replica id ->
    # utilization / goodput share / inflight p99.  Empty when the serving
    # front runs a single unclustered backend.
    replica_rows: Dict[int, ReplicaRow] = dataclasses.field(
        default_factory=dict
    )
    # Per-tenant rows (multi-tenant admission): lane name -> share /
    # shed_rate / goodput / p99 split.  Empty when the serving front runs
    # the single-class FIFO (no tenants configured, no tagged requests).
    tenant_rows: Dict[str, TenantRow] = dataclasses.field(
        default_factory=dict
    )
    # p99 latency split by priority class ("interactive" / "batch") —
    # per-class isolation, not averages, is what holds tail latency.
    # Populated only alongside tenant_rows.
    priority_p99: Dict[str, float] = dataclasses.field(default_factory=dict)

    def row(self) -> str:
        return (
            f"acc={self.aggregate_accuracy:6.2f}%  sla={self.sla_attainment*100:6.2f}%  "
            f"ondev={self.ondevice_reliance*100:5.2f}%  "
            f"lat={self.mean_latency_ms:7.1f}±{self.std_latency_ms:5.1f}ms  "
            f"p99={self.p99_latency_ms:7.1f}ms"
        )


def summarize(
    *,
    accuracy_used: np.ndarray,
    latency_ms: np.ndarray,
    t_sla_ms: float | np.ndarray,
    model_names: list[str],
    model_index: np.ndarray,
    used_remote: np.ndarray | None = None,
    queue_wait_ms: np.ndarray | None = None,
    race_resolution: np.ndarray | None = None,
    time_to_schedule_ms: np.ndarray | None = None,
    n_rejected: int = 0,
    replica: np.ndarray | None = None,
    replica_inflight: np.ndarray | None = None,
    tenant: np.ndarray | None = None,
    priority: np.ndarray | None = None,
    rejected_tenants: Dict[str, int] | None = None,
) -> RequestMetrics:
    """Build :class:`RequestMetrics` from per-request outcomes.

    ``queue_wait_ms`` (per-request scheduling-tick wait),
    ``race_resolution`` (per-request "remote_won" / "ondevice_won" /
    "unhedged" / "degraded" strings), and ``time_to_schedule_ms`` are
    optional — trace-driven simulation has no queue or race bookkeeping,
    so their aggregates default to empty/0.  ``t_sla_ms`` may be a
    per-request vector when requests carry individual SLAs.

    ``n_rejected`` counts requests the admission queue shed (REJECTED
    terminal state) — they have no latency/accuracy rows, but they *do*
    count against ``shed_rate`` and ``goodput``.  The per-request arrays
    may be empty when every request of a tick was shed.

    ``replica`` (per-request cluster replica id, ``-1`` for requests that
    never rode a pool replica — i.e. degrade-lane rows; a hedged row that
    lost the race still carries the replica that ran its remote leg) and
    ``replica_inflight`` (the replica's queue depth at dispatch) feed the
    per-replica ``replica_rows`` aggregates; both optional and safe on
    empty batches.

    ``tenant`` (per-request lane names, ``None`` entries charged to the
    implicit ``"default"`` lane), ``priority`` (per-request
    ``"interactive"`` / ``"batch"`` class strings), and
    ``rejected_tenants`` (lane name -> rejects this summary covers) feed
    ``tenant_rows`` and ``priority_p99``.  Both stay empty unless some
    request actually carried a tenant tag or a tenant was charged a
    reject — an untenanted front produces metrics identical to the
    pre-tenancy ones.
    """
    accuracy_used = np.asarray(accuracy_used, dtype=np.float64)
    latency_ms = np.asarray(latency_ms, dtype=np.float64)
    n = len(latency_ms)
    # The one SLA-attainment predicate: sla_attainment, goodput, and the
    # per-replica goodput_share rows must all agree on who attained.
    attained_mask = latency_ms <= np.asarray(t_sla_ms) + 1e-9
    attained = float(attained_mask.mean()) if n else 0.0
    reliance = (
        0.0
        if used_remote is None or not n
        else float(1.0 - np.mean(used_remote))
    )
    submitted = n + n_rejected

    usage: Dict[str, float] = {}
    counts = np.bincount(
        np.asarray(model_index, dtype=np.int64), minlength=len(model_names)
    )
    for name, c in zip(model_names, counts):
        if c:
            usage[name] = float(c) / n

    replica_rows: Dict[int, ReplicaRow] = {}
    if replica is not None and n:
        rep = np.asarray(replica, dtype=np.int64)
        n_attained = int(attained_mask.sum())
        ids = sorted(int(r) for r in np.unique(rep) if r >= 0)
        if ids:
            per_rows = {r: int(np.sum(rep == r)) for r in ids}
            busiest = max(per_rows.values())
            inflight = (
                None
                if replica_inflight is None
                else np.asarray(replica_inflight, dtype=np.float64)
            )
            for r in ids:
                mask = rep == r
                replica_rows[r] = ReplicaRow(
                    share=per_rows[r] / n,
                    goodput_share=(
                        float(np.sum(attained_mask & mask)) / n_attained
                        if n_attained
                        else 0.0
                    ),
                    utilization=per_rows[r] / busiest,
                    p99_inflight=(
                        quantile(inflight[mask], 99, default=0.0)
                        if inflight is not None
                        else 0.0
                    ),
                )

    tenant_rows: Dict[str, TenantRow] = {}
    priority_p99: Dict[str, float] = {}
    rejected_tenants = rejected_tenants or {}
    tenancy_active = bool(rejected_tenants) or (
        tenant is not None and any(t is not None for t in tenant)
    )
    if tenancy_active:
        names_arr = np.asarray(
            [
                _DEFAULT_TENANT if t is None else str(t)
                for t in (
                    tenant if tenant is not None else [None] * n
                )
            ],
            dtype=object,
        )
        prio_arr = (
            None
            if priority is None
            else np.asarray([str(p) for p in priority], dtype=object)
        )
        lane_names = sorted(
            set(names_arr.tolist()) | set(rejected_tenants)
        )
        for lane in lane_names:
            mask = names_arr == lane if n else np.zeros(0, dtype=bool)
            served = int(mask.sum())
            rejects = int(rejected_tenants.get(lane, 0))
            lane_submitted = served + rejects
            lane_attained = (
                int((attained_mask & mask).sum()) if served else 0
            )
            if served and prio_arr is not None:
                classes, counts_c = np.unique(
                    prio_arr[mask], return_counts=True
                )
                dominant = str(classes[int(np.argmax(counts_c))])
            else:
                dominant = "interactive"
            tenant_rows[lane] = TenantRow(
                priority=dominant,
                share=served / n if n else 0.0,
                shed_rate=(
                    rejects / lane_submitted if lane_submitted else 0.0
                ),
                goodput=(
                    lane_attained / lane_submitted if lane_submitted else 0.0
                ),
                p99_latency_ms=quantile(
                    latency_ms[mask] if served else (), 99, default=0.0
                ),
                n_requests=served,
                n_rejected=rejects,
            )
        if prio_arr is not None and n:
            for cls in np.unique(prio_arr):
                cmask = prio_arr == cls
                priority_p99[str(cls)] = quantile(
                    latency_ms[cmask], 99, default=0.0
                )

    return RequestMetrics(
        n_requests=n,
        aggregate_accuracy=float(accuracy_used.mean()) if n else 0.0,
        sla_attainment=attained,
        ondevice_reliance=reliance,
        mean_latency_ms=float(latency_ms.mean()) if n else 0.0,
        std_latency_ms=float(latency_ms.std()) if n else 0.0,
        p50_latency_ms=quantile(latency_ms, 50, default=0.0),
        p99_latency_ms=quantile(latency_ms, 99, default=0.0),
        model_usage=usage,
        mean_queue_wait_ms=(
            0.0
            if queue_wait_ms is None or not n
            else float(np.mean(queue_wait_ms))
        ),
        p99_queue_wait_ms=(
            0.0
            if queue_wait_ms is None or not n
            else quantile(queue_wait_ms, 99, default=0.0)
        ),
        race_resolution=(
            {}
            if race_resolution is None
            else {
                outcome: (
                    float(np.mean(np.asarray(race_resolution) == outcome))
                    if n
                    else 0.0
                )
                for outcome in (
                    "remote_won", "ondevice_won", "unhedged", "degraded"
                )
            }
        ),
        mean_time_to_schedule_ms=(
            0.0
            if time_to_schedule_ms is None or not n
            else float(np.mean(time_to_schedule_ms))
        ),
        n_rejected=int(n_rejected),
        shed_rate=(float(n_rejected) / submitted if submitted else 0.0),
        goodput=(attained * n / submitted if submitted else 0.0),
        replica_rows=replica_rows,
        tenant_rows=tenant_rows,
        priority_p99=priority_p99,
    )
