"""Baseline selection algorithms the paper compares against (§VI).

All baselines share the vectorized signature used by the simulator:

    fn(key, accuracy, mu, sigma, t_sla, t_budget) -> (index (R,), fallback (R,))

``t_sla`` is the raw SLA (the *static greedy* baseline ignores the network
and budgets against the full SLA); ``t_budget`` is the network-aware budget.
``fallback`` marks requests for which stage 1 found no feasible model (only
meaningful for budgeted algorithms; static algorithms never "fall back" —
they simply miss their SLA).

Every algorithm is also exposed in *probability form* via
:data:`POLICY_PROBABILITIES`:

    fn(accuracy, mu, sigma, t_sla, t_budget, utility_power=...)
        -> (probs (R, N), base_index (R,), fallback (R,))

Each row of ``probs`` is the per-request selection distribution over the
zoo (deterministic policies yield one-hot rows).  The batched online
scheduler samples from these rows host-side with a pre-drawn uniform per
request, which keeps its random stream independent of chunking — the
property the batched-vs-scalar equivalence tests rely on.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.selection import select_batch, selection_probabilities

__all__ = [
    "ALGORITHMS",
    "POLICY_PROBABILITIES",
    "get_algorithm",
    "get_policy_probabilities",
]

_EPS = 1e-9


def _greedy_at(accuracy, mu, sigma, budget):
    """argmax accuracy s.t. mu+sigma < budget; fastest if none fits."""
    budget = jnp.atleast_1d(budget)[:, None]
    fits = (mu + sigma)[None, :] < budget
    any_fit = fits.any(axis=-1)
    score = accuracy[None, :] - _EPS * mu[None, :]
    idx = jnp.argmax(jnp.where(fits, score, -jnp.inf), axis=-1)
    idx = jnp.where(any_fit, idx, jnp.argmin(mu)).astype(jnp.int32)
    return idx, ~any_fit


def mdinference(key, accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    sel = select_batch(
        key, accuracy, mu, sigma, t_budget, utility_power=utility_power
    )
    return sel.index, sel.fallback


def static_greedy(key, accuracy, mu, sigma, t_sla, t_budget):
    """Most accurate model fitting the *SLA* (network-oblivious)."""
    return _greedy_at(accuracy, mu, sigma, jnp.broadcast_to(t_sla, t_budget.shape))


def budget_greedy(key, accuracy, mu, sigma, t_sla, t_budget):
    """Beyond-paper: network-aware greedy (stage 1 only, no exploration)."""
    return _greedy_at(accuracy, mu, sigma, t_budget)


def static_accuracy(key, accuracy, mu, sigma, t_sla, t_budget):
    """Always the most accurate model (Table IV baseline)."""
    idx = jnp.full(t_budget.shape, jnp.argmax(accuracy), dtype=jnp.int32)
    return idx, jnp.zeros(t_budget.shape, bool)


def static_latency(key, accuracy, mu, sigma, t_sla, t_budget):
    """Always the fastest model (Table IV baseline)."""
    idx = jnp.full(t_budget.shape, jnp.argmin(mu), dtype=jnp.int32)
    return idx, jnp.zeros(t_budget.shape, bool)


def pure_random(key, accuracy, mu, sigma, t_sla, t_budget):
    """Uniform over the whole zoo (Fig 6 stage-1 ablation)."""
    n = accuracy.shape[0]
    idx = jax.random.randint(key, t_budget.shape, 0, n, dtype=jnp.int32)
    return idx, jnp.zeros(t_budget.shape, bool)


def _exploration_mask(accuracy, mu, sigma, t_budget):
    """Stages 1+2 shared by the related-* ablations."""
    probs, base_index, fallback = selection_probabilities(
        accuracy, mu, sigma, t_budget
    )
    mu_b = mu[base_index][:, None]
    sig_b = sigma[base_index][:, None]
    in_me = (mu[None, :] >= mu_b - sig_b) & (mu[None, :] <= mu_b + sig_b)
    return in_me, base_index, fallback


def related_random(key, accuracy, mu, sigma, t_sla, t_budget):
    """Uniform over M_E (Fig 6 stage-3 ablation: no utility weighting)."""
    in_me, base_index, fallback = _exploration_mask(accuracy, mu, sigma, t_budget)
    logits = jnp.where(in_me, 0.0, -jnp.inf)
    idx = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    idx = jnp.where(fallback, jnp.argmin(mu), idx).astype(jnp.int32)
    return idx, fallback


def related_accurate(key, accuracy, mu, sigma, t_sla, t_budget):
    """Most accurate member of M_E (Fig 6 stage-3 ablation: no exploration)."""
    in_me, base_index, fallback = _exploration_mask(accuracy, mu, sigma, t_budget)
    score = accuracy[None, :] - _EPS * mu[None, :]
    idx = jnp.argmax(jnp.where(in_me, score, -jnp.inf), axis=-1).astype(jnp.int32)
    idx = jnp.where(fallback, jnp.argmin(mu), idx).astype(jnp.int32)
    return idx, fallback


def oracle(key, accuracy, mu, sigma, t_sla, t_budget):
    """Beyond-paper upper bound: greedy against the *actual* remaining budget.

    Identical to budget_greedy when estimation is exact; differs under noisy
    estimators.  Useful as a ceiling in ablation plots.
    """
    return _greedy_at(accuracy, mu, sigma, t_budget)


ALGORITHMS: Dict[str, Callable] = {
    "mdinference": mdinference,
    "static_greedy": static_greedy,
    "budget_greedy": budget_greedy,
    "static_accuracy": static_accuracy,
    "static_latency": static_latency,
    "pure_random": pure_random,
    "related_random": related_random,
    "related_accurate": related_accurate,
    "oracle": oracle,
}


def get_algorithm(name: str) -> Callable:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; available: {sorted(ALGORITHMS)}"
        ) from None


# ---------------------------------------------------------------------------
# Probability-form policies (for the batched online scheduler).
# ---------------------------------------------------------------------------
def _one_hot_rows(index, n, dtype=jnp.float32):
    return jax.nn.one_hot(index, n, dtype=dtype)


def mdinference_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    return selection_probabilities(
        accuracy, mu, sigma, jnp.atleast_1d(t_budget), utility_power=utility_power
    )


def static_greedy_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    idx, fb = _greedy_at(accuracy, mu, sigma, jnp.broadcast_to(t_sla, t_budget.shape))
    return _one_hot_rows(idx, accuracy.shape[0]), idx, fb


def budget_greedy_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    idx, fb = _greedy_at(accuracy, mu, sigma, t_budget)
    return _one_hot_rows(idx, accuracy.shape[0]), idx, fb


def static_accuracy_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    idx = jnp.full(t_budget.shape, jnp.argmax(accuracy), dtype=jnp.int32)
    return _one_hot_rows(idx, accuracy.shape[0]), idx, jnp.zeros(t_budget.shape, bool)


def static_latency_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    idx = jnp.full(t_budget.shape, jnp.argmin(mu), dtype=jnp.int32)
    return _one_hot_rows(idx, accuracy.shape[0]), idx, jnp.zeros(t_budget.shape, bool)


def pure_random_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    n = accuracy.shape[0]
    probs = jnp.full(t_budget.shape + (n,), 1.0 / n, dtype=jnp.float32)
    # No stage-1 base: hedging decisions fall back to the fastest profile.
    base = jnp.full(t_budget.shape, jnp.argmin(mu), dtype=jnp.int32)
    return probs, base, jnp.zeros(t_budget.shape, bool)


def related_random_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    in_me, base, fb = _exploration_mask(accuracy, mu, sigma, t_budget)
    count = jnp.maximum(in_me.sum(axis=-1, keepdims=True), 1)
    probs = jnp.where(in_me, 1.0 / count, 0.0).astype(jnp.float32)
    fastest_onehot = _one_hot_rows(
        jnp.full(t_budget.shape, jnp.argmin(mu), dtype=jnp.int32), accuracy.shape[0]
    )
    probs = jnp.where(fb[:, None], fastest_onehot, probs)
    return probs, base, fb


def related_accurate_probs(accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    in_me, base, fb = _exploration_mask(accuracy, mu, sigma, t_budget)
    score = accuracy[None, :] - _EPS * mu[None, :]
    idx = jnp.argmax(jnp.where(in_me, score, -jnp.inf), axis=-1).astype(jnp.int32)
    idx = jnp.where(fb, jnp.argmin(mu), idx).astype(jnp.int32)
    return _one_hot_rows(idx, accuracy.shape[0]), base, fb


POLICY_PROBABILITIES: Dict[str, Callable] = {
    "mdinference": mdinference_probs,
    "static_greedy": static_greedy_probs,
    "budget_greedy": budget_greedy_probs,
    "static_accuracy": static_accuracy_probs,
    "static_latency": static_latency_probs,
    "pure_random": pure_random_probs,
    "related_random": related_random_probs,
    "related_accurate": related_accurate_probs,
    "oracle": budget_greedy_probs,
}


def get_policy_probabilities(name: str) -> Callable:
    try:
        return POLICY_PROBABILITIES[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; available: {sorted(POLICY_PROBABILITIES)}"
        ) from None
