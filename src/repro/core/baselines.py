"""Baseline selection algorithms the paper compares against (§VI).

All baselines share the vectorized signature used by the simulator:

    fn(key, accuracy, mu, sigma, t_sla, t_budget) -> (index (R,), fallback (R,))

``t_sla`` is the raw SLA (the *static greedy* baseline ignores the network
and budgets against the full SLA); ``t_budget`` is the network-aware budget.
``fallback`` marks requests for which stage 1 found no feasible model (only
meaningful for budgeted algorithms; static algorithms never "fall back" —
they simply miss their SLA).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.selection import select_batch, selection_probabilities

__all__ = ["ALGORITHMS", "get_algorithm"]

_EPS = 1e-9


def _greedy_at(accuracy, mu, sigma, budget):
    """argmax accuracy s.t. mu+sigma < budget; fastest if none fits."""
    budget = jnp.atleast_1d(budget)[:, None]
    fits = (mu + sigma)[None, :] < budget
    any_fit = fits.any(axis=-1)
    score = accuracy[None, :] - _EPS * mu[None, :]
    idx = jnp.argmax(jnp.where(fits, score, -jnp.inf), axis=-1)
    idx = jnp.where(any_fit, idx, jnp.argmin(mu)).astype(jnp.int32)
    return idx, ~any_fit


def mdinference(key, accuracy, mu, sigma, t_sla, t_budget, *, utility_power=1.0):
    sel = select_batch(
        key, accuracy, mu, sigma, t_budget, utility_power=utility_power
    )
    return sel.index, sel.fallback


def static_greedy(key, accuracy, mu, sigma, t_sla, t_budget):
    """Most accurate model fitting the *SLA* (network-oblivious)."""
    return _greedy_at(accuracy, mu, sigma, jnp.broadcast_to(t_sla, t_budget.shape))


def budget_greedy(key, accuracy, mu, sigma, t_sla, t_budget):
    """Beyond-paper: network-aware greedy (stage 1 only, no exploration)."""
    return _greedy_at(accuracy, mu, sigma, t_budget)


def static_accuracy(key, accuracy, mu, sigma, t_sla, t_budget):
    """Always the most accurate model (Table IV baseline)."""
    idx = jnp.full(t_budget.shape, jnp.argmax(accuracy), dtype=jnp.int32)
    return idx, jnp.zeros(t_budget.shape, bool)


def static_latency(key, accuracy, mu, sigma, t_sla, t_budget):
    """Always the fastest model (Table IV baseline)."""
    idx = jnp.full(t_budget.shape, jnp.argmin(mu), dtype=jnp.int32)
    return idx, jnp.zeros(t_budget.shape, bool)


def pure_random(key, accuracy, mu, sigma, t_sla, t_budget):
    """Uniform over the whole zoo (Fig 6 stage-1 ablation)."""
    n = accuracy.shape[0]
    idx = jax.random.randint(key, t_budget.shape, 0, n, dtype=jnp.int32)
    return idx, jnp.zeros(t_budget.shape, bool)


def _exploration_mask(accuracy, mu, sigma, t_budget):
    """Stages 1+2 shared by the related-* ablations."""
    probs, base_index, fallback = selection_probabilities(
        accuracy, mu, sigma, t_budget
    )
    mu_b = mu[base_index][:, None]
    sig_b = sigma[base_index][:, None]
    in_me = (mu[None, :] >= mu_b - sig_b) & (mu[None, :] <= mu_b + sig_b)
    return in_me, base_index, fallback


def related_random(key, accuracy, mu, sigma, t_sla, t_budget):
    """Uniform over M_E (Fig 6 stage-3 ablation: no utility weighting)."""
    in_me, base_index, fallback = _exploration_mask(accuracy, mu, sigma, t_budget)
    logits = jnp.where(in_me, 0.0, -jnp.inf)
    idx = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    idx = jnp.where(fallback, jnp.argmin(mu), idx).astype(jnp.int32)
    return idx, fallback


def related_accurate(key, accuracy, mu, sigma, t_sla, t_budget):
    """Most accurate member of M_E (Fig 6 stage-3 ablation: no exploration)."""
    in_me, base_index, fallback = _exploration_mask(accuracy, mu, sigma, t_budget)
    score = accuracy[None, :] - _EPS * mu[None, :]
    idx = jnp.argmax(jnp.where(in_me, score, -jnp.inf), axis=-1).astype(jnp.int32)
    idx = jnp.where(fallback, jnp.argmin(mu), idx).astype(jnp.int32)
    return idx, fallback


def oracle(key, accuracy, mu, sigma, t_sla, t_budget):
    """Beyond-paper upper bound: greedy against the *actual* remaining budget.

    Identical to budget_greedy when estimation is exact; differs under noisy
    estimators.  Useful as a ceiling in ablation plots.
    """
    return _greedy_at(accuracy, mu, sigma, t_budget)


ALGORITHMS: Dict[str, Callable] = {
    "mdinference": mdinference,
    "static_greedy": static_greedy,
    "budget_greedy": budget_greedy,
    "static_accuracy": static_accuracy,
    "static_latency": static_latency,
    "pure_random": pure_random,
    "related_random": related_random,
    "related_accurate": related_accurate,
    "oracle": oracle,
}


def get_algorithm(name: str) -> Callable:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; available: {sorted(ALGORITHMS)}"
        ) from None
