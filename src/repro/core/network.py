"""Network-time models and estimators (paper §III, §VI).

The paper's simulations draw the round-trip network time ``T_nw`` from
distributions parameterized by a mean and a coefficient of variation (CV),
and — for Table IV / Fig 7/8 — from 5 000-sample *measured* traces on a
university WiFi network (CV ~= 74 %) and a residential network.

We do not have the original traces, so :func:`university_trace` and
:func:`residential_trace` generate synthetic traces calibrated to Table IV's
two reliance columns, which pin two tail quantiles of each trace:

* MDInference / static-latency reliance == P(T_nw > SLA - mu_fastest)
  ~= P(T_nw > 246.8 ms):  0.26 % university, 3.16 % residential.
* static-accuracy reliance == P(T_nw > SLA - mu_NasNetLarge)
  ~= P(T_nw > 137.4 ms):  3.67 % university, 23.03 % residential.

A gamma body plus a small planted outage tail hits both quantiles:
university = gamma(mean 70 ms, CV 0.45) capped at 245 ms + 0.26 % uniform
(260, 900) ms; residential = gamma(mean 100 ms, CV 0.56) + 1.25 % uniform
(260, 1500) ms.  (The paper's "100 ms +- 50 ms" figure parameterizes its
CV-sweep simulations, not these measured traces.)
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "NetworkModel",
    "FixedCVNetwork",
    "LognormalNetwork",
    "TraceNetwork",
    "SwitchedNetwork",
    "university_trace",
    "residential_trace",
    "lte_trace",
    "NAMED_TRACES",
    "Estimator",
    "ExactEstimator",
    "NoisyEstimator",
    "EWMAEstimator",
]

_MIN_MS = 0.1  # network time floor; distributions are truncated below this


class NetworkModel:
    """Samples per-request round-trip network times (ms)."""

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FixedCVNetwork(NetworkModel):
    """Truncated-normal T_nw with a given mean and CV (paper Fig 4/5 sweep)."""

    mean_ms: float = 100.0
    cv: float = 0.5

    def sample(self, rng, n):
        sigma = self.mean_ms * self.cv
        out = rng.normal(self.mean_ms, sigma, size=n)
        return np.maximum(out, _MIN_MS)


@dataclasses.dataclass(frozen=True)
class LognormalNetwork(NetworkModel):
    """Lognormal T_nw parameterized by its mean and CV (heavier tail)."""

    mean_ms: float = 100.0
    cv: float = 0.74

    def sample(self, rng, n):
        var_ln = np.log1p(self.cv**2)
        mu_ln = np.log(self.mean_ms) - var_ln / 2.0
        out = rng.lognormal(mu_ln, np.sqrt(var_ln), size=n)
        return np.maximum(out, _MIN_MS)


@dataclasses.dataclass(frozen=True)
class TraceNetwork(NetworkModel):
    """Bootstrap-samples from an empirical trace of network times."""

    trace_ms: tuple[float, ...]

    def sample(self, rng, n):
        trace = np.asarray(self.trace_ms)
        return trace[rng.integers(0, len(trace), size=n)]


@dataclasses.dataclass(frozen=True)
class SwitchedNetwork(NetworkModel):
    """A mid-stream network handover: the first ``switch_frac`` fraction of
    requests samples from ``before``, the rest from ``after``.

    Models a device walking off university WiFi onto LTE (or back) —
    the paper's §III mobility motivation.  Requests are arrival-ordered
    in a :class:`~repro.serving.loadgen.LoadTrace`, so "first fraction of
    samples" is "first fraction of the run" for every arrival process in
    :mod:`repro.serving.loadgen`.
    """

    before: NetworkModel
    after: NetworkModel
    switch_frac: float = 0.5

    def __post_init__(self):
        if not 0.0 <= self.switch_frac <= 1.0:
            raise ValueError(
                f"switch_frac must be in [0, 1], got {self.switch_frac}"
            )

    def sample(self, rng, n):
        n_before = int(round(n * self.switch_frac))
        head = self.before.sample(rng, n_before)
        tail = self.after.sample(rng, n - n_before)
        return np.concatenate([np.asarray(head), np.asarray(tail)])


def _mixture_trace(
    rng: np.random.Generator,
    n: int,
    *,
    base_mean: float,
    base_cv: float,
    tail_frac: float,
    tail_lo: float,
    tail_hi: float,
    cap: float,
) -> np.ndarray:
    """Body-plus-tail synthetic trace.

    The body is a gamma distribution (non-negative, right-skewed, like WiFi
    RTTs) truncated at ``cap``; a ``tail_frac`` fraction of samples is drawn
    uniformly from ``[tail_lo, tail_hi]`` to model the long outages the paper
    measured.
    """
    shape = 1.0 / base_cv**2
    scale = base_mean / shape
    body = rng.gamma(shape, scale, size=n)
    if cap is not None:
        body = np.minimum(body, cap)
    tail = rng.uniform(tail_lo, tail_hi, size=n)
    is_tail = rng.random(n) < tail_frac
    return np.maximum(np.where(is_tail, tail, body), _MIN_MS)


def university_trace(seed: int = 0, n: int = 5000) -> TraceNetwork:
    """Synthetic university-WiFi trace (fast body, rare outages).

    Calibrated: P(T_nw > 137.4) ~= 3.67 %, P(T_nw > 246.8) ~= 0.26 %
    (Table IV reliance columns, university).
    """
    rng = np.random.default_rng(seed)
    t = _mixture_trace(
        rng,
        n,
        base_mean=70.0,
        base_cv=0.45,
        tail_frac=0.0026,
        tail_lo=260.0,
        tail_hi=900.0,
        cap=245.0,
    )
    return TraceNetwork(tuple(t.tolist()))


def residential_trace(seed: int = 1, n: int = 5000) -> TraceNetwork:
    """Synthetic residential trace (slower body, heavier tail).

    Calibrated: P(T_nw > 137.4) ~= 23.0 %, P(T_nw > 246.8) ~= 3.16 %
    (Table IV reliance columns, residential).
    """
    rng = np.random.default_rng(seed)
    t = _mixture_trace(
        rng,
        n,
        base_mean=100.0,
        base_cv=0.56,
        tail_frac=0.0125,
        tail_lo=260.0,
        tail_hi=1500.0,
        cap=None,
    )
    return TraceNetwork(tuple(t.tolist()))


def lte_trace(seed: int = 2, n: int = 5000) -> TraceNetwork:
    """Synthetic LTE trace (cellular: slower, jittery body, handover tail).

    Not calibrated to a Table IV column (the paper measured WiFi and
    residential links); parameters follow the paper's §III observation that
    cellular RTTs are both slower on average and far more variable, with
    multi-second outages during handovers.
    """
    rng = np.random.default_rng(seed)
    t = _mixture_trace(
        rng,
        n,
        base_mean=120.0,
        base_cv=0.80,
        tail_frac=0.02,
        tail_lo=300.0,
        tail_hi=3000.0,
        cap=None,
    )
    return TraceNetwork(tuple(t.tolist()))


#: Named trace factories for load generation and examples/benchmarks.
NAMED_TRACES = {
    "university": university_trace,
    "residential": residential_trace,
    "lte": lte_trace,
}


# ---------------------------------------------------------------------------
# Estimators: how the server guesses T_nw for the budget (paper: 2 x T_input,
# measured server-side before inference begins — i.e. near-exact for
# symmetric links).
# ---------------------------------------------------------------------------
class Estimator:
    def estimate(self, rng: np.random.Generator, actual: np.ndarray) -> np.ndarray:
        raise NotImplementedError


class ExactEstimator(Estimator):
    """T_nw known exactly (paper's 2xT_input with symmetric up/down links)."""

    def estimate(self, rng, actual):
        return np.asarray(actual)


@dataclasses.dataclass(frozen=True)
class NoisyEstimator(Estimator):
    """Multiplicative lognormal estimation error with a given relative std."""

    rel_std: float = 0.1

    def estimate(self, rng, actual):
        noise = rng.lognormal(0.0, self.rel_std, size=np.shape(actual))
        return np.asarray(actual) * noise


@dataclasses.dataclass(frozen=True)
class EWMAEstimator(Estimator):
    """Exponentially-weighted moving average over *previous* observations.

    Models a client that predicts the next RTT from history rather than
    measuring the current transfer.  Sequential by construction.
    """

    alpha: float = 0.3

    def estimate(self, rng, actual):
        actual = np.asarray(actual)
        est = np.empty_like(actual)
        ewma = actual[0] if len(actual) else 0.0
        for i, obs in enumerate(actual):
            est[i] = ewma
            ewma = self.alpha * obs + (1.0 - self.alpha) * ewma
        return est
