"""MDInference's three-stage probabilistic model selection (paper §V-A).

Two implementations are provided:

* :func:`select_ref` — a direct, readable Python transliteration of the
  paper's algorithm.  One request at a time.  This is the oracle used in
  tests.
* :func:`select_batch` — a fully vectorized ``jnp`` implementation that
  selects for a whole batch of requests in one shot.  It is ``jax.jit``-able
  and is what both the simulator and the serving scheduler use.

Stage 1 (greedy base, Eq. 1–2):
    maximize A(m) subject to mu(m) + sigma(m) < T_budget.
    If no model satisfies the constraint the *fastest* model is chosen and
    execution begins immediately (no exploration).

Stage 2 (exploration set, Eq. 3):
    M_E = { m : mu(m) in [mu(m_b) - sigma(m_b), mu(m_b) + sigma(m_b)] }.

Stage 3 (utility sampling, Eq. 4):
    U(m) = A(m) * (T_budget - (mu(m)+sigma(m))) / |T_budget - mu(m)|,
    normalized over M_E, sampled.

Notes on faithfulness:
  * Eq. 4 can yield negative utilities for M_E members that violate the
    latency constraint; a negative selection probability is meaningless, so
    we clamp utilities at zero before normalizing (the paper's stage 3 is
    described as "accounting for" such members — clamping removes them).
    If *every* utility clamps to zero we fall back to the base model.
  * ``utility_power`` (default 1.0) is a beyond-paper knob: probabilities are
    proportional to ``U**utility_power``.  1.0 reproduces Eq. 4 exactly;
    larger values sharpen selection toward the max-utility model.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import ModelRegistry

__all__ = [
    "SelectionResult",
    "compute_budget",
    "select_ref",
    "select_batch",
    "selection_probabilities",
]

_EPS = 1e-9


def compute_budget(t_sla_ms, t_nw_ms):
    """``T_budget = T_sla - T_nw`` (paper §V-A)."""
    return t_sla_ms - t_nw_ms


@dataclasses.dataclass(frozen=True)
class SelectionResult:
    """Outcome of one selection."""

    index: int  # model chosen for execution
    base_index: int  # stage-1 base model m_b
    fallback: bool  # True when stage 1 found no feasible model
    exploration_set: tuple[int, ...]  # indices of M_E (empty on fallback)
    probabilities: tuple[float, ...]  # selection probs aligned with M_E


# ---------------------------------------------------------------------------
# Reference (per-request, plain Python) implementation.
# ---------------------------------------------------------------------------
def select_ref(
    registry: ModelRegistry,
    t_budget_ms: float,
    rng: np.random.Generator,
    *,
    utility_power: float = 1.0,
) -> SelectionResult:
    """Paper-faithful single-request selection."""
    profiles = registry.profiles

    # Stage 1: greedy base model.
    eligible = [i for i, p in enumerate(profiles) if p.mu_ms + p.sigma_ms < t_budget_ms]
    if not eligible:
        fastest = registry.fastest_index
        return SelectionResult(
            index=fastest,
            base_index=fastest,
            fallback=True,
            exploration_set=(),
            probabilities=(),
        )
    base = max(eligible, key=lambda i: (profiles[i].accuracy, -profiles[i].mu_ms))
    mu_b, sig_b = profiles[base].mu_ms, profiles[base].sigma_ms

    # Stage 2: exploration set around the base model.
    explore = [
        i
        for i, p in enumerate(profiles)
        if mu_b - sig_b <= p.mu_ms <= mu_b + sig_b
    ]

    # Stage 3: utility-weighted sampling.
    utils = []
    for i in explore:
        p = profiles[i]
        denom = abs(t_budget_ms - p.mu_ms) + _EPS
        u = p.accuracy * (t_budget_ms - (p.mu_ms + p.sigma_ms)) / denom
        utils.append(max(u, 0.0) ** utility_power if u > 0 else 0.0)
    total = sum(utils)
    if total <= 0.0:
        return SelectionResult(
            index=base,
            base_index=base,
            fallback=False,
            exploration_set=tuple(explore),
            probabilities=tuple(0.0 for _ in explore),
        )
    probs = [u / total for u in utils]
    choice = explore[int(rng.choice(len(explore), p=probs))]
    return SelectionResult(
        index=choice,
        base_index=base,
        fallback=False,
        exploration_set=tuple(explore),
        probabilities=tuple(probs),
    )


# ---------------------------------------------------------------------------
# Vectorized (batched, jit-able) implementation.
# ---------------------------------------------------------------------------
class BatchSelection(NamedTuple):
    """Vectorized selection outcome for a batch of requests."""

    index: jax.Array  # (R,) int32 — model chosen per request
    base_index: jax.Array  # (R,) int32 — stage-1 base model
    fallback: jax.Array  # (R,) bool — stage-1 infeasible, fastest used
    probabilities: jax.Array  # (R, N) float32 — stage-3 probs (0 outside M_E)


def selection_probabilities(
    accuracy: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    t_budget: jax.Array,
    *,
    utility_power: float = 1.0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stages 1–3 without sampling.

    Args:
      accuracy, mu, sigma: (N,) model profile arrays.
      t_budget: (R,) per-request budgets in ms.

    Returns:
      (probs (R, N), base_index (R,), fallback (R,)).
      On fallback rows ``probs`` is a one-hot of the fastest model.
    """
    t_budget = jnp.asarray(t_budget)
    squeeze = t_budget.ndim == 0
    t_budget = jnp.atleast_1d(t_budget)[:, None]  # (R, 1)

    fits = (mu + sigma)[None, :] < t_budget  # (R, N)
    any_fit = fits.any(axis=-1)  # (R,)

    # Stage 1: among feasible models maximize accuracy, tie-break on lower mu.
    score = accuracy[None, :] - _EPS * mu[None, :]
    base_index = jnp.argmax(jnp.where(fits, score, -jnp.inf), axis=-1)
    fastest = jnp.argmin(mu)
    base_index = jnp.where(any_fit, base_index, fastest).astype(jnp.int32)

    # Stage 2: exploration set around the base model.
    mu_b = mu[base_index][:, None]  # (R, 1)
    sig_b = sigma[base_index][:, None]
    in_me = (mu[None, :] >= mu_b - sig_b) & (mu[None, :] <= mu_b + sig_b)

    # Stage 3: utilities (Eq. 4), clamped at zero, normalized over M_E.
    denom = jnp.abs(t_budget - mu[None, :]) + _EPS
    util = accuracy[None, :] * (t_budget - (mu + sigma)[None, :]) / denom
    util = jnp.where(in_me, jnp.maximum(util, 0.0), 0.0)
    util = jnp.where(util > 0, util**utility_power, 0.0)
    total = util.sum(axis=-1, keepdims=True)

    base_onehot = jax.nn.one_hot(base_index, mu.shape[0], dtype=util.dtype)
    fastest_onehot = jax.nn.one_hot(
        jnp.full_like(base_index, fastest), mu.shape[0], dtype=util.dtype
    )
    probs = jnp.where(total > 0, util / jnp.maximum(total, _EPS), base_onehot)
    probs = jnp.where(any_fit[:, None], probs, fastest_onehot)
    if squeeze:
        return probs[0], base_index[0], ~any_fit[0]
    return probs, base_index, ~any_fit


def select_batch(
    key: jax.Array,
    accuracy: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    t_budget: jax.Array,
    *,
    utility_power: float = 1.0,
) -> BatchSelection:
    """Vectorized three-stage selection for a batch of requests."""
    probs, base_index, fallback = selection_probabilities(
        accuracy, mu, sigma, jnp.atleast_1d(t_budget), utility_power=utility_power
    )
    logits = jnp.log(jnp.maximum(probs, 1e-30))
    index = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    return BatchSelection(
        index=index,
        base_index=base_index,
        fallback=jnp.atleast_1d(fallback),
        probabilities=probs,
    )
