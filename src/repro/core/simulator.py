"""Empirically-driven simulation engine (paper §VI methodology).

Per simulation: draw ``n_requests`` network times from a network model,
estimate them (the server's 2xT_input measurement), run a selection
algorithm over the zoo, sample execution latencies ~ N(mu, sigma), and
aggregate SLA / accuracy metrics — optionally resolving each request through
the on-device duplication mechanism.

The selection step is the vectorized jnp implementation under ``jax.jit``;
the surrounding sampling is NumPy (it is plain Monte-Carlo bookkeeping).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.duplication import (
    DEFAULT_ON_DEVICE,
    ModelProfile,
    resolve_duplication,
)
from repro.core.network import Estimator, ExactEstimator, NetworkModel
from repro.core.registry import ModelRegistry
from repro.core.sla import RequestMetrics, summarize

__all__ = ["SimConfig", "SimResult", "run_simulation"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    registry: ModelRegistry
    algorithm: Union[str, Callable] = "mdinference"
    t_sla_ms: float = 250.0
    n_requests: int = 10_000
    network: NetworkModel = None  # required
    estimator: Estimator = dataclasses.field(default_factory=ExactEstimator)
    duplication: bool = False
    ondevice: ModelProfile = DEFAULT_ON_DEVICE
    seed: int = 0
    utility_power: float = 1.0  # 1.0 == paper-faithful Eq. 4
    queue_delay_mean_ms: float = 0.0  # optional server queueing transients
    queue_spike_prob: float = 0.0


@dataclasses.dataclass(frozen=True)
class SimResult:
    metrics: RequestMetrics
    model_index: np.ndarray  # (R,) selected model per request
    fallback: np.ndarray  # (R,) stage-1 infeasible
    t_nw_ms: np.ndarray  # (R,) actual network time
    exec_ms: np.ndarray  # (R,) remote execution time
    remote_latency_ms: np.ndarray  # (R,) network + execution (+ queue)
    used_remote: Optional[np.ndarray]  # (R,) or None when duplication off


@functools.partial(jax.jit, static_argnames=("fn", "utility_power"))
def _run_selection(fn, key, acc, mu, sigma, t_sla, t_budget, utility_power):
    if fn is baselines.mdinference:
        return fn(key, acc, mu, sigma, t_sla, t_budget, utility_power=utility_power)
    return fn(key, acc, mu, sigma, t_sla, t_budget)


def run_simulation(cfg: SimConfig) -> SimResult:
    if cfg.network is None:
        raise ValueError("SimConfig.network is required")
    rng = np.random.default_rng(cfg.seed)
    reg = cfg.registry
    n = cfg.n_requests

    # 1. Network times and the server's estimate of them.
    t_nw = cfg.network.sample(rng, n)
    t_nw_est = cfg.estimator.estimate(rng, t_nw)
    t_budget = cfg.t_sla_ms - t_nw_est

    # 2. Model selection (vectorized, jitted).
    fn = (
        baselines.get_algorithm(cfg.algorithm)
        if isinstance(cfg.algorithm, str)
        else cfg.algorithm
    )
    key = jax.random.key(cfg.seed)
    idx, fallback = _run_selection(
        fn,
        key,
        jnp.asarray(reg.accuracy),
        jnp.asarray(reg.mu),
        jnp.asarray(reg.sigma),
        jnp.float32(cfg.t_sla_ms),
        jnp.asarray(t_budget, dtype=jnp.float32),
        cfg.utility_power,
    )
    idx = np.asarray(idx)
    fallback = np.asarray(fallback)

    # 3. Remote execution latency ~ N(mu, sigma), optional queueing spikes.
    exec_ms = np.maximum(
        rng.normal(reg.mu[idx], reg.sigma[idx]), 0.1
    )
    if cfg.queue_spike_prob > 0.0:
        spike = rng.random(n) < cfg.queue_spike_prob
        exec_ms = exec_ms + spike * rng.exponential(
            cfg.queue_delay_mean_ms, size=n
        )
    remote_latency = t_nw + exec_ms

    # 4. Resolve (with or without duplication) and summarize.
    if cfg.duplication:
        ondev_ms = np.maximum(
            rng.normal(cfg.ondevice.mu_ms, cfg.ondevice.sigma_ms, size=n), 0.1
        )
        out = resolve_duplication(
            remote_latency_ms=remote_latency,
            remote_accuracy=reg.accuracy[idx],
            ondevice_latency_ms=ondev_ms,
            ondevice_accuracy=cfg.ondevice.accuracy,
            t_sla_ms=cfg.t_sla_ms,
        )
        metrics = summarize(
            accuracy_used=out.accuracy,
            latency_ms=out.latency_ms,
            t_sla_ms=cfg.t_sla_ms,
            model_names=reg.names,
            model_index=idx,
            used_remote=out.used_remote,
        )
        used_remote = out.used_remote
    else:
        metrics = summarize(
            accuracy_used=reg.accuracy[idx],
            latency_ms=remote_latency,
            t_sla_ms=cfg.t_sla_ms,
            model_names=reg.names,
            model_index=idx,
        )
        used_remote = None

    return SimResult(
        metrics=metrics,
        model_index=idx,
        fallback=fallback,
        t_nw_ms=t_nw,
        exec_ms=exec_ms,
        remote_latency_ms=remote_latency,
        used_remote=used_remote,
    )
