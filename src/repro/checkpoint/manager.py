"""Checkpointing: atomic, async-capable save/restore with resharding.

Fault-tolerance contract:
  * Saves are atomic (write to ``step_N.tmp`` then rename) — a crash mid-save
    never corrupts the latest checkpoint.
  * ``restore`` accepts target shardings and ``device_put``s each leaf onto
    them: restoring onto a *different* mesh (elastic restart after losing a
    pod, or scaling data-parallel up/down) is just a re-shard, exercised in
    tests/test_checkpoint.py.
  * ``save_async`` snapshots to host memory synchronously and writes on a
    background thread — the train loop stalls for the device->host copy only.
  * Keeps the most recent ``keep`` checkpoints (plus any step in
    ``keep_steps``), pruned oldest-first.

Single-process implementation note: on a real multi-host pod each process
writes only its addressable shards (jax.experimental.array_serialization);
the manifest format (flat path -> shape/dtype) is unchanged.  The process-
local npz container is the only thing that would change.
"""
from __future__ import annotations

import itertools
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}

    def walk(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (str(k),), v)
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                walk(path + (str(i),), v)
        else:
            flat[_SEP.join(path)] = node

    walk((), tree)
    return flat


def _unflatten_like(template, flat: dict):
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (str(k),), v) for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(walk(path + (str(i),), v) for i, v in enumerate(node))
        if isinstance(node, list):
            return [walk(path + (str(i),), v) for i, v in enumerate(node)]
        return flat[_SEP.join(path)]

    return walk((), template)


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, keep_steps=()):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.keep_steps = set(keep_steps)
        self._thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1].split(".")[0])
            for p in self.dir.glob("step_*")
            if ".tmp" not in p.name
        )

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state, *, extra: Optional[dict] = None):
        """Blocking atomic save."""
        self.wait()  # don't race an in-flight async save of the same step
        host = jax.tree.map(np.asarray, state)
        self._write(step, host, extra or {})

    def save_async(self, step: int, state, *, extra: Optional[dict] = None):
        """Snapshot synchronously, write in the background."""
        self.wait()  # one in-flight save at a time
        host = jax.tree.map(np.asarray, state)  # device->host happens here
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    _uniq = itertools.count()

    def _write(self, step: int, host_state, extra: dict):
        flat = _flatten(host_state)
        # Unique staging dir: concurrent writers of the same step (sync +
        # async) must never share a tmp path; the final rename is atomic.
        tmp = self.dir / f"step_{step:08d}.tmp{os.getpid()}_{next(self._uniq)}"
        final = self._step_dir(step)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra,
            "arrays": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()
            },
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            if s not in self.keep_steps:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(
        self,
        template,
        step: Optional[int] = None,
        *,
        shardings=None,
    ):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree (same structure) of Shardings —
        leaves are device_put onto them (reshard-on-restore).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        with np.load(d / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_like(template, flat)

        def put(x, t, s=None):
            arr = np.asarray(x).astype(np.asarray(t).dtype if hasattr(t, "dtype") else x.dtype)
            return jax.device_put(arr, s) if s is not None else jax.numpy.asarray(arr)

        if shardings is not None:
            return jax.tree.map(put, tree, template, shardings), step
        return jax.tree.map(lambda x, t: put(x, t), tree, template), step

    def manifest(self, step: int) -> dict:
        return json.loads((self._step_dir(step) / "manifest.json").read_text())
