"""Fault-tolerant checkpointing with reshard-on-restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
