"""Per-replica health state: circuit breakers + drain flags.

The pool becomes *dynamic* here: every :class:`repro.serving.cluster.Replica`
carries a :class:`ReplicaHealth`, and routing consults it each pick — a
replica leaves the eligible set the same tick its breaker opens or a drain
begins, and rejoins the same tick a half-open probe succeeds.

The breaker is the classic three-state machine::

        failure (fatal, or consecutive >= threshold)
    CLOSED ──────────────────────────────────────────▶ OPEN (reason, open_until)
      ▲                                                  │ cooldown elapses
      │ probe succeeds                                   ▼
      └───────────────────────────────────────────── HALF_OPEN
                 probe fails ──▶ back to OPEN (cooldown backs off)

* **closed** — healthy; every completion feeds the consecutive-failure
  counter (any success resets it).
* **open** — not routable; carries the trip ``reason`` and ``open_until_ms``
  (loop-clock).  Fatal trips (worker death, timeout) open immediately;
  ordinary execution errors must accumulate ``failure_threshold``
  consecutively.  Repeated trips back the cooldown off exponentially, so a
  flapping replica converges to long quarantines instead of oscillating.
* **half_open** — the cooldown elapsed; exactly *one* probe batch may be
  routed (``on_dispatch`` claims it).  Success closes the breaker and
  resets the backoff; failure re-opens with the next-longer cooldown.

A *permanent* trip (``open_until_ms = inf`` — an operator ``kill``) never
half-opens; only an explicit :meth:`CircuitBreaker.reset` (rejoin) recovers
it.  Draining is orthogonal: a draining replica is unroutable regardless of
breaker state, but its in-flight batches finish normally.

All timing is in loop-clock milliseconds (the serving loop's trace time,
fed through ``ClusterBackend.advance_clock``), so breaker behavior is
deterministic under the sync/CI dispatch mode.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

__all__ = ["BreakerConfig", "CircuitBreaker", "ReplicaHealth"]


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Tunables of one replica's circuit breaker."""

    failure_threshold: int = 3  # consecutive errors that trip a closed breaker
    cooldown_ms: float = 1_000.0  # first open period (loop-clock ms)
    backoff: float = 2.0  # cooldown multiplier per consecutive trip
    max_cooldown_ms: float = 30_000.0  # backoff ceiling

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got {self.failure_threshold}"
            )
        if self.cooldown_ms <= 0:
            raise ValueError(f"cooldown_ms must be > 0, got {self.cooldown_ms}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")


class CircuitBreaker:
    """Three-state (closed / open / half-open) failure isolator.

    Not internally locked: all transitions happen on the serving loop's
    tick thread (routing, completion collection) — the cluster layer is
    the single writer.
    """

    def __init__(self, cfg: BreakerConfig = BreakerConfig()):
        self.cfg = cfg
        self.state = "closed"
        self.reason: Optional[str] = None
        self.open_until_ms: Optional[float] = None
        self.consecutive_failures = 0
        self.trips = 0  # lifetime trip count (drives the cooldown backoff)
        self._probe_inflight = False
        # Optional observability hookup (set by the cluster layer, which
        # knows the replica id).  None keeps transitions metric-free.
        self._obs = None
        self._obs_labels: dict = {}
        self._obs_track: Optional[str] = None

    def attach_observability(self, obs, track: Optional[str] = None, **labels):
        """Wire trip/recovery events to a metrics+trace handle.

        ``labels`` (e.g. ``replica="2"``) tag the counters; ``track``
        places the ``breaker.trip`` instants on that trace row.
        """
        self._obs = obs
        self._obs_labels = labels
        self._obs_track = track

    # -- inspection -----------------------------------------------------------
    @property
    def healthy(self) -> bool:
        return self.state == "closed"

    @property
    def permanently_open(self) -> bool:
        return self.state == "open" and self.open_until_ms == math.inf

    # -- routing-side ---------------------------------------------------------
    def routable(self, now_ms: float) -> bool:
        """Whether a batch may be routed here at ``now_ms``.

        An open breaker whose cooldown elapsed transitions to half-open
        *here* (routing is the observer of time); half-open admits exactly
        one probe at a time — claimed by :meth:`on_dispatch`, not by this
        check, so pure eligibility queries (``hosted_mask``) never consume
        the probe slot.
        """
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.open_until_ms is not None and now_ms >= self.open_until_ms:
                self.state = "half_open"
                self._probe_inflight = False
                return True
            return False
        return not self._probe_inflight  # half_open: one probe at a time

    def on_dispatch(self, now_ms: float) -> None:
        """A batch was routed here; a half-open breaker's probe slot is
        now claimed until that batch completes."""
        if self.state == "half_open":
            self._probe_inflight = True

    # -- completion-side ------------------------------------------------------
    def on_success(self, now_ms: float) -> None:
        """A routed batch completed: close the breaker, reset the backoff."""
        if self._obs is not None and self.state != "closed":
            self._obs.counter(
                "breaker_recoveries_total", **self._obs_labels
            ).inc()
        self.state = "closed"
        self.reason = None
        self.open_until_ms = None
        self.consecutive_failures = 0
        self.trips = 0
        self._probe_inflight = False

    def on_failure(self, now_ms: float, reason: str, fatal: bool = False) -> None:
        """A routed batch failed.

        ``fatal`` (worker death, timeout) trips immediately; ordinary
        errors trip after ``failure_threshold`` consecutive failures.  A
        half-open probe failure always re-opens (that is the probe's job).
        """
        if self.permanently_open:
            return  # a killed replica stays killed until reset()
        self.consecutive_failures += 1
        if (
            fatal
            or self.state == "half_open"
            or self.consecutive_failures >= self.cfg.failure_threshold
        ):
            self.trip(now_ms, reason)

    def trip(self, now_ms: float, reason: str, permanent: bool = False) -> None:
        """Open the breaker (cooldown backs off per consecutive trip)."""
        if self._obs is not None:
            self._obs.counter(
                "breaker_trips_total", **self._obs_labels
            ).inc()
            self._obs.tracer.instant(
                "breaker.trip",
                cat="health",
                track=self._obs_track,
                reason=reason,
                permanent=permanent,
                now_ms=now_ms,
                **self._obs_labels,
            )
        self.trips += 1
        self.state = "open"
        self.reason = reason
        self._probe_inflight = False
        if permanent:
            self.open_until_ms = math.inf
        else:
            cooldown = min(
                self.cfg.cooldown_ms * self.cfg.backoff ** (self.trips - 1),
                self.cfg.max_cooldown_ms,
            )
            self.open_until_ms = now_ms + cooldown

    def reset(self) -> None:
        """Operator rejoin: forget all failure history and close."""
        self.state = "closed"
        self.reason = None
        self.open_until_ms = None
        self.consecutive_failures = 0
        self.trips = 0
        self._probe_inflight = False

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        extra = f", reason={self.reason!r}" if self.reason else ""
        return f"CircuitBreaker({self.state}{extra})"


class ReplicaHealth:
    """One replica's routability: breaker state + drain flag.

    ``draining`` removes the replica from routing without tripping the
    breaker — in-flight batches complete, nothing new arrives, and
    ``undrain``/rejoin restores it instantly (drain is an operator
    action, not a failure).
    """

    def __init__(self, breaker: Optional[CircuitBreaker] = None):
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.draining = False

    def routable(self, now_ms: float) -> bool:
        return not self.draining and self.breaker.routable(now_ms)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        drain = ", draining" if self.draining else ""
        return f"ReplicaHealth({self.breaker.state}{drain})"
