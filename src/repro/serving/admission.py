"""Bounded admission with explicit backpressure — the loop's front door.

The event loop (:class:`repro.serving.loop.ServingLoop`) used to drain its
*entire* pending list every tick: a burst from ``loadgen`` inflated batch
sizes and queue waits without limit.  :class:`AdmissionQueue` makes
admission a first-class, capacity-bounded stage:

* ``max_pending`` — the bounded FIFO of admitted-but-unscheduled requests.
  What happens at capacity is the *overload policy* (below).
* ``max_chunk`` — per-tick scheduling cap: one tick takes at most this
  many requests; the rest stay queued across ticks (the persistent
  multi-tick queue).
* ``max_inflight_ticks`` — dispatch gate for the ``wait=False`` event
  loop: no new tick is dispatched while this many are already in flight.

Overload policies (engaged only when ``max_pending`` is set):

* ``"unbounded"`` — no capacity bound; byte-identical to the pre-admission
  loop (the compatibility default, and the reference the regression tests
  pin).
* ``"block"`` — client-side backpressure: ``submit`` returns a future that
  is *not yet admitted* (``InferenceFuture.admitted`` is False); it waits
  in an overflow room and is admitted FIFO as capacity frees.  No work is
  dropped — the queue is pushed back to the client.
* ``"shed"`` — deadline-aware rejection: a request at capacity, or one
  whose queue wait already makes its SLA unreachable
  (:func:`sla_unreachable`), resolves immediately with the terminal
  :attr:`repro.serving.lifecycle.RequestState.REJECTED` state.  Served
  requests keep a bounded wait — the policy trades goodput for tail
  latency.
* ``"degrade"`` — accuracy-for-latency: overflow routes to the on-device
  tier *alone* (no remote leg, no two-tier hedge).  The server queue stays
  bounded and every request is answered, at the duplicate's accuracy.

The shed predicate is deliberately *monotone in queue wait*: a request shed
at wait ``w`` would also be shed at any wait ``> w`` (property-tested in
``tests/test_admission.py``) — so shedding never resurrects a request that
a longer wait would have doomed.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.serving.lifecycle import InferenceFuture, RequestState
from repro.serving.tenancy import TenantConfig, TenantLanes

__all__ = [
    "OVERLOAD_POLICIES",
    "AdmissionConfig",
    "AdmissionBatch",
    "AdmissionQueue",
    "sla_unreachable",
]

OVERLOAD_POLICIES = ("unbounded", "block", "shed", "degrade")

_UNSET = object()  # retune(): "leave this knob alone" sentinel


def sla_unreachable(
    queue_wait_ms: float,
    sla_ms: float,
    t_nw_est_ms: float = 0.0,
    service_floor_ms: float = 0.0,
    headroom_ms: float = 0.0,
    ondevice_floor_ms: Optional[float] = None,
) -> bool:
    """True when a request's SLA cannot be met even by the fastest path.

    The cheapest completion estimate is the better of the two execution
    paths: the remote leg (``t_nw_est_ms`` network round trip + the
    fastest model's expected execution ``service_floor_ms``) and — when a
    hedge tier exists (``ondevice_floor_ms``) — the on-device duplicate,
    which has *no* network leg.  On a terrible network the duplicate is
    exactly what rescues the request, so shedding must not charge it the
    network estimate.  ``headroom_ms`` adds a safety margin.  Monotone in
    ``queue_wait_ms`` by construction — no other term depends on the wait.
    """
    best_ms = t_nw_est_ms + service_floor_ms
    if ondevice_floor_ms is not None:
        best_ms = min(best_ms, ondevice_floor_ms)
    return queue_wait_ms + best_ms + headroom_ms > sla_ms


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Capacity bounds + overload policy for an :class:`AdmissionQueue`.

    The default (everything ``None``, policy ``"unbounded"``) reproduces
    the pre-admission loop exactly: every submit is admitted immediately
    and every tick drains the whole pending queue.
    """

    max_pending: Optional[int] = None  # bounded FIFO capacity (None: ∞)
    max_chunk: Optional[int] = None  # per-tick scheduling cap (None: all)
    max_inflight_ticks: Optional[int] = None  # wait=False dispatch gate
    policy: str = "unbounded"  # what happens at max_pending capacity
    shed_headroom_ms: float = 0.0  # extra margin in the shed predicate
    # Multi-tenant QoS: per-tenant lanes drained strict-priority +
    # deficit-weighted-fair (None — the default — keeps the single-class
    # FIFO path, byte-identical to the pre-tenancy queue).
    tenants: Optional[Tuple[TenantConfig, ...]] = None

    def __post_init__(self):
        if self.policy not in OVERLOAD_POLICIES:
            raise ValueError(
                f"policy must be one of {OVERLOAD_POLICIES}, got {self.policy!r}"
            )
        if self.tenants is not None:
            object.__setattr__(self, "tenants", tuple(self.tenants))
            for t in self.tenants:
                if not isinstance(t, TenantConfig):
                    raise TypeError(f"tenants must be TenantConfig, got {t!r}")
        tenant_bounded = self.tenants is not None and any(
            t.max_pending is not None for t in self.tenants
        )
        if (
            self.policy != "unbounded"
            and self.max_pending is None
            and not tenant_bounded
        ):
            raise ValueError(
                f"policy {self.policy!r} requires max_pending (the capacity "
                "whose overflow it governs) — globally or on some tenant"
            )
        for field in ("max_pending", "max_chunk", "max_inflight_ticks"):
            v = getattr(self, field)
            if v is not None and v < 1:
                raise ValueError(f"{field} must be >= 1 or None, got {v}")

    @property
    def bounded(self) -> bool:
        return self.max_pending is not None and self.policy != "unbounded"


@dataclasses.dataclass
class AdmissionBatch:
    """What one tick takes from the admission queue."""

    chunk: List[InferenceFuture]  # requests for the remote/hedged path
    degraded: List[InferenceFuture]  # requests for the on-device-only path
    shed: List[InferenceFuture]  # rejected this take (already REJECTED)
    now_ms: float  # the tick's loop-clock timestamp

    def __bool__(self) -> bool:
        return bool(self.chunk or self.degraded)


class AdmissionQueue:
    """Bounded FIFO admission stage with pluggable overload policies.

    Thread-safe: :meth:`offer` may race :meth:`take` from another thread —
    a submitted future lands in exactly one of (admitted queue, overflow
    room, degrade lane, rejected), never vanishes.  Conservation holds at
    all times::

        n_submitted == n_resolved + n_rejected + n_cancelled + backlog + in-flight
    """

    def __init__(self, cfg: AdmissionConfig = AdmissionConfig()):
        self.cfg = cfg
        self._obs = None  # Observability handle; None keeps the bare path
        self._lock = threading.Lock()
        self._admitted: Deque[InferenceFuture] = deque()
        self._overflow: Deque[InferenceFuture] = deque()  # block policy
        self._degraded: Deque[InferenceFuture] = deque()  # degrade policy
        # Tenancy: per-tenant lanes replace the single admitted FIFO when
        # the config names tenants (None keeps the FIFO path untouched).
        self._lanes: Optional[TenantLanes] = (
            None if cfg.tenants is None else TenantLanes(cfg.tenants)
        )
        self.n_submitted = 0
        self.n_rejected = 0  # overflow-rejected + deadline-shed
        self.n_degraded = 0  # routed to the on-device-only lane
        self.n_requeued = 0  # lost-batch rows returned by the loop
        # Per-tenant accounting (lane name -> count); empty without lanes.
        self.tenant_submitted: Dict[str, int] = {}
        self.tenant_rejected: Dict[str, int] = {}

    # -- bookkeeping -----------------------------------------------------------
    @staticmethod
    def _queued(q: Deque[InferenceFuture]) -> int:
        return sum(1 for f in q if f.state is RequestState.QUEUED)

    @property
    def pending(self) -> int:
        """Admitted requests waiting for a tick (bounded by max_pending)."""
        with self._lock:
            if self._lanes is not None:
                return self._lanes.n_queued()
            return self._queued(self._admitted)

    @property
    def blocked(self) -> int:
        """Not-yet-admitted requests waiting in the overflow room."""
        with self._lock:
            return self._queued(self._overflow)

    @property
    def degrade_pending(self) -> int:
        """Requests waiting in the on-device-only degrade lane."""
        with self._lock:
            return self._queued(self._degraded)

    @property
    def backlog(self) -> int:
        """Everything still waiting for a tick, across all lanes."""
        with self._lock:
            admitted = (
                self._lanes.n_queued()
                if self._lanes is not None
                else self._queued(self._admitted)
            )
            return (
                admitted
                + self._queued(self._overflow)
                + self._queued(self._degraded)
            )

    def tenant_pending(self, name: str) -> int:
        """Queued requests in one tenant's lane (0 without tenancy)."""
        with self._lock:
            if self._lanes is None:
                return 0
            return self._lanes.n_queued(name)

    @staticmethod
    def _admit_stamp(future: InferenceFuture) -> None:
        future.admitted = True
        future.admitted_wall_ms = time.perf_counter() * 1e3

    # -- observability ---------------------------------------------------------
    def attach_observability(self, obs) -> None:
        """Attach a :class:`repro.observability.Observability` handle.

        Offer dispositions, take-side shed counts, queue-wait histograms,
        and lane-depth gauges are emitted through it.  Never attached
        (the default), every path is the exact pre-observability one.
        """
        self._obs = obs

    def _note_offer(self, disposition: str) -> None:
        self._obs.counter(
            "admission_offers_total", disposition=disposition
        ).inc()

    def _note_take(self, batch: AdmissionBatch) -> None:
        """Record one take's outcome (only called with ``_obs`` attached)."""
        obs = self._obs
        wait_hist = obs.histogram("admission_queue_wait_ms")
        for f in batch.chunk:
            wait_hist.record(max(batch.now_ms - f.request.arrival_ms, 0.0))
        if batch.shed:
            obs.counter("admission_shed_total").inc(len(batch.shed))
        if batch.degraded:
            obs.counter("admission_degraded_taken_total").inc(
                len(batch.degraded)
            )
        obs.gauge("admission_pending").set(self.pending)
        obs.gauge("admission_blocked").set(self.blocked)
        if self._lanes is not None:
            for f in batch.chunk:
                obs.counter(
                    "tenant_selected_total", tenant=self._lanes.name_of(f)
                ).inc()
            with self._lock:
                depths = self._lanes.depths()
            for name, depth in depths.items():
                obs.gauge("tenant_lane_depth", tenant=name).set(depth)

    # -- adaptive retuning -----------------------------------------------------
    def retune(
        self,
        *,
        max_pending=_UNSET,
        max_chunk=_UNSET,
        shed_headroom_ms=_UNSET,
    ) -> AdmissionConfig:
        """Replace the queue's *capacity* knobs mid-run — the surface the
        adaptive :class:`repro.serving.controller.AdmissionController`
        drives.  Returns the config now in effect.

        Only capacity knobs are retunable; policy, tenants, and the
        inflight gate are structural and keep their configured values.
        The swap is atomic under the queue lock and re-validated by
        :class:`AdmissionConfig` (shrinking ``max_pending`` below 1, or
        dropping it while a bounded policy is active, raises instead of
        wedging the queue).  Already-admitted requests are never
        retro-shed by a shrink: capacity is only consulted on *offer*,
        and the shed predicate is monotone in the margin — a smaller
        ``shed_headroom_ms`` sheds a strict subset of what the old
        margin would have (regression-tested in
        ``tests/test_admission.py``).
        """
        kw = {}
        if max_pending is not _UNSET:
            kw["max_pending"] = max_pending
        if max_chunk is not _UNSET:
            kw["max_chunk"] = max_chunk
        if shed_headroom_ms is not _UNSET:
            kw["shed_headroom_ms"] = float(shed_headroom_ms)
        with self._lock:
            if kw:
                self.cfg = dataclasses.replace(self.cfg, **kw)
            return self.cfg

    # -- submit side -----------------------------------------------------------
    def offer(self, future: InferenceFuture) -> str:
        """Place one submitted future; returns its disposition:
        ``"admitted"`` | ``"blocked"`` | ``"degraded"`` | ``"rejected"``.
        """
        disposition = (
            self._offer_tenant(future)
            if self._lanes is not None
            else self._offer_fifo(future)
        )
        if self._obs is not None:
            self._note_offer(disposition)
        return disposition

    def _offer_fifo(self, future: InferenceFuture) -> str:
        with self._lock:
            self.n_submitted += 1
            if not self.cfg.bounded:
                self._admitted.append(future)
                self._admit_stamp(future)
                return "admitted"
            if self._queued(self._admitted) < self.cfg.max_pending:
                self._admitted.append(future)
                self._admit_stamp(future)
                return "admitted"
            if self.cfg.policy == "block":
                self._overflow.append(future)
                return "blocked"
            if self.cfg.policy == "degrade":
                self._degraded.append(future)
                self._admit_stamp(future)
                self.n_degraded += 1
                return "degraded"
        # shed: capacity tail-drop — the queue never grows past
        # max_pending, and the newest request is the one with the least
        # wait invested.  The terminal transition runs outside the lock
        # (it may wake waiters) and can lose to a racing cancel(), so the
        # counter only tracks transitions that actually happened.
        if future._mark_rejected():
            with self._lock:
                self.n_rejected += 1
                self._charge_tenant_reject(future)
            return "rejected"
        return "cancelled"

    def _charge_tenant_reject(self, future: InferenceFuture) -> None:
        """Under self._lock: per-tenant reject accounting.

        In lanes mode every reject is charged to its lane; in FIFO mode
        only *tagged* requests are counted (an untagged single-class run
        keeps its accounting — and metrics — exactly as before tenancy).
        """
        if self._lanes is not None:
            name = self._lanes.name_of(future)
        else:
            name = future.request.tenant
            if name is None:
                return
        self.tenant_rejected[name] = self.tenant_rejected.get(name, 0) + 1

    # -- tenancy (cfg.tenants set) --------------------------------------------
    def _over_capacity(self, lane) -> bool:
        """Under self._lock: is this lane's next admit over capacity —
        globally (max_pending across all lanes) or per-tenant?"""
        if self.cfg.policy == "unbounded":
            return False
        if (
            self.cfg.max_pending is not None
            and self._lanes.n_queued() >= self.cfg.max_pending
        ):
            return True
        return (
            lane.cfg.max_pending is not None
            and lane.n_queued >= lane.cfg.max_pending
        )

    def _offer_tenant(self, future: InferenceFuture) -> str:
        """Lane-routing offer: the tenant's lane (and its bound) replaces
        the single FIFO; the overload policies keep their meaning, applied
        when either the global or the tenant's capacity is exceeded."""
        with self._lock:
            self.n_submitted += 1
            lane = self._lanes.resolve(future)
            name = lane.cfg.name
            self.tenant_submitted[name] = (
                self.tenant_submitted.get(name, 0) + 1
            )
            if not self._over_capacity(lane):
                self._lanes.append(lane, future)
                self._admit_stamp(future)
                return "admitted"
            if self.cfg.policy == "block":
                self._overflow.append(future)
                return "blocked"
            if self.cfg.policy == "degrade":
                self._degraded.append(future)
                self._admit_stamp(future)
                self.n_degraded += 1
                return "degraded"
        # shed — same outside-the-lock transition as the FIFO path.
        if future._mark_rejected():
            with self._lock:
                self.n_rejected += 1
                self._charge_tenant_reject(future)
            return "rejected"
        return "cancelled"

    def _refill_lanes(self) -> None:
        """Under self._lock: admit overflow-room futures whose lane has
        capacity again (block policy).  Unlike the single-FIFO refill this
        may skip over the head — one tenant's full lane must not block
        another tenant's admission (no cross-tenant head-of-line)."""
        if self.cfg.policy != "block" or not self._overflow:
            return
        kept: Deque[InferenceFuture] = deque()
        while self._overflow:
            f = self._overflow.popleft()
            lane = self._lanes.resolve(f)
            if not self._over_capacity(lane):
                self._lanes.append(lane, f)
                self._admit_stamp(f)
            else:
                kept.append(f)
        self._overflow = kept

    def _shed_lanes(
        self,
        now_ms: float,
        default_sla_ms: float,
        service_floor_ms: float,
        ondevice_floor_ms: Optional[float],
    ) -> List[InferenceFuture]:
        """Under self._lock: collect SLA-unreachable requests across every
        lane (same predicate as the FIFO shed) and drop them."""
        shed = []
        for f in self._lanes.all_queued():
            r = f.request
            wait = max(now_ms - r.arrival_ms, 0.0)
            sla = default_sla_ms if r.sla_ms is None else r.sla_ms
            if sla_unreachable(
                wait, sla, r.t_nw_est_ms, service_floor_ms,
                self.cfg.shed_headroom_ms, ondevice_floor_ms,
            ):
                shed.append(f)
        self._lanes.discard(shed)
        return shed

    def _take_tenant(
        self,
        now_ms: Optional[float],
        *,
        default_sla_ms: float,
        service_floor_ms: float,
        ondevice_floor_ms: Optional[float],
    ) -> AdmissionBatch:
        """Tenancy-mode take: same phases as the FIFO take, but the chunk
        comes from :meth:`TenantLanes.select` — strict interactive-over-
        batch priority, deficit-weighted-fair within a class — and shed
        rejections are charged to their tenant."""
        shed: List[InferenceFuture] = []
        lanes = self._lanes
        with self._lock:
            lanes.prune()
            self._prune()  # overflow + degrade deques
            self._refill_lanes()
            if self.cfg.policy == "shed":
                shed_now = now_ms
                if shed_now is None:
                    # The would-be chunk's latest arrival (a pure peek —
                    # lane deficits don't advance).
                    peek = lanes.select(self.cfg.max_chunk, commit=False)
                    if peek:
                        shed_now = max(f.request.arrival_ms for f in peek)
                if shed_now is not None:
                    shed = self._shed_lanes(
                        float(shed_now), default_sla_ms, service_floor_ms,
                        ondevice_floor_ms,
                    )
                    self._refill_lanes()
            chunk = lanes.select(self.cfg.max_chunk)
            self._refill_lanes()  # the chunk's slots free immediately
            if chunk and now_ms is None:
                now_ms = max(f.request.arrival_ms for f in chunk)
            degraded = self._take_degraded()
        shed = [f for f in shed if f._mark_rejected()]
        if shed:
            with self._lock:
                self.n_rejected += len(shed)
                for f in shed:
                    self._charge_tenant_reject(f)
        if now_ms is None and degraded:
            now_ms = max(f.request.arrival_ms for f in degraded)
        return AdmissionBatch(
            chunk=chunk, degraded=degraded, shed=shed,
            now_ms=0.0 if now_ms is None else float(now_ms),
        )

    def requeue(self, futures: List[InferenceFuture]) -> None:
        """Return lost-batch futures to the *front* of the admitted queue.

        Called by the loop when a replica failure loses a dispatched
        batch: the rows already went through admission once (they are
        counted in ``n_submitted`` and invested real queue wait), so they
        re-enter at the head — ahead of younger arrivals — and bypass the
        ``max_pending`` capacity check (they held a slot when first
        admitted; bouncing them to the overload policy would turn a
        replica fault into spurious shed/degrade).  Conservation is
        unchanged: a requeued request is backlog again, not a new submit.
        """
        with self._lock:
            for f in reversed(futures):
                if self._lanes is not None:
                    self._lanes.append_front(f)
                else:
                    self._admitted.appendleft(f)
            self.n_requeued += len(futures)
        if self._obs is not None and futures:
            self._obs.counter("admission_requeued_total").inc(len(futures))

    # -- tick side -------------------------------------------------------------
    def take(
        self,
        now_ms: Optional[float],
        *,
        default_sla_ms: float,
        service_floor_ms: float = 0.0,
        ondevice_floor_ms: Optional[float] = None,
    ) -> AdmissionBatch:
        """One tick's admission work: prune, refill, (shed,) select.

        1. Drop futures that left QUEUED state (cancelled) from every lane.
        2. Refill the admitted queue FIFO from the overflow room (block).
        3. Under ``shed``: reject every admitted request — including the
           would-be chunk — whose wait at the tick clock makes its SLA
           unreachable, then refill freed capacity again.
        4. Select the first ``max_chunk`` surviving requests as the tick's
           chunk; ``now_ms`` defaults to the chunk's latest arrival (the
           pre-admission loop's convention).
        5. Take up to ``max_chunk`` requests from the degrade lane.

        The returned futures are still QUEUED — the loop claims them with
        ``_try_schedule`` (so a racing ``cancel()`` keeps its guarantee).

        With tenancy enabled (``cfg.tenants``) step 4's selection is the
        strict-priority deficit-weighted-fair lane drain instead of the
        FIFO prefix; everything else keeps its semantics.
        """
        if self._lanes is not None:
            batch = self._take_tenant(
                now_ms,
                default_sla_ms=default_sla_ms,
                service_floor_ms=service_floor_ms,
                ondevice_floor_ms=ondevice_floor_ms,
            )
        else:
            batch = self._take_fifo(
                now_ms,
                default_sla_ms=default_sla_ms,
                service_floor_ms=service_floor_ms,
                ondevice_floor_ms=ondevice_floor_ms,
            )
        if self._obs is not None:
            self._note_take(batch)
        return batch

    def _take_fifo(
        self,
        now_ms: Optional[float],
        *,
        default_sla_ms: float,
        service_floor_ms: float,
        ondevice_floor_ms: Optional[float],
    ) -> AdmissionBatch:
        shed: List[InferenceFuture] = []
        with self._lock:
            self._prune()
            self._refill()
            if self.cfg.policy == "shed":
                # The shed clock: the caller's tick time, or the would-be
                # chunk's latest arrival (what _select_chunk would pick).
                shed_now = now_ms
                if shed_now is None and self._admitted:
                    shed_now = max(
                        f.request.arrival_ms for f in self._chunk_prefix()
                    )
                if shed_now is not None:
                    shed = self._shed(
                        float(shed_now), default_sla_ms, service_floor_ms,
                        ondevice_floor_ms,
                    )
                    self._refill()
            chunk = self._chunk_prefix()
            for _ in chunk:
                self._admitted.popleft()
            self._refill()  # the chunk's slots free immediately
            if chunk and now_ms is None:
                now_ms = max(f.request.arrival_ms for f in chunk)
            degraded = self._take_degraded()
        # The terminal transitions run outside the lock (they may wake
        # waiters); a racing cancel() can win, in which case the future is
        # CANCELLED, not REJECTED — only real transitions are counted.
        shed = [f for f in shed if f._mark_rejected()]
        if shed:
            with self._lock:
                self.n_rejected += len(shed)
                for f in shed:
                    self._charge_tenant_reject(f)
        if now_ms is None and degraded:
            now_ms = max(f.request.arrival_ms for f in degraded)
        return AdmissionBatch(
            chunk=chunk, degraded=degraded, shed=shed,
            now_ms=0.0 if now_ms is None else float(now_ms),
        )

    # The helpers below run under self._lock.
    def _prune(self) -> None:
        for q in (self._admitted, self._overflow, self._degraded):
            stale = any(f.state is not RequestState.QUEUED for f in q)
            if stale:
                kept = [f for f in q if f.state is RequestState.QUEUED]
                q.clear()
                q.extend(kept)

    def _refill(self) -> None:
        if not self.cfg.bounded or self.cfg.policy != "block":
            return
        while self._overflow and len(self._admitted) < self.cfg.max_pending:
            future = self._overflow.popleft()
            self._admitted.append(future)
            self._admit_stamp(future)

    def _chunk_prefix(self) -> List[InferenceFuture]:
        cap = self.cfg.max_chunk
        n = len(self._admitted) if cap is None else min(cap, len(self._admitted))
        return [self._admitted[i] for i in range(n)]

    def _shed(
        self,
        now_ms: float,
        default_sla_ms: float,
        service_floor_ms: float,
        ondevice_floor_ms: Optional[float] = None,
    ) -> List[InferenceFuture]:
        shed, kept = [], []
        for f in self._admitted:
            r = f.request
            wait = max(now_ms - r.arrival_ms, 0.0)
            sla = default_sla_ms if r.sla_ms is None else r.sla_ms
            if sla_unreachable(
                wait, sla, r.t_nw_est_ms, service_floor_ms,
                self.cfg.shed_headroom_ms, ondevice_floor_ms,
            ):
                shed.append(f)
            else:
                kept.append(f)
        if shed:
            self._admitted.clear()
            self._admitted.extend(kept)
        return shed

    def _take_degraded(self) -> List[InferenceFuture]:
        cap = self.cfg.max_chunk
        n = len(self._degraded) if cap is None else min(cap, len(self._degraded))
        return [self._degraded.popleft() for _ in range(n)]
