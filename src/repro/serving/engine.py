"""Serving engine: the policy-facing front over pluggable execution backends.

The engine no longer owns compiled executables — that is the
:class:`repro.serving.backend.ExecutionBackend` layer's job.  The engine
wires the scheduler (policy half) to two execution tiers:

* ``backend`` — the remote tier (:class:`repro.serving.backend.JitBackend`
  by default): per-variant jitted prefill/decode, real batched decoding.
* ``hedge_backend`` — the optional on-device tier
  (:class:`repro.serving.backend.OnDeviceBackend`): a real tiny duplicate
  variant.  When present, hedged requests execute on *both* tiers and
  duplication resolves on measured wall time; when absent, the scheduler
  falls back to sampling its on-device latency profile (the simulator
  reference path).

The request-queue front (:meth:`ServingEngine.serve_queue`) is the
continuous-batching layer: a chunk of queued requests is scheduled in one
``decide_batch`` call, grouped by selected variant, executed as one real
``generate`` batch per variant, observed back into the scheduler's live
profiles (both tiers), and resolved through hedged duplication.  Feed it
arrival windows from :mod:`repro.serving.loadgen` to serve an open-loop
trace.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.registry import ModelRegistry
from repro.core.sla import RequestMetrics, summarize
from repro.serving.backend import ExecutionBackend, JitBackend, OnDeviceBackend, Variant
from repro.serving.scheduler import pad_to_pow2

__all__ = ["Variant", "ServingEngine", "QueuedRequest", "CompletedRequest"]


@dataclasses.dataclass
class QueuedRequest:
    """One pending inference request in the serving queue."""

    rid: int
    tokens: np.ndarray  # (S,) prompt tokens
    n_steps: int
    t_nw_est_ms: float
    t_nw_actual_ms: float
    arrival_ms: float = 0.0


@dataclasses.dataclass
class CompletedRequest:
    """Resolved outcome of one served request."""

    rid: int
    model_name: str
    model_index: int
    # (n_steps,) generated tokens.  With a real hedge tier (hedge_measured)
    # these come from the tier that answered; in the sampled-hedge
    # simulation there is no duplicate execution, so they are always the
    # remote model's output even when the simulated duplicate "wins".
    tokens: np.ndarray
    exec_ms: float  # wall time of the variant batch this request rode in
    remote_ms: float  # queue wait + network + execution
    latency_ms: float  # user-observed (post-duplication)
    accuracy: float  # quality of the result actually used
    used_remote: bool
    hedged: bool
    queue_wait_ms: float = 0.0  # dispatch tick - arrival (charged to budget)
    ondevice_ms: Optional[float] = None  # duplicate's latency (hedged only)
    hedge_measured: bool = False  # True: ondevice_ms is real wall time


def _pad_batch(requests, rows_idx) -> Tuple[np.ndarray, int]:
    """Right-pad a group's prompts into one (pow2-rows, width) batch."""
    width = max(len(requests[i].tokens) for i in rows_idx)
    batch = np.zeros((pad_to_pow2(len(rows_idx)), width), dtype=np.int32)
    for row, i in enumerate(rows_idx):
        t = np.asarray(requests[i].tokens, dtype=np.int32)
        batch[row, : len(t)] = t
    steps = max(requests[i].n_steps for i in rows_idx)
    return batch, steps


class ServingEngine:
    def __init__(
        self,
        max_len: int = 256,
        backend: Optional[ExecutionBackend] = None,
        hedge_backend: Optional[OnDeviceBackend] = None,
    ):
        self.backend = backend if backend is not None else JitBackend(max_len)
        self.hedge_backend = hedge_backend

    # -- thin delegation to the remote tier ----------------------------------
    @property
    def max_len(self):
        """The remote tier's sequence cap (owned by the backend)."""
        return getattr(self.backend, "max_len", None)

    @property
    def variants(self):
        return self.backend.variants

    def register(self, v: Variant):
        self.backend.register(v)

    def generate(self, name: str, tokens: np.ndarray, n_steps: int, greedy=True):
        """Real batched generation on the remote tier.  Returns
        (generated (B, n_steps), wall_ms)."""
        return self.backend.generate(name, tokens, n_steps)

    # -- continuous-batching front -------------------------------------------
    def serve_queue(
        self,
        scheduler,
        requests: Sequence[QueuedRequest],
        dispatch_ms: Optional[float] = None,
    ) -> Tuple[List[CompletedRequest], Optional[RequestMetrics]]:
        """Serve one chunk of queued requests with continuous batching.

        One ``decide_batch`` call schedules the whole chunk; requests that
        picked the same variant run as a single real ``generate`` batch on
        the remote tier (prompts right-padded to the group's longest, rows
        padded to a power of two to bound the set of compiled shapes).
        Every request in a variant batch shares the batch's wall time — the
        continuous-batching cost model.  Backends absorb XLA compile time
        with an untimed warm-up per shape, so it is never charged to
        requests or folded into the live EWMA profiles.

        Hedged rows additionally run as one real batch on the
        ``hedge_backend`` (when configured): both tiers' *measured* wall
        times feed ``scheduler.resolve_chunk``, the on-device observation
        folds into the scheduler's live on-device EWMA profile, and
        requests the duplicate wins return the hedge variant's tokens.
        Without a hedge backend the duplicate's latency is sampled from the
        scheduler's on-device profile (simulation fallback — the reference
        behavior for equivalence tests).

        ``dispatch_ms`` is the scheduling-tick timestamp (e.g. the close
        of the arrival window): each request's queueing wait
        ``dispatch_ms - arrival_ms`` is charged against its budget at
        selection time, included in its reported latency, and recorded on
        the completion (``queue_wait_ms``).  Defaults to the chunk's
        latest arrival (zero wait when ``arrival_ms`` is unset).  Ticks
        are assumed to execute independently — earlier windows' wall time
        does not serialize into later ones.

        Returns ``(completions, metrics)`` with completions in the input
        order; ``metrics`` is None for an empty chunk.
        """
        if not requests:
            return [], None
        arrivals = np.asarray([r.arrival_ms for r in requests])
        if dispatch_ms is None:
            dispatch_ms = float(arrivals.max())
        queue_wait = np.maximum(dispatch_ms - arrivals, 0.0)
        decision = scheduler.decide_batch(
            np.asarray([r.t_nw_est_ms for r in requests]) + queue_wait
        )
        n = len(requests)
        exec_ms = np.empty(n)
        gen_tokens: List[Optional[np.ndarray]] = [None] * n
        for m in np.unique(decision.model_index):
            name = scheduler.names[int(m)]
            group = np.flatnonzero(decision.model_index == m)
            batch, steps = _pad_batch(requests, group)
            out, wall_ms = self.backend.run_batch(name, batch, steps)
            exec_ms[group] = wall_ms
            for row, i in enumerate(group):
                gen_tokens[i] = out[row, : requests[i].n_steps]
        scheduler.observe_batch(decision.model_index, exec_ms)

        remote_ms = (
            queue_wait
            + np.asarray([r.t_nw_actual_ms for r in requests])
            + exec_ms
        )

        # The hedge tier: run every hedged row's duplicate as one real
        # batch; its measured wall time is the duplicate's latency.
        hedged_rows = np.flatnonzero(decision.hedged)
        measured = self.hedge_backend is not None and hedged_rows.size > 0
        ondevice_in: Optional[np.ndarray] = None
        hedge_tokens: dict[int, np.ndarray] = {}
        if measured:
            batch, steps = _pad_batch(requests, hedged_rows)
            out, wall_ms = self.hedge_backend.hedge(batch, steps)
            for row, i in enumerate(hedged_rows):
                hedge_tokens[int(i)] = out[row, : requests[i].n_steps]
            ondevice_in = np.full(n, wall_ms)
            scheduler.observe_ondevice(np.full(hedged_rows.size, wall_ms))

        # Both tiers launch at the dispatch tick, so queue wait charges the
        # duplicate's race clock too — SLA accounting stays honest when the
        # wait alone approaches the SLA.
        acc_used, latency, used_remote, ondevice_ms = scheduler.resolve_chunk(
            decision, remote_ms, ondevice_ms=ondevice_in,
            ondevice_wait_ms=queue_wait,
        )
        completions = [
            CompletedRequest(
                rid=requests[i].rid,
                model_name=scheduler.names[int(decision.model_index[i])],
                model_index=int(decision.model_index[i]),
                tokens=(
                    hedge_tokens[i]
                    if i in hedge_tokens and not used_remote[i]
                    else gen_tokens[i]
                ),
                exec_ms=float(exec_ms[i]),
                remote_ms=float(remote_ms[i]),
                latency_ms=float(latency[i]),
                accuracy=float(acc_used[i]),
                used_remote=bool(used_remote[i]),
                hedged=bool(decision.hedged[i]),
                queue_wait_ms=float(queue_wait[i]),
                ondevice_ms=(
                    float(ondevice_ms[i]) if decision.hedged[i] else None
                ),
                hedge_measured=measured and bool(decision.hedged[i]),
            )
            for i in range(n)
        ]
        metrics = summarize(
            accuracy_used=acc_used,
            latency_ms=latency,
            t_sla_ms=scheduler.cfg.t_sla_ms,
            model_names=scheduler.names,
            model_index=decision.model_index,
            used_remote=used_remote,
            queue_wait_ms=queue_wait,
        )
        return completions, metrics

    def measure_profiles(
        self, prompt_len: int, gen_tokens: int, batch: int = 1, trials: int = 5,
        seed: int = 0,
    ) -> ModelRegistry:
        """Measure real wall-clock latency profiles (the paper's Table III
        methodology: repeated timed executions per model)."""
        profiles = [
            self.backend.measure_profile(
                name, prompt_len, gen_tokens, batch=batch, trials=trials,
                seed=seed,
            )
            for name in self.variants
        ]
        return ModelRegistry(sorted(profiles, key=lambda p: p.accuracy))
