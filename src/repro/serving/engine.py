"""Serving engine: variant registry + compatibility front over the loop.

The engine owns the two execution tiers:

* ``backend`` — the remote tier (:class:`repro.serving.backend.JitBackend`
  by default): per-variant jitted prefill/decode, real batched decoding.
* ``hedge_backend`` — the optional on-device tier
  (:class:`repro.serving.backend.OnDeviceBackend`): a real tiny duplicate
  variant.  When present, hedged requests execute on *both* tiers and
  duplication resolves on measured wall time; when absent, the scheduler
  falls back to sampling its on-device latency profile (the simulator
  reference path).

Request scheduling/dispatch now lives in the event-loop layer
(:class:`repro.serving.loop.ServingLoop`): admission →
``decide_batch`` → concurrent per-tier dispatch → hedged resolution.
:meth:`ServingEngine.serve_queue` survives as a thin compatibility shim —
one sync-collected tick of a ``ServingLoop`` over this engine's backends —
so the pre-loop equivalence references (``chunk_size=1``, sampled-hedge
simulation) keep holding verbatim.  New code should drive a
``ServingLoop`` (plus :class:`repro.serving.client.InferenceClient`)
directly.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.registry import ModelRegistry
from repro.core.sla import RequestMetrics
from repro.serving.backend import ExecutionBackend, JitBackend, OnDeviceBackend, Variant
from repro.serving.lifecycle import CompletedRequest, QueuedRequest

__all__ = ["Variant", "ServingEngine", "QueuedRequest", "CompletedRequest"]


class ServingEngine:
    def __init__(
        self,
        max_len: Optional[int] = None,
        backend: Optional[ExecutionBackend] = None,
        hedge_backend: Optional[OnDeviceBackend] = None,
        dispatch: str = "sync",
        continuous: bool = False,
        geometry=None,
    ):
        # The engine is the *compatibility* surface, so it defaults to the
        # serialized reference behavior legacy callers measured against;
        # the new API (ServingLoop) defaults to async dispatch.
        # ``continuous=True`` swaps the remote tier for the
        # continuous-batching backend (fixed-shape compiled entries,
        # block-paged slot cache) and defaults dispatch to "stepped";
        # ``geometry`` (a ServingGeometry) then sizes its ladder and pool.
        if backend is None:
            if continuous:
                from repro.configs.mdinference_zoo import SERVING_GEOMETRY
                from repro.serving.backend import ContinuousBatchingBackend

                backend = ContinuousBatchingBackend(
                    SERVING_GEOMETRY if geometry is None else geometry
                )
                if dispatch == "sync":
                    dispatch = "stepped"
            else:
                backend = JitBackend(max_len)
        self.backend = backend
        self.hedge_backend = hedge_backend
        self.dispatch = dispatch

    # -- thin delegation to the remote tier ----------------------------------
    @property
    def max_len(self):
        """The remote tier's sequence cap (owned by the backend)."""
        return getattr(self.backend, "max_len", None)

    @property
    def variants(self):
        return self.backend.variants

    def register(self, v: Variant):
        self.backend.register(v)

    def generate(self, name: str, tokens: np.ndarray, n_steps: int, greedy=True):
        """Real batched generation on the remote tier.  Returns
        (generated (B, n_steps), wall_ms)."""
        return self.backend.generate(name, tokens, n_steps)

    def make_loop(
        self,
        scheduler,
        dispatch: Optional[str] = None,
        admission=None,
        controller=None,
        observability=None,
    ):
        """Build a :class:`repro.serving.loop.ServingLoop` over this
        engine's backends (the event-loop serving front).

        ``admission`` is an optional
        :class:`repro.serving.admission.AdmissionConfig` — the bounded
        admission queue with overload policies; ``None`` keeps the
        unbounded compatibility behavior.  ``controller`` is an optional
        :class:`repro.serving.controller.AdmissionController` closing the
        adaptive loop over that queue; ``None`` keeps the static config.
        ``observability`` is an optional
        :class:`repro.observability.Observability` handle the loop
        threads through every layer; ``None`` keeps the stack untraced
        (the regression-pinned default).
        """
        from repro.serving.loop import ServingLoop

        return ServingLoop(
            scheduler,
            self.backend,
            self.hedge_backend,
            dispatch=self.dispatch if dispatch is None else dispatch,
            admission=admission,
            controller=controller,
            observability=observability,
        )

    # -- compatibility shim over the event loop ------------------------------
    def serve_queue(
        self,
        scheduler,
        requests: Sequence[QueuedRequest],
        dispatch_ms: Optional[float] = None,
    ) -> Tuple[List[CompletedRequest], Optional[RequestMetrics]]:
        """Serve one chunk of queued requests with continuous batching.

        Thin shim: admits ``requests`` into a fresh
        :class:`repro.serving.loop.ServingLoop` and collects exactly one
        tick at ``dispatch_ms`` (default: the chunk's latest arrival).  All
        semantics — one ``decide_batch`` call per chunk, per-variant
        ``generate`` batches with shared wall times, queue wait charged to
        both race clocks, measured-or-sampled hedge resolution — live in
        the loop now; this wrapper only preserves the historical
        batch-in/batch-out signature.  The engine's ``dispatch`` mode
        decides whether the tiers' batches run serialized ("sync", the
        default here — the deterministic reference legacy callers
        measured against) or overlap ("async").

        Returns ``(completions, metrics)`` with completions in the input
        order; ``metrics`` is None for an empty chunk.
        """
        if not requests:
            return [], None
        loop = self.make_loop(scheduler)
        for r in requests:
            loop.submit(r)
        result = loop.tick(now_ms=dispatch_ms)
        return result.completions, result.metrics

    def measure_profiles(
        self, prompt_len: int, gen_tokens: int, batch: int = 1, trials: int = 5,
        seed: int = 0,
    ) -> ModelRegistry:
        """Measure real wall-clock latency profiles (the paper's Table III
        methodology: repeated timed executions per model)."""
        profiles = [
            self.backend.measure_profile(
                name, prompt_len, gen_tokens, batch=batch, trials=trials,
                seed=seed,
            )
            for name in self.variants
        ]
        return ModelRegistry(sorted(profiles, key=lambda p: p.accuracy))
