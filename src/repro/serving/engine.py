"""Serving engine: compiled prefill/decode executables per zoo variant.

This is the execution half of the serving stack (the scheduler is the
policy half).  Each registered variant gets jitted prefill/decode functions
and a measured latency profile; ``generate`` runs real batched decoding.
On CPU this drives the end-to-end example with tiny variants; on a pod the
same engine holds the per-arch compiled executables from the dry-run path.

The request-queue front (:meth:`ServingEngine.serve_queue`) is the
continuous-batching layer: a chunk of queued requests is scheduled in one
``decide_batch`` call, grouped by selected variant, executed as one real
``generate`` batch per variant, observed back into the scheduler's live
profiles, and resolved through hedged duplication.  Feed it arrival
windows from :mod:`repro.serving.loadgen` to serve an open-loop trace.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import ModelProfile, ModelRegistry
from repro.core.sla import RequestMetrics, summarize
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.scheduler import pad_to_pow2

__all__ = ["Variant", "ServingEngine", "QueuedRequest", "CompletedRequest"]


@dataclasses.dataclass
class Variant:
    name: str
    cfg: ModelConfig
    params: dict
    quality: float  # A(m) for the selection algorithm


@dataclasses.dataclass
class QueuedRequest:
    """One pending inference request in the serving queue."""

    rid: int
    tokens: np.ndarray  # (S,) prompt tokens
    n_steps: int
    t_nw_est_ms: float
    t_nw_actual_ms: float
    arrival_ms: float = 0.0


@dataclasses.dataclass
class CompletedRequest:
    """Resolved outcome of one served request."""

    rid: int
    model_name: str
    model_index: int
    tokens: np.ndarray  # (n_steps,) generated tokens
    exec_ms: float  # wall time of the variant batch this request rode in
    remote_ms: float  # queue wait + network + execution
    latency_ms: float  # user-observed (post-duplication)
    accuracy: float  # quality of the result actually used
    used_remote: bool
    hedged: bool


class ServingEngine:
    def __init__(self, max_len: int = 256):
        self.max_len = max_len
        self.variants: Dict[str, Variant] = {}
        self._prefill = {}
        self._decode = {}
        self._warmed_shapes: set = set()

    def register(self, v: Variant):
        cfg = v.cfg
        self.variants[v.name] = v

        @jax.jit
        def prefill_fn(params, tokens):
            return T.prefill(cfg, params, {"tokens": tokens}, max_len=self.max_len)

        @jax.jit
        def decode_fn(params, cache, token, pos):
            return T.decode_step(cfg, params, cache, token, pos)

        self._prefill[v.name] = prefill_fn
        self._decode[v.name] = decode_fn

    def generate(self, name: str, tokens: np.ndarray, n_steps: int, greedy=True):
        """Real batched generation.  Returns (generated (B, n_steps), wall_ms)."""
        v = self.variants[name]
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        if n_steps <= 0:
            return np.zeros((B, 0), dtype=np.int32), 0.0
        t0 = time.perf_counter()
        cache, logits = self._prefill[name](v.params, tokens)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(n_steps):
            out.append(tok)
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = self._decode[name](v.params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        wall_ms = (time.perf_counter() - t0) * 1e3
        return np.stack([np.asarray(t) for t in out], axis=1), wall_ms

    def serve_queue(
        self,
        scheduler,
        requests: Sequence[QueuedRequest],
        dispatch_ms: Optional[float] = None,
    ) -> Tuple[List[CompletedRequest], Optional[RequestMetrics]]:
        """Serve one chunk of queued requests with continuous batching.

        One ``decide_batch`` call schedules the whole chunk; requests that
        picked the same variant run as a single real ``generate`` batch
        (prompts right-padded to the group's longest, rows padded to a
        power of two to bound the set of compiled shapes).  Every request
        in a variant batch shares the batch's wall time — the
        continuous-batching cost model.  The first occurrence of each
        (variant, shape) runs an untimed warm-up ``generate`` so XLA
        compile time is never charged to requests or folded into the live
        EWMA profiles.  Observed wall times feed
        ``scheduler.observe_batch``, and outcomes resolve through the
        scheduler's hedged duplication.

        ``dispatch_ms`` is the scheduling-tick timestamp (e.g. the close
        of the arrival window): each request's queueing wait
        ``dispatch_ms - arrival_ms`` is charged against its budget at
        selection time and included in its reported latency.  Defaults to
        the chunk's latest arrival (zero wait when ``arrival_ms`` is
        unset).  Ticks are assumed to execute independently — earlier
        windows' wall time does not serialize into later ones.

        Returns ``(completions, metrics)`` with completions in the input
        order; ``metrics`` is None for an empty chunk.
        """
        if not requests:
            return [], None
        arrivals = np.asarray([r.arrival_ms for r in requests])
        if dispatch_ms is None:
            dispatch_ms = float(arrivals.max())
        queue_wait = np.maximum(dispatch_ms - arrivals, 0.0)
        decision = scheduler.decide_batch(
            np.asarray([r.t_nw_est_ms for r in requests]) + queue_wait
        )
        n = len(requests)
        exec_ms = np.empty(n)
        gen_tokens: List[Optional[np.ndarray]] = [None] * n
        for m in np.unique(decision.model_index):
            name = scheduler.names[int(m)]
            group = np.flatnonzero(decision.model_index == m)
            width = max(len(requests[i].tokens) for i in group)
            steps = max(requests[i].n_steps for i in group)
            rows = pad_to_pow2(len(group))
            batch = np.zeros((rows, width), dtype=np.int32)
            for row, i in enumerate(group):
                t = np.asarray(requests[i].tokens, dtype=np.int32)
                batch[row, : len(t)] = t
            shape_key = (name, rows, width, steps)
            if shape_key not in self._warmed_shapes:
                self.generate(name, batch, steps)  # compile, untimed
                self._warmed_shapes.add(shape_key)
            out, wall_ms = self.generate(name, batch, steps)
            exec_ms[group] = wall_ms
            for row, i in enumerate(group):
                gen_tokens[i] = out[row, : requests[i].n_steps]
        scheduler.observe_batch(decision.model_index, exec_ms)

        remote_ms = (
            queue_wait
            + np.asarray([r.t_nw_actual_ms for r in requests])
            + exec_ms
        )
        acc_used, latency, used_remote = scheduler.resolve_chunk(
            decision, remote_ms
        )
        completions = [
            CompletedRequest(
                rid=requests[i].rid,
                model_name=scheduler.names[int(decision.model_index[i])],
                model_index=int(decision.model_index[i]),
                tokens=gen_tokens[i],
                exec_ms=float(exec_ms[i]),
                remote_ms=float(remote_ms[i]),
                latency_ms=float(latency[i]),
                accuracy=float(acc_used[i]),
                used_remote=bool(used_remote[i]),
                hedged=bool(decision.hedged[i]),
            )
            for i in range(n)
        ]
        metrics = summarize(
            accuracy_used=acc_used,
            latency_ms=latency,
            t_sla_ms=scheduler.cfg.t_sla_ms,
            model_names=scheduler.names,
            model_index=decision.model_index,
            used_remote=used_remote,
        )
        return completions, metrics

    def measure_profiles(
        self, prompt_len: int, gen_tokens: int, batch: int = 1, trials: int = 5,
        seed: int = 0,
    ) -> ModelRegistry:
        """Measure real wall-clock latency profiles (the paper's Table III
        methodology: repeated timed executions per model)."""
        rng = np.random.default_rng(seed)
        profiles = []
        for name, v in self.variants.items():
            tokens = rng.integers(0, v.cfg.vocab_size, (batch, prompt_len))
            self.generate(name, tokens, 1)  # warmup/compile
            times = []
            for _ in range(trials):
                _, ms = self.generate(name, tokens, gen_tokens)
                times.append(ms)
            profiles.append(
                ModelProfile(
                    name=name,
                    accuracy=v.quality,
                    mu_ms=float(np.mean(times)),
                    sigma_ms=float(np.std(times) + 1e-3),
                )
            )
        return ModelRegistry(sorted(profiles, key=lambda p: p.accuracy))
