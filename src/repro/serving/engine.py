"""Serving engine: compiled prefill/decode executables per zoo variant.

This is the execution half of the serving stack (the scheduler is the
policy half).  Each registered variant gets jitted prefill/decode functions
and a measured latency profile; ``generate`` runs real batched decoding.
On CPU this drives the end-to-end example with tiny variants; on a pod the
same engine holds the per-arch compiled executables from the dry-run path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import ModelProfile, ModelRegistry
from repro.models import transformer as T
from repro.models.config import ModelConfig

__all__ = ["Variant", "ServingEngine"]


@dataclasses.dataclass
class Variant:
    name: str
    cfg: ModelConfig
    params: dict
    quality: float  # A(m) for the selection algorithm


class ServingEngine:
    def __init__(self, max_len: int = 256):
        self.max_len = max_len
        self.variants: Dict[str, Variant] = {}
        self._prefill = {}
        self._decode = {}

    def register(self, v: Variant):
        cfg = v.cfg
        self.variants[v.name] = v

        @jax.jit
        def prefill_fn(params, tokens):
            return T.prefill(cfg, params, {"tokens": tokens}, max_len=self.max_len)

        @jax.jit
        def decode_fn(params, cache, token, pos):
            return T.decode_step(cfg, params, cache, token, pos)

        self._prefill[v.name] = prefill_fn
        self._decode[v.name] = decode_fn

    def generate(self, name: str, tokens: np.ndarray, n_steps: int, greedy=True):
        """Real batched generation.  Returns (generated (B, n_steps), wall_ms)."""
        v = self.variants[name]
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        t0 = time.perf_counter()
        cache, logits = self._prefill[name](v.params, tokens)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(n_steps):
            out.append(tok)
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = self._decode[name](v.params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        wall_ms = (time.perf_counter() - t0) * 1e3
        return np.stack([np.asarray(t) for t in out], axis=1), wall_ms

    def measure_profiles(
        self, prompt_len: int, gen_tokens: int, batch: int = 1, trials: int = 5,
        seed: int = 0,
    ) -> ModelRegistry:
        """Measure real wall-clock latency profiles (the paper's Table III
        methodology: repeated timed executions per model)."""
        rng = np.random.default_rng(seed)
        profiles = []
        for name, v in self.variants.items():
            tokens = rng.integers(0, v.cfg.vocab_size, (batch, prompt_len))
            self.generate(name, tokens, 1)  # warmup/compile
            times = []
            for _ in range(trials):
                _, ms = self.generate(name, tokens, gen_tokens)
                times.append(ms)
            profiles.append(
                ModelProfile(
                    name=name,
                    accuracy=v.quality,
                    mu_ms=float(np.mean(times)),
                    sigma_ms=float(np.std(times) + 1e-3),
                )
            )
        return ModelRegistry(sorted(profiles, key=lambda p: p.accuracy))
