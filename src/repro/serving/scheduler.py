"""MDInference as a first-class serving scheduler — batched online core.

Online version of the paper's algorithm: per request it estimates the
network time, budgets, runs the three-stage selection, and hedges with the
fast tier (straggler mitigation).  Unlike the offline simulator it also
*updates* the latency profiles from observed execution times (EWMA on mu and
sigma) — the paper's stage-3 exploration exists precisely so that stale
profiles (queueing transients, concept drift, §V-A) get re-discovered; the
online update closes that loop.

Batched API
-----------
The scheduler operates on *chunks* of requests at once:

* :meth:`MDInferenceScheduler.decide_batch` — vectorized selection for a
  chunk of network-time estimates.  Selection probabilities come from the
  jitted policy registry (:data:`repro.core.baselines.POLICY_PROBABILITIES`,
  ``mdinference`` by default); the concrete model per request is sampled
  host-side by inverse-CDF against a pre-drawn uniform, so the random
  stream is *independent of chunking*.
* :meth:`MDInferenceScheduler.observe_batch` — folds a chunk of observed
  execution times into the live EWMA profiles, replaying each model's
  observations in arrival order (bit-identical to scalar ``observe`` calls).
* :meth:`MDInferenceScheduler.run_trace` — chunked trace-driven loop.  All
  randomness (selection uniforms, execution z-scores, on-device z-scores)
  is drawn up-front, so ``chunk_size=1`` and ``chunk_size=1024`` consume
  identical draws.  With ``profile_ewma=0`` the two produce *identical*
  model choices and metrics; with EWMA on, chunking freezes the profiles
  within a chunk (selection sees chunk-start profiles) and the paths agree
  within statistical tolerance.

``chunk_size=1`` is the scalar reference path; the per-request
:meth:`decide` / :meth:`observe` methods are thin wrappers over the chunk
API and remain the convenient interface for interactive use.

Two-tier hedge resolution
-------------------------
:meth:`MDInferenceScheduler.resolve_chunk` resolves hedged requests against
the on-device duplicate.  The *primary* path receives measured on-device
wall times (``ondevice_ms``) from a real hedge-tier execution
(:class:`repro.serving.backend.OnDeviceBackend` via
``ServingEngine.serve_queue``); sampling the on-device latency profile
survives only as the simulator fallback (``ondevice_ms=None`` — what
:meth:`run_trace` uses).  Measured hedge executions fold into a live
on-device EWMA profile (:meth:`observe_ondevice`) exactly like remote
observations fold into the per-model profiles.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import get_policy_probabilities
from repro.core.duplication import HedgePolicy, resolve_duplication
from repro.core.registry import ModelProfile, ModelRegistry
from repro.core.sla import RequestMetrics, summarize

__all__ = [
    "SchedulerConfig",
    "MDInferenceScheduler",
    "Decision",
    "BatchDecision",
    "pad_to_pow2",
]

_EXEC_FLOOR_MS = 0.1


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    t_sla_ms: float = 250.0
    utility_power: float = 1.0
    hedge: HedgePolicy = dataclasses.field(default_factory=HedgePolicy)
    profile_ewma: float = 0.05  # 0 disables online profile updates
    seed: int = 0
    algorithm: str = "mdinference"  # any repro.core.baselines policy
    chunk_size: int = 256  # 1 == scalar reference path
    # Sub-chunk profile refresh for run_trace: selection normally sees the
    # chunk-start profile snapshot for the whole chunk; with this set, a
    # chunk is served in sub-chunks of this many requests and the EWMA
    # snapshot refreshes between them — drift shows up mid-chunk instead
    # of one whole chunk late.  Mechanically this caps the effective
    # serving stride at min(chunk_size, subchunk_refresh): it exists as a
    # separate knob so callers can bound snapshot *staleness* without
    # redefining the batching granularity their jit shapes / callers are
    # tuned to (the pre-drawn randomness makes the two commute; see the
    # identity test).  None keeps the frozen-snapshot behavior.
    subchunk_refresh: Optional[int] = None

    def __post_init__(self):
        if self.subchunk_refresh is not None and self.subchunk_refresh < 1:
            raise ValueError(
                "subchunk_refresh must be >= 1 or None, "
                f"got {self.subchunk_refresh}"
            )


@dataclasses.dataclass
class Decision:
    model_index: int
    model_name: str
    hedged: bool
    t_budget_ms: float
    fallback: bool


@dataclasses.dataclass
class BatchDecision:
    """Vectorized scheduling decision for a chunk of requests."""

    model_index: np.ndarray  # (C,) int — model chosen per request
    base_index: np.ndarray  # (C,) int — stage-1 base (hedging reference)
    hedged: np.ndarray  # (C,) bool
    t_budget_ms: np.ndarray  # (C,) float
    fallback: np.ndarray  # (C,) bool

    def __len__(self) -> int:
        return len(self.model_index)

    def scalar(self, i: int, names: list[str]) -> Decision:
        return Decision(
            model_index=int(self.model_index[i]),
            model_name=names[int(self.model_index[i])],
            hedged=bool(self.hedged[i]),
            t_budget_ms=float(self.t_budget_ms[i]),
            fallback=bool(self.fallback[i]),
        )


@functools.lru_cache(maxsize=None)
def _jitted_policy(algorithm: str, utility_power: float):
    """One compiled (probs, base, fallback) function per (policy, power)."""
    fn = get_policy_probabilities(algorithm)

    @jax.jit
    def run(accuracy, mu, sigma, t_sla, t_budget):
        return fn(
            accuracy, mu, sigma, t_sla, t_budget, utility_power=utility_power
        )

    return run


def pad_to_pow2(n: int) -> int:
    """Round a chunk/batch length up to a power of two.

    Shared by the scheduler (budget vectors) and the engine (generate
    batches) to bound the set of jit-compiled shapes.
    """
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


class MDInferenceScheduler:
    def __init__(
        self,
        registry: ModelRegistry,
        ondevice: ModelProfile,
        cfg: SchedulerConfig = SchedulerConfig(),
    ):
        self.base_registry = registry
        self.ondevice = ondevice
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # Live profile estimates (start from the registry's priors).  The
        # EWMA tracks the variance; ``sigma`` is its derived view (kept in
        # sync so the fold avoids lossy sqrt/square round trips).
        self.mu = registry.mu.astype(np.float64).copy()
        self.sigma = registry.sigma.astype(np.float64).copy()
        self._var = self.sigma**2
        # Live on-device (hedge-tier) profile: seeded from the prior, refined
        # by measured hedge executions (observe_ondevice).
        self.ondevice_mu = float(ondevice.mu_ms)
        self.ondevice_sigma = float(ondevice.sigma_ms)
        self._ondevice_var = self.ondevice_sigma**2
        self.accuracy = registry.accuracy.astype(np.float64).copy()
        self.names = registry.names
        self._policy = _jitted_policy(cfg.algorithm, cfg.utility_power)
        # Mid-flight join accounting (continuous-batching tier): per-model
        # EWMA of time-to-first-token for requests grafted into the
        # persistent decode batch.  Purely observational — selection stays
        # a function of the execution profiles — but it is the signal a
        # future admission policy would gate joins on, and the bench
        # reports it alongside the latency rows.
        self.join_ttft_mu = np.full(len(self.names), np.nan)
        self._join_var = np.zeros(len(self.names))
        self.join_count = np.zeros(len(self.names), dtype=np.int64)
        self._log: list[dict] = []
        # Optional repro.observability.Observability handle (set by the
        # serving loop).  None keeps every path free of metric writes.
        self.observability = None

    # -- batched decision path ----------------------------------------------
    def decide_batch(
        self,
        t_nw_est_ms: np.ndarray,
        *,
        uniforms: Optional[np.ndarray] = None,
        eligible: Optional[np.ndarray] = None,
    ) -> BatchDecision:
        """Vectorized selection for a chunk of network-time estimates.

        ``uniforms`` (one U[0,1) draw per request) lets callers pre-draw the
        sampling randomness; when omitted the scheduler's own rng is used.

        ``eligible`` is an optional bool mask over the zoo (one entry per
        model): selection places zero probability on masked-out models.
        The serving loop passes the cluster's hosted-variant mask
        (:meth:`repro.serving.cluster.ClusterBackend.hosted_mask`) so a
        partial zoo sharding constrains selection — routing never has to
        place a row on a replica that doesn't host its variant.  An
        all-True mask is exactly the unmasked path (bit-identical); a
        request whose eligible models all have zero selection mass falls
        back to the fastest eligible model (``fallback`` set).
        """
        t_nw_est_ms = np.atleast_1d(np.asarray(t_nw_est_ms, dtype=np.float64))
        n = len(t_nw_est_ms)
        budgets = self.cfg.t_sla_ms - t_nw_est_ms
        if uniforms is None:
            uniforms = self.rng.random(n)
        if eligible is not None:
            eligible = np.asarray(eligible, dtype=bool)
            if eligible.shape != (len(self.names),):
                raise ValueError(
                    f"eligible mask must have shape ({len(self.names)},), "
                    f"got {eligible.shape}"
                )
            if not eligible.any():
                raise ValueError("eligible mask excludes every model")
            if eligible.all():
                eligible = None  # the unmasked path, bit-identical

        # Jit-friendly: pad the budget vector to a power-of-two length so
        # arbitrary chunk sizes reuse a handful of compiled shapes.
        padded = pad_to_pow2(n)
        budgets_in = np.full(padded, -1.0, dtype=np.float32)
        budgets_in[:n] = budgets
        probs, base, fallback = self._policy(
            jnp.asarray(self.accuracy, jnp.float32),
            jnp.asarray(self.mu, jnp.float32),
            jnp.asarray(self.sigma, jnp.float32),
            jnp.float32(self.cfg.t_sla_ms),
            jnp.asarray(budgets_in),
        )
        probs = np.asarray(probs, dtype=np.float64)[:n]
        base = np.asarray(base)[:n].astype(np.int64)
        fallback = np.asarray(fallback)[:n]

        if eligible is not None:
            # Placement-aware selection: zero the masked-out models.  A
            # request left with no selection mass falls back to the
            # fastest eligible model; the hedging reference (base) is
            # remapped there too when the stage-1 base is ineligible.
            probs = np.where(eligible[None, :], probs, 0.0)
            fastest = int(
                np.flatnonzero(eligible)[np.argmin(self.mu[eligible])]
            )
            dead = probs.sum(axis=1) <= 0.0
            if dead.any():
                probs[dead, fastest] = 1.0
                fallback = fallback | dead
            base = np.where(eligible[base], base, fastest)

        # Inverse-CDF sampling against the pre-drawn uniforms: the result for
        # request i depends only on (profiles, budget_i, u_i), never on chunk
        # boundaries.  `<=` (not `<`) so u == 0.0 still lands on the first
        # positive-mass index rather than unconditionally picking index 0.
        cum = np.cumsum(probs, axis=1)
        total = cum[:, -1:]
        idx = np.minimum(
            (cum <= uniforms[:, None] * total).sum(axis=1), probs.shape[1] - 1
        ).astype(np.int64)

        hedged = np.asarray(
            self.cfg.hedge.should_hedge(budgets, self.mu[base], self.sigma[base]),
            dtype=bool,
        )
        return BatchDecision(
            model_index=idx,
            base_index=base,
            hedged=hedged,
            t_budget_ms=budgets,
            fallback=fallback,
        )

    # -- the paper's per-request path (scalar wrappers) ----------------------
    def decide(self, t_nw_est_ms: float) -> Decision:
        d = self.decide_batch(np.asarray([t_nw_est_ms]))
        return d.scalar(0, self.names)

    def _ewma_fold(self, mu: float, var: float, xs: np.ndarray) -> tuple[float, float]:
        a = self.cfg.profile_ewma
        for x in xs:
            delta = x - mu
            mu += a * delta
            var = max((1 - a) * (var + a * delta * delta), 1e-6)
        return mu, var

    def observe_batch(self, model_index: np.ndarray, exec_ms: np.ndarray):
        """Fold a chunk of observations into the EWMA profiles.

        Observations are replayed per model in arrival order, so the result
        is identical to issuing scalar :meth:`observe` calls one by one.
        """
        obs = self.observability
        if obs is not None:
            mi = np.atleast_1d(np.asarray(model_index))
            ex = np.atleast_1d(np.asarray(exec_ms, dtype=np.float64))
            for m, x in zip(mi, ex):
                obs.histogram(
                    "scheduler_observed_exec_ms", model=self.names[int(m)]
                ).record(float(x))
        if self.cfg.profile_ewma <= 0:
            return
        model_index = np.atleast_1d(np.asarray(model_index))
        exec_ms = np.atleast_1d(np.asarray(exec_ms, dtype=np.float64))
        for m in np.unique(model_index):
            self.mu[m], self._var[m] = self._ewma_fold(
                self.mu[m], self._var[m], exec_ms[model_index == m]
            )
            self.sigma[m] = np.sqrt(self._var[m])
            if obs is not None:
                obs.gauge(
                    "scheduler_mu_ms", model=self.names[int(m)]
                ).set(float(self.mu[m]))

    def observe(self, model_index: int, exec_ms: float):
        """EWMA profile update from an observed execution (drift handling)."""
        self.observe_batch(np.asarray([model_index]), np.asarray([exec_ms]))

    def observe_ondevice(self, exec_ms: np.ndarray):
        """Fold measured hedge-tier executions into the live on-device profile.

        Same EWMA as :meth:`observe_batch`, applied to the duplicate tier:
        the sampled-hedge fallback (and hedging heuristics built on the
        on-device profile) track the real hedge variant instead of a
        static prior.
        """
        if self.cfg.profile_ewma <= 0:
            return
        self.ondevice_mu, self._ondevice_var = self._ewma_fold(
            self.ondevice_mu,
            self._ondevice_var,
            np.atleast_1d(np.asarray(exec_ms, dtype=np.float64)),
        )
        self.ondevice_sigma = float(np.sqrt(self._ondevice_var))
        if self.observability is not None:
            self.observability.gauge("scheduler_ondevice_mu_ms").set(
                self.ondevice_mu
            )

    def observe_join(self, model_index: np.ndarray, ttft_ms: np.ndarray):
        """Fold mid-flight continuous-batching joins into the TTFT profile.

        ``ttft_ms`` is each joined request's measured prefill-to-first-token
        wall time (stamped by the continuous backend at graft).  Same
        per-model replay-in-order EWMA as :meth:`observe_batch`."""
        if self.cfg.profile_ewma <= 0:
            return
        model_index = np.atleast_1d(np.asarray(model_index))
        ttft_ms = np.atleast_1d(np.asarray(ttft_ms, dtype=np.float64))
        for m in np.unique(model_index):
            xs = ttft_ms[model_index == m]
            mu = self.join_ttft_mu[m]
            if np.isnan(mu):  # first observation seeds the EWMA
                mu, self._join_var[m] = float(xs[0]), 0.0
                xs = xs[1:]
            self.join_ttft_mu[m], self._join_var[m] = self._ewma_fold(
                mu, self._join_var[m], xs
            )
            self.join_count[m] += int((model_index == m).sum())
            if self.observability is not None:
                self.observability.gauge(
                    "scheduler_join_ttft_mu_ms", model=self.names[int(m)]
                ).set(float(self.join_ttft_mu[m]))

    # -- outcome resolution ---------------------------------------------------
    def resolve_chunk(
        self,
        decision: BatchDecision,
        remote_latency_ms: np.ndarray,
        ondevice_ms: Optional[np.ndarray] = None,
        ondevice_wait_ms: float | np.ndarray = 0.0,
        t_sla_ms: float | np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Resolve a chunk through hedged duplication.

        ``ondevice_ms`` is the duplicate's *execution* latency per request —
        measured wall times from a real hedge-tier execution on the primary
        path (``ServingEngine.serve_queue`` with an ``OnDeviceBackend``).
        When omitted the duplicate is *simulated* by sampling the live
        on-device profile — the fallback used by :meth:`run_trace` and the
        reference behavior for equivalence tests.

        ``ondevice_wait_ms`` is the delay before the duplicate *starts*
        (the serving front passes each request's queue wait: the duplicate
        is launched at the dispatch tick, not at arrival).  It is added to
        the duplicate's race clock so SLA accounting stays honest under
        queueing; pure simulation has no queue and leaves it 0.

        ``t_sla_ms`` overrides the scheduler-wide SLA — a scalar or a
        per-request vector (the serving loop passes per-request SLAs from
        :attr:`repro.serving.lifecycle.QueuedRequest.sla_ms`).

        Returns ``(accuracy_used, latency_ms, used_remote, ondevice_ms)``;
        the last element echoes the duplicate's from-arrival latencies
        actually raced (wait + execution).  Non-hedged requests keep their
        remote outcome; hedged requests race the on-device duplicate via
        :func:`resolve_duplication`.
        """
        remote_latency_ms = np.asarray(remote_latency_ms, dtype=np.float64)
        n = len(remote_latency_ms)
        if ondevice_ms is None:
            ondevice_ms = np.maximum(
                self.ondevice_mu
                + self.ondevice_sigma * self.rng.standard_normal(n),
                _EXEC_FLOOR_MS,
            )
        ondevice_ms = np.asarray(ondevice_ms, dtype=np.float64) + ondevice_wait_ms
        if t_sla_ms is None:
            t_sla_ms = self.cfg.t_sla_ms
        sel_acc = self.accuracy[decision.model_index]
        out = resolve_duplication(
            remote_latency_ms,
            sel_acc,
            ondevice_ms,
            self.ondevice.accuracy,
            t_sla_ms,
        )
        acc_used = np.where(decision.hedged, out.accuracy, sel_acc)
        latency = np.where(decision.hedged, out.latency_ms, remote_latency_ms)
        used_remote = np.where(decision.hedged, out.used_remote, True)
        return acc_used, latency, used_remote, ondevice_ms

    # -- trace-driven loop ----------------------------------------------------
    def run_trace(
        self,
        t_nw_actual: np.ndarray,
        t_nw_est: Optional[np.ndarray] = None,
        exec_sampler: Optional[Callable[[int, np.random.Generator], float]] = None,
        chunk_size: Optional[int] = None,
    ) -> RequestMetrics:
        """Serve a trace of requests (one per network sample), in chunks.

        All randomness is pre-drawn up-front, so the outcome with
        ``profile_ewma=0`` is independent of ``chunk_size``; with EWMA
        enabled, ``chunk_size=1`` is the scalar reference behavior.

        With :attr:`SchedulerConfig.subchunk_refresh` set, each chunk is
        served in sub-chunks of that many requests, folding observations
        in *between* them: selection no longer sees a profile snapshot
        frozen at chunk start, so drift (queueing transients, §V-A) is
        re-discovered mid-chunk.  With ``profile_ewma=0`` the refresh is a
        no-op and the outcome is bit-identical (the randomness is
        pre-drawn per request, not per chunk).
        """
        t_nw_actual = np.asarray(t_nw_actual, dtype=np.float64)
        if t_nw_est is None:
            t_nw_est = t_nw_actual
        t_nw_est = np.asarray(t_nw_est, dtype=np.float64)
        chunk = self.cfg.chunk_size if chunk_size is None else chunk_size
        if chunk < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk}")
        # Sub-chunk refresh: serve in smaller strides so the EWMA snapshot
        # selection sees is at most `subchunk_refresh` requests stale.
        refresh = self.cfg.subchunk_refresh
        if refresh is not None:
            chunk = min(chunk, refresh)
        n = len(t_nw_actual)

        # Pre-drawn randomness: selection uniforms, execution z-scores,
        # on-device z-scores.  One draw per request regardless of chunking.
        u_sel = self.rng.random(n)
        z_exec = self.rng.standard_normal(n)
        z_ondev = self.rng.standard_normal(n)

        acc_used = np.empty(n)
        lat = np.empty(n)
        used_remote = np.empty(n, bool)
        idxs = np.empty(n, np.int64)

        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            sl = slice(lo, hi)
            d = self.decide_batch(t_nw_est[sl], uniforms=u_sel[sl])
            idxs[sl] = d.model_index
            if exec_sampler is None:
                exec_ms = np.maximum(
                    self.mu[d.model_index]
                    + self.sigma[d.model_index] * z_exec[sl],
                    _EXEC_FLOOR_MS,
                )
            else:
                exec_ms = np.asarray(
                    [exec_sampler(int(m), self.rng) for m in d.model_index]
                )
            self.observe_batch(d.model_index, exec_ms)
            remote = t_nw_actual[sl] + exec_ms
            ondev_ms = np.maximum(
                self.ondevice_mu + self.ondevice_sigma * z_ondev[sl],
                _EXEC_FLOOR_MS,
            )
            acc_used[sl], lat[sl], used_remote[sl], _ = self.resolve_chunk(
                d, remote, ondev_ms
            )
            for j in range(hi - lo):
                self._log.append(
                    {
                        "model": self.names[int(d.model_index[j])],
                        "hedged": bool(d.hedged[j]),
                        "remote_ms": float(remote[j]),
                        "latency_ms": float(lat[lo + j]),
                    }
                )

        return summarize(
            accuracy_used=acc_used,
            latency_ms=lat,
            t_sla_ms=self.cfg.t_sla_ms,
            model_names=self.names,
            model_index=idxs,
            used_remote=used_remote,
        )

    @property
    def log(self):
        return list(self._log)
