"""MDInference as a first-class serving scheduler.

Online version of the paper's algorithm: per request it estimates the
network time, budgets, runs the three-stage selection, and hedges with the
fast tier (straggler mitigation).  Unlike the offline simulator it also
*updates* the latency profiles from observed execution times (EWMA on mu and
sigma) — the paper's stage-3 exploration exists precisely so that stale
profiles (queueing transients, concept drift, §V-A) get re-discovered; the
online update closes that loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.duplication import HedgePolicy, resolve_duplication
from repro.core.registry import ModelProfile, ModelRegistry
from repro.core.selection import select_ref
from repro.core.sla import RequestMetrics, summarize

__all__ = ["SchedulerConfig", "MDInferenceScheduler", "Decision"]


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    t_sla_ms: float = 250.0
    utility_power: float = 1.0
    hedge: HedgePolicy = dataclasses.field(default_factory=HedgePolicy)
    profile_ewma: float = 0.05  # 0 disables online profile updates
    seed: int = 0


@dataclasses.dataclass
class Decision:
    model_index: int
    model_name: str
    hedged: bool
    t_budget_ms: float
    fallback: bool


class MDInferenceScheduler:
    def __init__(
        self,
        registry: ModelRegistry,
        ondevice: ModelProfile,
        cfg: SchedulerConfig = SchedulerConfig(),
    ):
        self.base_registry = registry
        self.ondevice = ondevice
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # Live profile estimates (start from the registry's priors).
        self.mu = registry.mu.astype(np.float64).copy()
        self.sigma = registry.sigma.astype(np.float64).copy()
        self.accuracy = registry.accuracy.astype(np.float64).copy()
        self.names = registry.names
        self._log: list[dict] = []

    # -- the paper's per-request path ---------------------------------------
    def decide(self, t_nw_est_ms: float) -> Decision:
        reg = ModelRegistry(
            [
                ModelProfile(n, a, m, s)
                for n, a, m, s in zip(self.names, self.accuracy, self.mu, self.sigma)
            ]
        )
        budget = self.cfg.t_sla_ms - t_nw_est_ms
        sel = select_ref(
            reg, budget, self.rng, utility_power=self.cfg.utility_power
        )
        base_mu = self.mu[sel.base_index]
        base_sigma = self.sigma[sel.base_index]
        hedged = bool(
            self.cfg.hedge.should_hedge(
                np.asarray([budget]), np.asarray([base_mu]), np.asarray([base_sigma])
            )[0]
        )
        return Decision(
            model_index=sel.index,
            model_name=self.names[sel.index],
            hedged=hedged,
            t_budget_ms=budget,
            fallback=sel.fallback,
        )

    def observe(self, model_index: int, exec_ms: float):
        """EWMA profile update from an observed execution (drift handling)."""
        a = self.cfg.profile_ewma
        if a <= 0:
            return
        delta = exec_ms - self.mu[model_index]
        self.mu[model_index] += a * delta
        var = self.sigma[model_index] ** 2
        var = (1 - a) * (var + a * delta * delta)
        self.sigma[model_index] = np.sqrt(max(var, 1e-6))

    # -- trace-driven loop ----------------------------------------------------
    def run_trace(
        self,
        t_nw_actual: np.ndarray,
        t_nw_est: Optional[np.ndarray] = None,
        exec_sampler: Optional[Callable[[int, np.random.Generator], float]] = None,
    ) -> RequestMetrics:
        """Serve a trace of requests (one per network sample)."""
        t_nw_actual = np.asarray(t_nw_actual, dtype=np.float64)
        if t_nw_est is None:
            t_nw_est = t_nw_actual
        n = len(t_nw_actual)
        acc_used = np.empty(n)
        lat = np.empty(n)
        used_remote = np.empty(n, bool)
        idxs = np.empty(n, np.int64)

        for i in range(n):
            d = self.decide(float(t_nw_est[i]))
            idxs[i] = d.model_index
            if exec_sampler is None:
                exec_ms = max(
                    self.rng.normal(self.mu[d.model_index], self.sigma[d.model_index]),
                    0.1,
                )
            else:
                exec_ms = exec_sampler(d.model_index, self.rng)
            self.observe(d.model_index, exec_ms)
            remote = t_nw_actual[i] + exec_ms
            if d.hedged:
                ondev_ms = max(
                    self.rng.normal(self.ondevice.mu_ms, self.ondevice.sigma_ms), 0.1
                )
                out = resolve_duplication(
                    np.asarray([remote]),
                    np.asarray([self.accuracy[d.model_index]]),
                    np.asarray([ondev_ms]),
                    self.ondevice.accuracy,
                    self.cfg.t_sla_ms,
                )
                acc_used[i] = out.accuracy[0]
                lat[i] = out.latency_ms[0]
                used_remote[i] = out.used_remote[0]
            else:
                acc_used[i] = self.accuracy[d.model_index]
                lat[i] = remote
                used_remote[i] = True
            self._log.append(
                {
                    "model": d.model_name,
                    "hedged": d.hedged,
                    "remote_ms": remote,
                    "latency_ms": lat[i],
                }
            )

        return summarize(
            accuracy_used=acc_used,
            latency_ms=lat,
            t_sla_ms=self.cfg.t_sla_ms,
            model_names=self.names,
            model_index=idxs,
            used_remote=used_remote,
        )

    @property
    def log(self):
        return list(self._log)
