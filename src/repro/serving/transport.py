"""Replica transport: the message boundary under the cluster's pool.

PR 5's :class:`repro.serving.cluster.ClusterBackend` was horizontal in
name only — every replica an in-process object sharing the loop's fate.
:class:`ProcessTransportBackend` puts a replica behind a *real* boundary:
its backend runs in a spawned worker process and every batch crosses a
pipe as serialized submit/completion messages
(:mod:`repro.serving.transport_worker`).  The worker can genuinely die —
and the parent observes it as :class:`ReplicaDied` on every in-flight
batch, reconciling the replica's inflight/EWMA accounting on the way out
(the routing signals must not leak rows a dead worker will never
complete).

Two modes, one failure surface:

* ``mode="process"`` — the real boundary: spawned worker, pickled
  messages, a pump thread demultiplexing completions, worker-death and
  per-batch timeout detection, :meth:`kill` / :meth:`restart` for fault
  injection and rejoin.
* ``mode="inline"`` — the sync/CI fallback: the factory's backend runs
  in-process (zero new concurrency), but the *fault surface is
  preserved*: :meth:`kill` makes every subsequent batch raise
  :class:`ReplicaDied`, and :meth:`inject_failures` queues deterministic
  :class:`RemoteExecutionError` faults — so breaker/requeue tests run
  byte-deterministically under ``dispatch="sync"``.

Error taxonomy (all :class:`TransportError`):

* :class:`ReplicaDied` — the worker is gone (death, kill, timeout):
  *fatal* to the circuit breaker, trips immediately.
* :class:`RemoteExecutionError` — the worker survived but the batch
  raised: counts toward the breaker's consecutive-failure threshold.

Either way the batch's rows leave ``inflight_rows`` (``_note_done`` with
``wall_ms=None``) — the accounting-reconcile contract the routers depend
on.
"""
from __future__ import annotations

import itertools
import multiprocessing as mp
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.serving.backend import BatchHandle, ExecutionBackend, Variant
from repro.serving.transport_worker import worker_main

__all__ = [
    "TransportError",
    "ReplicaDied",
    "RemoteExecutionError",
    "FailedBatchHandle",
    "ProcessTransportBackend",
]


class TransportError(RuntimeError):
    """A batch was lost to the transport layer (never produced tokens)."""


class ReplicaDied(TransportError):
    """The replica's worker is gone — death, kill, or timeout.  Fatal to
    the circuit breaker (trips immediately)."""


class RemoteExecutionError(TransportError):
    """The worker survived but the batch raised remotely.  Counts toward
    the breaker's consecutive-failure threshold."""


class FailedBatchHandle(BatchHandle):
    """A handle for a batch the transport already knows is lost.

    ``poll`` is immediately True (there is nothing to wait for) and
    ``wait`` raises the stored :class:`TransportError` — the serving
    loop's collection path turns that into requeue/hedge-failover instead
    of tokens.
    """

    def __init__(self, name: str, n_rows: int, error: TransportError):
        super().__init__(name, n_rows)
        self.error = error

    def poll(self) -> bool:
        return True

    def wait(self, timeout=None):
        raise self.error


class _PendingBatch:
    """Parent-side slot for one submitted batch awaiting its completion
    message (process mode)."""

    def __init__(self):
        self.event = threading.Event()
        self.result: Optional[Tuple[np.ndarray, float]] = None
        self.error: Optional[TransportError] = None
        # Tracing extras (populated only when the submit asked for them):
        # the worker's relative timings and the parent-side receive stamp.
        self.span_info: Optional[dict] = None
        self.recv_wall_ms: Optional[float] = None


class ProcessTransportBackend(ExecutionBackend):
    """One replica's backend behind a process (or inline) transport.

    ``factory`` builds the actual execution backend — in the worker for
    ``mode="process"`` (it must be picklable: a top-level callable), in
    this process for ``mode="inline"``.  Registration is mirrored: the
    parent keeps the variant metadata (so placement/routing see
    ``variants``) and forwards each registration across the boundary.
    """

    def __init__(
        self,
        factory: Callable[[], ExecutionBackend],
        *,
        mode: str = "process",
        timeout_s: Optional[float] = 60.0,
        max_len: Optional[int] = None,
    ):
        if mode not in ("process", "inline"):
            raise ValueError(f"mode must be 'process' or 'inline', got {mode!r}")
        super().__init__()
        self.factory = factory
        self.mode = mode
        self.timeout_s = timeout_s
        self._dead: Optional[str] = None  # death reason, None while alive
        self._seq = itertools.count()
        self._inner: Optional[ExecutionBackend] = None
        self._fail_queue: list = []  # inline-mode injected faults
        self._conn = None
        self._proc: Optional[mp.process.BaseProcess] = None
        self._pending: Dict[int, _PendingBatch] = {}
        self._send_lock = threading.Lock()
        self._pump_thread: Optional[threading.Thread] = None
        if mode == "inline":
            self._inner = factory()
            self.max_len = (
                max_len if max_len is not None
                else getattr(self._inner, "max_len", None)
            )
        else:
            self.max_len = max_len
            self._spawn()

    # -- lifecycle ------------------------------------------------------------
    @property
    def alive(self) -> bool:
        return self._dead is None

    def _spawn(self) -> None:
        ctx = mp.get_context("spawn")
        self._conn, child_conn = ctx.Pipe(duplex=True)
        self._proc = ctx.Process(
            target=worker_main, args=(child_conn, self.factory), daemon=True
        )
        self._proc.start()
        child_conn.close()  # the parent keeps only its end
        self._dead = None
        self._pump_thread = threading.Thread(
            target=self._pump, name="transport-pump", daemon=True
        )
        self._pump_thread.start()

    def _pump(self) -> None:
        """Demultiplex completion messages to their pending slots; a
        broken pipe means the worker died — fail everything in flight."""
        conn = self._conn
        try:
            while True:
                msg = conn.recv()
                kind, seq = msg[0], msg[1]
                slot = self._pending.pop(seq, None)
                if slot is None:
                    continue  # a timed-out batch already gave up on it
                if kind == "result":
                    slot.result = (msg[2], msg[3])
                    if len(msg) > 4:  # traced submit: worker-side timings
                        slot.span_info = msg[4]
                        slot.recv_wall_ms = time.perf_counter() * 1e3
                else:
                    slot.error = RemoteExecutionError(
                        f"batch failed in worker: {msg[2]}"
                    )
                slot.event.set()
        except (EOFError, OSError):
            self._fail_all_pending("worker process died")

    def _fail_all_pending(self, reason: str) -> None:
        self._dead = reason
        while self._pending:
            _, slot = self._pending.popitem()
            slot.error = ReplicaDied(reason)
            slot.event.set()

    def kill(self, reason: str = "killed") -> None:
        """Hard-kill the replica (fault injection / operator action).

        Process mode terminates the worker; either mode fails every
        in-flight batch with :class:`ReplicaDied` and makes every future
        submit raise it too, until :meth:`restart`.
        """
        if self._proc is not None and self._proc.is_alive():
            self._proc.terminate()
        self._fail_all_pending(reason)

    def restart(self) -> None:
        """Bring a dead replica back (the rejoin path).

        Process mode respawns the worker and replays registration from
        the parent's variant mirror; inline mode just clears the death
        flag.  Load accounting is already reconciled (failures drained
        inflight), so the recovered replica re-enters routing at zero.
        """
        if self._proc is not None:
            self._proc.join(timeout=5.0)
        self._dead = None
        self._fail_queue = []
        if self.mode == "process":
            self._spawn()
            for v in self.variants.values():
                self._conn.send(("register", v))

    def close(self) -> None:
        """Shut the worker down cleanly (tests / bench teardown)."""
        if self.mode == "process" and self.alive and self._proc is not None:
            try:
                self._conn.send(("stop",))
            except (BrokenPipeError, OSError):
                pass
            self._proc.join(timeout=5.0)
            if self._proc.is_alive():  # pragma: no cover - stuck worker
                self._proc.terminate()
        self._dead = "closed"

    # -- fault injection (inline mode) ----------------------------------------
    def inject_failures(self, n: int, reason: str = "injected fault") -> None:
        """Queue ``n`` deterministic batch failures (inline mode only) —
        the sync/CI stand-in for a worker that errors without dying."""
        if self.mode != "inline":
            raise ValueError(
                "inject_failures is the inline-mode fault hook; kill() the "
                "process worker instead"
            )
        self._fail_queue.extend([reason] * n)

    # -- the execution protocol, across the boundary --------------------------
    def register(self, v: Variant) -> None:
        self.variants[v.name] = v
        if self.mode == "inline":
            self._inner.register(v)
        elif self.alive:
            self._conn.send(("register", v))

    def run_batch(self, name, batch, n_steps):
        if self._dead is not None:
            raise ReplicaDied(f"replica is down: {self._dead}")
        if self.mode == "inline":
            if self._fail_queue:
                if self._obs is not None:
                    self._obs.counter(
                        "transport_batches_total", outcome="error"
                    ).inc()
                raise RemoteExecutionError(self._fail_queue.pop(0))
            if self._obs is None:
                return self._inner.run_batch(name, batch, n_steps)
            return self._run_inline_traced(name, batch, n_steps)
        return self._roundtrip(name, np.asarray(batch), int(n_steps))

    def _run_inline_traced(self, name, batch, n_steps):
        """Inline execution with the same span shape as process mode:
        a ``transport.roundtrip`` wrapping a ``worker.execute`` (here
        the 'worker' is this process — the boundary is logical only)."""
        tracer = self._obs.tracer
        span = tracer.start(
            "transport.roundtrip",
            parent=tracer.ambient_id(),
            cat="transport",
            track=self._obs_track,
            variant=name,
            rows=int(np.asarray(batch).shape[0]),
            mode="inline",
        )
        exec_span = tracer.start(
            "worker.execute",
            parent=span,
            cat="transport",
            track=self._obs_track,
            variant=name,
        )
        try:
            out = self._inner.run_batch(name, batch, n_steps)
        except BaseException as e:
            span.args["error"] = repr(e)
            self._obs.counter(
                "transport_batches_total", outcome="error"
            ).inc()
            raise
        finally:
            tracer.end(exec_span)
            tracer.end(span)
        self._obs.counter("transport_batches_total", outcome="ok").inc()
        self._obs.histogram("transport_roundtrip_ms").record(
            span.duration_ms
        )
        return out

    def generate(self, name, tokens, n_steps):
        if self.mode == "inline":
            if self._dead is not None:
                raise ReplicaDied(f"replica is down: {self._dead}")
            return self._inner.generate(name, tokens, n_steps)
        return self.run_batch(name, tokens, n_steps)

    def _roundtrip(self, name, batch, n_steps) -> Tuple[np.ndarray, float]:
        if self._obs is None:
            return self._roundtrip_raw(name, batch, n_steps, traced=False)[0]
        # Traced path: one transport.roundtrip span around the pipe trip,
        # with a worker.execute child reconstructed from the worker's
        # *relative* timings (perf_counter epochs differ across processes,
        # so the child is anchored to end at the parent-side receive
        # stamp and extend backwards by the reported duration).
        tracer = self._obs.tracer
        span = tracer.start(
            "transport.roundtrip",
            parent=tracer.ambient_id(),
            cat="transport",
            track=self._obs_track,
            variant=name,
            rows=int(batch.shape[0]),
            mode="process",
        )
        try:
            result, slot = self._roundtrip_raw(
                name, batch, n_steps, traced=True
            )
        except TransportError as e:
            span.args["error"] = str(e)
            tracer.end(span)
            self._obs.counter(
                "transport_batches_total", outcome="error"
            ).inc()
            raise
        if slot.span_info is not None and slot.recv_wall_ms is not None:
            info = slot.span_info
            exec_span = tracer.start(
                "worker.execute",
                parent=span,
                cat="transport",
                track=self._obs_track,
                variant=name,
                worker_wall_ms=info.get("wall_ms"),
                t0_ms=slot.recv_wall_ms - float(info.get("handle_ms", 0.0)),
            )
            tracer.end(exec_span, slot.recv_wall_ms)
        tracer.end(span)
        self._obs.counter("transport_batches_total", outcome="ok").inc()
        self._obs.histogram("transport_roundtrip_ms").record(
            span.duration_ms
        )
        return result

    def _roundtrip_raw(
        self, name, batch, n_steps, *, traced: bool
    ) -> Tuple[Tuple[np.ndarray, float], _PendingBatch]:
        slot = _PendingBatch()
        with self._send_lock:
            if self._dead is not None:
                raise ReplicaDied(f"replica is down: {self._dead}")
            seq = next(self._seq)
            self._pending[seq] = slot
            # Backward-compatible protocol extension: the 6th element asks
            # the worker to report its relative timings alongside the
            # result (old 5-tuples keep the old 4-tuple reply).
            msg = (
                ("submit", seq, name, batch, n_steps, True)
                if traced
                else ("submit", seq, name, batch, n_steps)
            )
            try:
                self._conn.send(msg)
            except (BrokenPipeError, OSError):
                self._pending.pop(seq, None)
                self._fail_all_pending("worker process died")
                raise ReplicaDied("worker process died") from None
        if not slot.event.wait(self.timeout_s):
            # A wedged worker is indistinguishable from a dead one; the
            # timeout converts the ambiguity into a definite death — kill
            # so no later batch waits on it too.
            self._pending.pop(seq, None)
            self.kill(f"batch timeout after {self.timeout_s}s")
            raise ReplicaDied(f"batch timeout after {self.timeout_s}s")
        if slot.error is not None:
            raise slot.error
        return slot.result, slot
