"""Process-worker entry point for :mod:`repro.serving.transport`.

Lives in its own module so a spawned child imports *only* this file plus
whatever the pickled backend factory pulls in — a stub factory keeps the
child completely jax-free, which is what makes process-transport tests
cheap enough for tier-1 CI.

Protocol (one duplex :class:`multiprocessing.connection.Connection`):

parent → child messages (tuples, first element is the op):

* ``("register", variant)`` — register one variant on the child backend.
* ``("submit", seq, name, batch, n_steps)`` — run one batch.
* ``("stop",)`` — exit the loop.

child → parent messages:

* ``("result", seq, out, wall_ms)`` — batch ``seq`` finished.
* ``("error", seq, repr_str)`` — batch ``seq`` raised; the exception is
  flattened to its ``repr`` (arbitrary exceptions may not pickle).

The child never shares memory with the parent: every batch crosses the
pipe as a pickled ndarray — the real message boundary the cluster's
fault model is built on.
"""
from __future__ import annotations


def worker_main(conn, factory) -> None:
    """Run a backend worker: build the backend, serve the message loop."""
    try:
        backend = factory()
    except BaseException as e:  # surface construction failure, then die
        try:
            conn.send(("error", -1, f"worker backend construction: {e!r}"))
        finally:
            conn.close()
        return
    try:
        while True:
            msg = conn.recv()
            op = msg[0]
            if op == "stop":
                break
            if op == "register":
                backend.register(msg[1])
                continue
            if op == "submit":
                seq, name, batch, n_steps = msg[1], msg[2], msg[3], msg[4]
                try:
                    out, wall_ms = backend.run_batch(name, batch, n_steps)
                    conn.send(("result", seq, out, float(wall_ms)))
                except BaseException as e:
                    conn.send(("error", seq, repr(e)))
                continue
            raise ValueError(f"unknown transport op {op!r}")
    except (EOFError, OSError):
        pass  # parent went away: nothing left to serve
    finally:
        conn.close()
