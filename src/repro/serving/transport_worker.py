"""Process-worker entry point for :mod:`repro.serving.transport`.

Lives in its own module so a spawned child imports *only* this file plus
whatever the pickled backend factory pulls in — a stub factory keeps the
child completely jax-free, which is what makes process-transport tests
cheap enough for tier-1 CI.

Protocol (one duplex :class:`multiprocessing.connection.Connection`):

parent → child messages (tuples, first element is the op):

* ``("register", variant)`` — register one variant on the child backend.
* ``("submit", seq, name, batch, n_steps)`` — run one batch.
* ``("submit", seq, name, batch, n_steps, True)`` — run one batch *and*
  report worker-side timings (the tracing-enabled submit; the trailing
  flag is the whole extension, so old parents and old workers interop).
* ``("stop",)`` — exit the loop.

child → parent messages:

* ``("result", seq, out, wall_ms)`` — batch ``seq`` finished.
* ``("result", seq, out, wall_ms, span_info)`` — traced completion;
  ``span_info`` is ``{"handle_ms", "wall_ms"}`` — *relative* durations
  (total submit-handling and the timed execution), because the child's
  ``perf_counter`` epoch is meaningless to the parent.  The parent
  anchors the reconstructed ``worker.execute`` span at its own receive
  stamp.
* ``("error", seq, repr_str)`` — batch ``seq`` raised; the exception is
  flattened to its ``repr`` (arbitrary exceptions may not pickle).

The child never shares memory with the parent: every batch crosses the
pipe as a pickled ndarray — the real message boundary the cluster's
fault model is built on.
"""
from __future__ import annotations

import time


def worker_main(conn, factory) -> None:
    """Run a backend worker: build the backend, serve the message loop."""
    try:
        backend = factory()
    except BaseException as e:  # surface construction failure, then die
        try:
            conn.send(("error", -1, f"worker backend construction: {e!r}"))
        finally:
            conn.close()
        return
    try:
        while True:
            msg = conn.recv()
            op = msg[0]
            if op == "stop":
                break
            if op == "register":
                backend.register(msg[1])
                continue
            if op == "submit":
                seq, name, batch, n_steps = msg[1], msg[2], msg[3], msg[4]
                traced = len(msg) > 5 and bool(msg[5])
                try:
                    t0 = time.perf_counter()
                    out, wall_ms = backend.run_batch(name, batch, n_steps)
                    if traced:
                        handle_ms = (time.perf_counter() - t0) * 1e3
                        span_info = {
                            "handle_ms": handle_ms,
                            "wall_ms": float(wall_ms),
                        }
                        conn.send(
                            ("result", seq, out, float(wall_ms), span_info)
                        )
                    else:
                        conn.send(("result", seq, out, float(wall_ms)))
                except BaseException as e:
                    conn.send(("error", seq, repr(e)))
                continue
            raise ValueError(f"unknown transport op {op!r}")
    except (EOFError, OSError):
        pass  # parent went away: nothing left to serve
    finally:
        conn.close()
