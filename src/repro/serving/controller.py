"""Closed-loop adaptive admission control (drift tracking).

MDInference's latency bound is conditional on *variability*: the paper's
university-vs-LTE gap is a network drifting under the client, and "A Note
on Latency Variability of DNNs for Mobile Inference" measures per-replica
service times swinging 30x.  A statically tuned
:class:`~repro.serving.admission.AdmissionConfig` is therefore wrong most
of the time: capacity sized for the diurnal trough over-admits at the
peak, capacity sized for the peak over-sheds in the shoulders.

:class:`AdmissionController` closes the loop.  Each tick it reads the
live signals the stack already produces —

* per-completion queue waits + shed counts from the tick's
  :class:`~repro.serving.loop.TickResult`,
* the scheduler's live service-rate EWMAs (``mu`` / ``ondevice_mu``) and
  join-TTFT EWMA (:class:`~repro.serving.scheduler.MDInferenceScheduler`),
* per-replica ``ewma_wall_ms`` from backend load accounting
  (:meth:`~repro.serving.cluster.ClusterBackend.snapshot`) —

and retunes the queue's ``max_pending`` capacity and ``shed_headroom_ms``
margin through :meth:`AdmissionQueue.retune
<repro.serving.admission.AdmissionQueue.retune>` with a bounded
AIMD-style law:

* **overload** (wait EWMA above the high watermark, or the tick shed) for
  ``hysteresis`` consecutive ticks → *multiplicative decrease* of
  capacity, and the shed margin tightens by the observed wait excess
  (shed earlier, keep the served tail short);
* **underload** (wait EWMA below the low watermark, shed-free) for
  ``hysteresis`` consecutive ticks → *additive increase* of capacity and
  a *multiplicative decay* of the margin (stop over-shedding);
* everything clamped to ``[min_pending, max_pending]`` /
  ``[0, max headroom]``, with the hysteresis streaks resetting on any
  neutral tick — a single spike never flaps the queue.

``controller=None`` on the loop is the compatibility default and is
byte-identical to the static config (regression-pinned).  The controller
itself is deterministic: no randomness, no wall clock — two seeded runs
retune identically (the drift gauntlet's seeded-twin pin).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.admission import AdmissionQueue

__all__ = ["ControllerConfig", "AdmissionController"]


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Law constants for :class:`AdmissionController` (all clamped)."""

    # The wait target: queue wait should stay below this fraction of the
    # loop SLA (served requests keep most of their budget for execution).
    target_wait_frac: float = 0.2
    low_water: float = 0.5  # underload below low_water x target wait
    high_water: float = 1.0  # overload above high_water x target wait
    wait_alpha: float = 0.4  # EWMA fold for the observed tick wait
    hysteresis: int = 2  # consecutive breaches before the law acts
    # Capacity law (AIMD): additive increase / multiplicative decrease,
    # clamped to [min_pending, max_pending].
    increase_step: int = 4
    decrease_factor: float = 0.5
    min_pending: int = 2
    max_pending: int = 4096
    # Shed-margin law: under overload the margin tightens by the larger
    # of a service-scaled floor step and the observed wait *excess* over
    # target (so a 30x service swing takes one proportional bite, not
    # thirty fixed ones); in calm it decays multiplicatively.  Clamped to
    # a fraction of SLA.
    headroom_step_frac: float = 0.5
    headroom_decay: float = 0.5
    max_headroom_frac: float = 0.8

    def __post_init__(self):
        if not 0.0 < self.target_wait_frac <= 1.0:
            raise ValueError(
                f"target_wait_frac must be in (0, 1], got {self.target_wait_frac}"
            )
        if not 0.0 <= self.low_water < self.high_water:
            raise ValueError(
                "need 0 <= low_water < high_water, got "
                f"{self.low_water} / {self.high_water}"
            )
        if not 0.0 < self.wait_alpha <= 1.0:
            raise ValueError(f"wait_alpha must be in (0, 1], got {self.wait_alpha}")
        if self.hysteresis < 1:
            raise ValueError(f"hysteresis must be >= 1, got {self.hysteresis}")
        if self.increase_step < 1:
            raise ValueError(
                f"increase_step must be >= 1, got {self.increase_step}"
            )
        if not 0.0 < self.decrease_factor < 1.0:
            raise ValueError(
                f"decrease_factor must be in (0, 1), got {self.decrease_factor}"
            )
        if not 1 <= self.min_pending <= self.max_pending:
            raise ValueError(
                "need 1 <= min_pending <= max_pending, got "
                f"{self.min_pending} / {self.max_pending}"
            )
        if not 0.0 <= self.headroom_decay < 1.0:
            raise ValueError(
                f"headroom_decay must be in [0, 1), got {self.headroom_decay}"
            )
        if self.headroom_step_frac < 0 or self.max_headroom_frac < 0:
            raise ValueError("headroom fractions must be >= 0")


class AdmissionController:
    """Bounded AIMD retuner for a live :class:`AdmissionQueue`.

    The loop drives it in two phases per tick: :meth:`observe` folds the
    collected tick's signals into the wait/service EWMAs (and advances
    the hysteresis streaks), :meth:`apply` — called at the top of the
    *next* tick, before admission take — enacts any due retune.  Both are
    no-ops on an unbounded queue (there is no capacity to tune).
    """

    def __init__(self, cfg: Optional[ControllerConfig] = None):
        self.cfg = cfg if cfg is not None else ControllerConfig()
        self.wait_ewma_ms: Optional[float] = None  # observed tick waits
        self.service_est_ms: float = 0.0  # live service estimate (for steps)
        self.sla_ms: float = 0.0  # loop SLA seen at the last observe
        self._over = 0  # consecutive overload ticks
        self._under = 0  # consecutive underload ticks
        self._shed_last = False  # last observed tick shed something
        self._tightened_last = False  # previous retune was a tighten
        self.n_ticks = 0
        self.n_retunes = 0
        # (now_ms, max_pending, shed_headroom_ms) after each retune —
        # the gauntlet's evidence that the law actually moved the knobs.
        self.log: List[Tuple[float, int, float]] = []
        # Optional repro.observability.Observability handle (set by the
        # loop); None keeps observe/apply free of metric writes.
        self.observability = None

    # -- phase 1: fold one collected tick's signals ------------------------
    def observe(
        self,
        result,
        *,
        scheduler,
        backend=None,
        now_ms: float = 0.0,
        backlog: int = 0,
    ) -> None:
        """Fold one :class:`~repro.serving.loop.TickResult` into the law's
        EWMAs and hysteresis streaks.  Reads the scheduler's live
        service/join EWMAs and — on a clustered backend — the per-replica
        ``ewma_wall_ms`` load accounting."""
        cfg = self.cfg
        self.n_ticks += 1
        self.sla_ms = float(scheduler.cfg.t_sla_ms)
        self._now_ms = float(now_ms)

        # Live service estimate: the fastest remote variant's EWMA mu,
        # lifted by what the replicas actually report (a slow replica's
        # wall EWMA) and the continuous tier's join TTFT.  This scales the
        # margin steps, so a 30x service swing takes 30x bigger margin
        # bites without retuning the law's constants.
        floor = float(np.min(scheduler.mu))
        walls = []
        snapshot = getattr(backend, "snapshot", None)
        if snapshot is not None:
            walls = [
                s.ewma_wall_ms
                for s in snapshot()
                if s.ewma_wall_ms is not None
                and s.health != "open"
                and not s.draining
            ]
        else:
            wall = getattr(backend, "ewma_wall_ms", None)
            if wall is not None:
                walls = [wall]
        join = np.asarray(
            getattr(scheduler, "join_ttft_mu", 0.0), dtype=float
        )
        finite = join[np.isfinite(join)] if join.size else join
        join_mu = float(np.max(finite)) if finite.size else 0.0
        self.service_est_ms = max(
            floor, max(walls) if walls else 0.0, join_mu
        )

        # Tick wait signal: the *max* completion wait (tail-sensitive) —
        # a tick that only shed carries the previous EWMA forward.
        waits = [c.queue_wait_ms for c in result.completions]
        if waits:
            w = max(waits)
            self.wait_ewma_ms = (
                w
                if self.wait_ewma_ms is None
                else cfg.wait_alpha * w
                + (1.0 - cfg.wait_alpha) * self.wait_ewma_ms
            )
        self._shed_last = result.stats.n_shed > 0

        obs = self.observability
        if obs is not None:
            if self.wait_ewma_ms is not None:
                obs.histogram("controller_wait_ewma_ms").record(
                    self.wait_ewma_ms
                )
            obs.gauge("controller_service_est_ms").set(self.service_est_ms)

        target = cfg.target_wait_frac * self.sla_ms
        wait = self.wait_ewma_ms if self.wait_ewma_ms is not None else 0.0
        overload = self._shed_last or wait > cfg.high_water * target
        underload = (
            not self._shed_last
            and wait < cfg.low_water * target
            and backlog == 0
        )
        if overload:
            self._over += 1
            self._under = 0
        elif underload:
            self._under += 1
            self._over = 0
        else:
            # Neutral zone: both streaks reset — hysteresis demands
            # *consecutive* evidence, so a lone spike never retunes.
            self._over = 0
            self._under = 0

    # -- phase 2: enact any due retune -------------------------------------
    def apply(self, queue: AdmissionQueue) -> bool:
        """Retune ``queue`` if a hysteresis streak is complete.  Returns
        True when a retune happened.  No-op on unbounded queues."""
        cfg = self.cfg
        qcfg = queue.cfg
        if qcfg.max_pending is None or qcfg.policy == "unbounded":
            return False
        pending = qcfg.max_pending
        headroom = qcfg.shed_headroom_ms
        max_headroom = cfg.max_headroom_frac * self.sla_ms
        target = cfg.target_wait_frac * self.sla_ms
        wait = self.wait_ewma_ms if self.wait_ewma_ms is not None else 0.0
        # Proportional tightening: one bite the size of the wait excess
        # (floored by a service-scaled step) reaches the drifted operating
        # point in O(1) retunes instead of O(drift / step).
        step = max(
            cfg.headroom_step_frac * self.service_est_ms, wait - target
        )
        if self._over >= cfg.hysteresis:
            new_pending = max(
                cfg.min_pending, int(pending * cfg.decrease_factor)
            )
            # Bounded escalation: overload that *persists through a
            # tighten* (another full hysteresis streak after the last
            # bite) means the backlog is still draining late — jump the
            # margin to its clamp so the queued tail is trimmed now
            # instead of ratcheting down one drain-interval at a time.
            if self._tightened_last:
                new_headroom = max_headroom
            else:
                new_headroom = min(headroom + step, max_headroom)
            self._tightened_last = True
        elif self._under >= cfg.hysteresis:
            new_pending = min(cfg.max_pending, pending + cfg.increase_step)
            new_headroom = headroom * cfg.headroom_decay
            if new_headroom < 1e-6:
                new_headroom = 0.0
            self._tightened_last = False
        else:
            return False
        self._over = 0
        self._under = 0
        if new_pending == pending and new_headroom == headroom:
            return False
        queue.retune(
            max_pending=new_pending, shed_headroom_ms=new_headroom
        )
        self.n_retunes += 1
        now_ms = getattr(self, "_now_ms", 0.0)
        self.log.append((now_ms, new_pending, new_headroom))
        if self.observability is not None:
            obs = self.observability
            direction = "tighten" if self._tightened_last else "relax"
            obs.counter(
                "controller_retunes_total", direction=direction
            ).inc()
            obs.gauge("controller_max_pending").set(new_pending)
            obs.gauge("controller_shed_headroom_ms").set(new_headroom)
            obs.tracer.instant(
                "controller.retune",
                cat="controller",
                now_ms=now_ms,
                direction=direction,
                max_pending=new_pending,
                shed_headroom_ms=new_headroom,
            )
        return True
