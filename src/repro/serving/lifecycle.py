"""Request lifecycle: the per-request objects of the async serving API.

A request moves through explicit states::

    QUEUED ──▶ SCHEDULED ──▶ EXECUTING ──▶ RESOLVED
       │ │          │             │
       │ └──────────┴─────────────┴──────▶ CANCELLED
       └─────────────────────────────────▶ REJECTED

* **QUEUED** — submitted to :class:`repro.serving.loop.ServingLoop` (or an
  :class:`repro.serving.client.InferenceClient`), waiting for a scheduling
  tick.  Under a bounded admission queue
  (:class:`repro.serving.admission.AdmissionQueue`) a queued future may
  not be *admitted* yet (``admitted`` False — parked in the overflow room
  by the ``block`` policy); ``admitted_wall_ms`` stamps the admission.
  :meth:`InferenceFuture.cancel` here frees the request entirely — it
  never occupies a batch slot on either tier.
* **REJECTED** — terminal: the admission queue refused the request (at
  capacity under the ``shed`` policy, or because its queue wait already
  made the SLA unreachable).  :meth:`InferenceFuture.result` raises
  :class:`RequestRejected`.  Only a QUEUED request can be rejected.
* **SCHEDULED** — a tick picked it up; ``decide_batch`` chose its variant.
* **EXECUTING** — dispatched to the execution tier(s); per-tier dispatch
  wall timestamps are recorded on the future.  Cancellation from here on
  cannot recall the batched execution, but the result is discarded at
  resolution (the measurement still folds into the live EWMA profiles —
  the work really happened).  A batch lost to a dead/failed replica sends
  its unhedged rows *back* to QUEUED (``_requeue`` — the loop re-admits
  them at the front of the admission queue), so replica failure loses no
  request.
* **RESOLVED** — hedged duplication resolved; :meth:`InferenceFuture.result`
  returns the :class:`CompletedRequest`.

The dataclasses :class:`QueuedRequest` / :class:`CompletedRequest` are the
wire format between the client, the loop, and the compatibility shim
(:meth:`repro.serving.engine.ServingEngine.serve_queue`).
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Dict, Iterator, List, Optional

import numpy as np

__all__ = [
    "RequestState",
    "RequestCancelled",
    "RequestRejected",
    "InferenceFuture",
    "QueuedRequest",
    "CompletedRequest",
    "StreamChunk",
]


class RequestState(enum.Enum):
    QUEUED = "queued"
    SCHEDULED = "scheduled"
    EXECUTING = "executing"
    RESOLVED = "resolved"
    CANCELLED = "cancelled"
    REJECTED = "rejected"


class RequestCancelled(RuntimeError):
    """Raised by :meth:`InferenceFuture.result` for a cancelled request."""


class RequestRejected(RuntimeError):
    """Raised by :meth:`InferenceFuture.result` for a request the admission
    queue refused (overload shedding / unreachable SLA)."""


@dataclasses.dataclass
class QueuedRequest:
    """One pending inference request in the serving queue."""

    rid: int
    tokens: np.ndarray  # (S,) prompt tokens
    n_steps: int
    t_nw_est_ms: float
    t_nw_actual_ms: float
    arrival_ms: float = 0.0
    sla_ms: Optional[float] = None  # per-request SLA (None: the loop's)
    # Tenancy: which admission lane the request rides (None: the implicit
    # "default" lane) and its priority class — "interactive" | "batch"
    # (None: the lane's configured class).
    tenant: Optional[str] = None
    priority: Optional[str] = None


@dataclasses.dataclass
class CompletedRequest:
    """Resolved outcome of one served request."""

    rid: int
    model_name: str
    model_index: int
    # (n_steps,) generated tokens.  With a real hedge tier (hedge_measured)
    # these come from the tier that answered; in the sampled-hedge
    # simulation there is no duplicate execution, so they are always the
    # remote model's output even when the simulated duplicate "wins".
    tokens: np.ndarray
    exec_ms: float  # wall time of the variant batch this request rode in
    remote_ms: float  # queue wait + network + execution
    latency_ms: float  # user-observed (post-duplication)
    accuracy: float  # quality of the result actually used
    used_remote: bool
    hedged: bool
    queue_wait_ms: float = 0.0  # dispatch tick - arrival (charged to budget)
    ondevice_ms: Optional[float] = None  # duplicate's latency (hedged only)
    hedge_measured: bool = False  # True: ondevice_ms is real wall time
    time_to_schedule_ms: float = 0.0  # scheduling tick - arrival
    race_resolution: str = "unhedged"  # remote_won | ondevice_won | unhedged
    # Cluster routing: which pool replica ran the remote batch (None on a
    # single unclustered backend and for degrade-lane rows — the on-device
    # hedge singleton is never a routable replica), and the replica's
    # queue depth in rows, this batch included, at dispatch.
    replica: Optional[int] = None
    replica_inflight: Optional[int] = None
    # Continuous-batching tier: wall time from dispatch to this row's first
    # token (prefill + graft into the persistent decode batch).  None on
    # the classic whole-batch tiers, where no first token exists before
    # batch end.
    ttft_ms: Optional[float] = None
    # Tenancy: the admission lane that served the request (None: untagged)
    # and its effective priority class at admission.
    tenant: Optional[str] = None
    priority: str = "interactive"


@dataclasses.dataclass(frozen=True)
class StreamChunk:
    """One decode token pushed to a streaming consumer before resolution.

    ``wall_ms`` is the absolute ``time.perf_counter()`` stamp (in ms) at
    which the token was emitted by the backend — the same stamp the
    continuous tier uses for its TTFT accounting, so for the first chunk
    ``wall_ms - future.tier_dispatch_wall_ms["remote"]`` equals the
    completion's ``ttft_ms``.
    """

    index: int  # position in the decode stream (0 = first token)
    token: int
    wall_ms: float


class InferenceFuture:
    """Handle to one in-flight request; resolved by the serving loop.

    Carries the loop-clock lifecycle timestamps (``submitted_ms``,
    ``scheduled_ms``, ``resolved_ms``) plus per-tier *wall-clock* dispatch
    and completion timestamps (``tier_dispatch_wall_ms`` /
    ``tier_done_wall_ms``, keys ``"remote"`` and ``"ondevice"``) — the raw
    material for race-clock assertions: with async dispatch both tiers'
    entries differ by thread-submit overhead, not by a serialized batch.
    """

    def __init__(self, request: QueuedRequest, loop=None):
        self.request = request
        self.state = RequestState.QUEUED
        self.submitted_ms: float = request.arrival_ms
        self.scheduled_ms: Optional[float] = None
        self.resolved_ms: Optional[float] = None
        # Admission bookkeeping: a bounded queue's "block" policy parks the
        # future un-admitted (backpressure); admitted_wall_ms stamps the
        # moment it actually entered the bounded pending queue.
        self.admitted: bool = False
        self.admitted_wall_ms: Optional[float] = None
        self.tier_dispatch_wall_ms: Dict[str, float] = {}
        self.tier_done_wall_ms: Dict[str, float] = {}
        # Effective priority class: the request's explicit priority, else
        # "interactive"; a tenancy-enabled admission queue re-stamps this
        # with the tenant lane's configured class at offer time.
        self.priority: str = (
            "interactive" if request.priority is None else request.priority
        )
        self._loop = loop
        # Observability: the request's root span and the tracer it lives
        # in — set by the loop at submit when tracing is enabled (both
        # stay None otherwise; every emission below is guarded).  The
        # lifecycle transitions are the single source of truth for the
        # terminal instants (resolve / shed / cancel) the conservation
        # check counts, and for the requeue back-edge mark.
        self.span = None
        self._tracer = None
        # The queued-period child span (submit → tick claim); reopened by
        # a lost-batch requeue so the tree shows every wait separately.
        self._queued_span = None
        self._event = threading.Event()
        # Streaming channel: decode tokens pushed by the backend (via the
        # loop's per-batch on_token callback) before resolution.
        self._chunks: List[StreamChunk] = []
        # Guards the QUEUED -> SCHEDULED / QUEUED -> CANCELLED transition:
        # cancel() may race the loop's tick from another thread, and a
        # request whose cancel() returned True must never be dispatched.
        self._state_lock = threading.Lock()
        self._completion: Optional[CompletedRequest] = None
        self._cancel_requested = False
        # How many times a replica failure sent this request back to
        # QUEUED (lost-batch recovery); diagnostic, not a retry budget.
        self.requeues = 0

    # -- inspection -----------------------------------------------------------
    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def tenant(self) -> Optional[str]:
        return self.request.tenant

    def done(self) -> bool:
        """True once the request is RESOLVED or CANCELLED (never blocks)."""
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self.state is RequestState.CANCELLED

    def rejected(self) -> bool:
        return self.state is RequestState.REJECTED

    @property
    def time_to_schedule_ms(self) -> Optional[float]:
        if self.scheduled_ms is None:
            return None
        return self.scheduled_ms - self.submitted_ms

    # -- cancellation ---------------------------------------------------------
    def cancel(self) -> bool:
        """Request cancellation.

        Returns True when the request was still QUEUED — it is dropped
        immediately and will never occupy a batch slot on either tier.
        Later states return False: the batched execution cannot be
        recalled, but the result is discarded at resolution (the loser- and
        winner-tier measurements still fold into the EWMA profiles) and
        :meth:`result` raises :class:`RequestCancelled`.
        """
        with self._state_lock:
            if self.done():
                return False
            if self.state is RequestState.QUEUED:
                self._mark_cancelled()
                return True
            self._cancel_requested = True
            return False

    # -- result ---------------------------------------------------------------
    def result(self, timeout: Optional[float] = None) -> CompletedRequest:
        """Block until resolved.

        With ``timeout=None`` (blocking mode) the call *drives* the
        attached loop — a single-threaded caller never deadlocks.  With a
        ``timeout`` (wall-clock seconds) it only waits on the resolution
        event — ticks must be driven elsewhere — and raises
        :class:`TimeoutError` when the timeout elapses; driving the loop
        here could run unbounded batch work past the deadline.  Raises
        :class:`RequestCancelled` for a cancelled request.
        """
        if timeout is None and not self._event.is_set() and self._loop is not None:
            self._loop.flush()
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.rid} unresolved after {timeout}s "
                f"(state={self.state.value})"
            )
        if self.state is RequestState.CANCELLED:
            raise RequestCancelled(f"request {self.request.rid} was cancelled")
        if self.state is RequestState.REJECTED:
            raise RequestRejected(
                f"request {self.request.rid} was rejected by admission "
                "(overload shed / unreachable SLA)"
            )
        assert self._completion is not None
        return self._completion

    # -- streaming ------------------------------------------------------------
    def _push_chunk(self, token: int, wall_ms: float) -> None:
        """Backend-side token emission (appended in decode order).

        Called from the dispatching thread (sync / stepped modes) while the
        future is still EXECUTING — list append is atomic under the GIL, so
        a concurrently iterating :meth:`stream` sees a consistent prefix.
        """
        self._chunks.append(
            StreamChunk(len(self._chunks), int(token), float(wall_ms))
        )
        if self._tracer is not None:
            self._tracer.instant(
                "stream.token",
                parent=self.span,
                cat="stream",
                t_ms=wall_ms,
                index=len(self._chunks) - 1,
            )

    @property
    def chunks(self) -> List[StreamChunk]:
        """Chunks streamed so far (decode order; grows until resolution)."""
        return list(self._chunks)

    def stream(self) -> Iterator[StreamChunk]:
        """Yield :class:`StreamChunk` tokens as the backend emits them.

        On a streaming-capable backend (the continuous-batching tier) every
        decode token is pushed *before* the future resolves — under stepped
        dispatch each ``poll()`` pump surfaces one more token, so a
        cooperative consumer observes genuinely incremental delivery; under
        sync dispatch the whole stream is pushed during the tick (still
        before ``_mark_resolved``) and yielded in order right after.

        Like ``result(timeout=None)``, the generator *drives* the attached
        loop when progress stalls (tick un-dispatched work, poll in-flight
        work), so a single-threaded consumer never deadlocks.  On backends
        with no token channel the stream degrades gracefully: it yields the
        completion's tokens as one burst stamped at consumption time.

        Note: the stream is the *remote* decode stream.  A hedged row whose
        duplicate wins the race may stream fewer tokens than ``n_steps``
        (its slot is released early); ``result()`` remains the
        authoritative answer.
        """
        i = 0
        while True:
            while i < len(self._chunks):
                chunk = self._chunks[i]
                i += 1
                yield chunk
            if self.done():
                break
            if self._loop is None:
                # Externally driven (a server thread owns the loop): just
                # wait for more chunks or resolution.
                self._event.wait(0.001)
                continue
            if self.state is RequestState.QUEUED:
                # Dispatch without collecting when the loop steps its
                # backend (chunks then flow incrementally via poll); the
                # whole-batch modes resolve us within the tick.
                stepped = self._loop.dispatch == "stepped"
                self._loop.tick(wait=not stepped)
                if self.state is RequestState.QUEUED and not self.done():
                    # Not taken this tick (inflight gate / backpressure).
                    self._loop.poll()
                    if (
                        self.state is RequestState.QUEUED
                        and not self._loop._inflight
                    ):
                        self._loop.flush()
            else:
                self._loop.poll()
        if i == 0 and self.state is RequestState.RESOLVED:
            # No token channel on the serving tier: degrade to one burst of
            # the completion's tokens, stamped now.
            now_ms = time.perf_counter() * 1e3
            for tok in np.asarray(self._completion.tokens).ravel():
                self._push_chunk(int(tok), now_ms)
            while i < len(self._chunks):
                chunk = self._chunks[i]
                i += 1
                yield chunk

    # -- loop-side transitions ------------------------------------------------
    def _try_schedule(self, now_ms: float) -> bool:
        """Atomically claim a QUEUED future for a tick; False if a racing
        cancel() (or a previous tick) got there first."""
        with self._state_lock:
            if self.state is not RequestState.QUEUED:
                return False
            self.state = RequestState.SCHEDULED
            self.scheduled_ms = now_ms
            if self._tracer is not None:
                self._end_queued()
                self._tracer.instant(
                    "scheduled", parent=self.span, cat="request",
                    now_ms=now_ms,
                )
            return True

    def _mark_executing(self, tier_dispatch_wall_ms: Dict[str, float]) -> None:
        self.state = RequestState.EXECUTING
        self.tier_dispatch_wall_ms.update(tier_dispatch_wall_ms)

    def _mark_resolved(self, completion: CompletedRequest) -> None:
        # Under the lock: a cancel() that returned False *after* observing
        # EXECUTING must still win (result discarded), never be overtaken
        # by a concurrent resolution.
        with self._state_lock:
            if self._cancel_requested:
                self._mark_cancelled()
                return
            self.state = RequestState.RESOLVED
            self._completion = completion
            self.resolved_ms = self.request.arrival_ms + completion.latency_ms
            if self._tracer is not None:
                self._tracer.instant(
                    "resolve",
                    parent=self.span,
                    cat="request",
                    race_resolution=completion.race_resolution,
                    latency_ms=completion.latency_ms,
                    model=completion.model_name,
                )
                self._tracer.end(self.span)
            self._event.set()

    def _end_queued(self) -> None:
        """Close the queued-period span (idempotent; no-op untraced)."""
        if self._tracer is not None and self._queued_span is not None:
            self._tracer.end(self._queued_span)

    def _mark_cancelled(self) -> None:
        self.state = RequestState.CANCELLED
        if self._tracer is not None:
            self._end_queued()
            self._tracer.instant("cancel", parent=self.span, cat="request")
            self._tracer.end(self.span)
        self._event.set()

    def _requeue(self) -> bool:
        """Send a SCHEDULED/EXECUTING request back to QUEUED — its batch
        was lost to a replica failure and it holds no result.

        A ``cancel()`` that raced the lost execution wins here (the
        request will never produce a result to discard, so it cancels
        now).  Returns True iff the request is QUEUED again and should
        re-enter the admission queue.
        """
        with self._state_lock:
            if self.done():
                return False
            if self._cancel_requested:
                self._mark_cancelled()
                return False
            if self.state not in (
                RequestState.SCHEDULED, RequestState.EXECUTING
            ):
                return False
            self.state = RequestState.QUEUED
            self.scheduled_ms = None
            self.requeues += 1
            if self._tracer is not None:
                self._tracer.instant(
                    "requeue", parent=self.span, cat="request",
                    requeues=self.requeues,
                )
                self._queued_span = self._tracer.start(
                    "queued",
                    parent=self.span,
                    cat="request",
                    track=self.span.track if self.span is not None else None,
                    requeue=self.requeues,
                )
            return True

    def _mark_rejected(self) -> bool:
        """Admission-side terminal transition (overload shed).

        Only a QUEUED request can be rejected — it never reached a batch,
        so there is no execution to discard.  A racing ``cancel()`` keeps
        its meaning: whoever takes ``_state_lock`` first wins the terminal
        state.  Returns True iff this call performed the transition (the
        admission queue's rejection counters track only real rejections).
        """
        with self._state_lock:
            if self.state is not RequestState.QUEUED:
                return False
            self.state = RequestState.REJECTED
            if self._tracer is not None:
                self._end_queued()
                self._tracer.instant("shed", parent=self.span, cat="request")
                self._tracer.end(self.span)
            self._event.set()
            return True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"InferenceFuture(rid={self.request.rid}, state={self.state.value})"
        )
