"""Block-paged per-request slot cache — the continuous tier's host-side state.

The continuous-batching backend keeps one persistent decode batch of
``n_slots`` rows.  Each slot's KV state lives in *pages* of a shared
physical pool; this module owns the host-side bookkeeping:

* the free-page pool and the per-slot page tables (page 0 is reserved as
  the trash page inactive rows write into — it is never allocated);
* the slot lifecycle ``FREE → PREFILLING → DECODING → RECYCLED``;
* conservation accounting: every slot freed is attributed to exactly one
  release reason (``resolved`` / ``hedge_win`` / ``cancel``), so
  ``freed_total == sum(freed_by_reason.values())`` and, at quiescence,
  every page is back in the free pool.  ``tests/test_continuous.py`` pins
  both invariants.

Pages are reserved *exactly* at graft time — ``ceil((prompt_len + n_steps)
/ page_size)`` pages per request — so a slot released early (a hedge win,
a cancel) returns its pages immediately and the next join reuses them; the
device-side pool never needs to grow or compact.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List

import numpy as np

__all__ = ["SlotState", "Slot", "BlockPagedSlotCache", "NoFreeSlot"]


class NoFreeSlot(Exception):
    """Raised when a join is requested and every slot is occupied."""


class SlotState(enum.Enum):
    FREE = "free"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    RECYCLED = "recycled"  # released; pages returned, awaiting next graft


@dataclasses.dataclass
class Slot:
    index: int
    state: SlotState = SlotState.FREE
    pages: List[int] = dataclasses.field(default_factory=list)
    prompt_len: int = 0
    n_steps: int = 0


class BlockPagedSlotCache:
    """Host-side page-pool + slot-table manager for the continuous batch.

    Device arrays (the KV page pools themselves) are owned by the backend;
    this class only decides *which* pages each slot uses and exposes the
    ``(n_slots, pages_per_slot)`` int32 page-table array the fixed-shape
    decode executable consumes.  Unreserved table entries point at the
    trash page (0), which the attention mask guarantees is never read.
    """

    TRASH_PAGE = 0

    def __init__(self, n_slots: int, n_pages: int, page_size: int,
                 pages_per_slot: int):
        if n_pages < 2:
            raise ValueError("need at least the trash page plus one real page")
        self.n_slots = n_slots
        self.n_pages = n_pages
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        # Page 0 is the trash page: reserved forever, never in the free pool.
        self._free_pages: List[int] = list(range(n_pages - 1, 0, -1))
        self.slots = [Slot(i) for i in range(n_slots)]
        # Conservation counters (the regression-pinned invariant).
        self.grafted_total = 0
        self.freed_total = 0
        self.freed_by_reason: Dict[str, int] = {
            "resolved": 0, "hedge_win": 0, "cancel": 0,
        }
        # Optional metrics hookup (set by the continuous backend); None
        # keeps the ledger metric-free.
        self._obs = None
        self._obs_labels: Dict[str, str] = {}

    def attach_observability(self, obs, **labels) -> None:
        """Mirror the conservation ledger into counters/gauges."""
        self._obs = obs
        self._obs_labels = labels

    def _note_capacity(self) -> None:
        self._obs.gauge(
            "slot_cache_free_pages", **self._obs_labels
        ).set(self.n_free_pages)
        self._obs.gauge(
            "slot_cache_free_slots", **self._obs_labels
        ).set(len(self.free_slots))

    # -- queries --------------------------------------------------------------
    @property
    def free_slots(self) -> List[int]:
        return [
            s.index
            for s in self.slots
            if s.state in (SlotState.FREE, SlotState.RECYCLED)
        ]

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def active_slots(self) -> List[int]:
        return [s.index for s in self.slots if s.state is SlotState.DECODING]

    def pages_needed(self, prompt_len: int, n_steps: int) -> int:
        return -(-(prompt_len + n_steps) // self.page_size)

    def can_join(self, prompt_len: int, n_steps: int) -> bool:
        return (
            bool(self.free_slots)
            and self.pages_needed(prompt_len, n_steps) <= self.n_free_pages
        )

    # -- lifecycle ------------------------------------------------------------
    def begin_prefill(self, prompt_len: int, n_steps: int) -> Slot:
        """FREE/RECYCLED → PREFILLING: claim a slot and reserve its pages.

        The reservation is exact — ``ceil((prompt_len + n_steps) /
        page_size)`` pages — so the pool can admit as many concurrent
        requests as genuinely fit, not a worst-case bound.
        """
        need = self.pages_needed(prompt_len, n_steps)
        if need > self.pages_per_slot:
            raise ValueError(
                f"request needs {need} pages > pages_per_slot "
                f"({self.pages_per_slot}); raise ServingGeometry.max_steps "
                "or prompt_width"
            )
        free = self.free_slots
        if not free:
            raise NoFreeSlot("all decode slots occupied")
        if need > self.n_free_pages:
            raise NoFreeSlot(
                f"page pool exhausted ({need} needed, {self.n_free_pages} free)"
            )
        slot = self.slots[free[0]]
        slot.state = SlotState.PREFILLING
        slot.pages = [self._free_pages.pop() for _ in range(need)]
        slot.prompt_len = prompt_len
        slot.n_steps = n_steps
        return slot

    def commit_graft(self, slot_index: int) -> None:
        """PREFILLING → DECODING: the KV state landed in the slot's pages."""
        slot = self.slots[slot_index]
        if slot.state is not SlotState.PREFILLING:
            raise ValueError(f"slot {slot_index} not prefilling: {slot.state}")
        slot.state = SlotState.DECODING
        self.grafted_total += 1
        if self._obs is not None:
            self._obs.counter(
                "slot_cache_grafted_total", **self._obs_labels
            ).inc()
            self._note_capacity()

    def release(self, slot_index: int, reason: str) -> None:
        """PREFILLING/DECODING → RECYCLED: return the slot's pages.

        ``reason`` must be one of ``resolved`` / ``hedge_win`` / ``cancel``
        — the conservation ledger every release is attributed to.
        """
        if reason not in self.freed_by_reason:
            raise ValueError(
                f"unknown release reason {reason!r}; "
                f"expected one of {sorted(self.freed_by_reason)}"
            )
        slot = self.slots[slot_index]
        if slot.state not in (SlotState.PREFILLING, SlotState.DECODING):
            raise ValueError(
                f"slot {slot_index} not releasable from {slot.state}"
            )
        self._free_pages.extend(reversed(slot.pages))
        slot.pages = []
        slot.prompt_len = 0
        slot.n_steps = 0
        slot.state = SlotState.RECYCLED
        self.freed_total += 1
        self.freed_by_reason[reason] += 1
        if self._obs is not None:
            self._obs.counter(
                "slot_cache_freed_total", reason=reason, **self._obs_labels
            ).inc()
            self._note_capacity()

    # -- device-facing views ---------------------------------------------------
    def page_table(self, slot_index: int) -> np.ndarray:
        """(pages_per_slot,) int32 table, trash-padded past the reservation."""
        table = np.full(self.pages_per_slot, self.TRASH_PAGE, dtype=np.int32)
        pages = self.slots[slot_index].pages
        table[: len(pages)] = pages
        return table

    def page_tables(self) -> np.ndarray:
        """(n_slots, pages_per_slot) int32 — the decode executable's view."""
        return np.stack([self.page_table(i) for i in range(self.n_slots)])

    # -- invariants ------------------------------------------------------------
    def check_conservation(self) -> None:
        """Assert the ledger balances (used by tests and debug paths)."""
        by_reason = sum(self.freed_by_reason.values())
        if self.freed_total != by_reason:
            raise AssertionError(
                f"freed_total={self.freed_total} != sum(reasons)={by_reason}"
            )
        reserved = sum(len(s.pages) for s in self.slots)
        if reserved + self.n_free_pages != self.n_pages - 1:
            raise AssertionError(
                f"page leak: {reserved} reserved + {self.n_free_pages} free "
                f"!= {self.n_pages - 1} allocatable"
            )

    def stats(self) -> Dict[str, int]:
        return {
            "grafted": self.grafted_total,
            "freed": self.freed_total,
            **{f"freed_{k}": v for k, v in self.freed_by_reason.items()},
            "free_pages": self.n_free_pages,
            "free_slots": len(self.free_slots),
        }
