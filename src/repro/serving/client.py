"""Client layer of the serving stack: submit prompts, get futures back.

:class:`InferenceClient` is the application-facing surface over a
:class:`repro.serving.loop.ServingLoop`.  ``submit`` admits one request
(assigning it a request id and an arrival timestamp on the loop clock) and
returns an :class:`repro.serving.lifecycle.InferenceFuture` immediately;
the caller observes the request's state, cancels it, or blocks on
``result()`` — which drives the loop when the caller is single-threaded,
so the minimal usage is just::

    client = InferenceClient(loop)
    future = client.submit(prompt_tokens, n_steps=8)
    completed = future.result()        # ticks the loop until resolved

Batch-oriented callers keep submitting and fire ``loop.tick(now_ms)``
themselves (one tick per arrival window — what
:meth:`repro.serving.loop.ServingLoop.drain_trace` automates).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.serving.lifecycle import InferenceFuture, QueuedRequest
from repro.serving.loop import ServingLoop

__all__ = ["InferenceClient"]


class InferenceClient:
    """Submit prompts to a serving loop; observe them as futures."""

    def __init__(self, loop: ServingLoop):
        self.loop = loop

    def submit(
        self,
        prompt: np.ndarray,
        n_steps: int,
        sla: Optional[float] = None,
        *,
        t_nw_est_ms: float = 0.0,
        t_nw_actual_ms: Optional[float] = None,
        arrival_ms: Optional[float] = None,
    ) -> InferenceFuture:
        """Admit one inference request.

        Args:
          prompt: (S,) prompt tokens.
          n_steps: tokens to generate.
          sla: per-request SLA in ms (None: the scheduler's global SLA).
            Budgeting *and* hedged resolution race against this value.
          t_nw_est_ms: server-side estimate of the request's network time
            (what selection budgets against).
          t_nw_actual_ms: the realized network time (defaults to the
            estimate — a perfect estimator).
          arrival_ms: loop-clock arrival (defaults to the loop's ``now``).
        """
        request = QueuedRequest(
            rid=self.loop.next_rid(),
            tokens=np.asarray(prompt, dtype=np.int32),
            n_steps=int(n_steps),
            t_nw_est_ms=float(t_nw_est_ms),
            t_nw_actual_ms=float(
                t_nw_est_ms if t_nw_actual_ms is None else t_nw_actual_ms
            ),
            arrival_ms=float(
                self.loop.now_ms if arrival_ms is None else arrival_ms
            ),
            sla_ms=None if sla is None else float(sla),
        )
        return self.loop.submit(request)
