"""Client layer of the serving stack: submit prompts, get futures back.

:class:`InferenceClient` is the application-facing surface over a
:class:`repro.serving.loop.ServingLoop`.  ``submit`` admits one request
(assigning it a request id and an arrival timestamp on the loop clock) and
returns an :class:`repro.serving.lifecycle.InferenceFuture` immediately;
the caller observes the request's state, cancels it, or blocks on
``result()`` — which drives the loop when the caller is single-threaded,
so the minimal usage is just::

    client = InferenceClient(loop)
    future = client.submit(prompt_tokens, n_steps=8)
    completed = future.result()        # ticks the loop until resolved

Batch-oriented callers keep submitting and fire ``loop.tick(now_ms)``
themselves (one tick per arrival window — what
:meth:`repro.serving.loop.ServingLoop.drain_trace` automates).

When the loop runs a *bounded* admission queue
(:class:`repro.serving.admission.AdmissionConfig`), ``submit`` is
backpressure-aware: under the ``block`` overload policy the returned
future may be *not yet admitted* (``future.admitted`` is False — it waits
in the overflow room until capacity frees), under ``shed`` it may come
back already REJECTED (``future.rejected()``; ``result()`` raises
:class:`repro.serving.lifecycle.RequestRejected`), and under ``degrade``
it will be answered by the on-device tier alone.  ``wait_admission=True``
turns the block policy into classic blocking backpressure: ``submit``
drives the loop until the request actually holds a queue slot.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.serving.lifecycle import InferenceFuture, QueuedRequest
from repro.serving.loop import ServingLoop

__all__ = ["InferenceClient"]


class InferenceClient:
    """Submit prompts to a serving loop; observe them as futures."""

    def __init__(self, loop: ServingLoop):
        self.loop = loop

    def submit(
        self,
        prompt: np.ndarray,
        n_steps: int,
        sla: Optional[float] = None,
        *,
        t_nw_est_ms: float = 0.0,
        t_nw_actual_ms: Optional[float] = None,
        arrival_ms: Optional[float] = None,
        wait_admission: bool = False,
        tenant: Optional[str] = None,
        priority: Optional[str] = None,
    ) -> InferenceFuture:
        """Submit one inference request to the loop's admission queue.

        Args:
          prompt: (S,) prompt tokens.
          n_steps: tokens to generate.
          sla: per-request SLA in ms (None: the scheduler's global SLA).
            Budgeting, hedged resolution, *and* deadline shedding race
            against this value.
          t_nw_est_ms: server-side estimate of the request's network time
            (what selection budgets against).
          t_nw_actual_ms: the realized network time (defaults to the
            estimate — a perfect estimator).
          arrival_ms: loop-clock arrival (defaults to the loop's ``now``).
          wait_admission: with a bounded queue and the ``block`` policy, a
            full queue parks the future un-admitted (``future.admitted``
            False) — the client-side backpressure signal.  ``True`` makes
            ``submit`` block instead: it drives the loop until the future
            holds a real queue slot (or reached a terminal state).  A
            single-threaded caller never deadlocks — each tick frees
            capacity that re-admits the overflow FIFO.
          tenant: tenancy lane name (None: the implicit "default" lane).
            With a tenancy-enabled admission queue the tag selects the
            request's weighted-fair lane and per-tenant capacity bound.
          priority: "interactive" | "batch" — overrides the tenant lane's
            configured priority class for this request (None: the lane's).
        """
        request = QueuedRequest(
            rid=self.loop.next_rid(),
            tokens=np.asarray(prompt, dtype=np.int32),
            n_steps=int(n_steps),
            t_nw_est_ms=float(t_nw_est_ms),
            t_nw_actual_ms=float(
                t_nw_est_ms if t_nw_actual_ms is None else t_nw_actual_ms
            ),
            arrival_ms=float(
                self.loop.now_ms if arrival_ms is None else arrival_ms
            ),
            sla_ms=None if sla is None else float(sla),
            tenant=tenant,
            priority=priority,
        )
        future = self.loop.submit(request)
        if wait_admission:
            while not (future.admitted or future.done()):
                if self.loop.tick() is None and not (
                    future.admitted or future.done()
                ):
                    # No forward progress possible without external events
                    # (e.g. in-flight ticks that must be polled elsewhere);
                    # hand the un-admitted future back to the caller.
                    break
        return future
