"""Multi-tenant QoS: per-tenant lanes drained deficit-weighted-fair.

The admission stage (:class:`repro.serving.admission.AdmissionQueue`) is a
single FIFO by default: one flooding tenant inflates every tenant's queue
wait, so the flood destroys the *interactive* tenants' p99 — exactly the
failure mode MDInference's SLA framing warns about for mixed traffic.
This module adds the isolation layer:

* :class:`TenantConfig` — one tenant's QoS contract: scheduling ``weight``,
  priority class (``"interactive"`` | ``"batch"``), an optional per-tenant
  ``max_pending`` bound (its private capacity slice), and ``burst_credit``
  (how many unused scheduling quanta an idle lane may bank).
* :class:`TenantLanes` — per-tenant FIFO lanes plus the drain policy:
  **strict priority** between classes (every queued interactive request is
  eligible before any batch request — batch traffic only soaks budget the
  interactive class left over) and **deficit round-robin** within a class
  (each non-empty lane earns ``weight`` quanta per round and spends whole
  requests against its accumulated deficit, giving long-run weighted-fair
  shares without starving low-weight lanes).

Requests carrying no tenant tag (``QueuedRequest.tenant is None``) — and
tags no configured lane matches — ride an implicit ``"default"`` lane
(weight 1.0, interactive), so a tenancy-enabled queue still serves
untagged traffic.

The deficit counter is the classic DRR formulation: a lane's deficit grows
by its weight each round it is non-empty, shrinks by one per request it
dequeues, and — when the lane empties — collapses to at most
``burst_credit`` (an idle lane cannot bank unbounded priority, only its
configured burst allowance).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serving.lifecycle import InferenceFuture, RequestState

__all__ = [
    "PRIORITY_CLASSES",
    "DEFAULT_TENANT",
    "TenantConfig",
    "TenantLanes",
    "parse_tenant_spec",
]

PRIORITY_CLASSES = ("interactive", "batch")

# Lane for untagged requests (QueuedRequest.tenant None) and unknown tags.
DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's QoS contract in the admission stage."""

    name: str
    weight: float = 1.0  # DRR quanta earned per round (within its class)
    priority: str = "interactive"  # strict class: interactive preempts batch
    max_pending: Optional[int] = None  # per-tenant queue bound (None: global)
    burst_credit: float = 0.0  # quanta an idle lane may bank for its next burst

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.priority not in PRIORITY_CLASSES:
            raise ValueError(
                f"priority must be one of {PRIORITY_CLASSES}, "
                f"got {self.priority!r}"
            )
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError(
                f"max_pending must be >= 1 or None, got {self.max_pending}"
            )
        if self.burst_credit < 0:
            raise ValueError(
                f"burst_credit must be >= 0, got {self.burst_credit}"
            )


class _Lane:
    """One tenant's FIFO queue plus its DRR deficit counter."""

    __slots__ = ("cfg", "q", "deficit")

    def __init__(self, cfg: TenantConfig):
        self.cfg = cfg
        self.q: Deque[InferenceFuture] = deque()
        self.deficit = 0.0

    @property
    def n_queued(self) -> int:
        return sum(1 for f in self.q if f.state is RequestState.QUEUED)


class TenantLanes:
    """Per-tenant lanes + the strict-priority deficit-weighted-fair drain.

    Not thread-safe on its own — the owning
    :class:`~repro.serving.admission.AdmissionQueue` serializes access
    under its lock, exactly as it does for its FIFO deques.
    """

    def __init__(self, tenants: Sequence[TenantConfig]):
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        self._lanes: Dict[str, _Lane] = {t.name: _Lane(t) for t in tenants}
        if DEFAULT_TENANT not in self._lanes:
            # Implicit lane for untagged / unknown-tag requests.
            self._lanes[DEFAULT_TENANT] = _Lane(TenantConfig(DEFAULT_TENANT))

    # -- routing ---------------------------------------------------------------
    def lane_of(self, future: InferenceFuture) -> _Lane:
        tag = future.request.tenant
        return self._lanes.get(
            DEFAULT_TENANT if tag is None else tag, self._lanes[DEFAULT_TENANT]
        )

    def name_of(self, future: InferenceFuture) -> str:
        return self.lane_of(future).cfg.name

    def resolve(self, future: InferenceFuture) -> _Lane:
        """Route a future to its lane and stamp its effective priority
        (an explicit per-request ``priority`` wins over the lane's)."""
        lane = self.lane_of(future)
        req_priority = future.request.priority
        future.priority = (
            lane.cfg.priority if req_priority is None else req_priority
        )
        return lane

    def config(self, name: str) -> TenantConfig:
        return self._lanes[name].cfg

    @property
    def names(self) -> List[str]:
        return list(self._lanes)

    # -- bookkeeping -----------------------------------------------------------
    def n_queued(self, name: Optional[str] = None) -> int:
        if name is not None:
            return self._lanes[name].n_queued
        return sum(lane.n_queued for lane in self._lanes.values())

    def depths(self) -> Dict[str, int]:
        """Per-lane queued depth (lane name -> count), for gauge export."""
        return {name: lane.n_queued for name, lane in self._lanes.items()}

    def all_queued(self) -> List[InferenceFuture]:
        return [f for lane in self._lanes.values() for f in lane.q]

    def append(self, lane: _Lane, future: InferenceFuture) -> None:
        lane.q.append(future)

    def append_front(self, future: InferenceFuture) -> None:
        """Requeue a lost-batch row at the *front* of its tenant's lane —
        the lane-local analogue of the FIFO's head re-insert."""
        self.lane_of(future).q.appendleft(future)

    def prune(self) -> None:
        """Drop futures that left QUEUED state (cancelled) from every lane."""
        for lane in self._lanes.values():
            if any(f.state is not RequestState.QUEUED for f in lane.q):
                kept = [f for f in lane.q if f.state is RequestState.QUEUED]
                lane.q.clear()
                lane.q.extend(kept)

    def discard(self, futures: List[InferenceFuture]) -> None:
        """Remove specific futures (the shed set) from their lanes."""
        doomed = {id(f) for f in futures}
        if not doomed:
            return
        for lane in self._lanes.values():
            if any(id(f) in doomed for f in lane.q):
                kept = [f for f in lane.q if id(f) not in doomed]
                lane.q.clear()
                lane.q.extend(kept)

    # -- the drain -------------------------------------------------------------
    def select(
        self, budget: Optional[int] = None, commit: bool = True
    ) -> List[InferenceFuture]:
        """Pick up to ``budget`` requests (None: everything queued).

        Strict priority between classes — the interactive lanes drain
        first, batch lanes spend only the leftover budget — and deficit
        round-robin by ``weight`` within a class.  ``commit=False`` is a
        pure peek: lane queues and deficits are left untouched (the shed
        clock uses it to ask "what *would* this take pick?").
        """
        total = sum(len(lane.q) for lane in self._lanes.values())
        cap = total if budget is None else min(int(budget), total)
        # name -> [queue, deficit]; commit mode mutates the live queues.
        state: Dict[str, list] = {
            name: [lane.q if commit else deque(lane.q), lane.deficit]
            for name, lane in self._lanes.items()
        }
        out: List[InferenceFuture] = []
        for cls in PRIORITY_CLASSES:
            if len(out) >= cap:
                break
            members = [
                name
                for name, lane in self._lanes.items()
                if lane.cfg.priority == cls
            ]
            out.extend(self._drr(members, state, cap - len(out)))
        if commit:
            for name, (_, deficit) in state.items():
                self._lanes[name].deficit = deficit
        return out

    def _drr(
        self, names: List[str], state: Dict[str, list], budget: int
    ) -> List[InferenceFuture]:
        out: List[InferenceFuture] = []
        active = deque(name for name in names if state[name][0])
        while active and len(out) < budget:
            name = active.popleft()
            cfg = self._lanes[name].cfg
            entry = state[name]
            entry[1] += cfg.weight  # this round's quantum
            take = min(int(entry[1]), budget - len(out), len(entry[0]))
            for _ in range(take):
                out.append(entry[0].popleft())
            entry[1] -= take
            if entry[0]:
                active.append(name)
            else:
                # An emptied lane banks at most its burst allowance.
                entry[1] = min(entry[1], cfg.burst_credit)
        return out


def parse_tenant_spec(spec: str) -> Tuple[TenantConfig, ...]:
    """Parse a CLI tenant spec: ``name[:weight[:class[:max_pending]]],...``

    Example: ``"ui:4:interactive,crawl:1:batch:32"``.
    """
    tenants = []
    for item in spec.split(","):
        parts = item.strip().split(":")
        if not parts[0]:
            raise ValueError(f"empty tenant name in spec {spec!r}")
        kw: dict = {"name": parts[0]}
        if len(parts) > 1 and parts[1]:
            kw["weight"] = float(parts[1])
        if len(parts) > 2 and parts[2]:
            kw["priority"] = parts[2]
        if len(parts) > 3 and parts[3]:
            kw["max_pending"] = int(parts[3])
        if len(parts) > 4:
            raise ValueError(f"too many fields in tenant spec item {item!r}")
        tenants.append(TenantConfig(**kw))
    return tuple(tenants)
