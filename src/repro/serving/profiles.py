"""Latency/quality profiles for the LM zoo — the TPU analogue of Table III.

``A(m)`` becomes a quality proxy (published-benchmark-flavored scores for
the text-generation tier of each arch; these parameterize the selection
trade-off exactly the way top-1 accuracy does in the paper).  ``mu(m)`` is a
roofline latency estimate on v5e: per request = prefill(P tokens) + T *
decode_step, each term ``max(compute, memory, collective)`` over the three
roofline components.  When a dry-run roofline JSON is available
(launch/dryrun.py writes one), profiles are refined from the *compiled*
numbers instead of the analytic ones.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

from repro.configs.archs import get_config
from repro.core.registry import ModelProfile, ModelRegistry

__all__ = ["V5E", "estimate_ms", "lm_zoo_registry", "ONDEVICE_TIER"]

V5E = {
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
}

# Quality proxies for the text-generation task tier (open-benchmark flavored;
# a stand-in for the paper's measured top-1 accuracy — see DESIGN.md).
QUALITY = {
    "llama4-scout-17b-a16e": 79.0,
    "qwen3-14b": 77.0,
    "phi3-mini-3.8b": 69.0,
    "llama3-8b": 68.0,
    "olmoe-1b-7b": 54.0,
    "gemma-2b": 42.0,
    "recurrentgemma-2b": 42.0,
    "xlstm-350m": 28.0,
}


def estimate_ms(flops, bytes_, coll_bytes=0.0, chips=8):
    """Roofline step-time estimate (ms): max of the three terms."""
    t_c = flops / (chips * V5E["peak_flops"])
    t_m = bytes_ / (chips * V5E["hbm_bw"])
    t_x = coll_bytes / (chips * V5E["ici_bw"])
    return 1e3 * max(t_c, t_m, t_x)


def _arch_latency_ms(arch: str, *, prompt=512, gen_tokens=64, chips=8):
    cfg = get_config(arch)
    n_active = cfg.param_count(active_only=True)
    n_total = cfg.param_count()
    # Prefill: compute-bound, 2*N_active FLOPs/token; weights read once.
    pre = estimate_ms(2 * n_active * prompt, 2 * n_total, chips=chips)
    # Decode: memory-bound — streams weights + KV/state per token.
    kv_bytes = 0
    for kind in cfg.layer_kinds():
        if kind in ("attn", "moe"):
            kv_bytes += 2 * prompt * cfg.n_kv_heads * cfg.head_dim * 2
        elif kind == "local":
            kv_bytes += 2 * min(cfg.window, prompt) * cfg.n_kv_heads * cfg.head_dim * 2
        elif kind in ("mlstm", "slstm", "recurrent"):
            kv_bytes += 4 * cfg.d_model * 4  # O(1) state, fp32
    dec = estimate_ms(2 * n_active, 2 * n_total + kv_bytes, chips=chips)
    return pre + gen_tokens * dec


def lm_zoo_registry(
    *,
    prompt: int = 512,
    gen_tokens: int = 64,
    chips: int = 8,
    sigma_frac: float = 0.04,
    roofline_json: Optional[str] = None,
) -> ModelRegistry:
    """The serving-tier zoo: every text-gen arch as a ModelProfile.

    ``sigma_frac`` models serving jitter (batching/queueing) as a fraction
    of mu — TPU step times are extremely stable, like Table III's sub-ms
    sigmas.  ``roofline_json``: optional dryrun output to refine mu.
    """
    refine = {}
    if roofline_json and Path(roofline_json).exists():
        data = json.loads(Path(roofline_json).read_text())
        for row in data.get("cells", []):
            if row.get("shape") == "decode_32k" and row.get("mesh") == "single_pod":
                # compiled per-step seconds -> per-token ms at this batch
                terms = row["terms_s"]
                refine[row["arch"]] = 1e3 * max(terms.values()) / row.get(
                    "global_batch", 1
                )

    profiles = []
    for arch, quality in QUALITY.items():
        mu = _arch_latency_ms(arch, prompt=prompt, gen_tokens=gen_tokens, chips=chips)
        if arch in refine:
            mu = refine[arch] * gen_tokens + mu * 0.1  # compiled decode + est prefill
        profiles.append(
            ModelProfile(name=arch, accuracy=quality, mu_ms=mu, sigma_ms=sigma_frac * mu)
        )
    return ModelRegistry(sorted(profiles, key=lambda p: p.accuracy))


# The hedged duplicate tier: the smallest, always-fast variant, replicated
# on every serving slice (the datacenter analogue of the on-device model).
ONDEVICE_TIER = ModelProfile(
    name="xlstm-350m (hedge tier)",
    accuracy=QUALITY["xlstm-350m"],
    mu_ms=_arch_latency_ms("xlstm-350m", prompt=512, gen_tokens=64, chips=1),
    sigma_ms=0.5,
)
