"""Replicated execution cluster: sharded zoo slices + load-aware routing.

A single :class:`repro.serving.backend.JitBackend` replica saturates
exactly when the admission queue starts shedding — the aggregate-accuracy
wins only hold if the chosen cloud model is actually served within budget
under load.  This module multiplies the backend seam horizontally:

* :class:`Replica` — one routable backend plus a live view of its load
  accounting (``inflight_rows``, cumulative ``dispatched_rows``, wall-time
  EWMA — maintained by :meth:`ExecutionBackend.submit_batch` itself) and
  its health (:class:`repro.serving.health.ReplicaHealth`: circuit
  breaker + drain flag — membership is *dynamic*).
* :class:`ReplicaPool` — N replicas + zoo placement across their slices
  (the cluster's state half: registration, hosted masks, snapshots).
* :class:`Router` — pluggable routing policy over the *eligible* replica
  set (:data:`ROUTERS`): ``round_robin`` (stateless cycling),
  ``least_inflight`` (join-shortest-queue over per-replica inflight rows,
  cumulative-work tie-break so serialized dispatch still balances), and
  ``power_of_two`` (two random replicas, pick by live wall-latency EWMA).
* :class:`ClusterBackend` — fronts a pool of N replicas behind the
  existing ``submit_batch -> BatchHandle`` protocol, so the serving loop
  and admission stages need no semantic changes.  Each replica may host a
  *slice* of the model zoo (:func:`shard_slices`); ``register`` places a
  variant on every admitting replica and routing never sends a row to a
  replica that doesn't host its variant.

Placement-aware selection: :meth:`ClusterBackend.hosted_mask` tells the
scheduler which variants have at least one live *routable* replica —
``MDInferenceScheduler.decide_batch(..., eligible=...)`` masks the rest
out, so a partial slice set (or a partially-failed pool) constrains
selection instead of crashing dispatch.  The mask is recomputed against
the loop clock every tick (:meth:`ClusterBackend.advance_clock`), so a
replica whose breaker opens leaves eligibility the *same tick*, and one
whose cooldown elapses re-enters it.

Fault handling: :meth:`ClusterBackend.submit_batch` converts a
:class:`repro.serving.transport.TransportError` raised at dispatch into a
:class:`repro.serving.transport.FailedBatchHandle` (the loop requeues or
hedge-fails-over those rows — a tick never crashes on a dead replica),
and the loop reports batch outcomes back through :meth:`note_success` /
:meth:`note_failure` to drive each replica's breaker.  When every hosting
replica is unroutable, :meth:`route` raises the typed
:class:`NoHealthyReplica` (never a bare ``ZeroDivisionError`` /
``IndexError`` from a router over an empty set).

The hedge tier is deliberately *not* poolable: the paper's on-device
duplicate is a device-side singleton, so an
:class:`~repro.serving.backend.OnDeviceBackend` is rejected as a replica.

A one-replica pool under ``round_robin`` is behaviorally identical to the
plain single-backend loop (regression-pinned in ``tests/test_cluster.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.backend import (
    BatchHandle,
    ExecutionBackend,
    OnDeviceBackend,
    Variant,
)
from repro.serving.health import BreakerConfig, CircuitBreaker, ReplicaHealth
from repro.serving.transport import (
    FailedBatchHandle,
    ReplicaDied,
    TransportError,
)

__all__ = [
    "ROUTERS",
    "NoHealthyReplica",
    "Replica",
    "ReplicaSpec",
    "parse_replica_specs",
    "ReplicaPool",
    "Router",
    "RoundRobinRouter",
    "LeastInflightRouter",
    "PowerOfTwoRouter",
    "make_router",
    "shard_slices",
    "ClusterBackend",
]


class NoHealthyReplica(RuntimeError):
    """Every replica hosting the variant is unroutable (breaker open,
    draining, or dead).  The serving loop diverts the affected rows to the
    on-device degrade lane instead of crashing the tick."""


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Per-replica hardware shape for a *heterogeneous* pool.

    Real fleets (llm-farm-style phone farms, mixed accelerator
    generations) are not homogeneous; the spec tells routing how unequal
    a replica is:

    * ``weight`` — relative serving capacity.  Load-aware routers divide
      a replica's inflight/dispatched rows by its weight, so a weight-2
      replica is expected to carry 2x the rows of a weight-1 one before
      looking equally loaded.
    * ``max_concurrency`` — a soft inflight-row cap: a replica at or
      above it is skipped by routing while any eligible peer has
      capacity (it never becomes *unroutable* — when every peer is full
      the pick proceeds over the full eligible set, so saturation is
      back-pressure, not an outage).
    * ``service_scale`` — relative service-time multiplier (1.0 =
      nominal, 2.0 = half-speed silicon).  Routing does not consume it
      directly — the live ``ewma_wall_ms`` measures actual slowness —
      but service models (``drain_trace`` coupling, benches) charge
      ``rows * service_scale`` so a slow replica's makespan is honest.

    The default spec (weight 1, no cap, scale 1) on every replica is the
    homogeneous pool, byte-identical to the pre-spec cluster
    (regression-pinned in ``tests/test_cluster.py``).
    """

    weight: float = 1.0
    max_concurrency: Optional[int] = None
    service_scale: float = 1.0

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.max_concurrency is not None and self.max_concurrency < 1:
            raise ValueError(
                f"max_concurrency must be >= 1 or None, got "
                f"{self.max_concurrency}"
            )
        if self.service_scale <= 0:
            raise ValueError(
                f"service_scale must be > 0, got {self.service_scale}"
            )


def parse_replica_specs(text: str, n_replicas: int) -> List[ReplicaSpec]:
    """Parse a CLI fleet description into per-replica specs.

    ``text`` is comma-separated, one ``weight[:max_concurrency[:scale]]``
    entry per replica (empty fields keep the default), e.g.
    ``"2:8:0.5,1,1::2"`` — a weight-2 replica capped at 8 inflight rows
    at double speed, a nominal replica, and a half-speed replica.
    """
    entries = [e.strip() for e in text.split(",")]
    if len(entries) != n_replicas:
        raise ValueError(
            f"--replica-spec names {len(entries)} replicas but the pool "
            f"has {n_replicas}"
        )
    specs = []
    for entry in entries:
        parts = entry.split(":")
        if len(parts) > 3:
            raise ValueError(
                f"replica spec entry {entry!r} has more than "
                "weight:max_concurrency:service_scale"
            )
        parts += [""] * (3 - len(parts))
        specs.append(
            ReplicaSpec(
                weight=float(parts[0]) if parts[0] else 1.0,
                max_concurrency=int(parts[1]) if parts[1] else None,
                service_scale=float(parts[2]) if parts[2] else 1.0,
            )
        )
    return specs


class Replica:
    """One routable backend replica in a pool.

    ``slice_names`` is the subset of the zoo this replica *admits* at
    registration (``None``: everything — full replication).  What it
    actually *hosts* is its backend's variant registry — the source of
    truth routing consults.  ``health`` is the replica's routability
    state (circuit breaker + drain flag); a replica can *host* a variant
    yet be unroutable this tick.  ``spec`` is the replica's hardware
    shape (:class:`ReplicaSpec`) — the default is the homogeneous
    nominal replica.
    """

    def __init__(
        self,
        replica_id: int,
        backend: ExecutionBackend,
        slice_names: Optional[Sequence[str]] = None,
        breaker: Optional[BreakerConfig] = None,
        spec: Optional[ReplicaSpec] = None,
    ):
        self.replica_id = replica_id
        self.backend = backend
        self.slice_names = (
            None if slice_names is None else frozenset(slice_names)
        )
        self.health = ReplicaHealth(
            None if breaker is None else CircuitBreaker(breaker)
        )
        self.spec = spec if spec is not None else ReplicaSpec()

    def admits(self, name: str) -> bool:
        """Whether registration may place variant ``name`` here."""
        return self.slice_names is None or name in self.slice_names

    def hosts(self, name: str) -> bool:
        """Whether this replica can execute variant ``name`` right now."""
        return name in self.backend.variants

    def routable(self, now_ms: float) -> bool:
        """Whether routing may send a batch here at ``now_ms`` (breaker
        closed or probing, not draining)."""
        return self.health.routable(now_ms)

    # Live load/latency accounting (maintained by the backend itself).
    @property
    def inflight_rows(self) -> int:
        return self.backend.inflight_rows

    @property
    def dispatched_rows(self) -> int:
        return self.backend.dispatched_rows

    @property
    def ewma_wall_ms(self) -> Optional[float]:
        return self.backend.ewma_wall_ms

    # Heterogeneity (spec-derived; nominal defaults on every replica).
    @property
    def weight(self) -> float:
        return self.spec.weight

    @property
    def service_scale(self) -> float:
        return self.spec.service_scale

    @property
    def has_capacity(self) -> bool:
        """Below the spec's soft inflight cap (always True uncapped)."""
        cap = self.spec.max_concurrency
        return cap is None or self.inflight_rows < cap

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Replica({self.replica_id}, inflight={self.inflight_rows}, "
            f"hosts={sorted(self.backend.variants)})"
        )


class Router:
    """Routing policy: pick one replica from the eligible (hosting,
    routable) set.

    ``pick`` receives only replicas that host the batch's variant and are
    routable this tick, in ascending ``replica_id`` order.  The eligible
    set is dynamic — health transitions grow and shrink it between picks —
    and an empty set raises the typed :class:`NoHealthyReplica` (never a
    bare ``IndexError``/``ZeroDivisionError``).
    """

    name = "?"

    @staticmethod
    def _require_nonempty(eligible: Sequence[Replica]) -> None:
        if not eligible:
            raise NoHealthyReplica(
                "every replica in the eligible set is unroutable"
            )

    def pick(self, eligible: Sequence[Replica]) -> Replica:
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Cycle over the eligible set, keyed on replica *identity* (load-blind).

    The rotation remembers the last-picked ``replica_id`` and takes the
    next-higher id present in today's eligible set (wrapping to the
    lowest).  A global ``counter % len(eligible)`` would skew the moment
    the set changes size between picks — e.g. a 3-replica pool shrinking
    to 2 makes ``counter % 2`` repeatedly skip one survivor — whereas the
    identity key stays fair under any interleaving of joins and leaves.
    """

    name = "round_robin"

    def __init__(self, seed: int = 0):
        self._last: Optional[int] = None  # replica_id of the previous pick

    def pick(self, eligible: Sequence[Replica]) -> Replica:
        self._require_nonempty(eligible)
        if self._last is None:
            choice = eligible[0]
        else:
            after = [r for r in eligible if r.replica_id > self._last]
            choice = after[0] if after else eligible[0]
        self._last = choice.replica_id
        return choice


class LeastInflightRouter(Router):
    """Join-shortest-queue over per-replica inflight-row accounting.

    Load is *weight-normalized* (``inflight_rows / weight``): in a
    heterogeneous pool a weight-2 replica absorbs 2x the rows of a
    weight-1 peer before looking equally loaded, so unequal hardware gets
    its proportional share instead of a blind even split.  Ties break on
    weight-normalized cumulative dispatched rows (least total work
    first), so serialized ``sync`` dispatch — where batches complete
    inline and inflight is 0 at every pick — still spreads load instead
    of pinning everything to replica 0; then on ``replica_id`` for
    determinism.  With the default weight 1 everywhere the keys equal
    the raw row counts — the homogeneous pool routes byte-identically.
    """

    name = "least_inflight"

    def __init__(self, seed: int = 0):
        pass

    def pick(self, eligible: Sequence[Replica]) -> Replica:
        self._require_nonempty(eligible)
        return min(
            eligible,
            key=lambda r: (
                r.inflight_rows / r.weight,
                r.dispatched_rows / r.weight,
                r.replica_id,
            ),
        )


class PowerOfTwoRouter(Router):
    """Power-of-two-choices: sample two replicas, keep the faster one.

    The comparison key is the live per-replica wall-latency EWMA (an
    unprobed replica counts as 0 so cold replicas get explored), then
    inflight rows, then ``replica_id``.  Sampling is seeded — routing is
    reproducible for a fixed request stream.

    Every ``probe_every``-th two-candidate pick takes the *less*-favored
    candidate instead: a replica whose EWMA got stuck high early would
    otherwise lose every pairing and never execute again, leaving its
    estimate permanently stale (latency-keyed p2c's classic starvation
    mode).  The bounded probe refreshes it, so a healthy replica with an
    unlucky early measurement rejoins the rotation.

    Because the EWMA dominates the key, consecutive picks (e.g. the
    sub-batches of one tick's fan-out) concentrate on the
    fastest-measured replica until its EWMA catches up — deliberate for
    a skewed pool (avoid the slow replica), load-blind for a homogeneous
    one.  Prefer ``least_inflight`` when within-tick spread matters more
    than latency skew.
    """

    name = "power_of_two"

    def __init__(self, seed: int = 0, probe_every: int = 16):
        if probe_every < 2:
            raise ValueError(f"probe_every must be >= 2, got {probe_every}")
        self.rng = np.random.default_rng(seed)
        self.probe_every = probe_every
        self._picks = 0

    @staticmethod
    def _key(r: Replica):
        # The EWMA already *measures* heterogeneity (a half-speed replica
        # reports 2x walls); the inflight tie-break is weight-normalized
        # so equal-EWMA candidates split proportionally to capacity.
        ewma = r.ewma_wall_ms
        return (
            0.0 if ewma is None else ewma,
            r.inflight_rows / r.weight,
            r.replica_id,
        )

    def pick(self, eligible: Sequence[Replica]) -> Replica:
        self._require_nonempty(eligible)
        if len(eligible) == 1:
            return eligible[0]
        i, j = self.rng.choice(len(eligible), size=2, replace=False)
        a, b = eligible[int(i)], eligible[int(j)]
        if self._key(a) > self._key(b):
            a, b = b, a  # a: favored, b: the probe candidate
        self._picks += 1
        return b if self._picks % self.probe_every == 0 else a


ROUTERS: Dict[str, Callable[..., Router]] = {
    RoundRobinRouter.name: RoundRobinRouter,
    LeastInflightRouter.name: LeastInflightRouter,
    PowerOfTwoRouter.name: PowerOfTwoRouter,
}


def make_router(name: str, seed: int = 0) -> Router:
    if name not in ROUTERS:
        raise ValueError(f"router must be one of {tuple(ROUTERS)}, got {name!r}")
    return ROUTERS[name](seed=seed)


def shard_slices(
    names: Sequence[str], n_replicas: int, overlap: int = 1
) -> List[List[str]]:
    """Round-robin zoo placement: variant ``i`` lands on ``overlap``
    consecutive replicas starting at ``i % n_replicas``.

    ``overlap=1`` gives disjoint slices (each variant on exactly one
    replica — the fully sharded zoo); ``overlap=n_replicas`` is full
    replication.  Every variant gets at least one replica, so the union
    always covers the zoo.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    if not 1 <= overlap <= n_replicas:
        raise ValueError(
            f"overlap must be in [1, {n_replicas}], got {overlap}"
        )
    slices: List[List[str]] = [[] for _ in range(n_replicas)]
    for i, name in enumerate(names):
        for o in range(overlap):
            slices[(i + o) % n_replicas].append(name)
    return slices


@dataclasses.dataclass(frozen=True)
class ReplicaSnapshot:
    """Point-in-time view of one replica's load accounting and health."""

    replica_id: int
    hosts: tuple
    inflight_rows: int
    dispatched_rows: int
    completed_batches: int
    ewma_wall_ms: Optional[float]
    # Health: breaker state machine + drain flag (see repro.serving.health).
    health: str = "closed"  # closed | open | half_open
    reason: Optional[str] = None  # why the breaker tripped (open/half_open)
    open_until_ms: Optional[float] = None  # loop-clock; inf: permanent (kill)
    draining: bool = False
    # Hardware shape (heterogeneous pools; nominal defaults otherwise).
    weight: float = 1.0
    max_concurrency: Optional[int] = None
    service_scale: float = 1.0


class ReplicaPool:
    """N backend replicas + zoo placement (the cluster's state half).

    The pool owns the replicas, variant placement across their slices,
    and load observability; the *protocol* half —
    :class:`ClusterBackend` — fronts a pool behind the single-backend
    execution interface.  ``slices`` restricts which variants each
    replica admits (see :func:`shard_slices`); ``None`` replicates every
    variant everywhere.  ``specs`` gives each replica its hardware shape
    (:class:`ReplicaSpec` — weight / soft concurrency cap / service
    scale) for heterogeneous fleets; ``None`` keeps every replica
    nominal, byte-identical to the pre-spec pool.
    """

    def __init__(
        self,
        backends: Sequence[ExecutionBackend],
        slices: Optional[Sequence[Sequence[str]]] = None,
        breaker: Optional[BreakerConfig] = None,
        specs: Optional[Sequence[ReplicaSpec]] = None,
    ):
        if not backends:
            raise ValueError("a ReplicaPool needs at least one replica")
        for b in backends:
            if isinstance(b, OnDeviceBackend):
                raise ValueError(
                    "OnDeviceBackend is the device-side hedge singleton, "
                    "not a routable replica — pass it to the serving loop "
                    "as hedge_backend instead"
                )
            if isinstance(b, ClusterBackend):
                # A nested cluster would report inflight 0 / EWMA None to
                # the outer router (its accounting lives on its replicas),
                # silently defeating load-aware routing.
                raise ValueError(
                    "nested ClusterBackend replicas are not supported — "
                    "flatten the backends into one pool (multi-host "
                    "transport is the queued follow-on for hierarchy)"
                )
        if slices is not None and len(slices) != len(backends):
            raise ValueError(
                f"slices covers {len(slices)} replicas but the pool has "
                f"{len(backends)}"
            )
        if specs is not None and len(specs) != len(backends):
            raise ValueError(
                f"specs covers {len(specs)} replicas but the pool has "
                f"{len(backends)}"
            )
        self.replicas = [
            Replica(
                i,
                b,
                None if slices is None else slices[i],
                breaker,
                spec=None if specs is None else specs[i],
            )
            for i, b in enumerate(backends)
        ]

    def __len__(self) -> int:
        return len(self.replicas)

    def place(self, v: Variant) -> List[Replica]:
        """Register a variant on every admitting replica; fails loudly
        when no slice admits it (the union must cover the zoo)."""
        placed = [r for r in self.replicas if r.admits(v.name)]
        if not placed:
            raise ValueError(
                f"no replica slice admits variant {v.name!r} — every "
                "variant needs at least one replica (see shard_slices)"
            )
        for r in placed:
            r.backend.register(v)
        return placed

    def replicas_for(self, name: str) -> List[Replica]:
        """The hosting replica set for a variant (ascending replica_id),
        health-blind — placement truth, not routability."""
        return [r for r in self.replicas if r.hosts(name)]

    def routable_for(self, name: str, now_ms: float) -> List[Replica]:
        """The replicas a batch of ``name`` may be routed to *right now*
        (hosting, breaker closed or probing, not draining)."""
        return [r for r in self.replicas_for(name) if r.routable(now_ms)]

    def hosted_mask(
        self, names: Sequence[str], now_ms: Optional[float] = None
    ) -> np.ndarray:
        """Bool mask over ``names``: True where >= 1 replica can serve the
        variant — the scheduler's selection-eligibility input.

        With ``now_ms`` the mask is *membership-aware*: a variant whose
        every hosting replica is unroutable (breaker open, draining) is
        masked out the same tick the health transition happens.  Without
        it the mask is static placement only (the pre-health behavior).
        """
        if now_ms is None:
            live = self.replicas
        else:
            live = [r for r in self.replicas if r.routable(now_ms)]
        return np.asarray(
            [any(r.hosts(n) for r in live) for n in names], dtype=bool
        )

    def snapshot(self) -> List[ReplicaSnapshot]:
        """Per-replica load accounting (for logs / benches / soak tests)."""
        return [
            ReplicaSnapshot(
                replica_id=r.replica_id,
                hosts=tuple(sorted(r.backend.variants)),
                inflight_rows=r.inflight_rows,
                dispatched_rows=r.dispatched_rows,
                completed_batches=r.backend.completed_batches,
                ewma_wall_ms=r.ewma_wall_ms,
                health=r.health.breaker.state,
                reason=r.health.breaker.reason,
                open_until_ms=r.health.breaker.open_until_ms,
                draining=r.health.draining,
                weight=r.spec.weight,
                max_concurrency=r.spec.max_concurrency,
                service_scale=r.spec.service_scale,
            )
            for r in self.replicas
        ]


class ClusterBackend(ExecutionBackend):
    """A replica pool behind the single-backend execution protocol.

    ``submit_batch`` routes each batch to one hosting replica via the
    routing policy and stamps the returned handle with ``replica`` (the
    chosen replica id) and ``inflight_at_dispatch`` (the replica's queue
    depth in rows, this batch included) — the serving loop threads both
    onto :class:`repro.serving.lifecycle.CompletedRequest`.

    Construct from raw backends (a :class:`ReplicaPool` is built for you)
    or pass a prebuilt pool.  Routing never considers a replica that
    doesn't host the batch's variant.
    """

    def __init__(
        self,
        backends: Sequence[ExecutionBackend] | ReplicaPool,
        *,
        router: str | Router = "round_robin",
        slices: Optional[Sequence[Sequence[str]]] = None,
        seed: int = 0,
        breaker: Optional[BreakerConfig] = None,
        specs: Optional[Sequence[ReplicaSpec]] = None,
    ):
        super().__init__()
        if isinstance(backends, ReplicaPool):
            if slices is not None or breaker is not None or specs is not None:
                raise ValueError(
                    "pass slices/breaker/specs to the ReplicaPool, not "
                    "the ClusterBackend"
                )
            self.pool = backends
        else:
            self.pool = ReplicaPool(
                backends, slices=slices, breaker=breaker, specs=specs
            )
        self.router = router if isinstance(router, Router) else make_router(
            router, seed=seed
        )
        # The cluster's view of the serving loop's clock (ms): breaker
        # cooldowns and routability are evaluated against this, so health
        # behavior is deterministic trace time, not wall time.
        self._now_ms = 0.0
        self._obs = None  # Observability handle; None keeps the bare path

    def attach_observability(self, obs, track: Optional[str] = None) -> None:
        """Propagate a metrics+trace handle through the pool: each
        replica's breaker and backend get it with the replica's trace
        track (``replica:<id>``), so worker spans and trip instants land
        on the right timeline row."""
        self._obs = obs
        for r in self.pool.replicas:
            rtrack = f"replica:{r.replica_id}"
            r.health.breaker.attach_observability(
                obs, track=rtrack, replica=str(r.replica_id)
            )
            attach = getattr(r.backend, "attach_observability", None)
            if attach is not None:
                attach(obs, track=rtrack)

    # -- membership clock -----------------------------------------------------
    def advance_clock(self, now_ms: float) -> None:
        """Feed the loop clock forward (ticks call this before routing);
        monotone — a stale caller never rewinds breaker cooldowns."""
        self._now_ms = max(self._now_ms, float(now_ms))

    @property
    def replicas(self) -> List[Replica]:
        return self.pool.replicas

    @property
    def n_replicas(self) -> int:
        return len(self.pool)

    @property
    def max_len(self):
        """The pool's sequence cap: the tightest across replicas (a
        heterogeneous pool caps at its most constrained member; on the
        homogeneous default every replica reports the same value)."""
        caps = [
            getattr(r.backend, "max_len", None) for r in self.pool.replicas
        ]
        caps = [c for c in caps if c is not None]
        return min(caps) if caps else None

    # -- placement ------------------------------------------------------------
    def register(self, v: Variant) -> None:
        self.pool.place(v)
        self.variants[v.name] = v

    def replicas_for(self, name: str) -> List[Replica]:
        return self.pool.replicas_for(name)

    def hosted_mask(self, names: Sequence[str]) -> np.ndarray:
        # Membership-aware: evaluated at the cluster clock, so the mask
        # tracks breaker/drain transitions tick-by-tick.
        return self.pool.hosted_mask(names, self._now_ms)

    def fan_out(self, name: str) -> int:
        """How many replicas a batch of this variant can spread across
        *this tick* (routable hosting replicas only)."""
        return max(1, len(self.pool.routable_for(name, self._now_ms)))

    # -- routing --------------------------------------------------------------
    def route(self, name: str) -> Replica:
        """Pick the replica that runs the next batch of variant ``name``.

        Distinguishes the two empty cases: *nothing hosts the variant* is
        a placement error (``ValueError`` — a registration bug), while
        *everything hosting it is unroutable* is an operational condition
        (:class:`NoHealthyReplica` — the loop degrades those rows).
        """
        hosting = self.pool.replicas_for(name)
        if not hosting:
            raise ValueError(
                f"no replica hosts variant {name!r} (slices: "
                f"{[sorted(r.backend.variants) for r in self.pool.replicas]})"
            )
        routable = [r for r in hosting if r.routable(self._now_ms)]
        # Soft concurrency cap: a replica at its spec's max_concurrency is
        # skipped while any routable peer has room — but when the whole
        # set is full, routing proceeds over it (saturation is
        # back-pressure, not an outage; NoHealthyReplica stays a pure
        # health signal).  Uncapped replicas (the default) always have
        # capacity, so the homogeneous pool routes byte-identically.
        eligible = [r for r in routable if r.has_capacity] or routable
        if not eligible:
            raise NoHealthyReplica(
                f"no healthy replica for variant {name!r}: "
                + "; ".join(
                    f"replica {r.replica_id} "
                    + (
                        "draining"
                        if r.health.draining
                        else f"{r.health.breaker.state}"
                        + (
                            f" ({r.health.breaker.reason})"
                            if r.health.breaker.reason
                            else ""
                        )
                    )
                    for r in hosting
                )
            )
        replica = self.router.pick(eligible)
        replica.health.breaker.on_dispatch(self._now_ms)
        return replica

    # -- health reporting (driven by the serving loop) ------------------------
    def note_success(self, replica_id: int) -> None:
        """A routed batch completed on ``replica_id``: feed its breaker
        (closes a half-open probe, resets the failure streak)."""
        self.replicas[replica_id].health.breaker.on_success(self._now_ms)

    def note_failure(
        self, replica_id: int, reason: str, *, fatal: bool = False
    ) -> None:
        """A routed batch was lost on ``replica_id``: feed its breaker
        (``fatal`` — worker death/timeout — trips immediately)."""
        self.replicas[replica_id].health.breaker.on_failure(
            self._now_ms, reason, fatal=fatal
        )

    # -- membership operations ------------------------------------------------
    def drain(self, replica_id: int) -> None:
        """Gracefully remove a replica from routing: nothing new is routed
        to it, in-flight batches finish normally (their completions still
        resolve), and :meth:`rejoin` restores it.  The loop requeues any
        rows a drain-then-death races out of."""
        self.replicas[replica_id].health.draining = True

    def rejoin(self, replica_id: int) -> None:
        """Bring a drained/tripped/killed replica back into routing:
        clears the drain flag, resets the breaker, and restarts a dead
        transport worker (when the backend supports it)."""
        r = self.replicas[replica_id]
        r.health.draining = False
        r.health.breaker.reset()
        restart = getattr(r.backend, "restart", None)
        if restart is not None and not getattr(r.backend, "alive", True):
            restart()

    def kill_replica(self, replica_id: int, reason: str = "killed") -> None:
        """Fault injection / hard removal: kill the replica's transport
        worker (when it has one) and trip its breaker *permanently* —
        only :meth:`rejoin` recovers it.  In-flight batches surface as
        :class:`~repro.serving.transport.ReplicaDied` at collection and
        the loop requeues their rows."""
        r = self.replicas[replica_id]
        kill = getattr(r.backend, "kill", None)
        if kill is not None:
            kill(reason)
        r.health.breaker.trip(self._now_ms, reason, permanent=True)

    # -- the execution protocol, routed ---------------------------------------
    def submit_batch(
        self, name: str, batch: np.ndarray, n_steps: int, *, sync: bool = False
    ) -> BatchHandle:
        try:
            replica = self.route(name)
        except NoHealthyReplica:
            if self._obs is not None:
                self._obs.counter(
                    "cluster_no_healthy_total", variant=name
                ).inc()
            raise
        depth = replica.inflight_rows + int(batch.shape[0])
        if self._obs is not None:
            self._obs.counter(
                "cluster_dispatched_rows_total",
                replica=str(replica.replica_id),
            ).inc(int(batch.shape[0]))
            self._obs.gauge(
                "cluster_inflight_rows", replica=str(replica.replica_id)
            ).set(depth)
        try:
            handle = replica.backend.submit_batch(
                name, batch, n_steps, sync=sync
            )
        except TransportError as e:
            # Sync dispatch surfaces transport faults inline; the replica
            # backend already reconciled its inflight accounting
            # (_note_done ran before the raise), so only the breaker and
            # the handle are left to produce here.  The loop treats the
            # FailedBatchHandle like any other lost batch.
            self.note_failure(
                replica.replica_id, str(e), fatal=isinstance(e, ReplicaDied)
            )
            handle = FailedBatchHandle(name, int(batch.shape[0]), e)
        handle.replica = replica.replica_id
        handle.inflight_at_dispatch = depth
        return handle

    def generate(self, name, tokens, n_steps):
        return self.route(name).backend.generate(name, tokens, n_steps)

    def run_batch(self, name, batch, n_steps):
        # Delegate whole: each replica owns its warm-shape set, so the
        # first batch a replica sees of a shape absorbs its own compile.
        return self.route(name).backend.run_batch(name, batch, n_steps)

    def measure_profile(
        self, name, prompt_len, gen_tokens, batch=1, trials=5, seed=0
    ):
        # Pin the measurement to one hosting replica: rotating the router
        # between timed trials would charge each replica's one-time
        # compile to the profile.  (In a heterogeneous pool this is the
        # *nominal* profile; live ewma_wall_ms tracks real per-replica
        # speed.)
        return self.replicas_for(name)[0].backend.measure_profile(
            name, prompt_len, gen_tokens, batch=batch, trials=trials, seed=seed
        )

    # -- observability --------------------------------------------------------
    def snapshot(self) -> List[ReplicaSnapshot]:
        """Per-replica load accounting (for logs / benches / soak tests)."""
        return self.pool.snapshot()
