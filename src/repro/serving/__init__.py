"""Serving: client futures + admission + event loop + policy + backends."""
from repro.serving.admission import (
    AdmissionConfig,
    AdmissionQueue,
    sla_unreachable,
)
from repro.serving.backend import (
    BatchHandle,
    ExecutionBackend,
    JitBackend,
    OnDeviceBackend,
    build_hedge_variant,
)
from repro.serving.client import InferenceClient
from repro.serving.cluster import (
    ROUTERS,
    ClusterBackend,
    LeastInflightRouter,
    NoHealthyReplica,
    PowerOfTwoRouter,
    Replica,
    ReplicaPool,
    ReplicaSpec,
    RoundRobinRouter,
    Router,
    make_router,
    parse_replica_specs,
    shard_slices,
)
from repro.serving.controller import AdmissionController, ControllerConfig
from repro.serving.health import BreakerConfig, CircuitBreaker, ReplicaHealth
from repro.serving.engine import (
    CompletedRequest,
    QueuedRequest,
    ServingEngine,
    Variant,
)
from repro.serving.lifecycle import (
    InferenceFuture,
    RequestCancelled,
    RequestRejected,
    RequestState,
)
from repro.serving.loadgen import (
    BurstyArrivals,
    DiurnalArrivals,
    LoadTrace,
    OverloadArrivals,
    PoissonArrivals,
    RampArrivals,
    SpikeArrivals,
    iter_windows,
    make_trace,
)
from repro.serving.loop import ServingLoop, TickResult, TickStats
from repro.serving.transport import (
    FailedBatchHandle,
    ProcessTransportBackend,
    RemoteExecutionError,
    ReplicaDied,
    TransportError,
)
from repro.serving.profiles import ONDEVICE_TIER, V5E, estimate_ms, lm_zoo_registry
from repro.serving.scheduler import (
    BatchDecision,
    Decision,
    MDInferenceScheduler,
    SchedulerConfig,
)

__all__ = [
    "AdmissionConfig", "AdmissionController", "AdmissionQueue",
    "BatchDecision", "BatchHandle",
    "BreakerConfig", "BurstyArrivals", "CircuitBreaker", "ClusterBackend",
    "CompletedRequest", "ControllerConfig", "Decision", "DiurnalArrivals",
    "ExecutionBackend", "FailedBatchHandle",
    "InferenceClient", "InferenceFuture", "JitBackend",
    "LeastInflightRouter", "LoadTrace", "MDInferenceScheduler",
    "NoHealthyReplica", "ONDEVICE_TIER", "OnDeviceBackend",
    "OverloadArrivals", "PoissonArrivals", "PowerOfTwoRouter",
    "ProcessTransportBackend", "QueuedRequest", "ROUTERS", "RampArrivals",
    "RemoteExecutionError", "Replica", "ReplicaDied", "ReplicaHealth",
    "ReplicaPool", "ReplicaSpec", "RequestCancelled", "RequestRejected",
    "RequestState", "RoundRobinRouter", "Router", "SchedulerConfig",
    "ServingEngine", "ServingLoop", "SpikeArrivals", "TickResult",
    "TickStats", "TransportError", "V5E",
    "Variant", "build_hedge_variant", "estimate_ms", "iter_windows",
    "lm_zoo_registry", "make_router", "make_trace", "parse_replica_specs",
    "shard_slices", "sla_unreachable",
]
