"""Serving: MDInference scheduler (policy) + execution engine + profiles."""
from repro.serving.engine import ServingEngine, Variant
from repro.serving.profiles import ONDEVICE_TIER, V5E, estimate_ms, lm_zoo_registry
from repro.serving.scheduler import Decision, MDInferenceScheduler, SchedulerConfig

__all__ = [
    "Decision", "MDInferenceScheduler", "SchedulerConfig",
    "ONDEVICE_TIER", "ServingEngine", "V5E", "Variant",
    "estimate_ms", "lm_zoo_registry",
]
