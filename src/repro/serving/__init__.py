"""Serving: MDInference scheduler (policy) + execution backends + load gen."""
from repro.serving.backend import (
    ExecutionBackend,
    JitBackend,
    OnDeviceBackend,
    build_hedge_variant,
)
from repro.serving.engine import (
    CompletedRequest,
    QueuedRequest,
    ServingEngine,
    Variant,
)
from repro.serving.loadgen import (
    BurstyArrivals,
    LoadTrace,
    PoissonArrivals,
    iter_windows,
    make_trace,
)
from repro.serving.profiles import ONDEVICE_TIER, V5E, estimate_ms, lm_zoo_registry
from repro.serving.scheduler import (
    BatchDecision,
    Decision,
    MDInferenceScheduler,
    SchedulerConfig,
)

__all__ = [
    "BatchDecision", "BurstyArrivals", "CompletedRequest", "Decision",
    "ExecutionBackend", "JitBackend", "LoadTrace", "MDInferenceScheduler",
    "ONDEVICE_TIER", "OnDeviceBackend", "PoissonArrivals", "QueuedRequest",
    "SchedulerConfig", "ServingEngine", "V5E", "Variant",
    "build_hedge_variant", "estimate_ms", "iter_windows", "lm_zoo_registry",
    "make_trace",
]
