"""Pluggable execution backends — the execution tier of the serving stack.

The policy half (:class:`repro.serving.scheduler.MDInferenceScheduler`)
decides *which* variant answers a request; an :class:`ExecutionBackend`
owns *how* variants execute.  Two tiers ship:

* :class:`JitBackend` — the remote/server tier: per-variant jitted
  prefill/decode executables, real batched greedy decoding.
* :class:`OnDeviceBackend` — the hedge tier: hosts exactly one real tiny
  variant (recipe from :data:`repro.configs.mdinference_zoo.ONDEVICE_HEDGE`,
  the paper's MobileNetV1_128 0.25 duplicate, §V-B).  Hedged requests run
  here *for real*, so duplication resolves on measured wall time instead of
  a profile sample.

Both tiers share the continuous-batching cost model through
:meth:`ExecutionBackend.run_batch`: the first occurrence of each
(variant, batch-shape) runs an untimed warm-up so XLA compile time is never
charged to requests or folded into live latency profiles.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mdinference_zoo import ONDEVICE_HEDGE, HedgeVariantSpec
from repro.core.registry import ModelProfile
from repro.models import transformer as T
from repro.models.config import ModelConfig

__all__ = [
    "Variant",
    "BatchHandle",
    "ExecutionBackend",
    "JitBackend",
    "OnDeviceBackend",
    "build_hedge_variant",
]


@dataclasses.dataclass
class Variant:
    name: str
    cfg: ModelConfig
    params: dict
    quality: float  # A(m) for the selection algorithm


class BatchHandle:
    """One in-flight batch on an execution tier (async dispatch protocol).

    Returned by :meth:`ExecutionBackend.submit_batch`.  :meth:`poll` never
    blocks; :meth:`wait` blocks (optionally up to ``timeout`` seconds) and
    returns the same ``(generated, wall_ms)`` pair as
    :meth:`ExecutionBackend.run_batch`.

    Wall-clock bookkeeping for race accounting:

    * ``dispatch_wall_ms`` — ``perf_counter`` stamp when the batch was
      submitted.  Two tiers dispatched in the same scheduling tick differ
      by thread-submit overhead only — this is the race clocks' shared
      start, replacing the serialized remote-then-duplicate measurement.
    * ``done_wall_ms`` — stamp when execution (warm-up included) finished.

    ``replica`` / ``inflight_at_dispatch`` are stamped by a routing layer
    (:class:`repro.serving.cluster.ClusterBackend`): which pool replica ran
    the batch and the replica's queue depth (rows, this batch included) at
    dispatch.  ``None`` on a plain single-backend handle.
    """

    def __init__(self, name: str, n_rows: int):
        self.name = name
        self.n_rows = n_rows
        self.dispatch_wall_ms = time.perf_counter() * 1e3
        self.done_wall_ms: Optional[float] = None
        self.replica: Optional[int] = None
        self.inflight_at_dispatch: Optional[int] = None

    def poll(self) -> bool:
        """Non-blocking: True once the batch result is ready."""
        raise NotImplementedError

    def wait(self, timeout: Optional[float] = None) -> Tuple[np.ndarray, float]:
        """Block until ready; returns ``(generated (B, n_steps), wall_ms)``."""
        raise NotImplementedError


class _CompletedBatchHandle(BatchHandle):
    """Sync-dispatch handle: the batch already ran inside ``submit_batch``."""

    def __init__(self, name, n_rows, dispatch_wall_ms, out, wall_ms):
        super().__init__(name, n_rows)
        self.dispatch_wall_ms = dispatch_wall_ms
        self.done_wall_ms = time.perf_counter() * 1e3
        self._result = (out, wall_ms)

    def poll(self) -> bool:
        return True

    def wait(self, timeout=None):
        return self._result


class _ThreadedBatchHandle(BatchHandle):
    """Async-dispatch handle: the batch runs on a worker thread.

    The worker executes the tier's warm-once-then-timed ``run_batch``, so
    the returned wall time keeps the same XLA-compile-free semantics as
    the synchronous path.  ``on_done(wall_ms | None)`` fires on the worker
    right when execution finishes (before the event is set) — the backend
    uses it to keep its inflight-row count and latency EWMA live.
    """

    def __init__(self, name, n_rows, fn, on_done=None):
        super().__init__(name, n_rows)
        self._done = threading.Event()
        self._result: Optional[Tuple[np.ndarray, float]] = None
        self._error: Optional[BaseException] = None

        def worker():
            try:
                self._result = fn()
            except BaseException as e:  # surfaced from wait()
                self._error = e
            finally:
                self.done_wall_ms = time.perf_counter() * 1e3
                if on_done is not None:
                    on_done(
                        self._result[1] if self._result is not None else None
                    )
                self._done.set()

        self._thread = threading.Thread(
            target=worker, name=f"batch-{name}", daemon=True
        )
        self._thread.start()

    def poll(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout=None):
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"batch on {self.name!r} unfinished after {timeout}s"
            )
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


_STATS_EWMA = 0.25  # live per-backend wall-latency EWMA (routing signal)


class ExecutionBackend:
    """What the policy-facing engine needs from an execution tier.

    Concrete backends implement :meth:`register` and :meth:`generate`;
    :meth:`run_batch` (warm-once-then-timed) is shared.

    Every backend keeps live load accounting, maintained by
    :meth:`submit_batch` regardless of dispatch mode:

    * ``inflight_rows`` — rows dispatched but not yet finished executing.
    * ``dispatched_rows`` / ``completed_batches`` — cumulative counters.
    * ``ewma_wall_ms`` — EWMA of observed batch wall times (``None`` until
      the first completion).

    These are the routing signals a :class:`repro.serving.cluster.ReplicaPool`
    reads per replica (join-shortest-queue, power-of-two-choices); on a
    single backend they are inert bookkeeping.
    """

    variants: Dict[str, Variant]

    def __init__(self):
        self.variants = {}
        self._warmed_shapes: set = set()
        self._stats_lock = threading.Lock()
        self.inflight_rows = 0
        self.dispatched_rows = 0
        self.completed_batches = 0
        self.ewma_wall_ms: Optional[float] = None

    def _note_dispatch(self, n_rows: int) -> None:
        with self._stats_lock:
            self.inflight_rows += n_rows
            self.dispatched_rows += n_rows

    def _note_done(self, n_rows: int, wall_ms: Optional[float]) -> None:
        """Completion hook: drop the rows from inflight and fold the batch
        wall time into the live EWMA (``wall_ms=None``: execution raised —
        the rows still leave the inflight count)."""
        with self._stats_lock:
            self.inflight_rows -= n_rows
            if wall_ms is not None:
                self.completed_batches += 1
                self.ewma_wall_ms = (
                    float(wall_ms)
                    if self.ewma_wall_ms is None
                    else (1 - _STATS_EWMA) * self.ewma_wall_ms
                    + _STATS_EWMA * float(wall_ms)
                )

    def register(self, v: Variant) -> None:
        raise NotImplementedError

    def generate(
        self, name: str, tokens: np.ndarray, n_steps: int
    ) -> Tuple[np.ndarray, float]:
        """Run real generation; returns (generated (B, n_steps), wall_ms)."""
        raise NotImplementedError

    def run_batch(
        self, name: str, batch: np.ndarray, n_steps: int
    ) -> Tuple[np.ndarray, float]:
        """Timed ``generate`` with a one-time untimed warm-up per shape.

        The warm-up absorbs XLA compilation so the returned wall time is an
        honest execution measurement (safe to fold into EWMA profiles).
        """
        shape_key = (name, batch.shape[0], batch.shape[1], n_steps)
        if shape_key not in self._warmed_shapes:
            self.generate(name, batch, n_steps)  # compile, untimed
            self._warmed_shapes.add(shape_key)
        return self.generate(name, batch, n_steps)

    def submit_batch(
        self, name: str, batch: np.ndarray, n_steps: int, *, sync: bool = False
    ) -> BatchHandle:
        """Dispatch a batch without waiting for it — the async protocol.

        With ``sync=False`` (the default) the batch runs on a worker thread
        and the returned :class:`BatchHandle` supports non-blocking
        :meth:`BatchHandle.poll`; batches submitted to *different* tiers in
        the same scheduling tick genuinely overlap.  ``sync=True`` executes
        inline before returning (a pre-completed handle) — the serialized
        fallback that keeps CI and the equivalence references deterministic.

        Either way the execution path is :meth:`run_batch`, so warm-up
        semantics and the measured wall time are identical across modes.
        """
        n_rows = int(batch.shape[0])
        self._note_dispatch(n_rows)
        if sync:
            dispatch_wall_ms = time.perf_counter() * 1e3
            try:
                out, wall_ms = self.run_batch(name, batch, n_steps)
            except BaseException:
                self._note_done(n_rows, None)
                raise
            self._note_done(n_rows, wall_ms)
            return _CompletedBatchHandle(
                name, n_rows, dispatch_wall_ms, out, wall_ms
            )
        return _ThreadedBatchHandle(
            name,
            n_rows,
            lambda: self.run_batch(name, batch, n_steps),
            on_done=lambda wall_ms: self._note_done(n_rows, wall_ms),
        )

    def measure_profile(
        self, name: str, prompt_len: int, gen_tokens: int, batch: int = 1,
        trials: int = 5, seed: int = 0,
    ) -> ModelProfile:
        """Measured latency profile of one variant (the paper's Table III
        methodology: untimed warm-up, then repeated timed executions)."""
        rng = np.random.default_rng(seed)
        v = self.variants[name]
        tokens = rng.integers(0, v.cfg.vocab_size, (batch, prompt_len))
        self.generate(name, tokens, 1)  # warmup/compile
        times = [
            self.generate(name, tokens, gen_tokens)[1] for _ in range(trials)
        ]
        return ModelProfile(
            name=v.name,
            accuracy=v.quality,
            mu_ms=float(np.mean(times)),
            sigma_ms=float(np.std(times) + 1e-3),
        )


class JitBackend(ExecutionBackend):
    """Per-variant jitted prefill/decode executables (the remote tier)."""

    def __init__(self, max_len: int = 256):
        super().__init__()
        self.max_len = max_len
        self._prefill = {}
        self._decode = {}

    def register(self, v: Variant) -> None:
        cfg = v.cfg
        self.variants[v.name] = v

        @jax.jit
        def prefill_fn(params, tokens):
            return T.prefill(cfg, params, {"tokens": tokens}, max_len=self.max_len)

        @jax.jit
        def decode_fn(params, cache, token, pos):
            return T.decode_step(cfg, params, cache, token, pos)

        self._prefill[v.name] = prefill_fn
        self._decode[v.name] = decode_fn

    def generate(self, name, tokens, n_steps, greedy=True):
        v = self.variants[name]
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        if n_steps <= 0:
            return np.zeros((B, 0), dtype=np.int32), 0.0
        t0 = time.perf_counter()
        cache, logits = self._prefill[name](v.params, tokens)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(n_steps):
            out.append(tok)
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = self._decode[name](v.params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        wall_ms = (time.perf_counter() - t0) * 1e3
        return np.stack([np.asarray(t) for t in out], axis=1), wall_ms


def build_hedge_variant(
    spec: HedgeVariantSpec = ONDEVICE_HEDGE, seed: int = 0
) -> Variant:
    """Materialize the zoo's on-device hedge recipe as a real Variant."""
    cfg = spec.config()
    params = T.init_params(cfg, jax.random.key(seed))
    return Variant(spec.name, cfg, params, spec.quality)


class OnDeviceBackend(JitBackend):
    """The hedge tier: a single always-fast variant, executed for real.

    Mirrors the paper's on-device duplicate: one model, small enough to
    finish within any reasonable SLA.  :meth:`hedge` runs the duplicate
    batch and returns measured wall time — the primary input to
    :meth:`repro.serving.scheduler.MDInferenceScheduler.resolve_chunk`.
    """

    def __init__(self, variant: Variant, max_len: int = 256):
        super().__init__(max_len)
        super().register(variant)
        self.hedge_name = variant.name

    @classmethod
    def from_zoo(
        cls,
        max_len: int = 256,
        seed: int = 0,
        spec: HedgeVariantSpec = ONDEVICE_HEDGE,
    ) -> "OnDeviceBackend":
        """Build the default hedge tier from the zoo's recipe."""
        return cls(build_hedge_variant(spec, seed), max_len=max_len)

    def register(self, v: Variant) -> None:
        raise ValueError(
            "OnDeviceBackend hosts exactly one hedge variant "
            f"({self.hedge_name!r}); register remote variants on the "
            "primary backend instead"
        )

    def hedge(self, batch: np.ndarray, n_steps: int) -> Tuple[np.ndarray, float]:
        """Run the duplicate batch on the hedge variant (warm-once, timed)."""
        return self.run_batch(self.hedge_name, batch, n_steps)

    def submit_hedge(
        self, batch: np.ndarray, n_steps: int, *, sync: bool = False
    ) -> BatchHandle:
        """Dispatch the duplicate batch without waiting (async protocol)."""
        return self.submit_batch(self.hedge_name, batch, n_steps, sync=sync)

    def measure_profile(self, name=None, *args, **kwargs) -> ModelProfile:
        """Measured latency profile of the hedge variant (Table III style).

        Keeps the base ``measure_profile(name, ...)`` contract but makes
        the name optional — this tier hosts exactly one variant.  Seeds
        the scheduler's on-device prior; the live EWMA refines it from
        real hedge executions during serving.
        """
        return super().measure_profile(
            self.hedge_name if name is None else name, *args, **kwargs
        )
