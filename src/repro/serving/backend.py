"""Pluggable execution backends — the execution tier of the serving stack.

The policy half (:class:`repro.serving.scheduler.MDInferenceScheduler`)
decides *which* variant answers a request; an :class:`ExecutionBackend`
owns *how* variants execute.  Two tiers ship:

* :class:`JitBackend` — the remote/server tier: per-variant jitted
  prefill/decode executables, real batched greedy decoding.
* :class:`OnDeviceBackend` — the hedge tier: hosts exactly one real tiny
  variant (recipe from :data:`repro.configs.mdinference_zoo.ONDEVICE_HEDGE`,
  the paper's MobileNetV1_128 0.25 duplicate, §V-B).  Hedged requests run
  here *for real*, so duplication resolves on measured wall time instead of
  a profile sample.

Both tiers share the continuous-batching cost model through
:meth:`ExecutionBackend.run_batch`: the first occurrence of each
(variant, batch-shape) runs an untimed warm-up so XLA compile time is never
charged to requests or folded into live latency profiles.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mdinference_zoo import (
    ONDEVICE_HEDGE,
    SERVING_GEOMETRY,
    HedgeVariantSpec,
    ServingGeometry,
)
from repro.core.registry import ModelProfile
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.block_cache import BlockPagedSlotCache, NoFreeSlot

__all__ = [
    "Variant",
    "BatchHandle",
    "ExecutionBackend",
    "JitBackend",
    "OnDeviceBackend",
    "ContinuousBatchingBackend",
    "build_hedge_variant",
]


@dataclasses.dataclass
class Variant:
    name: str
    cfg: ModelConfig
    params: dict
    quality: float  # A(m) for the selection algorithm


class BatchHandle:
    """One in-flight batch on an execution tier (async dispatch protocol).

    Returned by :meth:`ExecutionBackend.submit_batch`.  :meth:`poll` never
    blocks; :meth:`wait` blocks (optionally up to ``timeout`` seconds) and
    returns the same ``(generated, wall_ms)`` pair as
    :meth:`ExecutionBackend.run_batch`.

    Wall-clock bookkeeping for race accounting:

    * ``dispatch_wall_ms`` — ``perf_counter`` stamp when the batch was
      submitted.  Two tiers dispatched in the same scheduling tick differ
      by thread-submit overhead only — this is the race clocks' shared
      start, replacing the serialized remote-then-duplicate measurement.
    * ``done_wall_ms`` — stamp when execution (warm-up included) finished.

    ``replica`` / ``inflight_at_dispatch`` are stamped by a routing layer
    (:class:`repro.serving.cluster.ClusterBackend`): which pool replica ran
    the batch and the replica's queue depth (rows, this batch included) at
    dispatch.  ``None`` on a plain single-backend handle.
    """

    def __init__(self, name: str, n_rows: int):
        self.name = name
        self.n_rows = n_rows
        self.dispatch_wall_ms = time.perf_counter() * 1e3
        self.done_wall_ms: Optional[float] = None
        self.replica: Optional[int] = None
        self.inflight_at_dispatch: Optional[int] = None

    def poll(self) -> bool:
        """Non-blocking: True once the batch result is ready."""
        raise NotImplementedError

    def wait(self, timeout: Optional[float] = None) -> Tuple[np.ndarray, float]:
        """Block until ready; returns ``(generated (B, n_steps), wall_ms)``."""
        raise NotImplementedError


class _CompletedBatchHandle(BatchHandle):
    """Sync-dispatch handle: the batch already ran inside ``submit_batch``."""

    def __init__(self, name, n_rows, dispatch_wall_ms, out, wall_ms):
        super().__init__(name, n_rows)
        self.dispatch_wall_ms = dispatch_wall_ms
        self.done_wall_ms = time.perf_counter() * 1e3
        self._result = (out, wall_ms)

    def poll(self) -> bool:
        return True

    def wait(self, timeout=None):
        return self._result


class _ThreadedBatchHandle(BatchHandle):
    """Async-dispatch handle: the batch runs on a worker thread.

    The worker executes the tier's warm-once-then-timed ``run_batch``, so
    the returned wall time keeps the same XLA-compile-free semantics as
    the synchronous path.  ``on_done(wall_ms | None)`` fires on the worker
    right when execution finishes (before the event is set) — the backend
    uses it to keep its inflight-row count and latency EWMA live.
    """

    def __init__(self, name, n_rows, fn, on_done=None):
        super().__init__(name, n_rows)
        self._done = threading.Event()
        self._result: Optional[Tuple[np.ndarray, float]] = None
        self._error: Optional[BaseException] = None

        def worker():
            try:
                self._result = fn()
            except BaseException as e:  # surfaced from wait()
                self._error = e
            finally:
                self.done_wall_ms = time.perf_counter() * 1e3
                if on_done is not None:
                    on_done(
                        self._result[1] if self._result is not None else None
                    )
                self._done.set()

        self._thread = threading.Thread(
            target=worker, name=f"batch-{name}", daemon=True
        )
        self._thread.start()

    def poll(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout=None):
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"batch on {self.name!r} unfinished after {timeout}s"
            )
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


_STATS_EWMA = 0.25  # live per-backend wall-latency EWMA (routing signal)


class ExecutionBackend:
    """What the policy-facing engine needs from an execution tier.

    Concrete backends implement :meth:`register` and :meth:`generate`;
    :meth:`run_batch` (warm-once-then-timed) is shared.

    Every backend keeps live load accounting, maintained by
    :meth:`submit_batch` regardless of dispatch mode:

    * ``inflight_rows`` — rows dispatched but not yet finished executing.
    * ``dispatched_rows`` / ``completed_batches`` — cumulative counters.
    * ``ewma_wall_ms`` — EWMA of observed batch wall times (``None`` until
      the first completion).

    These are the routing signals a :class:`repro.serving.cluster.ReplicaPool`
    reads per replica (join-shortest-queue, power-of-two-choices); on a
    single backend they are inert bookkeeping.
    """

    variants: Dict[str, Variant]

    def __init__(self):
        self.variants = {}
        self._warmed_shapes: set = set()
        self._stats_lock = threading.Lock()
        self.inflight_rows = 0
        self.dispatched_rows = 0
        self.completed_batches = 0
        self.ewma_wall_ms: Optional[float] = None
        # Optional repro.observability.Observability handle + the trace
        # track this backend's spans land on (set by the cluster layer
        # with the replica's id, or by the loop for a single backend).
        self._obs = None
        self._obs_track: Optional[str] = None

    def attach_observability(self, obs, track: Optional[str] = None) -> None:
        """Wire this backend's dispatch path to a metrics+trace handle.

        Never attached (the default), every path is byte-identical to the
        uninstrumented backend.
        """
        self._obs = obs
        self._obs_track = track

    def _note_dispatch(self, n_rows: int) -> None:
        with self._stats_lock:
            self.inflight_rows += n_rows
            self.dispatched_rows += n_rows

    def _note_done(self, n_rows: int, wall_ms: Optional[float]) -> None:
        """Completion hook: drop the rows from inflight and fold the batch
        wall time into the live EWMA (``wall_ms=None``: execution raised —
        the rows still leave the inflight count)."""
        with self._stats_lock:
            self.inflight_rows -= n_rows
            if wall_ms is not None:
                self.completed_batches += 1
                self.ewma_wall_ms = (
                    float(wall_ms)
                    if self.ewma_wall_ms is None
                    else (1 - _STATS_EWMA) * self.ewma_wall_ms
                    + _STATS_EWMA * float(wall_ms)
                )

    def register(self, v: Variant) -> None:
        raise NotImplementedError

    def generate(
        self, name: str, tokens: np.ndarray, n_steps: int
    ) -> Tuple[np.ndarray, float]:
        """Run real generation; returns (generated (B, n_steps), wall_ms)."""
        raise NotImplementedError

    def run_batch(
        self, name: str, batch: np.ndarray, n_steps: int
    ) -> Tuple[np.ndarray, float]:
        """Timed ``generate`` with a one-time untimed warm-up per shape.

        The warm-up absorbs XLA compilation so the returned wall time is an
        honest execution measurement (safe to fold into EWMA profiles).
        """
        shape_key = (name, batch.shape[0], batch.shape[1], n_steps)
        if shape_key not in self._warmed_shapes:
            self.generate(name, batch, n_steps)  # compile, untimed
            self._warmed_shapes.add(shape_key)
        return self.generate(name, batch, n_steps)

    def submit_batch(
        self,
        name: str,
        batch: np.ndarray,
        n_steps: int,
        *,
        sync: bool = False,
        on_token=None,
    ) -> BatchHandle:
        """Dispatch a batch without waiting for it — the async protocol.

        With ``sync=False`` (the default) the batch runs on a worker thread
        and the returned :class:`BatchHandle` supports non-blocking
        :meth:`BatchHandle.poll`; batches submitted to *different* tiers in
        the same scheduling tick genuinely overlap.  ``sync=True`` executes
        inline before returning (a pre-completed handle) — the serialized
        fallback that keeps CI and the equivalence references deterministic.

        Either way the execution path is :meth:`run_batch`, so warm-up
        semantics and the measured wall time are identical across modes.

        ``on_token(row, token, wall_ms)`` is the streaming channel: a
        backend that decodes token-by-token calls it per emitted token
        (before the batch completes).  Whole-batch tiers have no per-token
        stream, so the base implementation ignores it; the serving loop
        only passes it to backends advertising ``supports_streaming``.
        """
        n_rows = int(batch.shape[0])
        self._note_dispatch(n_rows)
        if sync:
            dispatch_wall_ms = time.perf_counter() * 1e3
            try:
                out, wall_ms = self.run_batch(name, batch, n_steps)
            except BaseException:
                self._note_done(n_rows, None)
                raise
            self._note_done(n_rows, wall_ms)
            return _CompletedBatchHandle(
                name, n_rows, dispatch_wall_ms, out, wall_ms
            )
        run = lambda: self.run_batch(name, batch, n_steps)  # noqa: E731
        if self._obs is not None:
            # The handle's worker thread has no ambient span of its own;
            # capture the dispatching thread's (the loop's batch-group
            # span) and re-bind it so transport-level spans nest under it.
            tracer = self._obs.tracer
            ambient = tracer.ambient_id()

            def run(_inner=run):
                with tracer.bind(ambient):
                    return _inner()

        return _ThreadedBatchHandle(
            name,
            n_rows,
            run,
            on_done=lambda wall_ms: self._note_done(n_rows, wall_ms),
        )

    def measure_profile(
        self, name: str, prompt_len: int, gen_tokens: int, batch: int = 1,
        trials: int = 5, seed: int = 0,
    ) -> ModelProfile:
        """Measured latency profile of one variant (the paper's Table III
        methodology: untimed warm-up, then repeated timed executions)."""
        rng = np.random.default_rng(seed)
        v = self.variants[name]
        tokens = rng.integers(0, v.cfg.vocab_size, (batch, prompt_len))
        self.generate(name, tokens, 1)  # warmup/compile
        times = [
            self.generate(name, tokens, gen_tokens)[1] for _ in range(trials)
        ]
        return ModelProfile(
            name=v.name,
            accuracy=v.quality,
            mu_ms=float(np.mean(times)),
            sigma_ms=float(np.std(times) + 1e-3),
        )


class JitBackend(ExecutionBackend):
    """Per-variant jitted prefill/decode executables (the remote tier).

    ``max_len`` defaults to :data:`~repro.configs.mdinference_zoo.SERVING_GEOMETRY`
    — the zoo recipe is the single source of truth for cache geometry across
    all tiers (the historical hardcoded 256 lives there now).
    """

    def __init__(self, max_len: Optional[int] = None):
        super().__init__()
        self.max_len = SERVING_GEOMETRY.max_len if max_len is None else max_len
        self._prefill = {}
        self._decode = {}

    def register(self, v: Variant) -> None:
        cfg = v.cfg
        self.variants[v.name] = v

        @jax.jit
        def prefill_fn(params, tokens):
            return T.prefill(cfg, params, {"tokens": tokens}, max_len=self.max_len)

        @jax.jit
        def decode_fn(params, cache, token, pos):
            return T.decode_step(cfg, params, cache, token, pos)

        self._prefill[v.name] = prefill_fn
        self._decode[v.name] = decode_fn

    def generate(self, name, tokens, n_steps, greedy=True):
        v = self.variants[name]
        tokens = jnp.asarray(tokens, jnp.int32)
        B, S = tokens.shape
        if n_steps <= 0:
            return np.zeros((B, 0), dtype=np.int32), 0.0
        t0 = time.perf_counter()
        cache, logits = self._prefill[name](v.params, tokens)
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(n_steps):
            out.append(tok)
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = self._decode[name](v.params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        wall_ms = (time.perf_counter() - t0) * 1e3
        return np.stack([np.asarray(t) for t in out], axis=1), wall_ms


def build_hedge_variant(
    spec: HedgeVariantSpec = ONDEVICE_HEDGE, seed: int = 0
) -> Variant:
    """Materialize the zoo's on-device hedge recipe as a real Variant."""
    cfg = spec.config()
    params = T.init_params(cfg, jax.random.key(seed))
    return Variant(spec.name, cfg, params, spec.quality)


class OnDeviceBackend(JitBackend):
    """The hedge tier: a single always-fast variant, executed for real.

    Mirrors the paper's on-device duplicate: one model, small enough to
    finish within any reasonable SLA.  :meth:`hedge` runs the duplicate
    batch and returns measured wall time — the primary input to
    :meth:`repro.serving.scheduler.MDInferenceScheduler.resolve_chunk`.
    """

    def __init__(self, variant: Variant, max_len: Optional[int] = None):
        super().__init__(max_len)
        super().register(variant)
        self.hedge_name = variant.name

    @classmethod
    def from_zoo(
        cls,
        max_len: Optional[int] = None,
        seed: int = 0,
        spec: HedgeVariantSpec = ONDEVICE_HEDGE,
    ) -> "OnDeviceBackend":
        """Build the default hedge tier from the zoo's recipe."""
        return cls(build_hedge_variant(spec, seed), max_len=max_len)

    def register(self, v: Variant) -> None:
        raise ValueError(
            "OnDeviceBackend hosts exactly one hedge variant "
            f"({self.hedge_name!r}); register remote variants on the "
            "primary backend instead"
        )

    def hedge(self, batch: np.ndarray, n_steps: int) -> Tuple[np.ndarray, float]:
        """Run the duplicate batch on the hedge variant (warm-once, timed)."""
        return self.run_batch(self.hedge_name, batch, n_steps)

    def submit_hedge(
        self, batch: np.ndarray, n_steps: int, *, sync: bool = False
    ) -> BatchHandle:
        """Dispatch the duplicate batch without waiting (async protocol)."""
        return self.submit_batch(self.hedge_name, batch, n_steps, sync=sync)

    def measure_profile(self, name=None, *args, **kwargs) -> ModelProfile:
        """Measured latency profile of the hedge variant (Table III style).

        Keeps the base ``measure_profile(name, ...)`` contract but makes
        the name optional — this tier hosts exactly one variant.  Seeds
        the scheduler's on-device prior; the live EWMA refines it from
        real hedge executions during serving.
        """
        return super().measure_profile(
            self.hedge_name if name is None else name, *args, **kwargs
        )


# ---------------------------------------------------------------------------
# Continuous batching.
# ---------------------------------------------------------------------------
class _ContinuousBatchHandle(BatchHandle):
    """Handle over rows living inside the persistent decode batch.

    Rows complete *individually* — each occupies a slot of the continuous
    batch until it emits ``n_steps`` tokens (or is released early via
    :meth:`release_rows`: hedge win / cancel).  :meth:`poll` is passive;
    :meth:`wait` pumps the backend's decode loop until every row is done.

    ``ttft_wall_ms[i]`` is row *i*'s time-to-first-token: prefill + graft
    latency from submit, stamped the moment its first token exists — the
    quantity continuous batching exists to shrink (a joining request no
    longer waits for the in-flight batch to finish).
    """

    def __init__(self, backend, name: str, n_rows: int, n_steps: int):
        super().__init__(name, n_rows)
        self._backend = backend
        self.n_steps = n_steps
        self.row_slots: list = [None] * n_rows  # slot index while in-flight
        self.emitted: list = [[] for _ in range(n_rows)]
        self.done_rows = [False] * n_rows
        self.released_rows: Dict[int, str] = {}  # row -> release reason
        self.ttft_wall_ms: list = [None] * n_rows
        self._wall_ms: Optional[float] = None
        # Streaming channel: called as on_token(row, token, wall_ms) the
        # moment a token is appended to ``emitted`` — same wall stamp as
        # the TTFT accounting, so chunk timestamps and ttft_ms agree.
        self.on_token = None

    @property
    def all_done(self) -> bool:
        return all(self.done_rows)

    def poll(self) -> bool:
        return self.all_done

    def result(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_steps), dtype=np.int32)
        for i, toks in enumerate(self.emitted):
            if toks:
                out[i, : len(toks)] = toks[: self.n_steps]
        return out

    def wait(self, timeout=None):
        deadline = None if timeout is None else time.perf_counter() + timeout
        while not self.all_done:
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(
                    f"continuous batch on {self.name!r} unfinished "
                    f"after {timeout}s"
                )
            if not self._backend.pump(self.name):
                raise RuntimeError(
                    f"continuous batch on {self.name!r} stalled: "
                    "no active slots but rows incomplete"
                )
        assert self._wall_ms is not None
        return self.result(), self._wall_ms

    def release_rows(self, rows, reason: str) -> None:
        """Free the slots of still-running rows early (hedge win / cancel).

        The freed pages return to the pool immediately — the next join
        reuses them.  Released rows keep whatever tokens they emitted."""
        self._backend._release_handle_rows(self, rows, reason)


@dataclasses.dataclass
class _SlotRuntime:
    """Host-side state of one occupied decode slot."""

    handle: _ContinuousBatchHandle
    row: int  # row index within the handle
    tok: int  # last emitted token (next decode input)
    pos: int  # its absolute position (== tokens fed so far)


class _ContinuousEngine:
    """Per-variant compiled entry points + slot bookkeeping."""

    def __init__(self, variant: Variant, geometry: ServingGeometry):
        cfg = variant.cfg
        if not T.supports_paged_decode(cfg):
            raise ValueError(
                f"variant {variant.name!r} cannot run on the continuous "
                "tier (needs a causal attention-only stack without kv "
                "quantization)"
            )
        self.variant = variant
        self.geometry = geometry
        g = geometry
        self.cache_mgr = BlockPagedSlotCache(
            g.n_slots, g.total_pages, g.page_size, g.pages_per_slot
        )
        self.pool = T.init_paged_cache(cfg, g.total_pages, g.page_size)
        self.slot_rt: Dict[int, _SlotRuntime] = {}
        self.warmed = False

        # The fixed-shape entry points.  ``prefill`` is one jit object whose
        # cache holds exactly one entry per ladder batch size after warmup;
        # ``decode`` is a single (n_slots)-shaped executable.  No request
        # shape outside the ladder ever reaches XLA.
        @jax.jit
        def prefill_fn(params, tokens, lengths):
            cache, logits = T.prefill_ragged(
                cfg, params, {"tokens": tokens}, lengths,
                max_len=g.prompt_width,
            )
            return cache, jnp.argmax(logits, -1).astype(jnp.int32)

        @jax.jit
        def graft_fn(pool, prefill_cache, tables):
            # Batched: all rows of the chunk graft in one dispatch (one
            # compile per ladder batch size, like prefill).  Padded rows
            # carry an all-trash table.
            return T.graft_prefill_batch(
                cfg, pool, prefill_cache, tables, g.page_size
            )

        @jax.jit
        def decode_fn(params, pool, tables, token, pos):
            logits, pool = T.paged_decode_step(
                cfg, params, pool, tables, token, pos, g.page_size
            )
            return jnp.argmax(logits, -1).astype(jnp.int32), pool

        self.prefill_fn = prefill_fn
        self.graft_fn = graft_fn
        self.decode_fn = decode_fn

    @property
    def compile_count(self) -> int:
        return sum(
            fn._cache_size()
            for fn in (self.prefill_fn, self.graft_fn, self.decode_fn)
        )


class ContinuousBatchingBackend(ExecutionBackend):
    """Cross-tick continuous batching behind fixed-shape compiled entries.

    The phase split: **prefill** runs out-of-band at submit time on one of
    the pre-compiled per-batch-size entry points (``bs_ladder`` powers of
    two, partial chunks padded with masked rows), the resulting KV state is
    **grafted** into a free slot of the block-paged pool, and the request
    then rides the single persistent fixed-shape **decode** executable —
    joining the in-flight batch at the next step boundary instead of
    waiting for it to finish.  Slots recycle the moment a row resolves
    (``n_steps`` reached, hedge win, cancel), so the decode batch composition
    changes every step while its *shape* never does: after :meth:`warmup`,
    zero recompiles (assert via :attr:`compile_count`).

    Dispatch modes: ``submit_batch(sync=True)`` drives the engine inline to
    completion; ``sync=False`` is **stepped** — prefill + graft happen at
    submit (stamping per-row TTFT), decode advances one step per
    :meth:`pump` call.  No worker threads: deterministic under CI, and the
    serving loop's ``poll()`` becomes the step clock.
    """

    # The serving loop skips its power-of-two row padding: submissions are
    # decomposed onto the bs ladder here, so loop-side padding would just
    # burn decode slots on phantom rows.
    pads_internally = True
    # Token-by-token decode: the loop may pass submit_batch an on_token
    # callback, fired per emitted token before the row resolves.
    supports_streaming = True

    def __init__(self, geometry: ServingGeometry = SERVING_GEOMETRY):
        super().__init__()
        self.geometry = geometry
        self._engines: Dict[str, _ContinuousEngine] = {}

    # -- registration / warmup ------------------------------------------------
    def register(self, v: Variant) -> None:
        self.variants[v.name] = v
        self._engines[v.name] = _ContinuousEngine(v, self.geometry)
        if self._obs is not None:
            self._engines[v.name].cache_mgr.attach_observability(
                self._obs, variant=v.name
            )

    def attach_observability(self, obs, track: Optional[str] = None) -> None:
        super().attach_observability(obs, track)
        # The slot ledger emits graft/free counters and free-capacity
        # gauges; engines registered later attach in register().
        for nm, eng in self._engines.items():
            eng.cache_mgr.attach_observability(obs, variant=nm)

    def warmup(self, name: Optional[str] = None) -> None:
        """Compile every fixed-shape entry point (idempotent).

        One prefill + graft per ladder batch size, one decode step.  After
        this, :attr:`compile_count` must never grow — the regression gate
        CI asserts."""
        names = [name] if name is not None else list(self._engines)
        for nm in names:
            eng = self._engines[nm]
            if eng.warmed:
                continue
            g = self.geometry
            params = eng.variant.params
            for N in g.bs_ladder:
                toks = jnp.zeros((N, g.prompt_width), jnp.int32)
                lens = jnp.full((N,), g.prompt_width, jnp.int32)
                pcache, _ = eng.prefill_fn(params, toks, lens)
                # Graft through all-trash tables: every write lands in the
                # reserved trash page, so live slots are untouched.
                trash_tables = jnp.zeros((N, g.pages_per_slot), jnp.int32)
                eng.pool = eng.graft_fn(eng.pool, pcache, trash_tables)
            tables = jnp.zeros(
                (g.n_slots, g.pages_per_slot), jnp.int32
            )
            token = jnp.zeros((g.n_slots,), jnp.int32)
            pos = jnp.zeros((g.n_slots,), jnp.int32)
            _, eng.pool = eng.decode_fn(params, eng.pool, tables, token, pos)
            jax.block_until_ready(eng.pool)
            eng.warmed = True

    @property
    def compile_count(self) -> int:
        """Total XLA executables across every fixed-shape entry point.

        Constant after :meth:`warmup` — the 'zero post-warmup recompiles'
        counter the bench and CI gate assert on."""
        return sum(e.compile_count for e in self._engines.values())

    @property
    def joined_total(self) -> int:
        """Requests grafted into the continuous batch (lifetime)."""
        return sum(e.cache_mgr.grafted_total for e in self._engines.values())

    @property
    def recycled_total(self) -> int:
        """Slots freed back to the pool (lifetime, all release reasons)."""
        return sum(e.cache_mgr.freed_total for e in self._engines.values())

    def slot_stats(self, name: str) -> Dict[str, int]:
        return self._engines[name].cache_mgr.stats()

    def check_conservation(self) -> None:
        for eng in self._engines.values():
            eng.cache_mgr.check_conservation()

    # -- submission -----------------------------------------------------------
    def _ladder_chunks(self, n: int):
        """Decompose ``n`` rows into ladder batch sizes (largest-first).

        Remainders below the smallest rung are padded up to it with masked
        rows — never a new shape."""
        ladder = self.geometry.bs_ladder
        out = []
        left = n
        while left > 0:
            fit = [N for N in ladder if N <= left]
            N = max(fit) if fit else ladder[0]
            out.append((N, min(N, left)))  # (padded size, real rows)
            left -= min(N, left)
        return out

    def _acquire_slot(self, eng: _ContinuousEngine, prompt_len: int,
                      n_steps: int):
        """Claim a slot + pages, pumping the decode loop until one frees."""
        while True:
            try:
                return eng.cache_mgr.begin_prefill(prompt_len, n_steps)
            except NoFreeSlot:
                if not eng.slot_rt:
                    raise  # nothing in flight can ever free capacity
                self._pump_engine(eng)

    def submit_batch(
        self, name, batch, n_steps, *, sync: bool = False, on_token=None
    ):
        """Join ``batch`` rows into the continuous decode batch.

        ``sync=True`` runs the engine inline until every row completes.
        ``sync=False`` ('stepped'): prefill + graft happen now — TTFT is
        paid immediately, not at batch end — and decode advances via
        :meth:`pump` (the serving loop's ``poll()`` drives it).

        ``on_token(row, token, wall_ms)`` fires per emitted token — the
        first token at graft (the same wall stamp as ``ttft_wall_ms``),
        every later token from the decode pump — always *before* the row
        completes, under both dispatch modes."""
        g = self.geometry
        eng = self._engines[name]
        batch = np.asarray(batch, dtype=np.int32)
        B, S = batch.shape
        if S > g.prompt_width:
            raise ValueError(
                f"prompt width {S} exceeds ServingGeometry.prompt_width "
                f"({g.prompt_width})"
            )
        n_steps = int(n_steps)
        if n_steps > g.max_steps:
            raise ValueError(
                f"n_steps {n_steps} exceeds ServingGeometry.max_steps "
                f"({g.max_steps})"
            )
        self.warmup(name)
        self._note_dispatch(B)
        handle = _ContinuousBatchHandle(self, name, B, max(n_steps, 0))
        handle.on_token = on_token
        if n_steps <= 0:
            for i in range(B):
                handle.done_rows[i] = True
            self._finalize_handle(handle)
            return handle

        params = eng.variant.params
        wide = np.zeros((B, g.prompt_width), dtype=np.int32)
        wide[:, :S] = batch
        row0 = 0
        for N, n_real in self._ladder_chunks(B):
            chunk = np.zeros((N, g.prompt_width), dtype=np.int32)
            chunk[:n_real] = wide[row0 : row0 + n_real]
            lengths = np.full((N,), S, dtype=np.int32)
            slots = [
                self._acquire_slot(eng, S, n_steps) for _ in range(n_real)
            ]
            pcache, first = eng.prefill_fn(
                params, jnp.asarray(chunk), jnp.asarray(lengths)
            )
            first = np.asarray(first)
            # One batched graft for the whole chunk: real rows through
            # their slots' tables, padded rows through all-trash tables.
            tables = np.zeros((N, g.pages_per_slot), dtype=np.int32)
            for r, slot in enumerate(slots):
                tables[r] = eng.cache_mgr.page_table(slot.index)
            eng.pool = eng.graft_fn(eng.pool, pcache, jnp.asarray(tables))
            for r, slot in enumerate(slots):
                row = row0 + r
                eng.cache_mgr.commit_graft(slot.index)
                tok = int(first[r])
                # One wall stamp for both the TTFT accounting and the
                # streamed chunk: first_chunk.wall_ms - dispatch == ttft.
                now_wall = time.perf_counter() * 1e3
                handle.emitted[row].append(tok)
                handle.ttft_wall_ms[row] = now_wall - handle.dispatch_wall_ms
                if self._obs is not None:
                    self._obs.histogram(
                        "continuous_ttft_ms", variant=name
                    ).record(handle.ttft_wall_ms[row])
                    self._obs.tracer.instant(
                        "graft",
                        parent=self._obs.tracer.ambient_id(),
                        cat="continuous",
                        track=self._obs_track,
                        t_ms=now_wall,
                        variant=name,
                        slot=slot.index,
                    )
                if handle.on_token is not None:
                    handle.on_token(row, tok, now_wall)
                if n_steps == 1:
                    eng.slot_rt[slot.index] = _SlotRuntime(handle, row, tok, S)
                    self._retire_slot(eng, slot.index, "resolved")
                else:
                    handle.row_slots[row] = slot.index
                    eng.slot_rt[slot.index] = _SlotRuntime(handle, row, tok, S)
            row0 += n_real
        if sync:
            handle.wait()
        return handle

    # -- the decode loop ------------------------------------------------------
    def pump(self, name: Optional[str] = None) -> bool:
        """Advance the persistent decode batch one step boundary.

        Returns True if any engine had active slots to step.  This is the
        continuous tier's clock: the serving loop calls it from ``poll()``,
        and :meth:`_ContinuousBatchHandle.wait` spins it."""
        engines = (
            [self._engines[name]] if name is not None
            else list(self._engines.values())
        )
        advanced = False
        for eng in engines:
            advanced |= self._pump_engine(eng)
        return advanced

    def _pump_engine(self, eng: _ContinuousEngine) -> bool:
        if not eng.slot_rt:
            return False
        g = self.geometry
        token = np.zeros((g.n_slots,), dtype=np.int32)
        pos = np.zeros((g.n_slots,), dtype=np.int32)
        for s, rt in eng.slot_rt.items():
            token[s] = rt.tok
            pos[s] = rt.pos
        tables = eng.cache_mgr.page_tables()
        next_tok, eng.pool = eng.decode_fn(
            eng.variant.params,
            eng.pool,
            jnp.asarray(tables),
            jnp.asarray(token),
            jnp.asarray(pos),
        )
        next_tok = np.asarray(next_tok)
        now_wall = time.perf_counter() * 1e3
        for s in list(eng.slot_rt):
            rt = eng.slot_rt[s]
            rt.tok = int(next_tok[s])
            rt.pos += 1
            rt.handle.emitted[rt.row].append(rt.tok)
            if rt.handle.on_token is not None:
                rt.handle.on_token(rt.row, rt.tok, now_wall)
            if len(rt.handle.emitted[rt.row]) >= rt.handle.n_steps:
                self._retire_slot(eng, s, "resolved")
        return True

    # -- retirement / early release -------------------------------------------
    def _retire_slot(self, eng: _ContinuousEngine, slot: int,
                     reason: str) -> None:
        rt = eng.slot_rt.pop(slot)
        eng.cache_mgr.release(slot, reason)
        rt.handle.row_slots[rt.row] = None
        rt.handle.done_rows[rt.row] = True
        if rt.handle.all_done:
            self._finalize_handle(rt.handle)

    def _release_handle_rows(self, handle: _ContinuousBatchHandle, rows,
                             reason: str) -> None:
        eng = self._engines[handle.name]
        for row in rows:
            if handle.done_rows[row]:
                continue
            slot = handle.row_slots[row]
            handle.released_rows[row] = reason
            if slot is not None:
                self._retire_slot(eng, slot, reason)
            else:
                handle.done_rows[row] = True
                if handle.all_done:
                    self._finalize_handle(handle)

    def _finalize_handle(self, handle: _ContinuousBatchHandle) -> None:
        if handle._wall_ms is not None:
            return
        handle.done_wall_ms = time.perf_counter() * 1e3
        handle._wall_ms = handle.done_wall_ms - handle.dispatch_wall_ms
        self._note_done(handle.n_rows, handle._wall_ms)

    # -- ExecutionBackend protocol --------------------------------------------
    def generate(self, name, tokens, n_steps):
        handle = self.submit_batch(name, tokens, n_steps, sync=True)
        return handle.result(), handle._wall_ms

    def run_batch(self, name, batch, n_steps):
        # Fixed-shape entries make the base per-(shape, n_steps) warm-once
        # bookkeeping unnecessary: one warmup covers every request shape.
        self.warmup(name)
        return self.generate(name, batch, n_steps)
