"""Event-loop serving front: admission → decide → dispatch → resolve ticks.

:class:`ServingLoop` is the middle layer of the three-layer serving stack
(client / loop / backend).  Requests are *submitted* (admission — they
become :class:`repro.serving.lifecycle.InferenceFuture` objects in QUEUED
state) and served by *ticks*: one tick schedules the pending chunk with a
single ``decide_batch`` call, dispatches every variant group — and the
hedged rows' on-device duplicate — through the async
:meth:`repro.serving.backend.ExecutionBackend.submit_batch` protocol, then
collects, observes, and resolves.

Admission is a first-class, capacity-bounded stage
(:class:`repro.serving.admission.AdmissionQueue`): ``max_pending`` bounds
the persistent multi-tick queue, ``max_chunk`` caps how much one tick may
take (a burst no longer inflates a single batch without limit), and
``max_inflight_ticks`` gates ``wait=False`` dispatch.  At capacity the
overload policy decides: ``block`` (client-side backpressure — futures
wait un-admitted), ``shed`` (deadline-aware REJECTED resolution), or
``degrade`` (overflow served by the on-device tier alone, no remote leg).
The default is the unbounded compatibility behavior: every tick drains
everything, byte-identical to the pre-admission loop.

Because *all* batches of a tick are submitted before any is waited on, the
remote batch and the on-device duplicate genuinely run concurrently
(``dispatch="async"``, worker threads): ``resolve_chunk`` races
first-completion wall times measured over the same interval, instead of
two serialized measurements.  Both tiers' race clocks start at the
dispatch tick — the queue wait is charged to each exactly once
(previously the duplicate's wall clock silently started after the remote
batch finished; see ``TickStats`` for the overlap evidence).

``dispatch="sync"`` is the serialized fallback: ``submit_batch`` executes
inline, keeping CI runs and the equivalence references deterministic.
:meth:`ServingEngine.serve_queue <repro.serving.engine.ServingEngine.serve_queue>`
is a thin shim over one sync-collected tick of this loop.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sla import RequestMetrics, summarize
from repro.serving.admission import AdmissionConfig, AdmissionQueue
from repro.serving.backend import BatchHandle, ExecutionBackend, OnDeviceBackend
from repro.serving.cluster import NoHealthyReplica
from repro.serving.transport import (
    FailedBatchHandle,
    ReplicaDied,
    TransportError,
)
from repro.serving.lifecycle import (
    CompletedRequest,
    InferenceFuture,
    QueuedRequest,
    RequestState,
)
from repro.serving.loadgen import LoadTrace, iter_windows
from repro.serving.scheduler import pad_to_pow2
from repro.serving.tenancy import DEFAULT_TENANT

__all__ = ["ServingLoop", "TickResult", "TickStats"]

_DEGRADE_EXEC_FLOOR_MS = 0.1  # matches the scheduler's sampled-exec floor


def _pad_batch(requests, rows_idx, pad_rows: bool = True) -> Tuple[np.ndarray, int]:
    """Right-pad a group's prompts into one (pow2-rows, width) batch.

    ``pad_rows=False`` skips the power-of-two row padding — the
    continuous-batching backend decomposes row counts onto its own ladder
    internally, so loop-side padding would just burn decode slots."""
    width = max(len(requests[i].tokens) for i in rows_idx)
    n_rows = pad_to_pow2(len(rows_idx)) if pad_rows else len(rows_idx)
    batch = np.zeros((n_rows, width), dtype=np.int32)
    for row, i in enumerate(rows_idx):
        t = np.asarray(requests[i].tokens, dtype=np.int32)
        batch[row, : len(t)] = t
    steps = max(requests[i].n_steps for i in rows_idx)
    return batch, steps


def _replica_array(completions) -> np.ndarray:
    """Per-completion cluster replica ids for summarize (-1: unrouted —
    single-backend rows and degrade-lane rows; a hedged row that lost the
    race still carries the replica that ran its remote leg)."""
    return np.asarray(
        [-1 if c.replica is None else c.replica for c in completions],
        dtype=np.int64,
    )


def _replica_inflight_array(completions) -> np.ndarray:
    return np.asarray(
        [
            0 if c.replica_inflight is None else c.replica_inflight
            for c in completions
        ],
        dtype=np.int64,
    )


def _tenant_array(completions) -> np.ndarray:
    """Per-completion tenant lane names for summarize (None: untagged)."""
    return np.asarray([c.tenant for c in completions], dtype=object)


def _priority_array(completions) -> np.ndarray:
    return np.asarray([c.priority for c in completions], dtype=object)


def _make_stream_cb(batch: List[InferenceFuture], part: np.ndarray):
    """Per-group token callback: backend row index -> that row's future.

    The group's batch rows are exactly ``part``'s futures (streaming
    backends pad internally, so no phantom rows exist); a guard keeps a
    misbehaving backend from indexing past the group.
    """
    futures = [batch[int(i)] for i in part]

    def on_token(row: int, token: int, wall_ms: float) -> None:
        if 0 <= row < len(futures):
            futures[row]._push_chunk(token, wall_ms)

    return on_token


def _rejected_tenant_counts(shed_info, default_lane: bool) -> Dict[str, int]:
    """Fold per-shed (tenant, priority) pairs into lane -> reject counts.

    Untagged sheds are charged to the implicit ``"default"`` lane only
    when tenancy is configured (``default_lane``) — an untenanted,
    untagged front keeps producing metrics with no tenant rows at all.
    """
    counts: Dict[str, int] = {}
    for tenant, _ in shed_info:
        if tenant is None:
            if not default_lane:
                continue
            tenant = DEFAULT_TENANT
        counts[tenant] = counts.get(tenant, 0) + 1
    return counts


@dataclasses.dataclass
class TickStats:
    """Wall-clock evidence of one tick's dispatch behavior.

    ``span_wall_ms`` (first dispatch → last completion) versus
    ``serialized_wall_ms`` (sum of the tiers' individual wall times) is the
    overlap witness: async dispatch gives ``span < serialized`` on any
    hedged tick, a serialized tick gives ``span ≈ serialized``.
    """

    n_requests: int
    n_hedged: int
    remote_wall_ms: float  # sum of the remote variant batches' wall times
    hedge_wall_ms: Optional[float]  # duplicate batch wall time (measured)
    span_wall_ms: float  # first dispatch -> last batch completion
    dispatch_spread_wall_ms: float  # max - min dispatch stamp across tiers
    hedge_dispatched_before_remote_done: Optional[bool]
    n_shed: int = 0  # rejected by admission at this tick (shed policy)
    n_degraded: int = 0  # served on-device-only at this tick (degrade policy)
    # Fault accounting: rows whose remote batch was lost to a replica
    # failure this tick, and how many of those went back to admission
    # (the rest resolved through their measured hedge duplicate).
    n_lost: int = 0
    n_requeued: int = 0
    # Rows dispatched per cluster replica this tick (empty: unclustered
    # backend — every remote row then counts as one replica's work).
    replica_rows: Dict[int, int] = dataclasses.field(default_factory=dict)
    # Continuous-batching accounting (zero on classic whole-batch tiers):
    # requests grafted into the persistent decode batch since the last
    # collection, slots recycled back to the pool since the last
    # collection, and the backend's *absolute* compiled-executable count —
    # constant after warmup is the zero-recompile invariant CI gates on.
    n_joined: int = 0
    n_recycled: int = 0
    compile_count: int = 0

    @property
    def serialized_wall_ms(self) -> float:
        return self.remote_wall_ms + (self.hedge_wall_ms or 0.0)

    @property
    def hedge_rows(self) -> int:
        """Live rows in the measured duplicate batch (0: no hedge tier)."""
        return self.n_hedged if self.hedge_wall_ms is not None else 0

    @property
    def max_replica_rows(self) -> int:
        """Rows on the tick's busiest replica — the parallel-server
        makespan unit a service model should charge (falls back to the
        whole tick's rows on an unclustered backend)."""
        return (
            max(self.replica_rows.values())
            if self.replica_rows
            else self.n_requests
        )


@dataclasses.dataclass
class TickResult:
    """Outcome of one scheduling tick."""

    completions: List[CompletedRequest]  # resolved, submission order
    metrics: Optional[RequestMetrics]  # None for an empty / all-cancelled tick
    stats: TickStats


@dataclasses.dataclass
class _InflightTick:
    """A dispatched-but-uncollected tick (async mode can carry these)."""

    futures: List[InferenceFuture]
    requests: List[QueuedRequest]
    decision: object  # BatchDecision, or None for a degrade-only tick
    queue_wait: np.ndarray
    t_sla: object  # scalar or (n,) vector raced at resolution
    now_ms: float
    groups: List[Tuple[int, np.ndarray, BatchHandle]]  # (model, rows, handle)
    row_handles: List[BatchHandle]  # request index -> its remote handle
    hedged_rows: np.ndarray
    hedge_handle: Optional[BatchHandle]
    # Overload-degraded rows: served by the on-device tier alone.
    degraded_futures: List[InferenceFuture] = dataclasses.field(
        default_factory=list
    )
    degrade_queue_wait: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0)
    )
    degrade_handle: Optional[BatchHandle] = None
    n_shed: int = 0
    # (tenant lane, priority class) of each request shed at this tick —
    # per-tenant rejection accounting for summarize.
    shed_info: List[Tuple[Optional[str], str]] = dataclasses.field(
        default_factory=list
    )
    # Observability (all None/empty with tracing off): the tick span, the
    # per-group batch spans (index-aligned with ``groups``), and the
    # hedge / degrade batch spans — opened at dispatch, closed at collect.
    tick_span: object = None
    group_spans: List[object] = dataclasses.field(default_factory=list)
    hedge_span: object = None
    degrade_span: object = None

    def poll(self) -> bool:
        handles = [h for _, _, h in self.groups]
        for h in (self.hedge_handle, self.degrade_handle):
            if h is not None:
                handles.append(h)
        return all(h.poll() for h in handles)


class ServingLoop:
    """Admission → ``decide_batch`` → concurrent dispatch → resolution.

    Parameters
    ----------
    scheduler:
        The policy half (:class:`repro.serving.scheduler.MDInferenceScheduler`).
    backend:
        The remote tier.
    hedge_backend:
        Optional on-device tier; without it hedges resolve on profile
        samples (the simulation reference).
    dispatch:
        ``"async"`` (worker threads, tiers overlap — the default) or
        ``"sync"`` (inline execution, deterministic serialized fallback).
    admission:
        An :class:`repro.serving.admission.AdmissionConfig` (or a prebuilt
        :class:`~repro.serving.admission.AdmissionQueue`).  ``None`` is the
        unbounded compatibility default — every submit admitted, every
        tick drains everything.
    controller:
        An optional :class:`repro.serving.controller.AdmissionController`
        closing the loop over the admission queue: each collected tick is
        observed, and due retunes (bounded AIMD over ``max_pending`` /
        ``shed_headroom_ms``) are applied at the top of the next tick
        before admission take.  ``None`` — the default — keeps the static
        config byte-identical to the pre-controller loop
        (regression-pinned).
    observability:
        An optional :class:`repro.observability.Observability` handle.
        The loop is the fan-out point: it attaches the handle to the
        admission queue, controller, scheduler, both backend tiers (a
        cluster propagates to every replica's breaker and transport), and
        instruments its own tick/dispatch/collect path — request span
        trees, tick and batch spans, and the loop's counters/histograms.
        ``None`` — the default — keeps every layer on its exact
        pre-observability path (regression-pinned byte identity).
    """

    def __init__(
        self,
        scheduler,
        backend: ExecutionBackend,
        hedge_backend: Optional[OnDeviceBackend] = None,
        *,
        dispatch: str = "async",
        admission: Optional[AdmissionConfig | AdmissionQueue] = None,
        controller=None,
        observability=None,
    ):
        if dispatch not in ("async", "sync", "stepped"):
            raise ValueError(
                "dispatch must be 'async', 'sync' or 'stepped', "
                f"got {dispatch!r}"
            )
        self.scheduler = scheduler
        self.backend = backend
        self.hedge_backend = hedge_backend
        self.dispatch = dispatch
        self.now_ms = 0.0
        # Continuous-batching counters seen at the last collection (for the
        # per-tick n_joined / n_recycled deltas in TickStats).
        self._joined_seen = getattr(backend, "joined_total", 0)
        self._recycled_seen = getattr(backend, "recycled_total", 0)
        if admission is None:
            admission = AdmissionConfig()
        self.admission = (
            admission
            if isinstance(admission, AdmissionQueue)
            else AdmissionQueue(admission)
        )
        self.controller = controller
        self._inflight: List[_InflightTick] = []
        self._rid = itertools.count()
        self.observability = None
        if observability is not None:
            self.attach_observability(observability)

    def attach_observability(self, obs) -> None:
        """Thread one observability handle through the whole stack.

        The loop owns the fan-out so callers attach exactly once: the
        admission queue (and through it the tenant lanes), the controller,
        the scheduler's EWMA gauges, and both backend tiers — a clustered
        remote tier forwards to each replica's breaker and transport, a
        continuous tier to its slot-cache ledger.
        """
        self.observability = obs
        self.admission.attach_observability(obs)
        self.scheduler.observability = obs
        if self.controller is not None:
            self.controller.observability = obs
        for tier, track in (
            (self.backend, "remote"),
            (self.hedge_backend, "ondevice"),
        ):
            attach = getattr(tier, "attach_observability", None)
            if attach is not None:
                attach(obs, track=track)

    # -- admission ------------------------------------------------------------
    def next_rid(self) -> int:
        return next(self._rid)

    def submit(self, request: QueuedRequest) -> InferenceFuture:
        """Submit a request to the admission queue.

        Under the unbounded default the future is admitted immediately and
        waits QUEUED for the next tick.  A bounded queue at capacity
        applies its overload policy instead: the future may come back
        not-yet-admitted (``block`` — check
        :attr:`~repro.serving.lifecycle.InferenceFuture.admitted`), already
        REJECTED (``shed``), or routed to the on-device-only degrade lane.
        """
        future = InferenceFuture(request, loop=self)
        obs = self.observability
        if obs is not None:
            tracer = obs.tracer
            track = (
                f"tenant:{request.tenant}"
                if request.tenant is not None
                else "requests"
            )
            future._tracer = tracer
            future.span = tracer.start(
                "request",
                cat="request",
                track=track,
                rid=request.rid,
                tenant=request.tenant,
                arrival_ms=request.arrival_ms,
            )
            future._queued_span = tracer.start(
                "queued", parent=future.span, cat="request", track=track
            )
            obs.counter("loop_submitted_total").inc()
        self.admission.offer(future)
        return future

    @property
    def pending(self) -> int:
        """Admitted requests waiting for a tick (≤ ``max_pending``)."""
        return self.admission.pending

    @property
    def blocked(self) -> int:
        """Backpressured requests waiting un-admitted (block policy)."""
        return self.admission.blocked

    @property
    def backlog(self) -> int:
        """Everything waiting for a tick across all admission lanes."""
        return self.admission.backlog

    @property
    def inflight(self) -> int:
        return sum(
            len(t.futures) + len(t.degraded_futures) for t in self._inflight
        )

    def _usage_names(self) -> List[str]:
        """Model-usage key space: the remote zoo plus the on-device tier
        (degraded completions are attributed to the duplicate)."""
        return list(self.scheduler.names) + [self.scheduler.ondevice.name]

    # -- cluster integration (inert on a single unclustered backend) ----------
    def _eligible_mask(self) -> Optional[np.ndarray]:
        """Selection-eligibility mask from the backend's zoo placement.

        A cluster backend with partial zoo slices exposes ``hosted_mask``:
        variants no live replica hosts are masked out of selection, so
        routing never has to place a row on a replica that doesn't host
        its variant.  Plain backends return ``None`` — the unmasked path,
        preserving the pre-cluster behavior bit-for-bit.
        """
        hosted = getattr(self.backend, "hosted_mask", None)
        if hosted is None:
            return None
        return hosted(self.scheduler.names)

    def _fan_out(self, name: str, rows: np.ndarray) -> List[np.ndarray]:
        """Split one variant group across the backend's replica fan-out.

        A cluster backend reports ``fan_out(name)`` (its hosting replica
        count); the group is split into that many near-equal row slices,
        each routed independently — the per-replica fan-out within one
        tick.  Plain backends (and one-replica pools) keep the single
        undivided batch, byte-identical to the pre-cluster dispatch.
        """
        fan = getattr(self.backend, "fan_out", None)
        k = 1 if fan is None else max(1, min(int(fan(name)), len(rows)))
        if k == 1:
            return [rows]
        return [part for part in np.array_split(rows, k) if part.size]

    # -- the event loop -------------------------------------------------------
    def tick(
        self, now_ms: Optional[float] = None, *, wait: bool = True
    ) -> Optional[TickResult]:
        """Run one scheduling tick over the pending chunk.

        ``now_ms`` is the tick's loop-clock timestamp (e.g. the close of an
        arrival window); it defaults to the chunk's latest arrival.  With
        ``wait=True`` the tick's batches are collected and resolved before
        returning (the continuous-batching semantics of the old
        ``serve_queue``).  ``wait=False`` returns ``None`` right after
        dispatch — futures stay EXECUTING and are resolved by a later
        :meth:`poll` / :meth:`drain` (the genuinely-async event loop).

        A bounded admission queue shapes what one tick may take: at most
        ``max_chunk`` requests (the rest stay queued across ticks), no new
        dispatch while ``max_inflight_ticks`` are in flight, and the shed /
        degrade overload policies resolve or reroute the overflow.  A tick
        that *only* sheds (every schedulable request rejected) returns its
        :class:`TickResult` immediately even with ``wait=False`` — there
        is nothing in flight to poll for, but the shed accounting
        (``stats.n_shed``, ``metrics.n_rejected``) must reach observers.
        """
        cfg = self.admission.cfg
        if (
            cfg.max_inflight_ticks is not None
            and len(self._inflight) >= cfg.max_inflight_ticks
        ):
            return None  # dispatch gate: requests stay queued for later
        # Closed-loop adaptivity: enact any retune the controller owes
        # from the last collected tick *before* this tick's admission
        # take, so the new capacity/margin govern this tick's offers and
        # sheds.  Inert (byte-identical path) without a controller.
        if self.controller is not None:
            self.controller.apply(self.admission)
        # The admission queue hands one tick's work over atomically: a
        # submit() racing this tick from another thread lands in either
        # this chunk or a later one, never vanishes.
        take = self.admission.take(
            now_ms,
            default_sla_ms=self.scheduler.cfg.t_sla_ms,
            # Cheapest remote execution; the shed predicate also considers
            # the network-free on-device duplicate — on a bad network the
            # hedge is exactly what still attains the SLA.
            service_floor_ms=float(np.min(self.scheduler.mu)),
            ondevice_floor_ms=float(self.scheduler.ondevice_mu),
        )
        if not take and not take.shed:
            return None
        now_ms = take.now_ms
        self.now_ms = max(self.now_ms, now_ms)
        obs = self.observability
        tick_span = None
        if obs is not None:
            tick_span = obs.tracer.start(
                "tick",
                cat="loop",
                track="loop",
                now_ms=now_ms,
                n_taken=len(take.chunk),
                n_degraded=len(take.degraded),
                n_shed=len(take.shed),
            )
        # Feed the loop clock to a clustered backend: breaker cooldowns,
        # drain state, and the hosted mask are all evaluated at tick time,
        # so membership transitions are visible the same tick they happen.
        advance = getattr(self.backend, "advance_clock", None)
        if advance is not None:
            advance(self.now_ms)
        # Atomic QUEUED -> SCHEDULED claim: a cancel() racing this tick from
        # another thread loses its slot here, never in a dispatched batch.
        batch = [f for f in take.chunk if f._try_schedule(now_ms)]
        degraded = [f for f in take.degraded if f._try_schedule(now_ms)]
        # Whole-pool outage: when no variant has a routable replica (every
        # hosting replica dead/draining), decide_batch has nothing to
        # select — divert the entire chunk to the on-device degrade lane
        # instead of crashing the tick.  Partial outages flow through
        # decide_batch's eligibility masking as usual.
        eligible = self._eligible_mask()
        if batch and eligible is not None and not eligible.any():
            degraded.extend(batch)
            batch = []
        if not batch and not degraded:
            if take.shed:  # all-shed tick: surface the rejection accounting
                return self._collect(
                    _InflightTick(
                        futures=[], requests=[], decision=None,
                        queue_wait=np.zeros(0), t_sla=self.scheduler.cfg.t_sla_ms,
                        now_ms=now_ms, groups=[], row_handles=[],
                        hedged_rows=np.zeros(0, dtype=np.int64),
                        hedge_handle=None, n_shed=len(take.shed),
                        shed_info=[
                            (f.request.tenant, f.priority) for f in take.shed
                        ],
                        tick_span=tick_span,
                    )
                )
            if tick_span is not None:
                obs.tracer.end(tick_span)
            return None
        # Dispatch modes: "sync" runs everything inline; "async" overlaps
        # tiers on worker threads; "stepped" is the continuous-batching
        # mode — remote rows join the persistent decode batch (prefill +
        # graft at submit, decode advanced by poll()'s pump), thread-free
        # and deterministic, while the hedge tier stays inline.
        sync = self.dispatch == "sync"
        hedge_sync = self.dispatch in ("sync", "stepped")

        decision = None
        t_sla: object = self.scheduler.cfg.t_sla_ms
        queue_wait = np.zeros(len(batch))
        groups: List[Tuple[int, np.ndarray, BatchHandle]] = []
        group_spans: List[object] = []
        hedge_span = None
        row_handles: List[Optional[BatchHandle]] = [None] * len(batch)
        hedged_rows = np.zeros(0, dtype=np.int64)
        hedge_handle: Optional[BatchHandle] = None
        requests = [f.request for f in batch]
        if batch:
            arrivals = np.asarray([r.arrival_ms for r in requests])
            queue_wait = np.maximum(now_ms - arrivals, 0.0)

            # Per-request SLA: selection budgets come from t_sla - est - wait,
            # expressed as an effective estimate offset against the loop SLA.
            loop_sla = self.scheduler.cfg.t_sla_ms
            slas = np.asarray(
                [
                    loop_sla if r.sla_ms is None else float(r.sla_ms)
                    for r in requests
                ]
            )
            t_sla = slas if np.any(slas != loop_sla) else loop_sla
            est = np.asarray([r.t_nw_est_ms for r in requests])
            decision = self.scheduler.decide_batch(
                est + queue_wait + (loop_sla - slas),
                eligible=eligible,
            )

            # Dispatch every batch of the tick before waiting on any of
            # them: the remote variant groups and the hedged rows'
            # duplicate all start at this tick — the shared origin of both
            # race clocks.  A cluster backend fans each variant group out
            # across its hosting replicas (one routed sub-batch per
            # replica the group can spread over), so several replicas run
            # concurrently within one tick.
            pad_rows = not getattr(self.backend, "pads_internally", False)
            streaming = getattr(self.backend, "supports_streaming", False)
            for m in np.unique(decision.model_index):
                rows = np.flatnonzero(decision.model_index == m)
                name = self.scheduler.names[int(m)]
                for part in self._fan_out(name, rows):
                    gbatch, steps = _pad_batch(requests, part, pad_rows=pad_rows)
                    # Streaming tier: route each backend row's emitted
                    # tokens onto its future's chunk channel.  Only passed
                    # to backends advertising supports_streaming, so the
                    # cluster/transport submit_batch signatures are
                    # untouched.
                    kwargs = (
                        {"on_token": _make_stream_cb(batch, part)}
                        if streaming
                        else {}
                    )
                    gspan = None
                    if obs is not None:
                        gspan = obs.tracer.start(
                            f"batch:{name}",
                            parent=tick_span,
                            cat="dispatch",
                            variant=name,
                            rows=int(part.size),
                        )
                    try:
                        # The group span is the ambient parent during
                        # submit so transport/backend spans nest under it
                        # even across the async path's worker thread.
                        if gspan is not None:
                            with obs.tracer.bind(gspan):
                                handle = self.backend.submit_batch(
                                    name, gbatch, steps, sync=sync, **kwargs
                                )
                        else:
                            handle = self.backend.submit_batch(
                                name, gbatch, steps, sync=sync, **kwargs
                            )
                    except NoHealthyReplica as e:
                        # The eligible mask was computed at the top of the
                        # tick; a same-tick health transition (e.g. the
                        # sole hosting replica's half-open probe already
                        # claimed) can still empty the routable set here.
                        # The rows are handled like any lost batch at
                        # collection (hedge failover or requeue).
                        handle = FailedBatchHandle(
                            name, int(gbatch.shape[0]), e
                        )
                        if gspan is not None:
                            gspan.args["error"] = "no_healthy_replica"
                    if gspan is not None:
                        replica = getattr(handle, "replica", None)
                        if replica is not None:
                            gspan.track = f"replica:{replica}"
                            gspan.args["replica"] = replica
                    groups.append((int(m), part, handle))
                    group_spans.append(gspan)
                    for i in part:
                        row_handles[i] = handle

            hedged_rows = np.flatnonzero(decision.hedged)
            if self.hedge_backend is not None and hedged_rows.size > 0:
                hbatch, hsteps = _pad_batch(requests, hedged_rows)
                if obs is not None:
                    hedge_span = obs.tracer.start(
                        "batch:hedge",
                        parent=tick_span,
                        cat="dispatch",
                        track="ondevice",
                        rows=int(hedged_rows.size),
                    )
                    with obs.tracer.bind(hedge_span):
                        hedge_handle = self.hedge_backend.submit_hedge(
                            hbatch, hsteps, sync=hedge_sync
                        )
                else:
                    hedge_handle = self.hedge_backend.submit_hedge(
                        hbatch, hsteps, sync=hedge_sync
                    )

        # Overload-degraded rows: the on-device tier alone answers — no
        # remote leg, no hedge race.  Without a hedge backend the duplicate
        # is simulated from the live on-device profile at collection.
        degrade_handle: Optional[BatchHandle] = None
        degrade_span = None
        degrade_queue_wait = np.zeros(len(degraded))
        if degraded:
            dreqs = [f.request for f in degraded]
            degrade_queue_wait = np.maximum(
                now_ms - np.asarray([r.arrival_ms for r in dreqs]), 0.0
            )
            if self.hedge_backend is not None:
                dbatch, dsteps = _pad_batch(dreqs, range(len(dreqs)))
                if obs is not None:
                    degrade_span = obs.tracer.start(
                        "batch:degrade",
                        parent=tick_span,
                        cat="dispatch",
                        track="ondevice",
                        rows=len(degraded),
                    )
                    with obs.tracer.bind(degrade_span):
                        degrade_handle = self.hedge_backend.submit_hedge(
                            dbatch, dsteps, sync=hedge_sync
                        )
                else:
                    degrade_handle = self.hedge_backend.submit_hedge(
                        dbatch, dsteps, sync=hedge_sync
                    )

        for i, f in enumerate(batch):
            tiers = {"remote": row_handles[i].dispatch_wall_ms}
            if hedge_handle is not None and decision.hedged[i]:
                tiers["ondevice"] = hedge_handle.dispatch_wall_ms
            f._mark_executing(tiers)
        for f in degraded:
            f._mark_executing(
                {}
                if degrade_handle is None
                else {"ondevice": degrade_handle.dispatch_wall_ms}
            )

        tick = _InflightTick(
            futures=batch,
            requests=requests,
            decision=decision,
            queue_wait=queue_wait,
            t_sla=t_sla,
            now_ms=now_ms,
            groups=groups,
            row_handles=row_handles,
            hedged_rows=hedged_rows,
            hedge_handle=hedge_handle,
            degraded_futures=degraded,
            degrade_queue_wait=degrade_queue_wait,
            degrade_handle=degrade_handle,
            n_shed=len(take.shed),
            shed_info=[(f.request.tenant, f.priority) for f in take.shed],
            tick_span=tick_span,
            group_spans=group_spans,
            hedge_span=hedge_span,
            degrade_span=degrade_span,
        )
        if not wait:
            self._inflight.append(tick)
            return None
        return self._collect(tick)

    def poll(self) -> List[TickResult]:
        """Resolve every in-flight tick whose batches all finished.

        Never blocks.  On a continuous-batching backend this is also the
        decode clock: each poll advances the persistent decode batch one
        step boundary (``pump``), then releases the slots of hedged rows
        whose race the duplicate has already won — their pages go back to
        the pool *now*, not at batch end.
        """
        pump = getattr(self.backend, "pump", None)
        if pump is not None:
            pump()
        for t in self._inflight:
            self._release_hedge_wins(t)
        # Evaluate poll() once per tick: a batch finishing between two
        # evaluations must land in exactly one of the two lists.
        ready = {id(t): t.poll() for t in self._inflight}
        done = [t for t in self._inflight if ready[id(t)]]
        self._inflight = [t for t in self._inflight if not ready[id(t)]]
        return [self._collect(t) for t in done]

    def _release_hedge_wins(self, tick: _InflightTick) -> None:
        """Recycle slots of hedged rows whose race is already decided.

        Once the on-device duplicate has finished, a hedged row still
        decoding remotely whose elapsed wall time has exhausted its SLA
        budget (``t_sla - queue_wait - t_nw``) can never resolve remote-won
        — the duplication rule (:func:`repro.core.duplication.resolve_duplication`)
        will pick the duplicate regardless of when the remote leg lands.
        Releasing the slot *now* frees its pages for the next join instead
        of carrying a dead row to ``n_steps``.  Inert on handles without
        per-row release (the classic whole-batch tiers)."""
        if tick.hedge_handle is None or not tick.hedge_handle.poll():
            return
        if tick.decision is None:
            return
        now_wall = time.perf_counter() * 1e3
        for _, rows, handle in tick.groups:
            release = getattr(handle, "release_rows", None)
            if release is None:
                continue
            elapsed = now_wall - handle.dispatch_wall_ms
            stale = []
            for row, i in enumerate(rows):
                if not tick.decision.hedged[i] or handle.done_rows[row]:
                    continue
                sla_i = (
                    float(tick.t_sla)
                    if np.isscalar(tick.t_sla)
                    else float(np.asarray(tick.t_sla)[i])
                )
                budget = (
                    sla_i
                    - tick.queue_wait[i]
                    - tick.requests[i].t_nw_actual_ms
                )
                if elapsed > budget:
                    stale.append(row)
            if stale:
                release(stale, "hedge_win")

    def drain(self) -> List[TickResult]:
        """Block until every in-flight tick resolves; returns their results."""
        inflight, self._inflight = self._inflight, []
        return [self._collect(t) for t in inflight]

    def flush(self) -> List[TickResult]:
        """Drive the loop until nothing is backlogged or in flight.

        The backlog spans every admission lane — the bounded pending
        queue, the block policy's overflow room, and the degrade lane — so
        a backpressured future still resolves through ``result()``.
        """
        results = self.drain()
        while self.backlog:
            before = self.backlog
            r = self.tick()
            if r is not None:
                results.append(r)
            results.extend(self.drain())
            if r is None and self.backlog >= before:
                break  # nothing schedulable (e.g. all raced to cancel)
        return results

    # -- replica health feedback ----------------------------------------------
    def _note_replica(
        self, replica: Optional[int], ok: bool, error: Optional[Exception] = None
    ) -> None:
        """Report a routed batch's outcome to a clustered backend's health
        layer (inert on plain backends and unrouted handles)."""
        if replica is None:
            return
        if ok:
            note = getattr(self.backend, "note_success", None)
            if note is not None:
                note(replica)
        else:
            note = getattr(self.backend, "note_failure", None)
            if note is not None:
                note(replica, str(error), fatal=isinstance(error, ReplicaDied))

    # -- observability emission (all call sites obs-guarded) ------------------
    def _note_request_tiers(self, f: InferenceFuture, c: CompletedRequest):
        """Per-request tier legs + TTFT instant on the request's span tree.

        The legs replay the future's recorded per-tier wall stamps — both
        race clocks start at the dispatch tick, so the spans make the
        overlap (or a serialized fallback's lack of it) visible per row.
        """
        tracer = self.observability.tracer
        disp, done = f.tier_dispatch_wall_ms, f.tier_done_wall_ms
        if "remote" in disp:
            track = (
                f"replica:{c.replica}" if c.replica is not None else "remote"
            )
            span = tracer.start(
                "remote", parent=f.span, cat="tier", track=track,
                t0_ms=disp["remote"], variant=c.model_name,
            )
            tracer.end(span, t1_ms=done.get("remote", disp["remote"]))
        if "ondevice" in disp:
            span = tracer.start(
                "ondevice", parent=f.span, cat="tier", track="ondevice",
                t0_ms=disp["ondevice"],
            )
            tracer.end(span, t1_ms=done.get("ondevice", disp["ondevice"]))
        if c.ttft_ms is not None:
            base = disp.get("remote")
            tracer.instant(
                "ttft", parent=f.span, cat="request",
                t_ms=None if base is None else base + c.ttft_ms,
                ttft_ms=c.ttft_ms,
            )

    def _note_tick(self, stats: TickStats, n_completions: int) -> None:
        """Fold one collected tick into the loop's metric families."""
        obs = self.observability
        obs.counter("loop_ticks_total").inc()
        obs.histogram("loop_tick_wall_ms").record(stats.span_wall_ms)
        for name, value in (
            ("loop_completions_total", n_completions),
            ("loop_shed_total", stats.n_shed),
            ("loop_degraded_total", stats.n_degraded),
            ("loop_hedged_total", stats.n_hedged),
            ("loop_lost_rows_total", stats.n_lost),
            ("loop_requeued_total", stats.n_requeued),
        ):
            if value:
                obs.counter(name).inc(value)
        obs.gauge("loop_inflight_ticks").set(len(self._inflight))

    # -- collection / resolution ---------------------------------------------
    def _collect(self, tick: _InflightTick) -> TickResult:
        obs = self.observability
        requests, decision = tick.requests, tick.decision
        n = len(requests)
        exec_ms = np.empty(n)
        lost = np.zeros(n, dtype=bool)  # rows whose remote batch was lost
        # Continuous-batching bookkeeping: rows released early from the
        # persistent decode batch (hedge win / cancel — their slot was
        # recycled before n_steps), and per-row time-to-first-token.
        released = np.zeros(n, dtype=bool)
        ttft = np.full(n, np.nan)
        gen_tokens: List[Optional[np.ndarray]] = [None] * n
        remote_wall_sum = 0.0
        for gi, (m, rows, handle) in enumerate(tick.groups):
            gspan = tick.group_spans[gi] if tick.group_spans else None
            try:
                out, wall_ms = handle.wait()
            except (TransportError, NoHealthyReplica) as e:
                # The batch never produced tokens: a dead/failed replica
                # (or a routing hole that opened mid-tick).  exec=inf makes
                # the vectorized race resolution treat the remote leg as
                # never arriving — hedged rows fail over to their measured
                # duplicate; unhedged rows are requeued below.  Replica
                # accounting was already reconciled by the transport
                # (inflight rows drained on failure), so only the breaker
                # needs the report.
                lost[rows] = True
                exec_ms[rows] = np.inf
                self._note_replica(handle.replica, ok=False, error=e)
                if gspan is not None:
                    gspan.args["error"] = repr(e)
                    obs.tracer.end(gspan)
                    obs.counter("loop_batches_lost_total").inc()
                continue
            remote_wall_sum += wall_ms
            exec_ms[rows] = wall_ms
            rel = getattr(handle, "released_rows", None)
            row_ttft = getattr(handle, "ttft_wall_ms", None)
            for row, i in enumerate(rows):
                gen_tokens[i] = out[row, : requests[i].n_steps]
                if row_ttft is not None and row_ttft[row] is not None:
                    ttft[i] = row_ttft[row]
                if rel and row in rel:
                    # The slot was recycled before n_steps: the remote leg
                    # never produced a full answer.  exec=inf routes the
                    # race to the duplicate without marking the row lost.
                    released[i] = True
                    exec_ms[i] = np.inf
            self._note_replica(handle.replica, ok=True)
            if gspan is not None:
                obs.tracer.end(gspan, t1_ms=handle.done_wall_ms)
            if obs is not None:
                replica = handle.replica if handle.replica is not None else -1
                obs.histogram(
                    "cluster_batch_wall_ms", replica=str(replica)
                ).record(float(wall_ms))

        completions: List[CompletedRequest] = []
        t_sla_live: List[float] = []  # per live completion, for summarize
        measured = tick.hedge_handle is not None
        hedge_wall: Optional[float] = None
        names = self.scheduler.names
        requeue: List[InferenceFuture] = []
        if n:
            # Lost batches and early-released rows have no honest wall
            # time: fold only surviving rows into the live profiles (the
            # no-failure path keeps the exact pre-fault call, preserving
            # the rng/EWMA stream the byte-identity regression pins).
            dead = lost | released
            if dead.any():
                if not dead.all():
                    self.scheduler.observe_batch(
                        decision.model_index[~dead], exec_ms[~dead]
                    )
            else:
                self.scheduler.observe_batch(decision.model_index, exec_ms)
            joined = ~np.isnan(ttft)
            if joined.any():
                self.scheduler.observe_join(
                    decision.model_index[joined], ttft[joined]
                )

            remote_ms = (
                tick.queue_wait
                + np.asarray([r.t_nw_actual_ms for r in requests])
                + exec_ms
            )

            ondevice_in: Optional[np.ndarray] = None
            hedge_tokens: Dict[int, np.ndarray] = {}
            if measured:
                out, hedge_wall = tick.hedge_handle.wait()
                if tick.hedge_span is not None:
                    obs.tracer.end(
                        tick.hedge_span, t1_ms=tick.hedge_handle.done_wall_ms
                    )
                for row, i in enumerate(tick.hedged_rows):
                    hedge_tokens[int(i)] = out[row, : requests[i].n_steps]
                ondevice_in = np.full(n, hedge_wall)
                self.scheduler.observe_ondevice(
                    np.full(tick.hedged_rows.size, hedge_wall)
                )

            # Both tiers launch at the dispatch tick, so queue wait charges
            # the duplicate's race clock too — and with async dispatch that
            # is also true of the *wall* clocks (see TickStats / the
            # regression test).
            acc_used, latency, used_remote, ondevice_ms = (
                self.scheduler.resolve_chunk(
                    decision, remote_ms, ondevice_ms=ondevice_in,
                    ondevice_wait_ms=tick.queue_wait, t_sla_ms=tick.t_sla,
                )
            )

            for i, f in enumerate(tick.futures):
                if lost[i] and not (measured and decision.hedged[i]):
                    # No tokens exist for this row anywhere (its hedge, if
                    # any, was only a simulated sample) — back through
                    # admission for a later tick on a surviving replica.
                    requeue.append(f)
                    continue
                done_walls = {}
                if tick.row_handles[i].done_wall_ms is not None:
                    done_walls["remote"] = tick.row_handles[i].done_wall_ms
                if measured and decision.hedged[i]:
                    done_walls["ondevice"] = tick.hedge_handle.done_wall_ms
                f.tier_done_wall_ms.update(done_walls)
                c = CompletedRequest(
                    rid=requests[i].rid,
                    model_name=names[int(decision.model_index[i])],
                    model_index=int(decision.model_index[i]),
                    tokens=(
                        hedge_tokens[i]
                        if i in hedge_tokens and not used_remote[i]
                        else gen_tokens[i]
                    ),
                    exec_ms=float(exec_ms[i]),
                    remote_ms=float(remote_ms[i]),
                    latency_ms=float(latency[i]),
                    accuracy=float(acc_used[i]),
                    used_remote=bool(used_remote[i]),
                    hedged=bool(decision.hedged[i]),
                    queue_wait_ms=float(tick.queue_wait[i]),
                    ondevice_ms=(
                        float(ondevice_ms[i]) if decision.hedged[i] else None
                    ),
                    hedge_measured=measured and bool(decision.hedged[i]),
                    time_to_schedule_ms=float(
                        tick.now_ms - requests[i].arrival_ms
                    ),
                    race_resolution=(
                        "unhedged" if not decision.hedged[i]
                        else "remote_failed" if lost[i]
                        else ("remote_won" if used_remote[i] else "ondevice_won")
                    ),
                    replica=tick.row_handles[i].replica,
                    replica_inflight=tick.row_handles[i].inflight_at_dispatch,
                    ttft_ms=None if np.isnan(ttft[i]) else float(ttft[i]),
                    tenant=requests[i].tenant,
                    priority=f.priority,
                )
                if obs is not None and f.span is not None:
                    self._note_request_tiers(f, c)
                f._mark_resolved(c)
                if f.state is RequestState.RESOLVED:
                    completions.append(c)
                    t_sla_live.append(
                        float(tick.t_sla)
                        if np.isscalar(tick.t_sla)
                        else float(np.asarray(tick.t_sla)[i])
                    )

        completions, t_sla_live = self._collect_degraded(
            tick, completions, t_sla_live
        )

        # Lost-batch recovery: the rows go back to the *front* of the
        # admission queue (they already invested queue wait) and are
        # rescheduled by a later tick — conservation holds because a
        # requeued request is backlog again, not a resolution.  A racing
        # cancel() wins inside _requeue (the row cancels instead).
        n_requeued = 0
        if requeue:
            back = [f for f in requeue if f._requeue()]
            if back:
                self.admission.requeue(back)
            n_requeued = len(back)

        metrics = None
        if completions or tick.n_shed:
            metrics = summarize(
                accuracy_used=np.asarray([c.accuracy for c in completions]),
                latency_ms=np.asarray([c.latency_ms for c in completions]),
                t_sla_ms=np.asarray(t_sla_live),
                model_names=self._usage_names(),
                model_index=np.asarray(
                    [c.model_index for c in completions], dtype=np.int64
                ),
                used_remote=np.asarray([c.used_remote for c in completions]),
                queue_wait_ms=np.asarray(
                    [c.queue_wait_ms for c in completions]
                ),
                race_resolution=np.asarray(
                    [c.race_resolution for c in completions]
                ),
                time_to_schedule_ms=np.asarray(
                    [c.time_to_schedule_ms for c in completions]
                ),
                n_rejected=tick.n_shed,
                replica=_replica_array(completions),
                replica_inflight=_replica_inflight_array(completions),
                tenant=_tenant_array(completions),
                priority=_priority_array(completions),
                rejected_tenants=_rejected_tenant_counts(
                    tick.shed_info,
                    default_lane=self.admission.cfg.tenants is not None,
                ),
            )

        # Continuous-batching deltas since the last collection (global to
        # the backend, so overlapping stepped ticks never double-count).
        n_joined = n_recycled = 0
        joined_now = getattr(self.backend, "joined_total", None)
        if joined_now is not None:
            n_joined = int(joined_now - self._joined_seen)
            self._joined_seen = joined_now
        recycled_now = getattr(self.backend, "recycled_total", None)
        if recycled_now is not None:
            n_recycled = int(recycled_now - self._recycled_seen)
            self._recycled_seen = recycled_now

        replica_rows: Dict[int, int] = {}
        for _, rows, handle in tick.groups:
            if handle.replica is not None:
                replica_rows[handle.replica] = (
                    replica_rows.get(handle.replica, 0) + len(rows)
                )

        dispatch_stamps = [h.dispatch_wall_ms for _, _, h in tick.groups]
        # A lost batch never finished — its handle has no done stamp.
        group_done = [
            h.done_wall_ms
            for _, _, h in tick.groups
            if h.done_wall_ms is not None
        ]
        done_stamps = list(group_done)
        for h in (tick.hedge_handle, tick.degrade_handle):
            if h is not None:
                dispatch_stamps.append(h.dispatch_wall_ms)
                done_stamps.append(h.done_wall_ms)
        stats = TickStats(
            n_requests=n,
            n_hedged=int(tick.hedged_rows.size),
            remote_wall_ms=remote_wall_sum,
            hedge_wall_ms=hedge_wall,
            span_wall_ms=(
                max(done_stamps) - min(dispatch_stamps) if done_stamps else 0.0
            ),
            dispatch_spread_wall_ms=(
                max(dispatch_stamps) - min(dispatch_stamps)
                if dispatch_stamps
                else 0.0
            ),
            hedge_dispatched_before_remote_done=(
                tick.hedge_handle.dispatch_wall_ms < max(group_done)
                if tick.hedge_handle is not None and group_done
                else None
            ),
            n_shed=tick.n_shed,
            n_degraded=len(tick.degraded_futures),
            n_lost=int(lost.sum()),
            n_requeued=n_requeued,
            replica_rows=replica_rows,
            n_joined=n_joined,
            n_recycled=n_recycled,
            compile_count=int(getattr(self.backend, "compile_count", 0)),
        )
        result = TickResult(
            completions=completions, metrics=metrics, stats=stats
        )
        if obs is not None:
            self._note_tick(stats, len(completions))
            if tick.tick_span is not None:
                tick.tick_span.args.update(
                    n_completions=len(completions),
                    n_lost=stats.n_lost,
                    n_requeued=stats.n_requeued,
                )
                obs.tracer.end(tick.tick_span)
        if self.controller is not None:
            self.controller.observe(
                result,
                scheduler=self.scheduler,
                backend=self.backend,
                now_ms=tick.now_ms,
                backlog=self.admission.backlog,
            )
        return result

    def _collect_degraded(
        self,
        tick: _InflightTick,
        completions: List[CompletedRequest],
        t_sla_live: List[float],
    ) -> Tuple[List[CompletedRequest], List[float]]:
        """Resolve the tick's on-device-only (overload-degraded) rows.

        With a real hedge backend the duplicate batch executed for real and
        its measured wall time folds into the live on-device EWMA profile;
        without one the execution is simulated from the profile (zero
        tokens — simulation only), mirroring the sampled-hedge fallback.
        There is no network leg: the duplicate runs on the device, so
        latency is queue wait + on-device execution.
        """
        nd = len(tick.degraded_futures)
        if not nd:
            return completions, t_sla_live
        obs = self.observability
        dreqs = [f.request for f in tick.degraded_futures]
        sched = self.scheduler
        if tick.degrade_handle is not None:
            dout, dwall = tick.degrade_handle.wait()
            if tick.degrade_span is not None:
                obs.tracer.end(
                    tick.degrade_span, t1_ms=tick.degrade_handle.done_wall_ms
                )
            d_exec = np.full(nd, dwall)
            d_tokens = [dout[row, : r.n_steps] for row, r in enumerate(dreqs)]
            sched.observe_ondevice(d_exec)
        else:
            d_exec = np.maximum(
                sched.ondevice_mu
                + sched.ondevice_sigma * sched.rng.standard_normal(nd),
                _DEGRADE_EXEC_FLOOR_MS,
            )
            d_tokens = [np.zeros(r.n_steps, dtype=np.int32) for r in dreqs]
        d_latency = tick.degrade_queue_wait + d_exec
        loop_sla = sched.cfg.t_sla_ms
        degrade_index = len(sched.names)  # the on-device slot in _usage_names
        for j, f in enumerate(tick.degraded_futures):
            if tick.degrade_handle is not None:
                f.tier_done_wall_ms.update(
                    {"ondevice": tick.degrade_handle.done_wall_ms}
                )
            r = dreqs[j]
            c = CompletedRequest(
                rid=r.rid,
                model_name=sched.ondevice.name,
                model_index=degrade_index,
                tokens=d_tokens[j],
                exec_ms=float(d_exec[j]),
                remote_ms=float(d_latency[j]),  # no remote leg: wait + exec
                latency_ms=float(d_latency[j]),
                accuracy=float(sched.ondevice.accuracy),
                used_remote=False,
                hedged=False,
                queue_wait_ms=float(tick.degrade_queue_wait[j]),
                ondevice_ms=float(d_latency[j]),
                hedge_measured=tick.degrade_handle is not None,
                time_to_schedule_ms=float(tick.now_ms - r.arrival_ms),
                race_resolution="degraded",
                tenant=r.tenant,
                priority=f.priority,
            )
            if obs is not None and f.span is not None:
                self._note_request_tiers(f, c)
            f._mark_resolved(c)
            if f.state is RequestState.RESOLVED:
                completions.append(c)
                t_sla_live.append(
                    loop_sla if r.sla_ms is None else float(r.sla_ms)
                )
        return completions, t_sla_live

    # -- loadgen integration --------------------------------------------------
    def drain_trace(
        self,
        trace: LoadTrace,
        window_ms: float,
        *,
        tokens_for: Callable[[int], np.ndarray],
        n_steps: int,
        on_tick: Optional[Callable[[float, TickResult], None]] = None,
        service_model: Optional[Callable[[TickResult], float]] = None,
    ) -> Tuple[List[CompletedRequest], Optional[RequestMetrics]]:
        """Drain a :mod:`repro.serving.loadgen` trace through the tick path.

        Each arrival window becomes one tick fired at the window's close;
        the wait until then is charged against each request's budget and
        latency.  ``on_tick(tick_ms, result)`` observes each tick.  Returns
        all completions plus trace-level aggregate metrics (including
        ``shed_rate`` / ``goodput`` when the admission queue rejected
        requests).

        ``service_model(result) -> ms`` couples service time into the loop
        clock: after each tick the server is busy for that long, and the
        next tick cannot fire earlier — so offered load beyond the service
        rate builds real queue wait instead of being absorbed into one
        instantaneous mega-batch.  This is what makes overload *visible*
        to the admission policies (and to ``bench_serving.py``'s
        ``serving/admission`` rows); ``None`` keeps the pre-admission
        windows-only clock.

        A bounded admission queue can leave a backlog after the last
        arrival window; the drain keeps ticking (one window's width at a
        time, service-coupled) until every lane is empty.
        """
        completions: List[CompletedRequest] = []
        rejected_before = self.admission.n_rejected
        tenant_rejected_before = dict(self.admission.tenant_rejected)
        busy_until_ms = 0.0
        tick_ms = 0.0

        def fire(t: float) -> float:
            nonlocal busy_until_ms
            if service_model is not None:
                t = max(t, busy_until_ms)
            result = self.tick(now_ms=float(t))
            if result is not None:
                if service_model is not None:
                    busy_until_ms = t + max(float(service_model(result)), 0.0)
                if on_tick is not None:
                    on_tick(float(t), result)
                completions.extend(result.completions)
            return t

        for window in iter_windows(trace, window_ms):
            for i in window:
                self.submit(
                    QueuedRequest(
                        rid=int(i),
                        tokens=tokens_for(int(i)),
                        n_steps=n_steps,
                        t_nw_est_ms=float(trace.t_nw_est_ms[i]),
                        t_nw_actual_ms=float(trace.t_nw_ms[i]),
                        arrival_ms=float(trace.arrival_ms[i]),
                        tenant=(
                            None
                            if trace.tenant is None or trace.tenant[i] is None
                            else str(trace.tenant[i])
                        ),
                    )
                )
            tick_ms = fire(
                (trace.arrival_ms[window[0]] // window_ms + 1) * window_ms
            )

        stalled = 0
        while self.backlog and stalled < 3:
            before = self.backlog
            tick_ms = fire(tick_ms + window_ms)
            stalled = stalled + 1 if self.backlog >= before else 0

        metrics = None
        n_rejected = self.admission.n_rejected - rejected_before
        if completions or n_rejected:
            metrics = summarize(
                accuracy_used=np.asarray([c.accuracy for c in completions]),
                latency_ms=np.asarray([c.latency_ms for c in completions]),
                t_sla_ms=self.scheduler.cfg.t_sla_ms,
                model_names=self._usage_names(),
                model_index=np.asarray([c.model_index for c in completions]),
                used_remote=np.asarray([c.used_remote for c in completions]),
                queue_wait_ms=np.asarray([c.queue_wait_ms for c in completions]),
                race_resolution=np.asarray(
                    [c.race_resolution for c in completions]
                ),
                time_to_schedule_ms=np.asarray(
                    [c.time_to_schedule_ms for c in completions]
                ),
                n_rejected=n_rejected,
                replica=_replica_array(completions),
                replica_inflight=_replica_inflight_array(completions),
                tenant=_tenant_array(completions),
                priority=_priority_array(completions),
                rejected_tenants={
                    name: count - tenant_rejected_before.get(name, 0)
                    for name, count in self.admission.tenant_rejected.items()
                    if count - tenant_rejected_before.get(name, 0) > 0
                },
            )
        return completions, metrics
