"""Event-loop serving front: admission → decide → dispatch → resolve ticks.

:class:`ServingLoop` is the middle layer of the three-layer serving stack
(client / loop / backend).  Requests are *submitted* (admission — they
become :class:`repro.serving.lifecycle.InferenceFuture` objects in QUEUED
state) and served by *ticks*: one tick schedules the pending chunk with a
single ``decide_batch`` call, dispatches every variant group — and the
hedged rows' on-device duplicate — through the async
:meth:`repro.serving.backend.ExecutionBackend.submit_batch` protocol, then
collects, observes, and resolves.

Because *all* batches of a tick are submitted before any is waited on, the
remote batch and the on-device duplicate genuinely run concurrently
(``dispatch="async"``, worker threads): ``resolve_chunk`` races
first-completion wall times measured over the same interval, instead of
two serialized measurements.  Both tiers' race clocks start at the
dispatch tick — the queue wait is charged to each exactly once
(previously the duplicate's wall clock silently started after the remote
batch finished; see ``TickStats`` for the overlap evidence).

``dispatch="sync"`` is the serialized fallback: ``submit_batch`` executes
inline, keeping CI runs and the equivalence references deterministic.
:meth:`ServingEngine.serve_queue <repro.serving.engine.ServingEngine.serve_queue>`
is a thin shim over one sync-collected tick of this loop.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sla import RequestMetrics, summarize
from repro.serving.backend import BatchHandle, ExecutionBackend, OnDeviceBackend
from repro.serving.lifecycle import (
    CompletedRequest,
    InferenceFuture,
    QueuedRequest,
    RequestState,
)
from repro.serving.loadgen import LoadTrace, iter_windows
from repro.serving.scheduler import pad_to_pow2

__all__ = ["ServingLoop", "TickResult", "TickStats"]


def _pad_batch(requests, rows_idx) -> Tuple[np.ndarray, int]:
    """Right-pad a group's prompts into one (pow2-rows, width) batch."""
    width = max(len(requests[i].tokens) for i in rows_idx)
    batch = np.zeros((pad_to_pow2(len(rows_idx)), width), dtype=np.int32)
    for row, i in enumerate(rows_idx):
        t = np.asarray(requests[i].tokens, dtype=np.int32)
        batch[row, : len(t)] = t
    steps = max(requests[i].n_steps for i in rows_idx)
    return batch, steps


@dataclasses.dataclass
class TickStats:
    """Wall-clock evidence of one tick's dispatch behavior.

    ``span_wall_ms`` (first dispatch → last completion) versus
    ``serialized_wall_ms`` (sum of the tiers' individual wall times) is the
    overlap witness: async dispatch gives ``span < serialized`` on any
    hedged tick, a serialized tick gives ``span ≈ serialized``.
    """

    n_requests: int
    n_hedged: int
    remote_wall_ms: float  # sum of the remote variant batches' wall times
    hedge_wall_ms: Optional[float]  # duplicate batch wall time (measured)
    span_wall_ms: float  # first dispatch -> last batch completion
    dispatch_spread_wall_ms: float  # max - min dispatch stamp across tiers
    hedge_dispatched_before_remote_done: Optional[bool]

    @property
    def serialized_wall_ms(self) -> float:
        return self.remote_wall_ms + (self.hedge_wall_ms or 0.0)

    @property
    def hedge_rows(self) -> int:
        """Live rows in the measured duplicate batch (0: no hedge tier)."""
        return self.n_hedged if self.hedge_wall_ms is not None else 0


@dataclasses.dataclass
class TickResult:
    """Outcome of one scheduling tick."""

    completions: List[CompletedRequest]  # resolved, submission order
    metrics: Optional[RequestMetrics]  # None for an empty / all-cancelled tick
    stats: TickStats


@dataclasses.dataclass
class _InflightTick:
    """A dispatched-but-uncollected tick (async mode can carry these)."""

    futures: List[InferenceFuture]
    requests: List[QueuedRequest]
    decision: object  # BatchDecision
    queue_wait: np.ndarray
    t_sla: object  # scalar or (n,) vector raced at resolution
    now_ms: float
    groups: List[Tuple[int, np.ndarray, BatchHandle]]  # (model, rows, handle)
    row_handles: List[BatchHandle]  # request index -> its remote handle
    hedged_rows: np.ndarray
    hedge_handle: Optional[BatchHandle]

    def poll(self) -> bool:
        handles = [h for _, _, h in self.groups]
        if self.hedge_handle is not None:
            handles.append(self.hedge_handle)
        return all(h.poll() for h in handles)


class ServingLoop:
    """Admission → ``decide_batch`` → concurrent dispatch → resolution.

    Parameters
    ----------
    scheduler:
        The policy half (:class:`repro.serving.scheduler.MDInferenceScheduler`).
    backend:
        The remote tier.
    hedge_backend:
        Optional on-device tier; without it hedges resolve on profile
        samples (the simulation reference).
    dispatch:
        ``"async"`` (worker threads, tiers overlap — the default) or
        ``"sync"`` (inline execution, deterministic serialized fallback).
    """

    def __init__(
        self,
        scheduler,
        backend: ExecutionBackend,
        hedge_backend: Optional[OnDeviceBackend] = None,
        *,
        dispatch: str = "async",
    ):
        if dispatch not in ("async", "sync"):
            raise ValueError(f"dispatch must be 'async' or 'sync', got {dispatch!r}")
        self.scheduler = scheduler
        self.backend = backend
        self.hedge_backend = hedge_backend
        self.dispatch = dispatch
        self.now_ms = 0.0
        self._pending: List[InferenceFuture] = []
        self._inflight: List[_InflightTick] = []
        self._rid = itertools.count()

    # -- admission ------------------------------------------------------------
    def next_rid(self) -> int:
        return next(self._rid)

    def submit(self, request: QueuedRequest) -> InferenceFuture:
        """Admit a request; it waits in QUEUED state for the next tick."""
        future = InferenceFuture(request, loop=self)
        self._pending.append(future)
        return future

    @property
    def pending(self) -> int:
        return sum(1 for f in self._pending if f.state is RequestState.QUEUED)

    @property
    def inflight(self) -> int:
        return sum(len(t.futures) for t in self._inflight)

    # -- the event loop -------------------------------------------------------
    def tick(
        self, now_ms: Optional[float] = None, *, wait: bool = True
    ) -> Optional[TickResult]:
        """Run one scheduling tick over the pending chunk.

        ``now_ms`` is the tick's loop-clock timestamp (e.g. the close of an
        arrival window); it defaults to the chunk's latest arrival.  With
        ``wait=True`` the tick's batches are collected and resolved before
        returning (the continuous-batching semantics of the old
        ``serve_queue``).  ``wait=False`` returns ``None`` right after
        dispatch — futures stay EXECUTING and are resolved by a later
        :meth:`poll` / :meth:`drain` (the genuinely-async event loop).
        """
        # Swap, don't read-then-clear: a submit() racing this tick from
        # another thread must land in either this batch or the next one,
        # never vanish between a snapshot and a clear().
        snapshot, self._pending = self._pending, []
        candidates = [f for f in snapshot if f.state is RequestState.QUEUED]
        if not candidates:
            return None
        if now_ms is None:
            now_ms = float(max(f.request.arrival_ms for f in candidates))
        # Atomic QUEUED -> SCHEDULED claim: a cancel() racing this tick from
        # another thread loses its slot here, never in a dispatched batch.
        batch = [f for f in candidates if f._try_schedule(now_ms)]
        if not batch:
            return None

        requests = [f.request for f in batch]
        arrivals = np.asarray([r.arrival_ms for r in requests])
        self.now_ms = max(self.now_ms, now_ms)
        queue_wait = np.maximum(now_ms - arrivals, 0.0)

        # Per-request SLA: selection budgets come from t_sla - est - wait,
        # expressed as an effective estimate offset against the loop SLA.
        loop_sla = self.scheduler.cfg.t_sla_ms
        slas = np.asarray(
            [loop_sla if r.sla_ms is None else float(r.sla_ms) for r in requests]
        )
        t_sla = slas if np.any(slas != loop_sla) else loop_sla
        est = np.asarray([r.t_nw_est_ms for r in requests])
        decision = self.scheduler.decide_batch(
            est + queue_wait + (loop_sla - slas)
        )

        # Dispatch every batch of the tick before waiting on any of them:
        # the remote variant groups and the hedged rows' duplicate all
        # start at this tick — the shared origin of both race clocks.
        sync = self.dispatch == "sync"
        groups: List[Tuple[int, np.ndarray, BatchHandle]] = []
        row_handles: List[Optional[BatchHandle]] = [None] * len(requests)
        for m in np.unique(decision.model_index):
            rows = np.flatnonzero(decision.model_index == m)
            gbatch, steps = _pad_batch(requests, rows)
            name = self.scheduler.names[int(m)]
            handle = self.backend.submit_batch(name, gbatch, steps, sync=sync)
            groups.append((int(m), rows, handle))
            for i in rows:
                row_handles[i] = handle

        hedged_rows = np.flatnonzero(decision.hedged)
        hedge_handle: Optional[BatchHandle] = None
        if self.hedge_backend is not None and hedged_rows.size > 0:
            hbatch, hsteps = _pad_batch(requests, hedged_rows)
            hedge_handle = self.hedge_backend.submit_hedge(
                hbatch, hsteps, sync=sync
            )

        for i, f in enumerate(batch):
            tiers = {"remote": row_handles[i].dispatch_wall_ms}
            if hedge_handle is not None and decision.hedged[i]:
                tiers["ondevice"] = hedge_handle.dispatch_wall_ms
            f._mark_executing(tiers)

        tick = _InflightTick(
            futures=batch,
            requests=requests,
            decision=decision,
            queue_wait=queue_wait,
            t_sla=t_sla,
            now_ms=now_ms,
            groups=groups,
            row_handles=row_handles,
            hedged_rows=hedged_rows,
            hedge_handle=hedge_handle,
        )
        if not wait:
            self._inflight.append(tick)
            return None
        return self._collect(tick)

    def poll(self) -> List[TickResult]:
        """Resolve every in-flight tick whose batches all finished.

        Never blocks: ticks with unfinished batches stay in flight.
        """
        # Evaluate poll() once per tick: a batch finishing between two
        # evaluations must land in exactly one of the two lists.
        ready = {id(t): t.poll() for t in self._inflight}
        done = [t for t in self._inflight if ready[id(t)]]
        self._inflight = [t for t in self._inflight if not ready[id(t)]]
        return [self._collect(t) for t in done]

    def drain(self) -> List[TickResult]:
        """Block until every in-flight tick resolves; returns their results."""
        inflight, self._inflight = self._inflight, []
        return [self._collect(t) for t in inflight]

    def flush(self) -> List[TickResult]:
        """Drive the loop until nothing is pending or in flight."""
        results = self.drain()
        while self.pending:
            r = self.tick()
            if r is not None:
                results.append(r)
            results.extend(self.drain())
        return results

    # -- collection / resolution ---------------------------------------------
    def _collect(self, tick: _InflightTick) -> TickResult:
        requests, decision = tick.requests, tick.decision
        n = len(requests)
        exec_ms = np.empty(n)
        gen_tokens: List[Optional[np.ndarray]] = [None] * n
        remote_wall_sum = 0.0
        for m, rows, handle in tick.groups:
            out, wall_ms = handle.wait()
            remote_wall_sum += wall_ms
            exec_ms[rows] = wall_ms
            for row, i in enumerate(rows):
                gen_tokens[i] = out[row, : requests[i].n_steps]
        self.scheduler.observe_batch(decision.model_index, exec_ms)

        remote_ms = (
            tick.queue_wait
            + np.asarray([r.t_nw_actual_ms for r in requests])
            + exec_ms
        )

        measured = tick.hedge_handle is not None
        ondevice_in: Optional[np.ndarray] = None
        hedge_wall: Optional[float] = None
        hedge_tokens: Dict[int, np.ndarray] = {}
        if measured:
            out, hedge_wall = tick.hedge_handle.wait()
            for row, i in enumerate(tick.hedged_rows):
                hedge_tokens[int(i)] = out[row, : requests[i].n_steps]
            ondevice_in = np.full(n, hedge_wall)
            self.scheduler.observe_ondevice(
                np.full(tick.hedged_rows.size, hedge_wall)
            )

        # Both tiers launch at the dispatch tick, so queue wait charges the
        # duplicate's race clock too — and with async dispatch that is also
        # true of the *wall* clocks (see TickStats / the regression test).
        acc_used, latency, used_remote, ondevice_ms = self.scheduler.resolve_chunk(
            decision, remote_ms, ondevice_ms=ondevice_in,
            ondevice_wait_ms=tick.queue_wait, t_sla_ms=tick.t_sla,
        )

        names = self.scheduler.names
        completions: List[CompletedRequest] = []
        live: List[int] = []
        for i, f in enumerate(tick.futures):
            done_walls = {"remote": tick.row_handles[i].done_wall_ms}
            if measured and decision.hedged[i]:
                done_walls["ondevice"] = tick.hedge_handle.done_wall_ms
            f.tier_done_wall_ms.update(done_walls)
            c = CompletedRequest(
                rid=requests[i].rid,
                model_name=names[int(decision.model_index[i])],
                model_index=int(decision.model_index[i]),
                tokens=(
                    hedge_tokens[i]
                    if i in hedge_tokens and not used_remote[i]
                    else gen_tokens[i]
                ),
                exec_ms=float(exec_ms[i]),
                remote_ms=float(remote_ms[i]),
                latency_ms=float(latency[i]),
                accuracy=float(acc_used[i]),
                used_remote=bool(used_remote[i]),
                hedged=bool(decision.hedged[i]),
                queue_wait_ms=float(tick.queue_wait[i]),
                ondevice_ms=(
                    float(ondevice_ms[i]) if decision.hedged[i] else None
                ),
                hedge_measured=measured and bool(decision.hedged[i]),
                time_to_schedule_ms=float(
                    tick.now_ms - requests[i].arrival_ms
                ),
                race_resolution=(
                    "unhedged" if not decision.hedged[i]
                    else ("remote_won" if used_remote[i] else "ondevice_won")
                ),
            )
            f._mark_resolved(c)
            if f.state is RequestState.RESOLVED:
                live.append(i)
                completions.append(c)

        metrics = None
        if live:
            idx = np.asarray(live)
            t_sla_live = (
                tick.t_sla
                if np.isscalar(tick.t_sla)
                else np.asarray(tick.t_sla)[idx]
            )
            metrics = summarize(
                accuracy_used=acc_used[idx],
                latency_ms=latency[idx],
                t_sla_ms=t_sla_live,
                model_names=names,
                model_index=decision.model_index[idx],
                used_remote=used_remote[idx],
                queue_wait_ms=tick.queue_wait[idx],
                race_resolution=np.asarray(
                    [c.race_resolution for c in completions]
                ),
                time_to_schedule_ms=np.asarray(
                    [c.time_to_schedule_ms for c in completions]
                ),
            )

        dispatch_stamps = [h.dispatch_wall_ms for _, _, h in tick.groups]
        done_stamps = [h.done_wall_ms for _, _, h in tick.groups]
        if tick.hedge_handle is not None:
            dispatch_stamps.append(tick.hedge_handle.dispatch_wall_ms)
            done_stamps.append(tick.hedge_handle.done_wall_ms)
        stats = TickStats(
            n_requests=n,
            n_hedged=int(tick.hedged_rows.size),
            remote_wall_ms=remote_wall_sum,
            hedge_wall_ms=hedge_wall,
            span_wall_ms=max(done_stamps) - min(dispatch_stamps),
            dispatch_spread_wall_ms=max(dispatch_stamps) - min(dispatch_stamps),
            hedge_dispatched_before_remote_done=(
                tick.hedge_handle.dispatch_wall_ms
                < max(h.done_wall_ms for _, _, h in tick.groups)
                if tick.hedge_handle is not None
                else None
            ),
        )
        return TickResult(completions=completions, metrics=metrics, stats=stats)

    # -- loadgen integration --------------------------------------------------
    def drain_trace(
        self,
        trace: LoadTrace,
        window_ms: float,
        *,
        tokens_for: Callable[[int], np.ndarray],
        n_steps: int,
        on_tick: Optional[Callable[[float, TickResult], None]] = None,
    ) -> Tuple[List[CompletedRequest], Optional[RequestMetrics]]:
        """Drain a :mod:`repro.serving.loadgen` trace through the tick path.

        Each arrival window becomes one tick fired at the window's close;
        the wait until then is charged against each request's budget and
        latency.  ``on_tick(tick_ms, result)`` observes each tick.  Returns
        all completions plus trace-level aggregate metrics.
        """
        completions: List[CompletedRequest] = []
        for window in iter_windows(trace, window_ms):
            for i in window:
                self.submit(
                    QueuedRequest(
                        rid=int(i),
                        tokens=tokens_for(int(i)),
                        n_steps=n_steps,
                        t_nw_est_ms=float(trace.t_nw_est_ms[i]),
                        t_nw_actual_ms=float(trace.t_nw_ms[i]),
                        arrival_ms=float(trace.arrival_ms[i]),
                    )
                )
            tick_ms = (trace.arrival_ms[window[0]] // window_ms + 1) * window_ms
            result = self.tick(now_ms=float(tick_ms))
            if result is None:
                continue
            if on_tick is not None:
                on_tick(float(tick_ms), result)
            completions.extend(result.completions)
        metrics = None
        if completions:
            metrics = summarize(
                accuracy_used=np.asarray([c.accuracy for c in completions]),
                latency_ms=np.asarray([c.latency_ms for c in completions]),
                t_sla_ms=self.scheduler.cfg.t_sla_ms,
                model_names=self.scheduler.names,
                model_index=np.asarray([c.model_index for c in completions]),
                used_remote=np.asarray([c.used_remote for c in completions]),
                queue_wait_ms=np.asarray([c.queue_wait_ms for c in completions]),
                race_resolution=np.asarray(
                    [c.race_resolution for c in completions]
                ),
                time_to_schedule_ms=np.asarray(
                    [c.time_to_schedule_ms for c in completions]
                ),
            )
        return completions, metrics
