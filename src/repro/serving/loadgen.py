"""Trace-driven load generation for the serving front.

Produces :class:`LoadTrace` objects — per-request arrival timestamps plus
network times (and the server's estimate of them) — that drive both the
offline scheduler (``MDInferenceScheduler.run_trace`` consumes the network
columns) and the live engine (``ServingEngine.serve_queue`` consumes
arrival-windowed chunks, i.e. continuous batching ticks).

Arrival processes:

* :class:`PoissonArrivals` — memoryless open-loop traffic at a target rate.
* :class:`BurstyArrivals` — a two-state Markov-modulated Poisson process:
  most of the time the base rate, occasionally a burst at
  ``burst_factor`` × the base rate (flash crowds / synchronized clients).
* :class:`OverloadArrivals` — a sustained overload phase: base-rate
  Poisson, then ``overload_factor`` × the base rate for a contiguous span
  of the stream, then base again (the adversarial input for the bounded
  admission queue's backpressure policies).
* :class:`RampArrivals` — the rate ramps linearly from ``rate_start_rps``
  to ``rate_end_rps`` across the stream (capacity-crossing sweeps: find
  where a policy starts shedding).
* :class:`DiurnalArrivals` — a smooth ramp-up-and-back-down (half-sine)
  rate profile: trough → peak → trough across the stream, the
  diurnal-drift input for the adaptive admission controller.
* :class:`SpikeArrivals` — steady Poisson arrivals paired with a
  *service-time* spike schedule (:meth:`SpikeArrivals.service_factor`):
  for a contiguous span of the horizon service times multiply by
  ``spike_factor`` (the 30x per-replica swings of "A Note on Latency
  Variability of DNNs for Mobile Inference").  The arrival stream itself
  stays steady — the drift is in the service model.
* :class:`MixedTenantArrivals` — two concurrent *tagged* lanes: an
  interactive Poisson lane plus a batch flood lane, each request carrying
  its tenant name (the adversarial input for the multi-tenant QoS lanes:
  does the flood destroy the interactive tenant's p99?).

Units: every rate parameter (``rate_rps``, ``rate_start_rps``, …) is in
**requests per second**; every timestamp and gap these processes emit is
in **milliseconds** (mean gap = ``1e3 / rate_rps`` ms).  Doubling a rate
halves the expected gaps, i.e. a 2x-rate trace yields ~2x the arrivals
inside any fixed horizon.

Network times come from any :class:`repro.core.network.NetworkModel`; the
named paper traces (university / residential / LTE) are exposed through
:data:`repro.core.network.NAMED_TRACES`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.network import Estimator, NetworkModel

__all__ = [
    "ArrivalProcess",
    "PoissonArrivals",
    "BurstyArrivals",
    "OverloadArrivals",
    "RampArrivals",
    "DiurnalArrivals",
    "SpikeArrivals",
    "MixedTenantArrivals",
    "LoadTrace",
    "make_trace",
    "iter_windows",
]


class ArrivalProcess:
    """Samples per-request arrival timestamps (ms, non-decreasing).

    Rate parameters on all subclasses are in requests per *second*
    (``*_rps``); emitted timestamps are in *milliseconds*.
    """

    def sample_arrivals_ms(self, rng: np.random.Generator, n: int) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless open-loop traffic: exponential gaps with mean
    ``1e3 / rate_rps`` ms (``rate_rps`` is in requests per second)."""

    rate_rps: float = 100.0

    def sample_arrivals_ms(self, rng, n):
        gaps = rng.exponential(1e3 / self.rate_rps, size=n)
        return np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """Two-state MMPP: base-rate Poisson with exponential-length bursts.

    ``p_enter`` / ``p_exit`` are per-request transition probabilities, so
    the expected burst length is ``1 / p_exit`` requests.
    """

    rate_rps: float = 100.0
    burst_factor: float = 8.0
    p_enter: float = 0.02
    p_exit: float = 0.2

    def sample_arrivals_ms(self, rng, n):
        base_gap = 1e3 / self.rate_rps
        burst_gap = base_gap / self.burst_factor
        gaps = np.empty(n)
        flips = rng.random(n)
        raw = rng.exponential(1.0, size=n)
        in_burst = False
        for i in range(n):
            if in_burst:
                if flips[i] < self.p_exit:
                    in_burst = False
            elif flips[i] < self.p_enter:
                in_burst = True
            gaps[i] = raw[i] * (burst_gap if in_burst else base_gap)
        return np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class OverloadArrivals(ArrivalProcess):
    """Sustained overload: a contiguous span of the stream arrives at
    ``overload_factor`` × the base rate.

    ``rate_rps`` is in requests per **second** (arrival timestamps are in
    ms; the overloaded span's mean gap is
    ``1e3 / (rate_rps * overload_factor)`` ms).
    ``overload_start`` / ``overload_stop`` are fractions of the *request
    stream* (not wall time): requests with index in
    ``[start*n, stop*n)`` use the overloaded rate.  The default is a
    2× overload over the middle half — long enough that an unbounded
    pending queue visibly diverges while bounded policies stay flat.
    """

    rate_rps: float = 100.0
    overload_factor: float = 2.0
    overload_start: float = 0.25
    overload_stop: float = 0.75

    def __post_init__(self):
        if not 0.0 <= self.overload_start <= self.overload_stop <= 1.0:
            raise ValueError(
                "need 0 <= overload_start <= overload_stop <= 1, got "
                f"[{self.overload_start}, {self.overload_stop})"
            )
        if self.overload_factor <= 0:
            raise ValueError(
                f"overload_factor must be > 0, got {self.overload_factor}"
            )

    def sample_arrivals_ms(self, rng, n):
        idx = np.arange(n)
        in_overload = (idx >= self.overload_start * n) & (
            idx < self.overload_stop * n
        )
        rate = np.where(
            in_overload, self.rate_rps * self.overload_factor, self.rate_rps
        )
        gaps = rng.exponential(1.0, size=n) * (1e3 / rate)
        return np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class RampArrivals(ArrivalProcess):
    """Linear rate ramp across the stream: ``rate_start_rps`` for the first
    request through ``rate_end_rps`` for the last (Poisson gaps at the
    instantaneous rate).  Both rates are in requests per **second**; the
    emitted arrival timestamps are in ms (instantaneous mean gap
    ``1e3 / rate_rps``).  Sweeps the offered load through the serving
    tier's capacity — where queue wait starts growing is the knee.
    """

    rate_start_rps: float = 50.0
    rate_end_rps: float = 200.0

    def __post_init__(self):
        if self.rate_start_rps <= 0 or self.rate_end_rps <= 0:
            raise ValueError(
                "ramp rates must be > 0, got "
                f"{self.rate_start_rps} -> {self.rate_end_rps}"
            )

    def sample_arrivals_ms(self, rng, n):
        frac = np.arange(n) / max(n - 1, 1)
        rate = self.rate_start_rps + frac * (
            self.rate_end_rps - self.rate_start_rps
        )
        gaps = rng.exponential(1.0, size=n) * (1e3 / rate)
        return np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Half-sine diurnal profile: the rate ramps smoothly from
    ``trough_rps`` up to ``peak_rps`` at mid-stream and back down
    (``rate(i) = trough + (peak - trough) * sin(pi * i / n)``).

    Rates are in requests per **second**; arrival timestamps are in ms.
    This is the slow-drift input for the adaptive admission controller: a
    static capacity tuned for the trough over-admits at the peak, one
    tuned for the peak over-sheds in the shoulders.
    """

    trough_rps: float = 50.0
    peak_rps: float = 300.0

    def __post_init__(self):
        if self.trough_rps <= 0 or self.peak_rps <= 0:
            raise ValueError(
                "diurnal rates must be > 0, got "
                f"{self.trough_rps} / {self.peak_rps}"
            )

    def sample_arrivals_ms(self, rng, n):
        frac = np.arange(n) / max(n - 1, 1)
        rate = self.trough_rps + (self.peak_rps - self.trough_rps) * np.sin(
            np.pi * frac
        )
        gaps = rng.exponential(1.0, size=n) * (1e3 / rate)
        return np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class SpikeArrivals(ArrivalProcess):
    """Steady Poisson arrivals plus a *service-time* spike schedule.

    Arrivals are plain Poisson at ``rate_rps`` (requests per second, ms
    timestamps) — the drift lives in the service model:
    :meth:`service_factor` returns ``spike_factor`` for loop-clock times
    inside ``[spike_start, spike_stop)`` (fractions of a given horizon)
    and ``1.0`` outside it.  Scenario harnesses fold it into the
    ``drain_trace`` ``service_model`` (and the backend's reported wall
    times) to realize a 30x per-replica service swing without changing
    the offered load.
    """

    rate_rps: float = 100.0
    spike_factor: float = 30.0
    spike_start: float = 0.4
    spike_stop: float = 0.6

    def __post_init__(self):
        if not 0.0 <= self.spike_start <= self.spike_stop <= 1.0:
            raise ValueError(
                "need 0 <= spike_start <= spike_stop <= 1, got "
                f"[{self.spike_start}, {self.spike_stop})"
            )
        if self.spike_factor <= 0:
            raise ValueError(
                f"spike_factor must be > 0, got {self.spike_factor}"
            )

    def sample_arrivals_ms(self, rng, n):
        gaps = rng.exponential(1e3 / self.rate_rps, size=n)
        return np.cumsum(gaps)

    def service_factor(self, t_ms: float, horizon_ms: float) -> float:
        """Service-time multiplier at loop-clock time ``t_ms`` of a run
        whose trace spans ``horizon_ms``."""
        if horizon_ms <= 0:
            return 1.0
        frac = t_ms / horizon_ms
        if self.spike_start <= frac < self.spike_stop:
            return float(self.spike_factor)
        return 1.0


@dataclasses.dataclass(frozen=True)
class MixedTenantArrivals(ArrivalProcess):
    """Two concurrent tagged lanes: interactive Poisson + a batch flood.

    Both lanes run over the same horizon; of ``n`` sampled requests, the
    lanes get counts proportional to their rates (so the merged stream
    realizes both offered rates simultaneously).  :meth:`sample_tagged`
    returns ``(arrival_ms, tenant)`` with per-request tenant names —
    :func:`make_trace` detects it and emits a tagged
    :class:`LoadTrace` that :meth:`repro.serving.loop.ServingLoop.drain_trace`
    forwards into each request's ``tenant`` field.
    """

    interactive_rps: float = 50.0
    batch_rps: float = 200.0
    interactive_tenant: str = "interactive"
    batch_tenant: str = "batch"

    def __post_init__(self):
        if self.interactive_rps <= 0 or self.batch_rps <= 0:
            raise ValueError(
                "lane rates must be > 0, got "
                f"{self.interactive_rps} / {self.batch_rps}"
            )

    def sample_tagged(self, rng, n):
        """Sample ``(arrival_ms, tenant)`` — merged, arrival-sorted."""
        if n == 0:
            return np.zeros(0), np.zeros(0, dtype=object)
        frac = self.interactive_rps / (self.interactive_rps + self.batch_rps)
        n_int = int(round(n * frac))
        if n >= 2:  # both lanes present whenever there is room for both
            n_int = min(max(n_int, 1), n - 1)
        n_bat = n - n_int
        t_int = np.cumsum(
            rng.exponential(1e3 / self.interactive_rps, size=n_int)
        )
        t_bat = np.cumsum(rng.exponential(1e3 / self.batch_rps, size=n_bat))
        arrival = np.concatenate([t_int, t_bat])
        tenant = np.asarray(
            [self.interactive_tenant] * n_int + [self.batch_tenant] * n_bat,
            dtype=object,
        )
        order = np.argsort(arrival, kind="stable")
        return arrival[order], tenant[order]

    def sample_arrivals_ms(self, rng, n):
        return self.sample_tagged(rng, n)[0]


@dataclasses.dataclass(frozen=True)
class LoadTrace:
    """One generated request stream (arrival-ordered)."""

    arrival_ms: np.ndarray  # (R,) non-decreasing arrival timestamps
    t_nw_ms: np.ndarray  # (R,) actual round-trip network times
    t_nw_est_ms: np.ndarray  # (R,) server-side estimates of t_nw_ms
    # (R,) per-request tenant names (object dtype), or None for an
    # untagged single-class stream — the compatibility default.
    tenant: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self.arrival_ms)

    @property
    def duration_ms(self) -> float:
        return float(self.arrival_ms[-1]) if len(self.arrival_ms) else 0.0

    @property
    def offered_rps(self) -> float:
        d = self.duration_ms
        return len(self) / (d / 1e3) if d > 0 else float("inf")


def make_trace(
    n: int,
    arrivals: ArrivalProcess,
    network: NetworkModel,
    estimator: Optional[Estimator] = None,
    seed: int = 0,
) -> LoadTrace:
    """Draw a request stream: arrivals x network times x estimates.

    ``arrivals`` rate parameters are in requests per **second**; all
    columns of the returned :class:`LoadTrace` (``arrival_ms``,
    ``t_nw_ms``, ``t_nw_est_ms``) are in **milliseconds**.
    """
    rng = np.random.default_rng(seed)
    tenant = None
    sample_tagged = getattr(arrivals, "sample_tagged", None)
    if sample_tagged is not None:
        arrival_ms, tenant = sample_tagged(rng, n)
    else:
        arrival_ms = arrivals.sample_arrivals_ms(rng, n)
    t_nw = network.sample(rng, n)
    t_est = t_nw if estimator is None else estimator.estimate(rng, t_nw)
    return LoadTrace(
        arrival_ms=np.asarray(arrival_ms, dtype=np.float64),
        t_nw_ms=np.asarray(t_nw, dtype=np.float64),
        t_nw_est_ms=np.asarray(t_est, dtype=np.float64),
        tenant=tenant,
    )


def iter_windows(trace: LoadTrace, window_ms: float) -> Iterator[np.ndarray]:
    """Group a trace into scheduling-tick windows (continuous batching).

    Yields index arrays: all requests whose arrival falls in
    ``[k*window_ms, (k+1)*window_ms)``, in arrival order, skipping empty
    windows.  Every request appears in exactly one window.
    """
    if window_ms <= 0:
        raise ValueError(f"window_ms must be > 0, got {window_ms}")
    n = len(trace)
    if n == 0:
        return
    buckets = np.floor_divide(trace.arrival_ms, window_ms).astype(np.int64)
    start = 0
    while start < n:
        stop = int(np.searchsorted(buckets, buckets[start], side="right"))
        yield np.arange(start, stop)
        start = stop
