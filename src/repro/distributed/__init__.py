"""Distribution: logical-axis sharding rules, compression, collectives."""
from repro.distributed.api import (
    RULES_1D, RULES_2D, RULES_3D, AxisRules, axis_rules, constrain,
    logical_to_spec, named_sharding,
)
from repro.distributed import compression

__all__ = [
    "RULES_1D", "RULES_2D", "RULES_3D", "AxisRules", "axis_rules",
    "compression", "constrain", "logical_to_spec", "named_sharding",
]
