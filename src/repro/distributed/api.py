"""Logical-axis sharding API (MaxText-style rules).

Model code never names mesh axes.  It annotates arrays with *logical* axes
("batch", "embed", "heads", ...) via :func:`constrain`, and declares parameter
logical axes in its spec tables.  The launcher activates a rule set mapping
logical axes -> mesh axes; outside an active rule set every annotation is a
no-op (so CPU unit tests run unsharded with zero ceremony).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "AxisRules",
    "axis_rules",
    "active_rules",
    "constrain",
    "logical_to_spec",
    "named_sharding",
    "RULES_1D",
    "RULES_2D",
    "RULES_2D_SP",
    "RULES_2D_DEC",
    "RULES_3D",
    "RULES_3D_SP",
    "RULES_3D_DEC",
]

MeshAxes = Union[None, str, tuple]


class AxisRules:
    """Mapping from logical axis names to mesh axes (or None = replicate)."""

    def __init__(self, mesh: Optional[Mesh], table: dict):
        self.mesh = mesh
        self.table = dict(table)

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        out = []
        for ax in logical_axes:
            if ax is None:
                out.append(None)
            else:
                if ax not in self.table:
                    raise KeyError(f"no rule for logical axis {ax!r}")
                out.append(self.table[ax])
        return P(*out)


_state = threading.local()


def active_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = active_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def constrain(x, *logical_axes):
    """Apply ``with_sharding_constraint`` under the active rules (else no-op)."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.spec(logical_axes)
    if rules.mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def logical_to_spec(logical_axes: Sequence[Optional[str]]) -> P:
    rules = active_rules()
    if rules is None:
        return P()
    return rules.spec(logical_axes)


def named_sharding(mesh: Mesh, rules: AxisRules, logical_axes) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(logical_axes))


# ---------------------------------------------------------------------------
# Standard rule tables.
#
# Logical axes:
#   batch       global batch                   -> all data-parallel axes
#   seq         sequence (activations)         -> unsharded by default
#   embed       d_model                        -> FSDP axis on weights
#   heads       attention query heads          -> tensor axis
#   kv_heads    attention kv heads             -> tensor axis (padded/replicated
#                                                 by GSPMD if count < axis size)
#   ffn         MLP hidden                     -> tensor axis
#   vocab       vocabulary                     -> tensor axis
#   experts     MoE experts                    -> unsharded (expert weights are
#                                                 TP on expert_ffn + FSDP on embed)
#   expert_ffn  per-expert hidden              -> tensor axis
#   moe_groups  MoE token groups               -> all axes (fully sharded tokens)
#   lru         recurrent (RG-LRU/xLSTM) width -> tensor axis
#   stats       tiny per-request/profile arrays-> replicated
# ---------------------------------------------------------------------------
def _table(data_axes, tensor_axis):
    return {
        "batch": data_axes,
        "seq": None,
        "embed": data_axes if isinstance(data_axes, str) else "data",
        "heads": tensor_axis,
        "kv_heads": tensor_axis,
        "ffn": tensor_axis,
        "vocab": tensor_axis,
        "experts": None,
        "expert_ffn": tensor_axis,
        "moe_groups": data_axes,  # groups follow the batch sharding; the
        # tensor axis parallelizes *inside* experts (expert_ffn)
        "lru": tensor_axis,
        "seq_kv": tensor_axis,  # decode KV-cache sequence dim
        "seq_act": None,  # residual-stream seq dim; "model" = Megatron-style
        # sequence parallelism (the RULES_*_SP variants)
        "embed_act": None,  # decode residual d_model dim; "data" = 2D
        # weight-stationary decode (no per-step FSDP weight gathers)
        "stats": None,
    }


def _flatten_axes(*axes):
    out = []
    for ax in axes:
        if ax is None:
            continue
        if isinstance(ax, tuple):
            out.extend(ax)
        else:
            out.append(ax)
    return tuple(out)


RULES_1D = {  # single-device / tests
    "batch": None,
    "seq_kv": None,
    "seq_act": None,
    "embed_act": None,
    "seq": None,
    "embed": None,
    "heads": None,
    "kv_heads": None,
    "ffn": None,
    "vocab": None,
    "experts": None,
    "expert_ffn": None,
    "moe_groups": None,
    "lru": None,
    "stats": None,
}

# Single pod: 16x16 ("data", "model").
RULES_2D = _table("data", "model")
RULES_2D["batch"] = ("data",)

# Two pods: (2, 16, 16) ("pod", "data", "model").  Weights FSDP over "data"
# (intra-pod), replicated across "pod" (gradient all-reduce crosses the
# inter-pod links once per step); batch over ("pod", "data").
RULES_3D = _table("data", "model")
RULES_3D["batch"] = ("pod", "data")
RULES_3D["moe_groups"] = ("pod", "data")

# Sequence-parallel variants: the residual stream (and hence RMSNorm work,
# scan carries, and the TP boundary collectives) is sharded over the tensor
# axis between blocks; GSPMD turns TP all-reduces into reduce-scatter +
# all-gather pairs and activation memory drops by the tensor-axis size.
RULES_2D_SP = dict(RULES_2D, seq_act="model")
RULES_3D_SP = dict(RULES_3D, seq_act="model")

# Serving-replica decode: the data axis is 16 independent TP-16 replicas —
# weights are NOT FSDP-sharded (embed -> None), so no per-token-step weight
# all-gathers; each replica's full TP copy is params/16 per chip.  (A fully
# sharded weight layout was tried and REGRESSED 10x: with the batch
# replicated, intra-block activations snap back to full-width per device —
# see EXPERIMENTS.md §Perf.)
RULES_2D_DEC = dict(RULES_2D, embed=None, moe_groups=None)
RULES_3D_DEC = dict(RULES_3D, embed=None, moe_groups=None)
