"""Gradient compression for slow (cross-pod / DCI) links.

int8 uniform quantization with per-tensor scales and *error feedback*
(Seide et al. / EF-SGD): the quantization residual is carried to the next
step so compression bias does not accumulate.

Two integration points:
  * :func:`quantize_dequantize` — a gradient transform applied inside the
    jitted train step (models the wire format; GSPMD still owns the actual
    collective).  This is what ``TrainConfig.grad_compression`` enables.
  * :func:`compressed_psum` — an explicit shard_map collective: quantize,
    sum int32 partials over the named axis, dequantize.  Used where the
    gradient exchange is hand-scheduled (cross-pod axis in RULES_3D) and in
    tests to verify end-to-end semantics.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "quantize_dequantize",
           "compressed_psum", "init_error_feedback"]


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_dequantize(grads, error_fb=None):
    """Quantization-aware gradient transform with error feedback.

    Returns (grads_hat, new_error_fb).  With ``error_fb=None`` feedback is
    disabled (plain quantization).
    """

    def one(g, e):
        gf = g.astype(jnp.float32)
        if e is not None:
            gf = gf + e
        q, s = quantize_int8(gf)
        ghat = dequantize_int8(q, s)
        new_e = gf - ghat if e is not None else None
        return ghat.astype(g.dtype), new_e

    if error_fb is None:
        out = jax.tree.map(lambda g: one(g, None)[0], grads)
        return out, None
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_fb)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tdef, [p[0] for p in pairs]),
        jax.tree.unflatten(tdef, [p[1] for p in pairs]),
    )


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x, axis_name: str):
    """int8-on-the-wire psum over a named axis (use inside shard_map).

    Each participant sends int8 + one fp32 scale; partial sums are exchanged
    as int32 (no overflow for <= 2^23 participants) and dequantized with the
    max scale.  ~4x traffic reduction vs fp32 all-reduce.
    """
    q, scale = quantize_int8(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # Requantize against the shared scale so the sum is coherent.
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale_max), -127, 127
    ).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale_max).astype(x.dtype)
