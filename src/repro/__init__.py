"""MDInference on TPU: SLA-bounded multi-model serving in JAX.

Reproduction + extension of Ogden & Guo (2020).  See README.md / DESIGN.md.
"""

__version__ = "1.0.0"
