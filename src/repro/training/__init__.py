"""Training substrate: optimizer, data pipeline, train-step factory."""
from repro.training.data import DataConfig, make_pipeline
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state, lr_at
from repro.training.train_loop import (
    TrainConfig, init_train_state, make_train_step, state_shardings,
)

__all__ = [
    "DataConfig", "OptimizerConfig", "TrainConfig",
    "adamw_update", "init_opt_state", "init_train_state", "lr_at",
    "make_pipeline", "make_train_step", "state_shardings",
]
