"""Train-step factory: remat, microbatch accumulation, sharding, donation.

``make_train_step`` closes over the model/optimizer configs and (optionally)
a mesh + logical-axis rules; it returns a jitted step with donated state and
NamedSharding-annotated inputs/outputs — the same function the multi-pod
dry-run lowers and the CPU examples execute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.distributed.api import AxisRules, axis_rules, named_sharding
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state

__all__ = ["TrainConfig", "init_train_state", "make_train_step", "state_shardings"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1  # gradient accumulation over the batch's lead dim
    grad_compression: bool = False  # int8 + error feedback on the exchange


def init_train_state(cfg: ModelConfig, key, train_cfg: TrainConfig = TrainConfig()):
    params = transformer.init_params(cfg, key)
    state = {"params": params, "opt": init_opt_state(params)}
    if train_cfg.grad_compression:
        state["error_fb"] = compression.init_error_feedback(params)
    return state


def state_axes(cfg: ModelConfig, train_cfg: TrainConfig = TrainConfig()):
    """Logical axes for the whole train state (mirrors init_train_state)."""
    p_axes = transformer.param_axes(cfg)
    axes = {
        "params": p_axes,
        "opt": {"mu": p_axes, "nu": p_axes, "step": ()},
    }
    if train_cfg.grad_compression:
        axes["error_fb"] = p_axes
    return axes


def state_shardings(cfg, mesh, rules: AxisRules, train_cfg=TrainConfig()):
    return jax.tree.map(
        lambda ax: named_sharding(mesh, rules, ax),
        state_axes(cfg, train_cfg),
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def batch_shardings(mesh, rules: AxisRules, batch_tree):
    def spec(a):
        return named_sharding(mesh, rules, ("batch",) + (None,) * (a.ndim - 1))

    return jax.tree.map(spec, batch_tree)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    train_cfg: TrainConfig = TrainConfig(),
    mesh=None,
    rules: Optional[AxisRules] = None,
):
    """Returns ``train_step(state, batch) -> (state, metrics)``."""

    def loss_fn(params, batch):
        return transformer.loss_fn(cfg, params, batch)

    def compute_grads(params, batch):
        if train_cfg.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        n = train_cfg.microbatches

        def resh(x):  # (B, ...) -> (n, B/n, ...)
            return x.reshape(n, x.shape[0] // n, *x.shape[1:])

        mbatch = jax.tree.map(resh, batch)

        def body(acc, mb):
            loss_a, grads_a, metrics_a = acc
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            grads_a = jax.tree.map(jnp.add, grads_a, grads)
            metrics_a = jax.tree.map(jnp.add, metrics_a, metrics)
            return (loss_a + loss, grads_a, metrics_a), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        zero_metrics = {k: jnp.float32(0.0) for k in ("xent", "aux", "tokens")}
        init = (jnp.float32(0.0), zero_grads, zero_metrics)
        (loss, grads, metrics), _ = jax.lax.scan(body, init, mbatch)
        grads = jax.tree.map(lambda g: g / n, grads)
        metrics = {
            k: (v if k == "tokens" else v / n) for k, v in metrics.items()
        }
        return loss / n, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        new_state = dict(state)
        if train_cfg.grad_compression:
            grads, new_state["error_fb"] = compression.quantize_dequantize(
                grads, state["error_fb"]
            )
        params, opt, opt_metrics = adamw_update(
            opt_cfg, state["params"], grads, state["opt"]
        )
        new_state["params"] = params
        new_state["opt"] = opt
        out_metrics = {"loss": loss, **opt_metrics}
        if metrics:
            out_metrics.update({k: v for k, v in metrics.items()})
        return new_state, out_metrics

    if mesh is None or rules is None:
        return jax.jit(train_step, donate_argnums=(0,))

    st_sh = state_shardings(cfg, mesh, rules, train_cfg)
    # Prefix sharding: dim 0 of every batch leaf is the global batch.
    b_sh = named_sharding(mesh, rules, ("batch",))

    def wrapped(state, batch):
        with axis_rules(rules):
            return train_step(state, batch)

    return jax.jit(
        wrapped,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )
